//! Cross-platform adaptivity sweep (paper Fig 7/8's story): the same
//! model + scenario, planned on PCIe vs NVLink nodes — HAP flips its
//! strategy with the interconnect and wins most where comm is slowest.
//!
//! Run: `cargo run --release --example platform_sweep`

use hap::benchkit::Table;
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Engine;
use hap::planner::{HapPlanner, PLANNER_SEED};
use hap::sim::LatencyModel;
use hap::strategy::{AttnStrategy, ExpertStrategy};

fn main() -> anyhow::Result<()> {
    let model = MoEModelConfig::mixtral_8x7b();
    let nodes = [
        NodeConfig::a6000x(4),
        NodeConfig::a100x(4),
        NodeConfig::a100x(8),
        NodeConfig::v100x(8),
    ];
    let scenario = Scenario::new("sweep", 2048, 64, 16);

    let mut table = Table::new(&["node", "interconnect", "HAP plan", "TP (s)", "HAP (s)", "speedup"]);
    for node in &nodes {
        // One trained latency model per GPU platform: the 4x and 8x
        // A100 nodes share the same cached forests instead of each
        // sweep iteration retraining them.
        let latency = LatencyModel::cached(&node.gpu, PLANNER_SEED);
        let planner = HapPlanner::with_latency(&model, node, latency);
        let engine = Engine::new(&model, node);
        let plan = planner.plan(&scenario, scenario.generate)?;
        let n = node.num_devices;
        let tp = engine
            .run_static(&AttnStrategy::new(n, 1), &ExpertStrategy::new(n, 1), &scenario, 1)
            .total();
        let hap = engine.run_plan(&plan, &scenario, 1).total();
        table.row(&[
            node.label(),
            node.gpu.interconnect.name().to_string(),
            plan.signature(),
            format!("{tp:.3}"),
            format!("{hap:.3}"),
            format!("{:.2}x", tp / hap),
        ]);
    }
    println!(
        "Mixtral-8x7B, 2048-token context / 64-token generation, batch 16\n\
         (TP baseline vs HAP, measured on the cluster simulator)\n"
    );
    table.print();
    println!("\nPCIe nodes should show the largest wins; NVLink nodes more modest ones.");
    Ok(())
}
