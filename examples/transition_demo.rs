//! Dynamic parallelism transition demo (paper §III-D): for an EP→TP
//! expert-strategy switch between prefill and decode, compare the two
//! transition mechanisms — collective resharding vs the INT4 CPU-backup
//! upload+dequant pipeline — across platforms, and run the *real*
//! INT4 quantize → dequantize round trip on actual expert weights from
//! the artifact set.
//!
//! Run: `cargo run --release --example transition_demo`

use hap::benchkit::Table;
use hap::config::{GpuSpec, MoEModelConfig};
use hap::quant::{self, Scheme};
use hap::sim::LatencyModel;
use hap::strategy::ExpertStrategy;
use hap::transition::TransitionModel;
use hap::util::stats;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // --- Part 1: the eq. 6 decision across platforms.
    let model = MoEModelConfig::mixtral_8x7b();
    let from = ExpertStrategy::new(1, 4); // EP4 prefill
    let to = ExpertStrategy::new(4, 1); // TP4 decode

    let mut table = Table::new(&[
        "platform",
        "T_reshard (ms)",
        "T_upload+deq (ms)",
        "overlap budget (ms)",
        "chosen",
        "charged (ms)",
    ]);
    for gpu in [GpuSpec::a6000(), GpuSpec::a100(), GpuSpec::v100()] {
        let lm = LatencyModel::train(&gpu, 1);
        let tm = TransitionModel::new(&model, &gpu);
        for overlap in [0.0, 0.4] {
            let c = tm.cost(&lm, &from, &to, overlap);
            table.row(&[
                format!("{} ({} ms overlap)", gpu.name, (overlap * 1e3) as u64),
                format!("{:.1}", c.reshard * 1e3),
                format!("{:.1}", c.raw_pipeline * 1e3),
                format!("{:.0}", overlap * 1e3),
                c.method.name().to_string(),
                format!("{:.1}", c.overhead * 1e3),
            ]);
        }
    }
    println!("EP4→TP4 expert transition for Mixtral-8x7B (eq. 6 decision):\n");
    table.print();

    // --- Part 2: real INT4 round trip on actual tiny-MoE weights.
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = hap::runtime::PjrtRuntime::load(dir)?;
        let blob = rt.read_weights()?;
        let store = hap::model::WeightStore::from_blob(&rt.manifest, &blob)?;
        let flat = store.expert_layer_flat(0)?;
        let cols = rt.manifest.model.inter;
        let rows = flat.len() / cols;
        println!("\nINT4 backup quality on layer-0 expert weights ({} values):", flat.len());
        let mut t2 = Table::new(&["scheme", "cosine sim", "rmse"]);
        for scheme in
            [Scheme::PerTensor, Scheme::PerChannel, Scheme::PerGroup { group_size: 128 }]
        {
            let q = quant::quantize(&flat[..rows * cols], rows, cols, scheme);
            let deq = quant::dequantize(&q);
            t2.row(&[
                scheme.name(),
                format!("{:.5}", stats::cosine_similarity(&flat[..rows * cols], &deq)),
                format!("{:.3e}", stats::rmse_f32(&flat[..rows * cols], &deq)),
            ]);
        }
        t2.print();
        println!("\nper-group stays >0.995 cosine similarity — the paper's threshold.");
    } else {
        println!("\n(artifacts/ not built — skipping the real-weights round trip)");
    }
    Ok(())
}
