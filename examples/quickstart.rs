//! Quickstart: search the optimal hybrid parallel plan for Mixtral-8x7B
//! on a 4×A6000 node under the paper's long-context/constrained-output
//! scenario, and compare against the static TP baseline.
//!
//! Run: `cargo run --release --example quickstart`

use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::planner::HapPlanner;

fn main() -> anyhow::Result<()> {
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let scenario = Scenario::long_constrained(); // 4096-token ctx, 64-token gen

    // Train the module-level latency simulation models (η/ρ random
    // forests on the platform's microbenchmark protocol) and solve the
    // strategy ILP.
    let planner = HapPlanner::new(&model, &node);
    let plan = planner.plan(&scenario, scenario.generate)?;
    println!("{plan}\n");

    let tp = planner.tp_baseline(&scenario);
    println!(
        "static TP predicts {:.0} ms; HAP predicts {:.0} ms → {:.2}x speedup",
        tp * 1e3,
        plan.predicted_total * 1e3,
        tp / plan.predicted_total
    );

    // The same call adapts across platforms: NVLink changes the answer.
    let a100 = NodeConfig::a100x(4);
    let planner_a100 = HapPlanner::new(&model, &a100);
    let plan_a100 = planner_a100.plan(&scenario, scenario.generate)?;
    println!("\non 4xA100 (NVLink) HAP instead picks: {}", plan_a100.signature());
    Ok(())
}
