//! End-to-end serving driver (the required full-system validation):
//! loads the real ~14M-parameter tiny-MoE AOT artifacts through the
//! PJRT CPU runtime, plans with HAP, then serves a batched workload of
//! generation requests through router → batcher → executor with REAL
//! compute on the request path (Python is not involved), reporting
//! latency/throughput under the HAP plan vs forced static TP.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serve_moe`

use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::planner::HapPlanner;
use hap::runtime::PjrtRuntime;
use hap::serving::{serve_workload, Request, ServeConfig};
use hap::util::rng::Rng;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts/ not built — run `make artifacts` first");
    }

    println!("loading + compiling AOT artifacts through PJRT ...");
    let rt = PjrtRuntime::load(dir)?;
    let m = rt.manifest.model.clone();
    println!(
        "tiny-moe: {} layers, hidden {}, {} experts (top-{}), batch {}, prompt {} — {} artifacts\n",
        m.layers,
        m.hidden,
        m.num_experts,
        m.top_k,
        m.batch,
        m.prefill_len,
        rt.artifact_names().len()
    );

    // Ask the HAP planner what it would do for this shape on the demo
    // node (the planner runs the same ILP the paper describes).
    let model_cfg = MoEModelConfig::tiny_moe();
    let node = NodeConfig::cpu_sim(4);
    let scenario = Scenario::new("serve-demo", m.prefill_len, 24, m.batch);
    let planner = HapPlanner::new(&model_cfg, &node);
    let plan = planner.plan(&scenario, scenario.generate)?;
    println!("HAP plan for the demo node: {}\n", plan.signature());

    // Workload: 24 requests with varied prompts/budgets.
    let make_workload = |seed: u64| -> Vec<Request> {
        let mut rng = Rng::new(seed);
        (0..24u64)
            .map(|id| {
                let len = rng.range(m.prefill_len / 2, m.prefill_len);
                let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
                Request::new(id, prompt, 16)
            })
            .collect()
    };

    // HAP-style phase-specific plan (EP prefill → TP decode, the
    // paper's dynamic parallelism transition) vs static TP.
    for config in [ServeConfig::hap_transition(4), ServeConfig::tp(4)] {
        println!("=== serving under {} ===", config.label());
        let report = serve_workload(&rt, &config, make_workload(7))?;
        println!("{}", report.metrics.summary());
        println!(
            "measured compute split: prefill {:.2} s | decode {:.2} s\n",
            report.prefill_time, report.decode_time
        );
    }

    println!(
        "note: on this single-CPU demo node both configs do the same\n\
         arithmetic, so throughput is similar — the point is that the\n\
         full three-layer stack (Pallas kernels → HLO artifacts → PJRT →\n\
         router/batcher/executor with a mid-request strategy transition)\n\
         composes and produces identical tokens (asserted in\n\
         rust/tests/runtime_e2e.rs). Platform-shaped latency effects are\n\
         measured by the cluster-simulator benches (cargo bench)."
    );
    Ok(())
}
