"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

assert_allclose is the CORE correctness signal for the compute layer;
hypothesis sweeps shapes/seeds so the kernels hold beyond the single
AOT shape.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.attention import attention_core_pallas, decode_core_pallas
from compile.kernels.dequant import dequant_int4_pallas
from compile.kernels.moe_ffn import moe_ffn_pallas
from compile.kernels.topk_gate import topk_gate_pallas

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(rng, *shape, std=0.5):
    return jnp.asarray(rng.normal(0.0, std, shape).astype(np.float32))


# ---------------------------------------------------------------- moe_ffn

@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 3),
    e=st.sampled_from([2, 4, 8]),
    i=st.sampled_from([32, 64]),
)
def test_moe_ffn_matches_dense_reference(seed, tiles, e, i):
    rng = np.random.default_rng(seed)
    tile = 32
    t, h = tiles * tile, 48
    x = rand(rng, t, h)
    gates = jnp.abs(rand(rng, t, e))
    wg, wu = rand(rng, e, h, i, std=0.1), rand(rng, e, h, i, std=0.1)
    wd = rand(rng, e, i, h, std=0.1)
    got = moe_ffn_pallas(x, gates, wg, wu, wd, token_tile=tile)
    want = jnp.zeros_like(x)
    for ei in range(e):
        y = ref.swiglu_ffn(x, wg[ei], wu[ei], wd[ei])
        want = want + gates[:, ei : ei + 1] * y
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_moe_ffn_with_topk_gates_equals_ref_moe():
    rng = np.random.default_rng(0)
    t, h, e, i, k = 128, 64, 8, 32, 2
    x = rand(rng, t, h)
    router = rand(rng, h, e, std=0.2)
    wg, wu = rand(rng, e, h, i, std=0.1), rand(rng, e, h, i, std=0.1)
    wd = rand(rng, e, i, h, std=0.1)
    gates = topk_gate_pallas(x, router, k, token_tile=64)
    got = moe_ffn_pallas(x, gates, wg, wu, wd, token_tile=64)
    want = ref.moe_ffn(x, router, wg, wu, wd, k)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_moe_ffn_tp_shards_sum_to_full():
    """TP semantics: shards of the intermediate dim sum to the whole."""
    rng = np.random.default_rng(1)
    t, h, e, i = 64, 32, 4, 64
    x = rand(rng, t, h)
    gates = jnp.abs(rand(rng, t, e))
    wg, wu = rand(rng, e, h, i, std=0.1), rand(rng, e, h, i, std=0.1)
    wd = rand(rng, e, i, h, std=0.1)
    full = moe_ffn_pallas(x, gates, wg, wu, wd, token_tile=t)
    tp = 4
    acc = jnp.zeros_like(full)
    for dv in range(tp):
        sl = slice(dv * i // tp, (dv + 1) * i // tp)
        acc = acc + moe_ffn_pallas(x, gates, wg[:, :, sl], wu[:, :, sl], wd[:, sl, :], token_tile=t)
    assert_allclose(np.asarray(acc), np.asarray(full), **TOL)


# --------------------------------------------------------------- topk gate

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), e=st.sampled_from([4, 8, 16]), k=st.integers(1, 3))
def test_topk_gate_matches_reference(seed, e, k):
    rng = np.random.default_rng(seed)
    t, h = 64, 32
    x = rand(rng, t, h)
    router = rand(rng, h, e, std=0.3)
    got = topk_gate_pallas(x, router, k, token_tile=32)
    want = ref.topk_gate(x, router, k)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_topk_gate_weights_sum_to_one_on_topk():
    rng = np.random.default_rng(2)
    x = rand(rng, 128, 32)
    router = rand(rng, 32, 8, std=0.3)
    w = np.asarray(topk_gate_pallas(x, router, 2, token_tile=64))
    nonzero = (w > 0).sum(axis=1)
    assert (nonzero == 2).all()
    assert_allclose(w.sum(axis=1), np.ones(128), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- attention

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), heads=st.sampled_from([2, 4]), s_tiles=st.integers(1, 3))
def test_attention_prefill_matches_reference(seed, heads, s_tiles):
    rng = np.random.default_rng(seed)
    b, s, d, h = 2, 32 * s_tiles, 16, 64
    x = rand(rng, b, s, h)
    wq = rand(rng, h, heads * d, std=0.1)
    wk = rand(rng, h, heads * d, std=0.1)
    wv = rand(rng, h, heads * d, std=0.1)
    wo = rand(rng, heads * d, h, std=0.1)
    q = (x @ wq).reshape(b, s, heads, d)
    k = (x @ wk).reshape(b, s, heads, d)
    v = (x @ wv).reshape(b, s, heads, d)
    got = attention_core_pallas(q, k, v, q_tile=32, k_tile=32)
    want, _, _ = ref.attention_prefill(x, wq, wk, wv, jnp.eye(heads * d, dtype=jnp.float32), heads, heads, d)
    want = want.reshape(b, s, heads, d)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-5, atol=5e-5)


def test_attention_decode_matches_reference():
    rng = np.random.default_rng(3)
    b, m, hq, kvh, d, h = 2, 48, 4, 2, 16, 64
    pos = 17
    x = rand(rng, b, 1, h)
    wq = rand(rng, h, hq * d, std=0.1)
    wk = rand(rng, h, kvh * d, std=0.1)
    wv = rand(rng, h, kvh * d, std=0.1)
    wo = rand(rng, hq * d, h, std=0.1)
    k_cache = rand(rng, b, m, kvh, d)
    v_cache = rand(rng, b, m, kvh, d)
    want, want_k, want_v = ref.attention_decode(
        x, k_cache, v_cache, pos, wq, wk, wv, wo, hq, kvh, d
    )
    # Kernel path mirrors model.attn_decode_module.
    from compile.model import attn_decode_module

    got, got_k, got_v = attn_decode_module(
        x, k_cache, v_cache, pos, jnp.ones(h), wq, wk, wv, wo, q_heads=hq, kv_heads=kvh, head_dim=d
    )
    # Reference includes no pre-norm; apply it for comparison.
    want_n, want_kn, want_vn = ref.attention_decode(
        ref.rms_norm(x, jnp.ones(h)), k_cache, v_cache, pos, wq, wk, wv, wo, hq, kvh, d
    )
    assert_allclose(np.asarray(got), np.asarray(want_n), rtol=5e-5, atol=5e-5)
    assert_allclose(np.asarray(got_k), np.asarray(want_kn), **TOL)
    assert_allclose(np.asarray(got_v), np.asarray(want_vn), **TOL)


def test_attention_prefill_is_causal():
    """Changing a future token must not change earlier outputs."""
    rng = np.random.default_rng(4)
    b, s, hq, d = 1, 64, 2, 16
    q = rand(rng, b, s, hq, d)
    k = rand(rng, b, s, hq, d)
    v = rand(rng, b, s, hq, d)
    base = np.asarray(attention_core_pallas(q, k, v, q_tile=32, k_tile=32))
    k2 = k.at[:, -1].set(99.0)
    v2 = v.at[:, -1].set(-99.0)
    pert = np.asarray(attention_core_pallas(q, k2, v2, q_tile=32, k_tile=32))
    assert_allclose(pert[:, :-1], base[:, :-1], **TOL)
    assert not np.allclose(pert[:, -1], base[:, -1])


# ----------------------------------------------------------------- dequant

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), groups=st.sampled_from([8, 16]), gsize=st.sampled_from([32, 128]))
def test_dequant_matches_reference(seed, groups, gsize):
    rng = np.random.default_rng(seed)
    n = groups * gsize
    codes = jnp.asarray(rng.integers(-8, 8, n), jnp.int32)
    scales = jnp.asarray(np.abs(rng.normal(0.01, 0.005, groups)).astype(np.float32) + 1e-4)
    zeros = jnp.asarray(rng.integers(-8, 8, groups).astype(np.float32))
    got = dequant_int4_pallas(codes, scales, zeros, gsize)
    want = ref.dequant_int4_per_group(codes, scales, zeros, gsize)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


def test_dequant_int4_round_trip_error_bound():
    """Quantize with numpy (mirror of the Rust quantizer), dequantize
    with the kernel: error ≤ scale/2."""
    rng = np.random.default_rng(5)
    gsize, groups = 64, 16
    x = rng.normal(0, 0.02, gsize * groups).astype(np.float32)
    blocks = x.reshape(groups, gsize)
    lo, hi = blocks.min(1), blocks.max(1)
    scale = np.maximum(hi - lo, 1e-12) / 15.0
    zero = np.round(-8.0 - lo / scale)
    codes = np.clip(np.round(blocks / scale[:, None] + zero[:, None]), -8, 7).astype(np.int32)
    deq = np.asarray(
        dequant_int4_pallas(
            jnp.asarray(codes.reshape(-1)), jnp.asarray(scale.astype(np.float32)), jnp.asarray(zero.astype(np.float32)), gsize
        )
    )
    err = np.abs(deq - x)
    assert (err <= scale[x.reshape(groups, gsize).argsort(1).argsort(1) // gsize].max() * 0.5 + 1e-7).all() or (
        err.reshape(groups, gsize) <= scale[:, None] * 0.5 + 1e-7
    ).all()
