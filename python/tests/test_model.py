"""L2 correctness: model-level invariants the Rust composition relies on.

- TP shard partials sum to the unsharded module output (attention and
  expert), for prefill and decode;
- EP shard contributions sum to the full expert output;
- decode(prefill(x)) is consistent: caches built by prefill + one decode
  step equal prefill over the extended sequence;
- the sharded composition of a *whole layer* matches the reference
  model.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.kernels import ref
from compile.model import TINY

TOL = dict(rtol=5e-5, atol=5e-5)


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(seed=0)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(42)
    return jnp.asarray(rng.integers(0, TINY.vocab, (TINY.batch, TINY.prefill_len)), jnp.int32)


def embed(tokens, weights):
    return M.embed_module(tokens, jnp.asarray(weights["embed"]))


def test_attn_prefill_tp_shards_sum_to_full(weights, tokens):
    x = embed(tokens, weights)
    l = 0
    full_w = M.shard_attn(weights, l, 1, 0)
    full, k_full, v_full = M.attn_prefill_module(
        x, **{k: jnp.asarray(v) for k, v in full_w.items()},
        q_heads=TINY.q_heads, kv_heads=TINY.kv_heads, head_dim=TINY.head_dim,
    )
    for t in (2, 4):
        acc = jnp.zeros_like(full)
        ks, vs = [], []
        for d in range(t):
            w = M.shard_attn(weights, l, t, d)
            out, k, v = M.attn_prefill_module(
                x, **{k2: jnp.asarray(v2) for k2, v2 in w.items()},
                q_heads=TINY.q_heads // t,
                kv_heads=max(TINY.kv_heads // t, 1),
                head_dim=TINY.head_dim,
            )
            acc = acc + out
            ks.append(k)
            vs.append(v)
        assert_allclose(np.asarray(acc), np.asarray(full), **TOL)
        # Concatenated KV shards = full KV.
        assert_allclose(np.asarray(jnp.concatenate(ks, axis=2)), np.asarray(k_full), **TOL)
        assert_allclose(np.asarray(jnp.concatenate(vs, axis=2)), np.asarray(v_full), **TOL)


def test_expert_tp_shards_sum_to_full(weights, tokens):
    x = embed(tokens, weights).reshape(-1, TINY.hidden)
    l = 1
    fw = M.shard_expert_tp(weights, l, 1, 0)
    full = M.expert_module_tp(
        x, *(jnp.asarray(fw[k]) for k in ("ln", "router", "wg", "wu", "wd")),
        top_k=TINY.top_k, token_tile=128,
    )
    for t in (2, 4):
        acc = jnp.zeros_like(full)
        for d in range(t):
            w = M.shard_expert_tp(weights, l, t, d)
            acc = acc + M.expert_module_tp(
                x, *(jnp.asarray(w[k]) for k in ("ln", "router", "wg", "wu", "wd")),
                top_k=TINY.top_k, token_tile=128,
            )
        assert_allclose(np.asarray(acc), np.asarray(full), **TOL)


def test_expert_ep_shards_sum_to_full(weights, tokens):
    x = embed(tokens, weights).reshape(-1, TINY.hidden)
    l = 2
    fw = M.shard_expert_tp(weights, l, 1, 0)
    full = M.expert_module_tp(
        x, *(jnp.asarray(fw[k]) for k in ("ln", "router", "wg", "wu", "wd")),
        top_k=TINY.top_k, token_tile=128,
    )
    for e in (2, 4):
        acc = jnp.zeros_like(full)
        for d in range(e):
            w = M.shard_expert_ep(weights, l, e, d)
            acc = acc + M.expert_module_ep(
                x, *(jnp.asarray(w[k]) for k in ("ln", "router", "sel", "wg", "wu", "wd")),
                top_k=TINY.top_k, token_tile=128,
            )
        assert_allclose(np.asarray(acc), np.asarray(full), **TOL)


def test_prefill_then_decode_consistent_with_longer_prefill(weights):
    """Decode-step invariant: prefill(s) + decode(token) must equal
    prefill(s+1) at the last position."""
    rng = np.random.default_rng(7)
    toks_full = jnp.asarray(
        rng.integers(0, TINY.vocab, (TINY.batch, TINY.prefill_len)), jnp.int32
    )
    toks_short = toks_full[:, :-1]
    # Reference prefill over s−1 tokens with padding-free caches.
    # Build padded caches of width max_len from the prefill caches.
    cfg = TINY
    logits_short, _, caches = M.tiny_prefill_reference(toks_short, weights)
    padded = []
    for (k, v) in caches:
        kc = jnp.zeros((cfg.batch, cfg.max_len, cfg.kv_heads, cfg.head_dim), jnp.float32)
        vc = jnp.zeros_like(kc)
        kc = kc.at[:, : cfg.prefill_len - 1].set(k)
        vc = vc.at[:, : cfg.prefill_len - 1].set(v)
        padded.append((kc, vc))
    last_tok = toks_full[:, -1:]
    logits_dec, _ = M.tiny_decode_reference(last_tok, padded, cfg.prefill_len - 1, weights)
    logits_full, _, _ = M.tiny_prefill_reference(toks_full, weights)
    assert_allclose(np.asarray(logits_dec), np.asarray(logits_full), rtol=2e-4, atol=2e-4)


def test_sharded_layer_composition_matches_reference(weights, tokens):
    """One full layer composed the way Rust composes it (TP-2 attention
    partial-sum + residual, EP-4 expert contribution-sum + residual)
    equals the reference layer."""
    cfg = TINY
    x = embed(tokens, weights)
    l = 3
    # Reference layer.
    w = {k.split(".")[-1]: jnp.asarray(v) for k, v in weights.items() if k.startswith(f"layer{l}.")}
    a_full, _, _ = M.attn_prefill_module(
        x, w["ln1"], w["wq"], w["wk"], w["wv"], w["wo"],
        q_heads=cfg.q_heads, kv_heads=cfg.kv_heads, head_dim=cfg.head_dim,
    )
    h1 = x + a_full
    e_full = M.expert_module_tp(
        h1.reshape(-1, cfg.hidden), w["ln2"], w["router"], w["wg"], w["wu"], w["wd"],
        top_k=cfg.top_k, token_tile=128,
    )
    want = h1 + e_full.reshape(h1.shape)

    # Sharded composition.
    t = 2
    a_acc = jnp.zeros_like(x)
    for d in range(t):
        sw = M.shard_attn(weights, l, t, d)
        out, _, _ = M.attn_prefill_module(
            x, **{k2: jnp.asarray(v2) for k2, v2 in sw.items()},
            q_heads=cfg.q_heads // t, kv_heads=cfg.kv_heads // t, head_dim=cfg.head_dim,
        )
        a_acc = a_acc + out
    h1s = x + a_acc
    e_acc = jnp.zeros((cfg.batch * cfg.prefill_len, cfg.hidden), jnp.float32)
    for d in range(4):
        sw = M.shard_expert_ep(weights, l, 4, d)
        e_acc = e_acc + M.expert_module_ep(
            h1s.reshape(-1, cfg.hidden),
            *(jnp.asarray(sw[k]) for k in ("ln", "router", "sel", "wg", "wu", "wd")),
            top_k=cfg.top_k, token_tile=128,
        )
    got = h1s + e_acc.reshape(h1s.shape)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_weight_order_and_shapes_cover_all_tensors():
    names = M.weight_order()
    assert names[0] == "embed" and names[-1] == "unembed"
    total = sum(int(np.prod(M.weight_shape(n))) for n in names)
    # ≈ 27M params for the tiny demo model? (embed+unembed 0.26M, layers ~6.5M)
    assert 5_000_000 < total < 40_000_000
    assert len(set(names)) == len(names)
