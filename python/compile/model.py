"""L2: the tiny-MoE JAX model — per-device module functions that the
Rust coordinator composes on the request path.

Contract with ``rust/src/model`` (see DESIGN.md):

- The model is decomposed exactly as the paper decomposes MoE layers:
  an **Attention module** and an **Expert module**, each lowered per
  (stage, shard) variant to its own HLO artifact.
- TP partial outputs **sum** across devices to the unsharded output;
  EP per-device contributions (owned experts only) also **sum**. The
  Rust runtime implements the combines (its "collectives").
- RMS norms run *inside* each module (they need the combined residual
  stream, which Rust holds between module calls).
- Weights are runtime inputs (not baked constants) so one artifact per
  shard degree serves every layer; Rust slices shards from
  ``artifacts/weights.bin`` with the same layout as `shard_*` below.

The tiny config must match `MoEModelConfig::tiny_moe()` on the Rust
side.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.attention import attention_core_pallas, decode_core_pallas
from .kernels.moe_ffn import moe_ffn_pallas
from .kernels.topk_gate import topk_gate_pallas


@dataclasses.dataclass(frozen=True)
class TinyConfig:
    """Demo model — ~27M params, runs for real on the CPU PJRT client."""

    batch: int = 4
    prefill_len: int = 64
    max_len: int = 192  # prefill + decode budget
    hidden: int = 256
    q_heads: int = 8
    kv_heads: int = 4
    head_dim: int = 32
    num_experts: int = 8
    top_k: int = 2
    inter: int = 512
    vocab: int = 512
    layers: int = 4


TINY = TinyConfig()


# --------------------------------------------------------------------------
# Weight generation (seeded) and the on-disk layout for weights.bin.
# --------------------------------------------------------------------------

def layer_weight_names(l):
    return [
        f"layer{l}.ln1",
        f"layer{l}.wq",
        f"layer{l}.wk",
        f"layer{l}.wv",
        f"layer{l}.wo",
        f"layer{l}.ln2",
        f"layer{l}.router",
        f"layer{l}.wg",
        f"layer{l}.wu",
        f"layer{l}.wd",
    ]


def weight_order(cfg=TINY):
    """Deterministic tensor order in weights.bin."""
    names = ["embed"]
    for l in range(cfg.layers):
        names.extend(layer_weight_names(l))
    names.extend(["ln_f", "unembed"])
    return names


def weight_shape(name, cfg=TINY):
    h, d = cfg.hidden, cfg.head_dim
    if name == "embed":
        return (cfg.vocab, h)
    if name == "unembed":
        return (h, cfg.vocab)
    if name in ("ln_f",) or name.endswith((".ln1", ".ln2")):
        return (h,)
    if name.endswith(".wq"):
        return (h, cfg.q_heads * d)
    if name.endswith((".wk", ".wv")):
        return (h, cfg.kv_heads * d)
    if name.endswith(".wo"):
        return (cfg.q_heads * d, h)
    if name.endswith(".router"):
        return (h, cfg.num_experts)
    if name.endswith((".wg", ".wu")):
        return (cfg.num_experts, h, cfg.inter)
    if name.endswith(".wd"):
        return (cfg.num_experts, cfg.inter, h)
    raise KeyError(name)


def init_weights(seed=0, cfg=TINY):
    """Seeded random weights (std 0.02 for matmuls, ones for norms)."""
    rng = np.random.default_rng(seed)
    weights = {}
    for name in weight_order(cfg):
        shape = weight_shape(name, cfg)
        if name.endswith((".ln1", ".ln2")) or name == "ln_f":
            weights[name] = np.ones(shape, np.float32)
        else:
            weights[name] = rng.normal(0.0, 0.02, shape).astype(np.float32)
    return weights


def write_weights_bin(weights, path, cfg=TINY):
    """Raw little-endian f32 concatenation in `weight_order`."""
    with open(path, "wb") as f:
        for name in weight_order(cfg):
            f.write(np.ascontiguousarray(weights[name], np.float32).tobytes())


# --------------------------------------------------------------------------
# Shard slicing — the layout contract mirrored by rust/src/model.
# --------------------------------------------------------------------------

def shard_attn(weights, l, t, d, cfg=TINY):
    """TP shard `d` of `t` for layer `l`'s attention weights.

    Q/O shard by query head; K/V shard by kv head (t ≤ kv_heads).
    """
    hd = cfg.head_dim
    hq_l = cfg.q_heads // t
    kv_l = max(cfg.kv_heads // t, 1)
    wq = weights[f"layer{l}.wq"].reshape(cfg.hidden, cfg.q_heads, hd)
    wk = weights[f"layer{l}.wk"].reshape(cfg.hidden, cfg.kv_heads, hd)
    wv = weights[f"layer{l}.wv"].reshape(cfg.hidden, cfg.kv_heads, hd)
    wo = weights[f"layer{l}.wo"].reshape(cfg.q_heads, hd, cfg.hidden)
    return dict(
        ln=weights[f"layer{l}.ln1"],
        wq=wq[:, d * hq_l : (d + 1) * hq_l].reshape(cfg.hidden, hq_l * hd),
        wk=wk[:, d * kv_l : (d + 1) * kv_l].reshape(cfg.hidden, kv_l * hd),
        wv=wv[:, d * kv_l : (d + 1) * kv_l].reshape(cfg.hidden, kv_l * hd),
        wo=wo[d * hq_l : (d + 1) * hq_l].reshape(hq_l * hd, cfg.hidden),
    )


def shard_expert_tp(weights, l, t, d, cfg=TINY):
    """TP shard: every expert's intermediate dim sliced to I/t."""
    i_l = cfg.inter // t
    wg = weights[f"layer{l}.wg"][:, :, d * i_l : (d + 1) * i_l]
    wu = weights[f"layer{l}.wu"][:, :, d * i_l : (d + 1) * i_l]
    wd = weights[f"layer{l}.wd"][:, d * i_l : (d + 1) * i_l, :]
    return dict(
        ln=weights[f"layer{l}.ln2"],
        router=weights[f"layer{l}.router"],
        wg=wg,
        wu=wu,
        wd=wd,
    )


def shard_expert_ep(weights, l, e, d, cfg=TINY):
    """EP shard: device `d` of `e` owns a contiguous expert block."""
    e_l = cfg.num_experts // e
    sel = np.zeros((e_l, cfg.num_experts), np.float32)
    for j in range(e_l):
        sel[j, d * e_l + j] = 1.0
    sl = slice(d * e_l, (d + 1) * e_l)
    return dict(
        ln=weights[f"layer{l}.ln2"],
        router=weights[f"layer{l}.router"],
        sel=sel,
        wg=weights[f"layer{l}.wg"][sl],
        wu=weights[f"layer{l}.wu"][sl],
        wd=weights[f"layer{l}.wd"][sl],
    )


# --------------------------------------------------------------------------
# Per-device module functions (the artifact bodies).
# --------------------------------------------------------------------------

def attn_prefill_module(x, ln, wq, wk, wv, wo, *, q_heads, kv_heads, head_dim):
    """x: [B, S, H] residual stream → (partial_out, k_cache_slice,
    v_cache_slice). Sum of partial_out over TP shards = full output."""
    b, s, _ = x.shape
    xn = ref.rms_norm(x, ln)
    q = (xn @ wq).reshape(b, s, q_heads, head_dim)
    k = (xn @ wk).reshape(b, s, kv_heads, head_dim)
    v = (xn @ wv).reshape(b, s, kv_heads, head_dim)
    rep = q_heads // kv_heads
    ctx = attention_core_pallas(q, jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2))
    out = ctx.reshape(b, s, q_heads * head_dim) @ wo
    return out, k, v


def attn_decode_module(
    x, k_cache, v_cache, pos, ln, wq, wk, wv, wo, *, q_heads, kv_heads, head_dim
):
    """x: [B, 1, H]; caches [B, M, KVH_local, D]; pos: scalar int32.
    Returns (partial_out, new_k_cache, new_v_cache)."""
    b = x.shape[0]
    xn = ref.rms_norm(x, ln)
    q = (xn @ wq).reshape(b, 1, q_heads, head_dim)
    k_new = (xn @ wk).reshape(b, 1, kv_heads, head_dim)
    v_new = (xn @ wv).reshape(b, 1, kv_heads, head_dim)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, pos, axis=1)
    rep = q_heads // kv_heads
    ctx = decode_core_pallas(
        q, jnp.repeat(k_cache, rep, 2), jnp.repeat(v_cache, rep, 2), pos
    )
    out = ctx.reshape(b, 1, q_heads * head_dim) @ wo
    return out, k_cache, v_cache


def expert_module_tp(x, ln, router, wg, wu, wd, *, top_k, token_tile):
    """x: [T, H] combined residual → partial FFN output [T, H]
    (sum over TP shards = full)."""
    xn = ref.rms_norm(x, ln)
    gates = topk_gate_pallas(xn, router, top_k, token_tile=token_tile)
    return moe_ffn_pallas(xn, gates, wg, wu, wd, token_tile=token_tile)


def expert_module_ep(x, ln, router, sel, wg, wu, wd, *, top_k, token_tile):
    """EP shard: `sel` [E_local, E] selects this device's experts from
    the full gate matrix; contributions sum over EP shards."""
    xn = ref.rms_norm(x, ln)
    gates = topk_gate_pallas(xn, router, top_k, token_tile=token_tile)
    gates_local = gates @ sel.T
    return moe_ffn_pallas(xn, gates_local, wg, wu, wd, token_tile=token_tile)


def valid_token_tile(t, preferred=128):
    """Largest tile ≤ preferred that divides t (static-shape helper)."""
    if t <= preferred:
        return t
    if t % preferred == 0:
        return preferred
    return math.gcd(t, preferred)


def embed_module(tokens, embed):
    """tokens: int32 [B, S] → [B, S, H]."""
    return jnp.take(embed, tokens, axis=0)


def head_module(x_last, ln_f, unembed):
    """x_last: [B, H] final residual → logits [B, V]."""
    return ref.rms_norm(x_last, ln_f) @ unembed


# --------------------------------------------------------------------------
# Unsharded reference model (test oracle for the Rust composition).
# --------------------------------------------------------------------------

def tiny_prefill_reference(tokens, weights, cfg=TINY):
    """Full prefill: returns (logits_last [B, V], residual [B, S, H],
    caches: list of (k, v) per layer)."""
    x = embed_module(tokens, jnp.asarray(weights["embed"]))
    caches = []
    for l in range(cfg.layers):
        w = {k.split(".")[-1]: jnp.asarray(v) for k, v in weights.items() if k.startswith(f"layer{l}.")}
        a_out, k, v = attn_prefill_module(
            x,
            w["ln1"],
            w["wq"],
            w["wk"],
            w["wv"],
            w["wo"],
            q_heads=cfg.q_heads,
            kv_heads=cfg.kv_heads,
            head_dim=cfg.head_dim,
        )
        x = x + a_out
        b, s, h = x.shape
        e_out = expert_module_tp(
            x.reshape(b * s, h),
            w["ln2"],
            w["router"],
            w["wg"],
            w["wu"],
            w["wd"],
            top_k=cfg.top_k,
            token_tile=valid_token_tile(b * s),
        )
        x = x + e_out.reshape(b, s, h)
        caches.append((k, v))
    logits = head_module(x[:, -1], jnp.asarray(weights["ln_f"]), jnp.asarray(weights["unembed"]))
    return logits, x, caches


def tiny_decode_reference(token, padded_caches, pos, weights, cfg=TINY):
    """One decode step with padded caches [B, M, KVH, D] per layer.
    Returns (logits [B, V], updated caches)."""
    x = embed_module(token, jnp.asarray(weights["embed"]))
    new_caches = []
    for l in range(cfg.layers):
        w = {k.split(".")[-1]: jnp.asarray(v) for k, v in weights.items() if k.startswith(f"layer{l}.")}
        kc, vc = padded_caches[l]
        a_out, kc, vc = attn_decode_module(
            x,
            kc,
            vc,
            pos,
            w["ln1"],
            w["wq"],
            w["wk"],
            w["wv"],
            w["wo"],
            q_heads=cfg.q_heads,
            kv_heads=cfg.kv_heads,
            head_dim=cfg.head_dim,
        )
        x = x + a_out
        b, s, h = x.shape
        e_out = expert_module_tp(
            x.reshape(b * s, h),
            w["ln2"],
            w["router"],
            w["wg"],
            w["wu"],
            w["wd"],
            top_k=cfg.top_k,
            token_tile=b * s,
        )
        x = x + e_out.reshape(b, s, h)
        new_caches.append((kc, vc))
    logits = head_module(x[:, -1], jnp.asarray(weights["ln_f"]), jnp.asarray(weights["unembed"]))
    return logits, new_caches
