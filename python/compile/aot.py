"""AOT lowering: every (module, stage, shard) variant → HLO text.

Python's last act: after ``make artifacts`` produces
``artifacts/*.hlo.txt`` + ``manifest.json`` + ``weights.bin``, the Rust
binary is self-contained and Python never runs on the request path.

HLO **text** (not serialized proto) is the interchange format: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md). Everything is
lowered with ``return_tuple=True`` and unwrapped with ``to_tuple`` on
the Rust side.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .model import TINY


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def shape_entry(s):
    return {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}


def build_artifacts(cfg=TINY):
    """Yield (name, jitted_fn, input_specs, meta) for every artifact."""
    b, s, m = cfg.batch, cfg.prefill_len, cfg.max_len
    h, d, v = cfg.hidden, cfg.head_dim, cfg.vocab
    e, i = cfg.num_experts, cfg.inter
    arts = []

    for t in (1, 2, 4):
        hq_l = cfg.q_heads // t
        kv_l = max(cfg.kv_heads // t, 1)
        fn = functools.partial(
            M.attn_prefill_module, q_heads=hq_l, kv_heads=kv_l, head_dim=d
        )
        ins = [
            spec((b, s, h)),
            spec((h,)),
            spec((h, hq_l * d)),
            spec((h, kv_l * d)),
            spec((h, kv_l * d)),
            spec((hq_l * d, h)),
        ]
        arts.append((f"attn_prefill_tp{t}", fn, ins, {"module": "attention", "stage": "prefill", "tp": t, "kv_local": kv_l, "q_local": hq_l}))

        fn = functools.partial(
            M.attn_decode_module, q_heads=hq_l, kv_heads=kv_l, head_dim=d
        )
        ins = [
            spec((b, 1, h)),
            spec((b, m, kv_l, d)),
            spec((b, m, kv_l, d)),
            spec((), jnp.int32),
            spec((h,)),
            spec((h, hq_l * d)),
            spec((h, kv_l * d)),
            spec((h, kv_l * d)),
            spec((hq_l * d, h)),
        ]
        arts.append((f"attn_decode_tp{t}", fn, ins, {"module": "attention", "stage": "decode", "tp": t, "kv_local": kv_l, "q_local": hq_l}))

    t_pre = b * s
    t_dec = b
    for t in (1, 2, 4):
        i_l = i // t
        for stage, tok, tile in (("prefill", t_pre, min(128, t_pre)), ("decode", t_dec, t_dec)):
            fn = functools.partial(M.expert_module_tp, top_k=cfg.top_k, token_tile=tile)
            ins = [
                spec((tok, h)),
                spec((h,)),
                spec((h, e)),
                spec((e, h, i_l)),
                spec((e, h, i_l)),
                spec((e, i_l, h)),
            ]
            arts.append((f"expert_{stage}_tp{t}", fn, ins, {"module": "expert", "stage": stage, "tp": t, "ep": 1}))

    for ep in (2, 4):
        e_l = e // ep
        for stage, tok, tile in (("prefill", t_pre, min(128, t_pre)), ("decode", t_dec, t_dec)):
            fn = functools.partial(M.expert_module_ep, top_k=cfg.top_k, token_tile=tile)
            ins = [
                spec((tok, h)),
                spec((h,)),
                spec((h, e)),
                spec((e_l, e)),
                spec((e_l, h, i)),
                spec((e_l, h, i)),
                spec((e_l, i, h)),
            ]
            arts.append((f"expert_{stage}_ep{ep}", fn, ins, {"module": "expert", "stage": stage, "tp": 1, "ep": ep}))

    arts.append(
        ("embed_prefill", M.embed_module, [spec((b, s), jnp.int32), spec((v, h))], {"module": "embed", "stage": "prefill"})
    )
    arts.append(
        ("embed_decode", M.embed_module, [spec((b, 1), jnp.int32), spec((v, h))], {"module": "embed", "stage": "decode"})
    )
    arts.append(
        ("head", M.head_module, [spec((b, h)), spec((h,)), spec((h, v))], {"module": "head", "stage": "both"})
    )
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = TINY
    weights = M.init_weights(args.seed, cfg)
    M.write_weights_bin(weights, os.path.join(args.out_dir, "weights.bin"), cfg)
    wtable = []
    offset = 0
    for name in M.weight_order(cfg):
        shape = list(M.weight_shape(name, cfg))
        n = int(np.prod(shape))
        wtable.append({"name": name, "shape": shape, "offset_floats": offset})
        offset += n

    entries = []
    for name, fn, ins, meta in build_artifacts(cfg):
        lowered = jax.jit(fn).lower(*ins)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *ins)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        entries.append(
            {
                "name": name,
                "file": fname,
                "meta": meta,
                "inputs": [shape_entry(x) for x in ins],
                "outputs": [shape_entry(x) for x in out_shapes],
            }
        )
        print(f"lowered {name}: {len(text)} chars")

    manifest = {
        "model": {
            "name": "tiny-moe",
            "batch": cfg.batch,
            "prefill_len": cfg.prefill_len,
            "max_len": cfg.max_len,
            "hidden": cfg.hidden,
            "q_heads": cfg.q_heads,
            "kv_heads": cfg.kv_heads,
            "head_dim": cfg.head_dim,
            "num_experts": cfg.num_experts,
            "top_k": cfg.top_k,
            "inter": cfg.inter,
            "vocab": cfg.vocab,
            "layers": cfg.layers,
            "seed": args.seed,
        },
        "weights_file": "weights.bin",
        "weights": wtable,
        "entries": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(entries)} artifacts, {offset} weight floats")


if __name__ == "__main__":
    main()
