"""L1 Pallas kernel: fused MoE expert FFN (SwiGLU grouped matmul).

The paper's compute hot-spot is the Expert module. On GPU this is a
grouped GEMM over warps; the TPU rethink (DESIGN.md §Hardware-Adaptation)
expresses the same schedule with a Pallas grid over (expert, token-tile)
and BlockSpecs that stage one expert's weight panel plus one token tile
through VMEM, hitting the MXU with (tile × H) @ (H × I) matmuls instead
of WMMA fragments.

Per-token routing weights arrive as a dense [T, E] matrix (zero outside
the top-k), so the kernel is shape-static: every expert processes every
token tile but multiplies its contribution by the (mostly zero) gate
column. For the tiny demo model (E=8, I=512) this dense formulation is
both MXU-friendly and exactly equal to the sparse dispatch semantics —
the oracle in ref.py computes the sparse form.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-tile size: multiple of 8 sublanes; 128 aligns with the MXU'd
# matmul dimension on real TPUs while staying small enough for the
# interpret-mode tests to be fast.
TOKEN_TILE = 128


def _moe_ffn_kernel(x_ref, gates_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """Grid: (experts, token tiles). VMEM blocks:

    x_ref:     [TILE, H]      — token tile (same for every expert step)
    gates_ref: [TILE, 1]      — this expert's gate column for the tile
    wg_ref/wu_ref: [H, I]     — expert e's gate/up panels
    wd_ref:    [I, H]         — expert e's down panel
    o_ref:     [TILE, H]      — accumulated output tile
    """
    e = pl.program_id(0)
    x = x_ref[...]
    # Weight blocks carry a leading singleton expert dim — index it off.
    g = x @ wg_ref[0]
    u = x @ wu_ref[0]
    act = g * (1.0 / (1.0 + jnp.exp(-g))) * u
    y = act @ wd_ref[0]
    contrib = gates_ref[...] * y

    # First expert initializes the accumulator, later ones add.
    @pl.when(e == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(e > 0)
    def _acc():
        o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("token_tile",))
def moe_ffn_pallas(x, gates, w_gate, w_up, w_down, token_tile=TOKEN_TILE):
    """Fused expert FFN over pre-computed dense gates.

    x: [T, H] (T divisible by token_tile); gates: [T, E];
    w_gate/w_up: [E, H, I]; w_down: [E, I, H] → [T, H].
    """
    t, h = x.shape
    e = w_gate.shape[0]
    i = w_gate.shape[2]
    assert t % token_tile == 0, f"T={t} not divisible by tile {token_tile}"
    grid = (e, t // token_tile)
    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, h), lambda ei, ti: (ti, 0)),
            pl.BlockSpec((token_tile, 1), lambda ei, ti: (ti, ei)),
            pl.BlockSpec((1, h, i), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, h, i), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, i, h), lambda ei, ti: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((token_tile, h), lambda ei, ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h), x.dtype),
        interpret=True,
    )(x, gates, w_gate, w_up, w_down)


def vmem_footprint_bytes(h, i, token_tile=TOKEN_TILE, dtype_bytes=4):
    """Estimated VMEM working set of one grid step (for DESIGN.md §Perf):
    token tile + gate column + three weight panels + output tile."""
    return dtype_bytes * (
        token_tile * h  # x tile
        + token_tile  # gate column
        + 2 * h * i  # gate/up panels
        + i * h  # down panel
        + token_tile * h  # output tile
        + 2 * token_tile * i  # activations g/u
    )
