"""L1 Pallas kernel: INT4 per-group dequantization.

The transition path's device-side half: codes arrive packed two per
byte (as int32 lanes of 8 nibbles for TPU-friendly layout here we keep
one code per int32 lane — the packing is host-side), and each group of
``group_size`` values shares an affine (scale, zero).

Bandwidth-bound by design: 1 int32 read + 1 f32 write per element with
a broadcast multiply-add — the VPU saturates HBM, which is what the
``T_dequant`` dictionary in the Rust transition model assumes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP_TILE = 8  # groups per grid step


def _dequant_kernel(codes_ref, scales_ref, zeros_ref, o_ref):
    """Blocks: codes [GT, G] int32; scales/zeros [GT, 1]; out [GT, G]."""
    c = codes_ref[...].astype(jnp.float32)
    o_ref[...] = (c - zeros_ref[...]) * scales_ref[...]


@functools.partial(jax.jit, static_argnames=("group_size",))
def dequant_int4_pallas(codes, scales, zeros, group_size):
    """codes: int32 [N] in [-8, 7]; scales/zeros: f32 [N / group_size]."""
    n = codes.shape[0]
    g = n // group_size
    assert g % GROUP_TILE == 0, (g, GROUP_TILE)
    c2 = codes.reshape(g, group_size)
    s2 = scales.reshape(g, 1)
    z2 = zeros.reshape(g, 1)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(g // GROUP_TILE,),
        in_specs=[
            pl.BlockSpec((GROUP_TILE, group_size), lambda i: (i, 0)),
            pl.BlockSpec((GROUP_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((GROUP_TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((GROUP_TILE, group_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, group_size), jnp.float32),
        interpret=True,
    )(c2, s2, z2)
    return out.reshape(n)
