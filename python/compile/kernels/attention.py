"""L1 Pallas kernels: tiled causal attention (prefill) and KV-cache
decode.

GPU flash-attention stages K/V tiles through shared memory per
threadblock; the TPU rethink expresses the same HBM→VMEM schedule with
a Pallas grid over (batch·head, q-tile) and an inner fori_loop over
k-tiles with online-softmax accumulators held in VMEM scratch
(DESIGN.md §Hardware-Adaptation).

Decode is a single-query attention against a padded KV cache with a
position mask — one grid step per (batch, head), the whole cache row
streamed through VMEM.

``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Q_TILE = 64
K_TILE = 64
NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, k_tile, seq):
    """Grid: (batch, q_heads, q tiles). Blocks:

    q_ref: [Q_TILE, D]; k_ref/v_ref: [S, D] (whole row for this bh);
    o_ref: [Q_TILE, D]. Online softmax over k-tiles.
    """
    qi = pl.program_id(2)
    # Blocks arrive with leading singleton (batch, head) dims.
    q = q_ref[0, 0]
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    q_tile = q.shape[0]

    m = jnp.full((q_tile, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((q_tile, 1), jnp.float32)
    acc = jnp.zeros((q_tile, d), jnp.float32)

    n_k_tiles = seq // k_tile

    def body(kt, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], kt * k_tile, k_tile, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], kt * k_tile, k_tile, axis=0)
        s = (q @ k.T) * scale  # [Q_TILE, K_TILE]
        # Causal mask: query row (qi*Q_TILE + r) attends keys ≤ itself.
        q_pos = qi * q_tile + jax.lax.broadcasted_iota(jnp.int32, (q_tile, k_tile), 0)
        k_pos = kt * k_tile + jax.lax.broadcasted_iota(jnp.int32, (q_tile, k_tile), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(axis=-1, keepdims=True)
        acc_new = alpha * acc + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_k_tiles, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_tile", "k_tile"))
def attention_core_pallas(q, k, v, q_tile=Q_TILE, k_tile=K_TILE):
    """Causal attention core (post-projection, pre-output-projection).

    q: [B, S, Hq, D]; k/v: [B, S, Hq, D] (KV already repeated to Hq).
    Returns ctx [B, S, Hq, D].
    """
    b, s, hq, d = q.shape
    # Clamp tiles for short sequences (static shapes, so this happens
    # once at trace time).
    q_tile = min(q_tile, s)
    k_tile = min(k_tile, s)
    assert s % q_tile == 0 and s % k_tile == 0, (s, q_tile, k_tile)
    # Layout: [B, H, S, D] so the grid can tile S per (b, h).
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, hq, s // q_tile)
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, k_tile=k_tile, seq=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_tile, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_tile, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        interpret=True,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref):
    """Grid: (batch, q_heads). Single query vs padded cache row.

    q_ref: [1, D]; k_ref/v_ref: [M, D]; pos_ref: [1] (valid length − 1,
    i.e. the index of the newest token); o_ref: [1, D].
    """
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    pos = pos_ref[0]
    d = q.shape[-1]
    m_len = k.shape[0]
    scale = 1.0 / (d ** 0.5)
    s = (q @ k.T) * scale  # [1, M]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, m_len), 1)
    s = jnp.where(idx <= pos, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o_ref[0, 0] = (p @ v / p.sum(axis=-1, keepdims=True)).astype(o_ref.dtype)


@jax.jit
def decode_core_pallas(q, k_cache, v_cache, pos):
    """Single-step attention core against a padded cache.

    q: [B, 1, Hq, D]; k_cache/v_cache: [B, M, Hq, D] (repeated to Hq);
    pos: scalar int32 index of the newest valid token.
    Returns ctx [B, 1, Hq, D].
    """
    b, _, hq, d = q.shape
    m = k_cache.shape[1]
    qt = q.transpose(0, 2, 1, 3)  # [B, H, 1, D]
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))
    grid = (b, hq)
    out = pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, m, d), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, m, d), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1,), lambda bi, hi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        interpret=True,
    )(qt, kt, vt, pos_arr)
    return out.transpose(0, 2, 1, 3)


def vmem_footprint_bytes(seq, head_dim, q_tile=Q_TILE, dtype_bytes=4):
    """Prefill kernel VMEM working set per grid step (§Perf)."""
    return dtype_bytes * (
        q_tile * head_dim  # q tile
        + 2 * seq * head_dim  # k, v rows
        + q_tile * head_dim  # acc
        + 2 * q_tile  # m, l
        + q_tile * head_dim  # out
    )
