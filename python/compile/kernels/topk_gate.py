"""L1 Pallas kernel: softmax top-k expert gating.

Computes dense routing weights [T, E]: softmax over the top-k experts'
logits, zero elsewhere (the Mixtral formulation, matching
``ref.topk_gate``). Dense output feeds the fused MoE FFN kernel and
keeps shapes static for AOT lowering.

Grid tiles tokens; each step holds a [TILE, H] activation block and the
[H, E] router matrix in VMEM (E is small: 8–64).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TOKEN_TILE = 128
NEG_INF = -1e30


def _gate_kernel(x_ref, wr_ref, o_ref, *, top_k):
    x = x_ref[...]
    logits = x @ wr_ref[...]  # [TILE, E]
    e = logits.shape[-1]

    # Iteratively peel the max k times to find the k-th largest value
    # (no jnp.sort in the kernel: keep ops MXU/VPU friendly).
    def peel(i, carry):
        work, kth = carry
        cur = work.max(axis=-1, keepdims=True)
        work = jnp.where(work >= cur, NEG_INF, work)
        return work, cur

    _, kth = jax.lax.fori_loop(0, top_k, peel, (logits, jnp.full((logits.shape[0], 1), NEG_INF)))
    mask = logits >= kth
    masked = jnp.where(mask, logits, NEG_INF)
    m = masked.max(axis=-1, keepdims=True)
    p = jnp.exp(masked - m)
    w = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o_ref[...] = jnp.where(mask, w, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("top_k", "token_tile"))
def topk_gate_pallas(x, w_router, top_k, token_tile=TOKEN_TILE):
    """x: [T, H]; w_router: [H, E] → weights [T, E]."""
    t, h = x.shape
    e = w_router.shape[1]
    assert t % token_tile == 0, (t, token_tile)
    return pl.pallas_call(
        functools.partial(_gate_kernel, top_k=top_k),
        grid=(t // token_tile,),
        in_specs=[
            pl.BlockSpec((token_tile, h), lambda ti: (ti, 0)),
            pl.BlockSpec((h, e), lambda ti: (0, 0)),
        ],
        out_specs=pl.BlockSpec((token_tile, e), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, e), x.dtype),
        interpret=True,
    )(x, w_router)
