"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness).

These are the ground truth the kernels are validated against in
``python/tests/test_kernels.py`` (assert_allclose + hypothesis sweeps)
and the semantics the Rust engine's combine logic assumes:

- TP partials across devices **sum** to the unsharded output;
- EP per-device contributions (owned experts only) **sum** to the full
  routed output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def swiglu_ffn(x, w_gate, w_up, w_down):
    """SwiGLU expert FFN: (silu(x·Wg) ⊙ (x·Wu))·Wd.

    x: [T, H]; w_gate/w_up: [H, I]; w_down: [I, H] → [T, H].
    """
    g = x @ w_gate
    u = x @ w_up
    act = jnp.asarray(silu(g) * u, x.dtype)
    return act @ w_down


def softmax(x, axis=-1):
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def topk_gate(x, w_router, top_k):
    """Top-k router: returns weights [T, E] (zero outside the top-k).

    Weights are the softmax over the selected experts' logits
    renormalized over the top-k set — the Mixtral formulation.
    """
    logits = x @ w_router  # [T, E]
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    thresh = sorted_desc[:, top_k - 1 : top_k]
    mask = (logits >= thresh).astype(x.dtype)
    neg = jnp.finfo(jnp.float32).min
    masked_logits = jnp.where(mask > 0, logits, neg)
    weights = softmax(masked_logits, axis=-1) * mask
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights


def moe_ffn(x, w_router, w_gate, w_up, w_down, top_k, owned_mask=None):
    """Full routed-expert module on tokens x: [T, H].

    w_router: [H, E]; w_gate/w_up: [E, H, I]; w_down: [E, I, H].
    owned_mask: optional [E] 0/1 vector — an EP shard owns a subset of
    experts; non-owned contributions are dropped so that summing over
    EP shards reconstructs the full output.
    """
    weights = topk_gate(x, w_router, top_k)
    if owned_mask is not None:
        weights = weights * owned_mask[None, :]
    out = jnp.zeros_like(x)
    num_experts = w_gate.shape[0]
    for e in range(num_experts):
        y = swiglu_ffn(x, w_gate[e], w_up[e], w_down[e])
        out = out + weights[:, e : e + 1] * y
    return out


def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * scale


def attention_prefill(x, wq, wk, wv, wo, q_heads, kv_heads, head_dim):
    """Causal GQA prefill attention. x: [B, S, H].

    Returns (out [B, S, H], k [B, S, KVH, D], v [B, S, KVH, D]).
    """
    b, s, _ = x.shape
    q = (x @ wq).reshape(b, s, q_heads, head_dim)
    k = (x @ wk).reshape(b, s, kv_heads, head_dim)
    v = (x @ wv).reshape(b, s, kv_heads, head_dim)
    rep = q_heads // kv_heads
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, x.dtype))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf) * scale  # [B, Hq, S, S]
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(causal[None, None, :, :], scores, jnp.finfo(jnp.float32).min)
    probs = softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, vf).reshape(b, s, q_heads * head_dim)
    return ctx @ wo, k, v


def attention_decode(x, k_cache, v_cache, pos, wq, wk, wv, wo, q_heads, kv_heads, head_dim):
    """Single-step GQA decode against a padded KV cache.

    x: [B, 1, H]; k_cache/v_cache: [B, M, KVH, D]; pos: scalar int32 —
    tokens 0..pos-1 are valid and the new token writes at index pos.
    Returns (out [B, 1, H], new_k_cache, new_v_cache).
    """
    b, _, _ = x.shape
    m = k_cache.shape[1]
    q = (x @ wq).reshape(b, 1, q_heads, head_dim)
    k_new = (x @ wk).reshape(b, 1, kv_heads, head_dim)
    v_new = (x @ wv).reshape(b, 1, kv_heads, head_dim)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, pos, axis=1)
    rep = q_heads // kv_heads
    kf = jnp.repeat(k_cache, rep, axis=2)
    vf = jnp.repeat(v_cache, rep, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, x.dtype))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf) * scale  # [B, Hq, 1, M]
    valid = jnp.arange(m)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, jnp.finfo(jnp.float32).min)
    probs = softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, vf).reshape(b, 1, q_heads * head_dim)
    return ctx @ wo, k_cache, v_cache


def dequant_int4_per_group(codes, scales, zeros, group_size):
    """INT4 per-group dequantization reference.

    codes: int32 [N] values in [-8, 7] (already unpacked); scales/zeros:
    [N // group_size] f32. Matches the Rust `quant` module's affine form
    x ≈ (code − zero) · scale.
    """
    n = codes.shape[0]
    g = n // group_size
    c = codes.reshape(g, group_size).astype(jnp.float32)
    return ((c - zeros[:, None]) * scales[:, None]).reshape(n)
