//! Minimal, std-only shim of the `anyhow` API surface this workspace
//! uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros, and
//! the [`Context`] extension trait.
//!
//! The offline build environment has no crates.io access, so this
//! in-tree crate stands in for the real `anyhow`. Errors are stored as
//! a context chain of rendered strings (outermost first); `{e}` prints
//! the outermost message, `{e:#}` and `{e:?}` print the whole chain.

use std::fmt;

/// A dynamically typed error with a chain of context messages,
/// outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std<E: std::error::Error + ?Sized>(err: &E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, colon-separated (anyhow style).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Blanket conversion from any std error. `Error` itself deliberately
// does not implement `std::error::Error`, which keeps this coherent
// with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

mod private {
    /// Sealed unifier over `anyhow::Error` and std errors so `Context`
    /// has a single blanket impl (the real anyhow's `ext::StdError`
    /// trick).
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> super::Error;
    }

    impl IntoAnyhow for super::Error {
        fn into_anyhow(self) -> super::Error {
            self
        }
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoAnyhow for E {
        fn into_anyhow(self) -> super::Error {
            super::Error::from_std(&self)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// results over both std errors and [`Error`].
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::IntoAnyhow> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(format!("{e}"), "bad value 42");
        fn fails() -> Result<()> {
            bail!("nope: {}", "reason");
        }
        assert!(fails().is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            let _ = "abc".parse::<usize>()?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn with_context_on_anyhow_result() {
        use super::Context as _;
        let r: Result<()> = Err(anyhow!("inner"));
        let r = r.with_context(|| "outer");
        assert_eq!(format!("{:#}", r.unwrap_err()), "outer: inner");
    }
}
