//! Typed stub of the `xla` PJRT bindings (offline build).
//!
//! The real serving path wraps the `xla` crate (PJRT CPU client +
//! `xla_extension` native library), which is unavailable in this
//! environment. This stub keeps the exact API surface the crate uses
//! so everything type-checks and the host-side [`Literal`] helpers
//! behave for real; creating a [`PjRtClient`] reports a clear runtime
//! error instead. Every caller already gates on `artifacts/` existing,
//! so the simulation/planner/serving-queue stack is unaffected.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `{e:?}`
/// formatting and `?` conversion into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct XlaError {
    pub msg: String,
}

impl XlaError {
    fn new(msg: &str) -> XlaError {
        XlaError { msg: msg.to_string() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

const STUB_MSG: &str =
    "PJRT runtime unavailable: built against the xla stub (no xla_extension in this environment)";

/// Host literal payload.
#[derive(Debug, Clone, PartialEq)]
enum LitData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Sized + Copy {
    fn wrap(values: &[Self]) -> LitDataOpaque;
    fn unwrap(data: &LitDataOpaque) -> Option<Vec<Self>>;
}

/// Opaque newtype so `LitData` stays private while `NativeType` is
/// public.
#[derive(Debug, Clone, PartialEq)]
pub struct LitDataOpaque(LitData);

impl NativeType for f32 {
    fn wrap(values: &[Self]) -> LitDataOpaque {
        LitDataOpaque(LitData::F32(values.to_vec()))
    }
    fn unwrap(data: &LitDataOpaque) -> Option<Vec<Self>> {
        match &data.0 {
            LitData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(values: &[Self]) -> LitDataOpaque {
        LitDataOpaque(LitData::I32(values.to_vec()))
    }
    fn unwrap(data: &LitDataOpaque) -> Option<Vec<Self>> {
        match &data.0 {
            LitData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor literal (fully functional in the stub).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: LitDataOpaque,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal { data: T::wrap(values), dims: vec![values.len() as i64] }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal { data: T::wrap(&[value]), dims: Vec::new() }
    }

    fn len(&self) -> usize {
        match &self.data.0 {
            LitData::F32(v) => v.len(),
            LitData::I32(v) => v.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(XlaError {
                msg: format!("reshape: {} elements into dims {dims:?}", self.len()),
            });
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| XlaError::new("to_vec: element type mismatch"))
    }

    /// Flatten a tuple literal (device results only — stub errors).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(XlaError::new(STUB_MSG))
    }
}

/// Device buffer handle (never obtainable from the stub client).
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new(STUB_MSG))
    }
}

/// Parsed HLO module (stub: path retained for diagnostics only).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _path: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        // Reading succeeds so missing-file errors still surface first.
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| XlaError { msg: format!("{}: {e}", path.as_ref().display()) })?;
        let _ = text;
        Ok(HloModuleProto { _path: path.as_ref().display().to_string() })
    }
}

/// Compilable computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Compiled executable (never obtainable from the stub client).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(STUB_MSG))
    }

    pub fn execute_b<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(STUB_MSG))
    }
}

/// PJRT client. The stub cannot execute, so construction fails with a
/// descriptive error rather than faking device semantics.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::new(STUB_MSG))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(STUB_MSG))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        // Concrete device-id type: the call sites pass a bare `None`,
        // which a generic parameter could not infer.
        Err(XlaError::new(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("stub"));
    }
}
