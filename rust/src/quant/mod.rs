//! INT4 weight quantization (paper §III-D, Table I).
//!
//! The dynamic parallelism-transition mechanism keeps a 4-bit quantized
//! backup of expert weights in CPU memory, uploaded and dequantized
//! instead of resharding over the interconnect. The paper evaluates
//! per-tensor, per-channel, and per-group schemes and adopts fine-
//! grained per-group quantization (group size 128) for its near-lossless
//! quality.
//!
//! Values are mapped to signed 4-bit integers in [-8, 7] with an
//! asymmetric affine transform `q = clamp(round(x / scale) + zero)`;
//! two nibbles pack per byte.
//!
//! # Serving path
//!
//! Beyond the transition backup, quantization is a live serving
//! configuration: [`QuantKind`] (int8 or int4) selected via
//! `ServeConfig::quant` / `hap serve --quant int8|int4` makes the host
//! executor store its matmul weights as
//! [`crate::model::kernels::PackedQuant`] — per-`(row, group)` affine
//! codes in the packed-panel layout — and dequantize on the fly inside
//! the blocked matmul. The affine parameters and code mapping are
//! defined *here* ([`affine_params`] / [`encode_signed`]; the int4
//! case is shared with [`quantize`] below) so the serving kernels and
//! the Table-I quantizer stay numerically identical by construction.

use crate::util::stats;

/// Integer width for quantized **serving** weights (the Table-I
/// quantizer below is int4-only, matching the paper's backup format).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantKind {
    Int8,
    Int4,
}

impl QuantKind {
    /// Parse a CLI/config spelling (`int8` / `int4`).
    pub fn parse(s: &str) -> Option<QuantKind> {
        match s {
            "int8" => Some(QuantKind::Int8),
            "int4" => Some(QuantKind::Int4),
            _ => None,
        }
    }

    pub fn bits(&self) -> usize {
        match self {
            QuantKind::Int8 => 8,
            QuantKind::Int4 => 4,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            QuantKind::Int8 => "int8",
            QuantKind::Int4 => "int4",
        }
    }
}

/// Asymmetric affine parameters `(scale, inv_scale, zero)` for one
/// block with value range `[lo, hi]`: codes span `[-8, 7]` (int4) or
/// `[-128, 127]` (int8), and a value decodes as
/// `code · scale - zero · scale`.
pub fn affine_params(kind: QuantKind, lo: f32, hi: f32) -> (f32, f32, f32) {
    let range = (hi - lo).max(1e-12);
    match kind {
        QuantKind::Int4 => {
            let scale = range / 15.0;
            let inv_scale = 15.0 / range;
            let zero = (-8.0 - lo * inv_scale).round();
            (scale, inv_scale, zero)
        }
        QuantKind::Int8 => {
            let scale = range / 255.0;
            let inv_scale = 255.0 / range;
            let zero = (-128.0 - lo * inv_scale).round();
            (scale, inv_scale, zero)
        }
    }
}

/// Encode one value as a signed code (int4: `[-8, 7]`, int8:
/// `[-128, 127]`). Round-half-up via `+0.5` and truncation on the
/// shifted (unsigned) code, exactly like the packed int4 quantizer.
pub fn encode_signed(kind: QuantKind, x: f32, inv_scale: f32, zero: f32) -> i8 {
    match kind {
        QuantKind::Int4 => {
            let shifted = (x * inv_scale + zero + 8.5).clamp(0.0, 15.0) as i32;
            (shifted - 8) as i8
        }
        QuantKind::Int8 => {
            let shifted = (x * inv_scale + zero + 128.5).clamp(0.0, 255.0) as i32;
            (shifted - 128) as i8
        }
    }
}

/// Quantization granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// One (scale, zero) pair for the whole tensor.
    PerTensor,
    /// One pair per output channel (row of a `rows × cols` matrix).
    PerChannel,
    /// One pair per contiguous group of `group_size` values within a row.
    PerGroup { group_size: usize },
}

impl Scheme {
    pub fn name(&self) -> String {
        match self {
            Scheme::PerTensor => "per-tensor".into(),
            Scheme::PerChannel => "per-channel".into(),
            Scheme::PerGroup { group_size } => format!("per-group({group_size})"),
        }
    }
}

/// An INT4-quantized tensor: packed nibbles + per-block parameters.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    /// Packed 4-bit codes, two per byte (low nibble first).
    pub packed: Vec<u8>,
    /// Per-block scale.
    pub scales: Vec<f32>,
    /// Per-block zero point (in quantized units, f32 for affine math).
    pub zeros: Vec<f32>,
    /// Elements per block.
    pub block_len: usize,
    /// Original element count.
    pub len: usize,
    pub scheme: Scheme,
}

impl QuantizedTensor {
    /// Bytes of storage (codes + parameters) — the V_dequant payload.
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + 8 * self.scales.len()
    }
}

/// Quantize a row-major `rows × cols` matrix.
pub fn quantize(data: &[f32], rows: usize, cols: usize, scheme: Scheme) -> QuantizedTensor {
    assert_eq!(data.len(), rows * cols, "shape mismatch");
    let block_len = match scheme {
        Scheme::PerTensor => data.len(),
        Scheme::PerChannel => cols,
        Scheme::PerGroup { group_size } => {
            assert!(group_size > 0 && cols % group_size == 0, "group must divide cols");
            group_size
        }
    };
    let n_blocks = data.len().div_ceil(block_len);
    let mut scales = Vec::with_capacity(n_blocks);
    let mut zeros = Vec::with_capacity(n_blocks);
    // §Perf: pack nibbles directly (no intermediate code vector);
    // inner loops use multiply-by-inverse instead of division.
    let mut packed = vec![0u8; data.len().div_ceil(2)];

    for (b, block) in data.chunks(block_len).enumerate() {
        // Single-pass min/max (auto-vectorizes).
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in block {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        // Asymmetric affine over [-8, 7] (shared with the serving
        // kernels via `affine_params`/`encode_signed`).
        let (scale, inv_scale, zero) = affine_params(QuantKind::Int4, lo, hi);
        scales.push(scale);
        zeros.push(zero);
        let base = b * block_len;
        // Two's-complement nibble of the signed code.
        let quantize1 =
            |x: f32| -> u8 { encode_signed(QuantKind::Int4, x, inv_scale, zero) as u8 & 0x0F };
        if base % 2 == 0 {
            let bytes = &mut packed[base / 2..(base + block.len()).div_ceil(2)];
            let mut pairs = block.chunks_exact(2);
            for (byte, pair) in bytes.iter_mut().zip(&mut pairs) {
                *byte = quantize1(pair[0]) | (quantize1(pair[1]) << 4);
            }
            if let [last] = pairs.remainder() {
                bytes[block.len() / 2] = quantize1(*last);
            }
        } else {
            for (j, &x) in block.iter().enumerate() {
                let i = base + j;
                let nib = quantize1(x);
                if i % 2 == 0 {
                    packed[i / 2] = (packed[i / 2] & 0xF0) | nib;
                } else {
                    packed[i / 2] = (packed[i / 2] & 0x0F) | (nib << 4);
                }
            }
        }
    }

    QuantizedTensor { packed, scales, zeros, block_len, len: data.len(), scheme }
}

/// Dequantize back to f32.
///
/// Hot path of the INT4-backup transition (§Perf): a 16-entry
/// nibble→f32 lookup table replaces per-element sign-extension, and
/// per-block `(scale, -zero·scale)` are hoisted so the inner loop is a
/// fused multiply-add over byte pairs.
pub fn dequantize(q: &QuantizedTensor) -> Vec<f32> {
    // code value for each nibble pattern (sign-extended 4-bit).
    const LUT: [f32; 16] = [
        0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, -8.0, -7.0, -6.0, -5.0, -4.0, -3.0, -2.0, -1.0,
    ];
    let mut out = vec![0.0f32; q.len];
    let block_len = q.block_len;
    for (b, chunk) in out.chunks_mut(block_len).enumerate() {
        let scale = q.scales[b];
        let bias = -q.zeros[b] * scale;
        let base = b * block_len; // element index of block start
        // Blocks are element-aligned but may start mid-byte when
        // block_len is odd; handle the general case per element pair.
        if base % 2 == 0 && chunk.len() % 2 == 0 {
            let bytes = &q.packed[base / 2..(base + chunk.len()) / 2];
            for (pair, &byte) in chunk.chunks_exact_mut(2).zip(bytes) {
                pair[0] = LUT[(byte & 0x0F) as usize] * scale + bias;
                pair[1] = LUT[(byte >> 4) as usize] * scale + bias;
            }
        } else {
            for (j, v) in chunk.iter_mut().enumerate() {
                let i = base + j;
                let byte = q.packed[i / 2];
                let nib = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                *v = LUT[nib as usize] * scale + bias;
            }
        }
    }
    out
}

/// Quality report for one scheme on one tensor (Table I's measurement
/// primitives).
#[derive(Debug, Clone)]
pub struct QuantReport {
    pub scheme: Scheme,
    pub cosine_similarity: f64,
    pub rmse: f64,
    pub max_abs_err: f64,
    pub storage_bytes: usize,
    pub original_bytes: usize,
}

impl QuantReport {
    pub fn compression_ratio(&self) -> f64 {
        self.original_bytes as f64 / self.storage_bytes as f64
    }
}

/// Quantize→dequantize round trip quality evaluation.
pub fn evaluate(data: &[f32], rows: usize, cols: usize, scheme: Scheme) -> QuantReport {
    let q = quantize(data, rows, cols, scheme);
    let deq = dequantize(&q);
    QuantReport {
        scheme,
        cosine_similarity: stats::cosine_similarity(data, &deq),
        rmse: stats::rmse_f32(data, &deq),
        max_abs_err: stats::max_abs_diff(data, &deq),
        storage_bytes: q.storage_bytes(),
        original_bytes: data.len() * 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian_matrix(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec_f32(rows * cols, 0.02)
    }

    #[test]
    fn round_trip_error_bounded_by_half_scale() {
        let data = gaussian_matrix(16, 128, 1);
        let q = quantize(&data, 16, 128, Scheme::PerGroup { group_size: 64 });
        let deq = dequantize(&q);
        for (i, (&x, &y)) in data.iter().zip(&deq).enumerate() {
            let block = i / q.block_len;
            let half_scale = q.scales[block] * 0.5 + 1e-7;
            assert!((x - y).abs() <= half_scale, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn per_group_beats_per_tensor() {
        // With outliers, fine granularity wins — Table I's structure.
        let mut data = gaussian_matrix(32, 256, 2);
        // Inject row-local outliers that blow up the global scale.
        for r in 0..32 {
            data[r * 256] = if r % 2 == 0 { 0.5 } else { -0.5 };
        }
        let pt = evaluate(&data, 32, 256, Scheme::PerTensor);
        let pg = evaluate(&data, 32, 256, Scheme::PerGroup { group_size: 128 });
        assert!(pg.rmse < pt.rmse * 0.5, "pg {} vs pt {}", pg.rmse, pt.rmse);
        assert!(pg.cosine_similarity > pt.cosine_similarity);
    }

    #[test]
    fn cosine_similarity_above_paper_threshold() {
        // Paper: quant→dequant keeps >99.5% cosine similarity.
        let data = gaussian_matrix(64, 512, 3);
        let rep = evaluate(&data, 64, 512, Scheme::PerGroup { group_size: 64 });
        assert!(rep.cosine_similarity > 0.995, "cos {}", rep.cosine_similarity);
    }

    #[test]
    fn compression_near_8x_minus_overhead() {
        let data = gaussian_matrix(128, 1024, 4);
        let rep = evaluate(&data, 128, 1024, Scheme::PerGroup { group_size: 128 });
        let ratio = rep.compression_ratio();
        assert!(ratio > 6.0 && ratio <= 8.0, "ratio {ratio}");
    }

    #[test]
    fn per_channel_block_structure() {
        let data = gaussian_matrix(8, 32, 5);
        let q = quantize(&data, 8, 32, Scheme::PerChannel);
        assert_eq!(q.scales.len(), 8);
        assert_eq!(q.block_len, 32);
    }

    #[test]
    fn odd_length_packs() {
        let data = vec![0.1f32, -0.2, 0.3];
        let q = quantize(&data, 1, 3, Scheme::PerTensor);
        assert_eq!(q.packed.len(), 2);
        let deq = dequantize(&q);
        assert_eq!(deq.len(), 3);
    }

    #[test]
    #[should_panic(expected = "group must divide")]
    fn bad_group_size_rejected() {
        let data = vec![0.0f32; 64];
        quantize(&data, 8, 8, Scheme::PerGroup { group_size: 3 });
    }

    #[test]
    fn constant_tensor_survives() {
        let data = vec![0.25f32; 256];
        let q = quantize(&data, 16, 16, Scheme::PerTensor);
        let deq = dequantize(&q);
        for &v in &deq {
            assert!((v - 0.25).abs() < 0.05);
        }
    }

    #[test]
    fn encode_signed_round_trip_bounded_by_half_scale() {
        let data = gaussian_matrix(4, 64, 9);
        for kind in [QuantKind::Int8, QuantKind::Int4] {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &x in &data {
                lo = lo.min(x);
                hi = hi.max(x);
            }
            let (scale, inv_scale, zero) = affine_params(kind, lo, hi);
            for &x in &data {
                let code = encode_signed(kind, x, inv_scale, zero);
                let y = code as f32 * scale + (-zero * scale);
                assert!((x - y).abs() <= scale * 0.5 + 1e-7, "{kind:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn exact_grid_round_trips_exactly() {
        // Values on the code grid with the full range present round-trip
        // bit-exactly: range is a power-of-two multiple of the spacing,
        // so scale is exact and zero lands on an integer. This is the
        // property the engine-level quantized-serving identity test
        // builds on.
        for (kind, denom, lo_n, hi_n) in
            [(QuantKind::Int8, 256.0f32, -128i32, 127), (QuantKind::Int4, 16.0, -8, 7)]
        {
            let vals: Vec<f32> = (lo_n..=hi_n).map(|n| n as f32 / denom).collect();
            let (scale, inv_scale, zero) = affine_params(kind, vals[0], *vals.last().unwrap());
            assert_eq!(zero, 0.0, "{kind:?} zero point");
            for &x in &vals {
                let code = encode_signed(kind, x, inv_scale, zero);
                let y = code as f32 * scale + (-zero * scale);
                assert_eq!(x.to_bits(), y.to_bits(), "{kind:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn quant_kind_parses() {
        assert_eq!(QuantKind::parse("int8"), Some(QuantKind::Int8));
        assert_eq!(QuantKind::parse("int4"), Some(QuantKind::Int4));
        assert_eq!(QuantKind::parse("fp8"), None);
        assert_eq!(QuantKind::Int8.bits(), 8);
        assert_eq!(QuantKind::Int4.name(), "int4");
    }
}
