//! Counter/gauge/histogram registry with JSON and Prometheus-style
//! text exposition.
//!
//! The registry is a *snapshot* structure: producers (e.g.
//! `serving::Metrics::registry`) build one at export time from their
//! own counters, so there is no shared-state instrumentation cost on
//! the serving hot path. Entry order is insertion order, which keeps
//! both expositions deterministic.

use crate::util::json::Json;
use crate::util::stats;

/// Quantile snapshot of a sample distribution.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistogramSnapshot {
    pub count: usize,
    pub sum: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl HistogramSnapshot {
    /// Snapshot a sample vector (all-zero for an empty one, matching
    /// the pinned `util::stats` empty-input behavior).
    pub fn from_samples(samples: &[f64]) -> HistogramSnapshot {
        HistogramSnapshot {
            count: samples.len(),
            sum: samples.iter().sum(),
            mean: stats::mean(samples),
            p50: stats::percentile(samples, 50.0),
            p95: stats::percentile(samples, 95.0),
            p99: stats::percentile(samples, 99.0),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", self.count.into()),
            ("sum", self.sum.into()),
            ("mean", self.mean.into()),
            ("p50", self.p50.into()),
            ("p95", self.p95.into()),
            ("p99", self.p99.into()),
        ])
    }
}

/// One metric value.
#[derive(Debug, Clone, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histogram(HistogramSnapshot),
}

/// Ordered name → value registry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Registry {
    entries: Vec<(String, MetricValue)>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register (or overwrite) a monotonic counter.
    pub fn counter(&mut self, name: &str, value: u64) {
        self.set(name, MetricValue::Counter(value));
    }

    /// Register (or overwrite) a point-in-time gauge.
    pub fn gauge(&mut self, name: &str, value: f64) {
        self.set(name, MetricValue::Gauge(value));
    }

    /// Register (or overwrite) a histogram snapshot of `samples`.
    pub fn histogram(&mut self, name: &str, samples: &[f64]) {
        self.set(name, MetricValue::Histogram(HistogramSnapshot::from_samples(samples)));
    }

    fn set(&mut self, name: &str, value: MetricValue) {
        match self.entries.iter_mut().find(|(k, _)| k == name) {
            Some(entry) => entry.1 = value,
            None => self.entries.push((name.to_string(), value)),
        }
    }

    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries.iter().find(|(k, _)| k == name).map(|(_, v)| v)
    }

    pub fn entries(&self) -> &[(String, MetricValue)] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// JSON exposition: `{name: value}` with histograms as quantile
    /// objects. Counters serialize as integers.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.entries
                .iter()
                .map(|(k, v)| {
                    let j = match v {
                        MetricValue::Counter(c) => Json::Num(*c as f64),
                        MetricValue::Gauge(g) => Json::Num(*g),
                        MetricValue::Histogram(h) => h.to_json(),
                    };
                    (k.clone(), j)
                })
                .collect(),
        )
    }

    /// Prometheus-style text exposition. Metric names get a `hap_`
    /// prefix and are sanitized to `[a-zA-Z0-9_]`; histograms render as
    /// summaries with `quantile` labels plus `_sum`/`_count` series.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, value) in &self.entries {
            let n = format!("hap_{}", sanitize(name));
            match value {
                MetricValue::Counter(c) => {
                    out.push_str(&format!("# TYPE {n} counter\n{n} {c}\n"));
                }
                MetricValue::Gauge(g) => {
                    out.push_str(&format!("# TYPE {n} gauge\n{n} {g}\n"));
                }
                MetricValue::Histogram(h) => {
                    out.push_str(&format!("# TYPE {n} summary\n"));
                    for (q, v) in [(0.5, h.p50), (0.95, h.p95), (0.99, h.p99)] {
                        out.push_str(&format!("{n}{{quantile=\"{q}\"}} {v}\n"));
                    }
                    out.push_str(&format!("{n}_sum {}\n{n}_count {}\n", h.sum, h.count));
                }
            }
        }
        out
    }
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Registry {
        let mut r = Registry::new();
        r.counter("requests_completed", 24);
        r.gauge("wall_time_seconds", 1.5);
        r.histogram("request_latency_seconds", &[0.1, 0.2, 0.3, 0.4]);
        r
    }

    #[test]
    fn json_exposition_round_trips() {
        let r = demo();
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("requests_completed").and_then(Json::as_usize), Some(24));
        let hist = parsed.get("request_latency_seconds").unwrap();
        assert_eq!(hist.get("count").and_then(Json::as_usize), Some(4));
        assert!((hist.get("mean").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-12);
        // Counters serialize as integers (no decimal point).
        assert!(j.to_string_compact().contains("\"requests_completed\":24"));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let text = demo().to_prometheus();
        assert!(text.contains("# TYPE hap_requests_completed counter"));
        assert!(text.contains("hap_requests_completed 24"));
        assert!(text.contains("# TYPE hap_wall_time_seconds gauge"));
        assert!(text.contains("# TYPE hap_request_latency_seconds summary"));
        assert!(text.contains("hap_request_latency_seconds{quantile=\"0.5\"}"));
        assert!(text.contains("hap_request_latency_seconds_count 4"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line: {line}");
        }
    }

    #[test]
    fn empty_histogram_snapshots_to_zeros() {
        let h = HistogramSnapshot::from_samples(&[]);
        assert_eq!(h.count, 0);
        assert_eq!(h.mean, 0.0);
        assert_eq!(h.p99, 0.0);
    }

    #[test]
    fn overwrite_keeps_insertion_order() {
        let mut r = demo();
        r.counter("requests_completed", 30);
        assert_eq!(r.len(), 3);
        assert_eq!(r.entries()[0].0, "requests_completed");
        assert_eq!(r.get("requests_completed"), Some(&MetricValue::Counter(30)));
    }
}
