//! # Observability: deterministic tracing, plan-decision audit, metrics.
//!
//! The serving stack's instrumentation layer (std-only, zero deps):
//!
//! - [`trace`] — the iteration-clock event stream. A [`Recorder`]
//!   collects typed [`TraceEvent`]s ordered by the engine's scheduler
//!   iteration and the executor's fault-clock op counter. Wall time is
//!   carried as a *payload* field, never as an ordering key, so two
//!   runs of the same seeded workload produce byte-identical streams
//!   once the wall-derived fields are stripped
//!   ([`canonical_stream`]) — the trace doubles as a regression
//!   oracle. [`ModuleTimes`] carries the per-module / per-device time
//!   attribution (the paper's Fig. 2 breakdown) measured around
//!   `ModelExecutor`'s `map_devices` fan-outs.
//! - [`registry`] — a small counter/gauge/histogram [`Registry`] with
//!   JSON and Prometheus-style text exposition. `serving::Metrics`
//!   exports onto it (`hap serve --metrics-out`,
//!   `ServeReport::telemetry`).
//!
//! The plan-decision audit record is [`PlanConsult`]: every
//! `SwitchController` consult in the adaptive loop captures the traffic
//! key, cached-vs-fresh candidate, predicted and measured s/token,
//! mispredict-EWMA factors, and the verdict with its breakeven
//! arithmetic. It is emitted both as a `PlanConsult` trace event by the
//! streaming engine and as JSONL by `hap adapt-replay --audit-out`.
//!
//! ## Trace schema (JSONL, one event per line)
//!
//! Envelope fields on every line:
//!
//! | field   | type | meaning                                          |
//! |---------|------|--------------------------------------------------|
//! | `seq`   | int  | per-run monotonic sequence number                |
//! | `iter`  | int  | engine scheduler iteration (step count)          |
//! | `op`    | int  | executor fault-clock op counter at emit time     |
//! | `event` | str  | event kind (one of the names below)              |
//!
//! Event kinds and payload fields (`*` marks wall-derived payloads that
//! [`canonical_stream`] strips before determinism comparison):
//!
//! | event            | emitted on                         | payload fields |
//! |------------------|------------------------------------|----------------|
//! | `Admit`          | request admitted into a slot/batch | `request`, `slot`, `prompt_tokens` |
//! | `PrefillChunk`   | one (chunked) prefill op           | `slot`, `start`, `len`, `done`, `secs`*, `modules`* |
//! | `DecodeStep`     | one decode iteration               | `decoding`, `capacity`, `secs`*, `modules`* |
//! | `PlanConsult`    | adaptive-loop consult              | `key`, `candidate`, `cached`, `active`, `evaluated`, `predicted_active_s`, `predicted_candidate_s`, `predicted_s_tok`, `measured_s_tok`*, `mispredict_active`*, `mispredict_candidate`*, `switch_cost_s`, `expected_dwell`, `decision`, `projected_savings_s`* |
//! | `Switch`         | plan switch scheduled/applied      | `from`, `to`, `mode` |
//! | `Reshard`        | resident weight layout changed     | `count`, `secs`* |
//! | `FaultDetected`  | classified device fault            | `device`, `kind`, `attempt` |
//! | `Retry`          | retryable fault backoff armed      | `attempt`, `backoff_iters` |
//! | `DegradedReplan` | degraded re-plan onto survivors    | `survivors`, `requeued` |
//! | `Retire`         | request completed                  | `request`, `slot`, `tokens`, `latency_s`*, `ttft_s`* |
//! | `Cancel`         | request cancelled                  | `request` |
//! | `BlockAlloc`     | paged-KV blocks allocated (delta)  | `blocks`, `in_use`, `free` |
//! | `BlockFree`      | paged-KV blocks released (delta)   | `blocks`, `in_use`, `free` |
//! | `PrefixHit`      | prompt matched a cached prefix     | `request`, `slot`, `shared_tokens`, `shared_blocks` |
//!
//! `modules` is a [`ModuleTimes`] object: `attn_s`, `expert_s`,
//! `collective_s`, `reshard_s`, `per_device_s` (all wall-derived).
//! `hap trace summarize` folds a trace into the per-module breakdown
//! via [`summarize_lines`].

pub mod registry;
pub mod trace;

pub use registry::{HistogramSnapshot, MetricValue, Registry};
pub use trace::{
    canonical_stream, events_to_jsonl, strip_wall_fields, summarize_lines, EventKind, ModuleTimes,
    PlanConsult, Recorder, TraceEvent, TraceSummary, WALL_FIELDS,
};
