//! Iteration-clock trace events, the deterministic [`Recorder`], and
//! trace folding (`hap trace summarize`).
//!
//! Ordering is by `(iter, seq)` — both deterministic counters. Wall
//! time only ever appears in payload fields named in [`WALL_FIELDS`];
//! [`canonical_stream`] strips those recursively so seeded runs can be
//! compared byte for byte. See the schema table in [`crate::obs`].

use crate::util::json::Json;
use crate::Result;

/// Payload field names that carry wall-clock-derived values. Everything
/// else in a trace line is a deterministic function of the seeded
/// workload, so stripping these yields the canonical comparable stream.
pub const WALL_FIELDS: &[&str] = &[
    "secs",
    "latency_s",
    "ttft_s",
    "attn_s",
    "expert_s",
    "collective_s",
    "reshard_s",
    "per_device_s",
    "measured_s_tok",
    "mispredict_active",
    "mispredict_candidate",
    "projected_savings_s",
];

/// Per-module executor time attribution (the paper's Fig. 2 axes):
/// seconds spent in attention / expert-FFN device compute, in the
/// coordinator-side collective combines, and in reshard
/// (slice + upload) work, plus cumulative in-closure seconds per
/// logical device from the `map_devices` fan-outs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModuleTimes {
    pub attn_s: f64,
    pub expert_s: f64,
    pub collective_s: f64,
    pub reshard_s: f64,
    /// Indexed by logical device id; survives grid shrinks (degraded
    /// re-plans) by keeping the widest extent seen.
    pub per_device_s: Vec<f64>,
}

impl ModuleTimes {
    /// Sum of the four module buckets.
    pub fn total(&self) -> f64 {
        self.attn_s + self.expert_s + self.collective_s + self.reshard_s
    }

    /// Add in-closure seconds for one device, growing the table.
    pub fn add_device(&mut self, device: usize, secs: f64) {
        if self.per_device_s.len() <= device {
            self.per_device_s.resize(device + 1, 0.0);
        }
        self.per_device_s[device] += secs;
    }

    /// Component-wise `self - earlier` (for per-op deltas against a
    /// snapshot of the executor's cumulative counters).
    pub fn delta_since(&self, earlier: &ModuleTimes) -> ModuleTimes {
        let mut per_device_s = self.per_device_s.clone();
        for (i, v) in earlier.per_device_s.iter().enumerate() {
            if i < per_device_s.len() {
                per_device_s[i] -= v;
            }
        }
        ModuleTimes {
            attn_s: self.attn_s - earlier.attn_s,
            expert_s: self.expert_s - earlier.expert_s,
            collective_s: self.collective_s - earlier.collective_s,
            reshard_s: self.reshard_s - earlier.reshard_s,
            per_device_s,
        }
    }

    /// Component-wise accumulate.
    pub fn accumulate(&mut self, delta: &ModuleTimes) {
        self.attn_s += delta.attn_s;
        self.expert_s += delta.expert_s;
        self.collective_s += delta.collective_s;
        self.reshard_s += delta.reshard_s;
        for (i, v) in delta.per_device_s.iter().enumerate() {
            self.add_device(i, *v);
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("attn_s", self.attn_s.into()),
            ("expert_s", self.expert_s.into()),
            ("collective_s", self.collective_s.into()),
            ("reshard_s", self.reshard_s.into()),
            ("per_device_s", self.per_device_s.clone().into()),
        ])
    }
}

/// One plan-decision audit record: everything the adaptive loop knew at
/// a `SwitchController` consult, so replay comparisons can explain a
/// switch/hold verdict instead of just scoring it. Predicted values
/// come from the deterministic simulator; `measured_s_tok` and the
/// mispredict factors are wall-derived (stripped for determinism
/// comparison).
#[derive(Debug, Clone, PartialEq)]
pub struct PlanConsult {
    /// Quantized traffic key, e.g. `ctx256/gen16/b8`.
    pub key: String,
    /// Candidate plan signature for the key.
    pub candidate: String,
    /// Whether the candidate came from the plan cache (vs a fresh solve).
    pub cached: bool,
    /// Active plan signature at consult time (`None` on cold start).
    pub active: Option<String>,
    /// Whether switch economics were evaluated this consult (the
    /// controller debounces/cools down without pricing a switch).
    pub evaluated: bool,
    /// Predicted whole-scenario latency of the active plan (seconds;
    /// non-finite on cold start serializes as null).
    pub predicted_active_s: f64,
    /// Predicted whole-scenario latency of the candidate plan.
    pub predicted_candidate_s: f64,
    /// Candidate predicted seconds per generated token.
    pub predicted_s_tok: f64,
    /// Measured seconds per token from the live dwell window, if fed
    /// back this consult.
    pub measured_s_tok: Option<f64>,
    /// Mispredict-EWMA factors for the active / candidate signatures.
    pub mispredict_active: Option<f64>,
    pub mispredict_candidate: Option<f64>,
    /// Predicted cost of switching active → candidate (seconds).
    pub switch_cost_s: f64,
    /// Controller's expected dwell (batches) used in the breakeven.
    pub expected_dwell: f64,
    /// Verdict label: `adopt`, `stay`, or `switch`.
    pub decision: String,
    /// For a `switch` verdict: projected savings over the expected
    /// dwell that beat `breakeven_factor × cost`.
    pub projected_savings_s: Option<f64>,
}

fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(v) => num_or_null(v),
        None => Json::Null,
    }
}

impl PlanConsult {
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.json_fields()
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    fn json_fields(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("key", self.key.as_str().into()),
            ("candidate", self.candidate.as_str().into()),
            ("cached", self.cached.into()),
            (
                "active",
                match &self.active {
                    Some(s) => s.as_str().into(),
                    None => Json::Null,
                },
            ),
            ("evaluated", self.evaluated.into()),
            ("predicted_active_s", num_or_null(self.predicted_active_s)),
            ("predicted_candidate_s", num_or_null(self.predicted_candidate_s)),
            ("predicted_s_tok", num_or_null(self.predicted_s_tok)),
            ("measured_s_tok", opt_num(self.measured_s_tok)),
            ("mispredict_active", opt_num(self.mispredict_active)),
            ("mispredict_candidate", opt_num(self.mispredict_candidate)),
            ("switch_cost_s", num_or_null(self.switch_cost_s)),
            ("expected_dwell", num_or_null(self.expected_dwell)),
            ("decision", self.decision.as_str().into()),
            ("projected_savings_s", opt_num(self.projected_savings_s)),
        ]
    }
}

/// Typed trace event payloads. See the schema table in [`crate::obs`].
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    Admit {
        request: u64,
        slot: usize,
        prompt_tokens: usize,
    },
    PrefillChunk {
        slot: usize,
        start: usize,
        len: usize,
        done: bool,
        secs: f64,
        modules: ModuleTimes,
    },
    DecodeStep {
        decoding: usize,
        capacity: usize,
        secs: f64,
        modules: ModuleTimes,
    },
    PlanConsult(PlanConsult),
    Switch {
        from: String,
        to: String,
        /// How the switch lands: `expert-reshard` (in-flight),
        /// `drain-scheduled`, `drain-applied`, `session-restart`,
        /// `forced`, or `gang`.
        mode: &'static str,
    },
    Reshard {
        count: usize,
        secs: f64,
    },
    FaultDetected {
        device: usize,
        kind: String,
        attempt: usize,
    },
    Retry {
        attempt: usize,
        backoff_iters: usize,
    },
    DegradedReplan {
        survivors: usize,
        requeued: usize,
    },
    Retire {
        request: u64,
        slot: usize,
        tokens: usize,
        latency_s: f64,
        ttft_s: f64,
    },
    Cancel {
        request: u64,
    },
    /// Paged KV: blocks allocated from the pool this scheduler
    /// iteration (delta), with the pool gauges after the step.
    BlockAlloc {
        blocks: usize,
        in_use: usize,
        free: usize,
    },
    /// Paged KV: blocks released to the pool this scheduler iteration
    /// (delta), with the pool gauges after the step.
    BlockFree {
        blocks: usize,
        in_use: usize,
        free: usize,
    },
    /// Paged KV: an admitted prompt matched a trie-cached prefix —
    /// `shared_tokens` of prefill skipped, `shared_blocks` attached
    /// copy-on-write.
    PrefixHit {
        request: u64,
        slot: usize,
        shared_tokens: usize,
        shared_blocks: usize,
    },
}

/// Canonical kind names, in schema order.
pub const KIND_NAMES: &[&str] = &[
    "Admit",
    "PrefillChunk",
    "DecodeStep",
    "PlanConsult",
    "Switch",
    "Reshard",
    "FaultDetected",
    "Retry",
    "DegradedReplan",
    "Retire",
    "Cancel",
    "BlockAlloc",
    "BlockFree",
    "PrefixHit",
];

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admit { .. } => "Admit",
            EventKind::PrefillChunk { .. } => "PrefillChunk",
            EventKind::DecodeStep { .. } => "DecodeStep",
            EventKind::PlanConsult(_) => "PlanConsult",
            EventKind::Switch { .. } => "Switch",
            EventKind::Reshard { .. } => "Reshard",
            EventKind::FaultDetected { .. } => "FaultDetected",
            EventKind::Retry { .. } => "Retry",
            EventKind::DegradedReplan { .. } => "DegradedReplan",
            EventKind::Retire { .. } => "Retire",
            EventKind::Cancel { .. } => "Cancel",
            EventKind::BlockAlloc { .. } => "BlockAlloc",
            EventKind::BlockFree { .. } => "BlockFree",
            EventKind::PrefixHit { .. } => "PrefixHit",
        }
    }

    fn json_fields(&self) -> Vec<(&'static str, Json)> {
        match self {
            EventKind::Admit { request, slot, prompt_tokens } => vec![
                ("request", (*request as f64).into()),
                ("slot", (*slot).into()),
                ("prompt_tokens", (*prompt_tokens).into()),
            ],
            EventKind::PrefillChunk { slot, start, len, done, secs, modules } => vec![
                ("slot", (*slot).into()),
                ("start", (*start).into()),
                ("len", (*len).into()),
                ("done", (*done).into()),
                ("secs", (*secs).into()),
                ("modules", modules.to_json()),
            ],
            EventKind::DecodeStep { decoding, capacity, secs, modules } => vec![
                ("decoding", (*decoding).into()),
                ("capacity", (*capacity).into()),
                ("secs", (*secs).into()),
                ("modules", modules.to_json()),
            ],
            EventKind::PlanConsult(c) => c.json_fields(),
            EventKind::Switch { from, to, mode } => vec![
                ("from", from.as_str().into()),
                ("to", to.as_str().into()),
                ("mode", (*mode).into()),
            ],
            EventKind::Reshard { count, secs } => {
                vec![("count", (*count).into()), ("secs", (*secs).into())]
            }
            EventKind::FaultDetected { device, kind, attempt } => vec![
                ("device", (*device).into()),
                ("kind", kind.as_str().into()),
                ("attempt", (*attempt).into()),
            ],
            EventKind::Retry { attempt, backoff_iters } => vec![
                ("attempt", (*attempt).into()),
                ("backoff_iters", (*backoff_iters).into()),
            ],
            EventKind::DegradedReplan { survivors, requeued } => vec![
                ("survivors", (*survivors).into()),
                ("requeued", (*requeued).into()),
            ],
            EventKind::Retire { request, slot, tokens, latency_s, ttft_s } => vec![
                ("request", (*request as f64).into()),
                ("slot", (*slot).into()),
                ("tokens", (*tokens).into()),
                ("latency_s", (*latency_s).into()),
                ("ttft_s", (*ttft_s).into()),
            ],
            EventKind::Cancel { request } => vec![("request", (*request as f64).into())],
            EventKind::BlockAlloc { blocks, in_use, free } => vec![
                ("blocks", (*blocks).into()),
                ("in_use", (*in_use).into()),
                ("free", (*free).into()),
            ],
            EventKind::BlockFree { blocks, in_use, free } => vec![
                ("blocks", (*blocks).into()),
                ("in_use", (*in_use).into()),
                ("free", (*free).into()),
            ],
            EventKind::PrefixHit { request, slot, shared_tokens, shared_blocks } => vec![
                ("request", (*request as f64).into()),
                ("slot", (*slot).into()),
                ("shared_tokens", (*shared_tokens).into()),
                ("shared_blocks", (*shared_blocks).into()),
            ],
        }
    }
}

/// One trace line: deterministic envelope + typed payload.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Engine scheduler iteration (step count) at emit time.
    pub iter: u64,
    /// Executor fault-clock op counter at emit time.
    pub op: u64,
    /// Per-run monotonic sequence number (ties within an iteration).
    pub seq: u64,
    pub kind: EventKind,
}

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("seq", (self.seq as f64).into()),
            ("iter", (self.iter as f64).into()),
            ("op", (self.op as f64).into()),
            ("event", self.kind.name().into()),
        ];
        fields.extend(self.kind.json_fields());
        Json::obj(fields)
    }
}

/// Collects [`TraceEvent`]s for one serving run. `disabled()` is the
/// zero-cost default: `record` drops the event without allocating, so
/// uninstrumented serving pays one branch per hook.
#[derive(Debug, Default)]
pub struct Recorder {
    enabled: bool,
    seq: u64,
    events: Vec<TraceEvent>,
}

impl Recorder {
    /// An enabled recorder.
    pub fn new() -> Recorder {
        Recorder { enabled: true, seq: 0, events: Vec::new() }
    }

    /// The no-op recorder (default for uninstrumented serving).
    pub fn disabled() -> Recorder {
        Recorder::default()
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event at `(iter, op)` on the iteration clock.
    pub fn record(&mut self, iter: u64, op: u64, kind: EventKind) {
        if !self.enabled {
            return;
        }
        let seq = self.seq;
        self.seq += 1;
        self.events.push(TraceEvent { iter, op, seq, kind });
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Drain the collected events (recorder stays enabled).
    pub fn take_events(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.events)
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Serialize events as JSONL (one compact object per line, trailing
/// newline when non-empty).
pub fn events_to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.to_json().to_string_compact());
        out.push('\n');
    }
    out
}

/// Recursively remove every [`WALL_FIELDS`] key from a JSON value.
pub fn strip_wall_fields(v: &Json) -> Json {
    match v {
        Json::Obj(fields) => Json::Obj(
            fields
                .iter()
                .filter(|(k, _)| !WALL_FIELDS.contains(&k.as_str()))
                .map(|(k, val)| (k.clone(), strip_wall_fields(val)))
                .collect(),
        ),
        Json::Arr(items) => Json::Arr(items.iter().map(strip_wall_fields).collect()),
        other => other.clone(),
    }
}

/// Fold a JSONL trace into its canonical comparable form: parse each
/// line, strip the wall-derived payload fields, re-serialize compactly.
/// Two seeded runs of the same workload must agree byte for byte here.
pub fn canonical_stream(jsonl: &str) -> Result<String> {
    let mut out = String::new();
    for (i, line) in jsonl.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
        out.push_str(&strip_wall_fields(&v).to_string_compact());
        out.push('\n');
    }
    Ok(out)
}

/// A folded trace: per-kind event counts plus the measured per-module
/// time breakdown (the Fig. 2 view of a run).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSummary {
    /// `(kind, count)` in schema order, all kinds present.
    pub counts: Vec<(String, usize)>,
    /// Highest scheduler iteration seen.
    pub iterations: u64,
    /// Module times summed over `DecodeStep`/`PrefillChunk` payloads,
    /// plus `Reshard` seconds.
    pub modules: ModuleTimes,
    /// Total instrumented op seconds (decode + prefill `secs`).
    pub span_secs: f64,
}

impl TraceSummary {
    pub fn count(&self, kind: &str) -> usize {
        self.counts.iter().find(|(k, _)| k == kind).map(|(_, c)| *c).unwrap_or(0)
    }

    /// `(module, share)` rows over the four module buckets (empty total
    /// yields zero shares).
    pub fn shares(&self) -> [(&'static str, f64); 4] {
        let total = self.modules.total();
        let frac = |x: f64| if total > 0.0 { x / total } else { 0.0 };
        [
            ("attention", frac(self.modules.attn_s)),
            ("expert_ffn", frac(self.modules.expert_s)),
            ("collective", frac(self.modules.collective_s)),
            ("reshard", frac(self.modules.reshard_s)),
        ]
    }

    pub fn to_json(&self) -> Json {
        let counts = Json::Obj(
            self.counts.iter().map(|(k, c)| (k.clone(), Json::from(*c))).collect(),
        );
        let shares = Json::Obj(
            self.shares().iter().map(|(k, s)| (k.to_string(), Json::Num(*s))).collect(),
        );
        Json::obj(vec![
            ("kind", "hap-trace-summary".into()),
            ("iterations", (self.iterations as f64).into()),
            ("events", counts),
            ("modules", self.modules.to_json()),
            ("module_shares", shares),
            ("span_secs", self.span_secs.into()),
        ])
    }

    /// Human-readable rendering for `hap trace summarize`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("iterations: {}\n", self.iterations));
        out.push_str("events:\n");
        for (k, c) in &self.counts {
            if *c > 0 {
                out.push_str(&format!("  {k:<16} {c}\n"));
            }
        }
        out.push_str("module breakdown (measured):\n");
        let m = &self.modules;
        for ((label, share), secs) in self
            .shares()
            .iter()
            .zip([m.attn_s, m.expert_s, m.collective_s, m.reshard_s])
        {
            out.push_str(&format!(
                "  {label:<12} {:>10.3} ms  {:>5.1}%\n",
                secs * 1e3,
                share * 100.0
            ));
        }
        out.push_str(&format!("  total        {:>10.3} ms\n", m.total() * 1e3));
        out
    }
}

/// Fold parsed trace lines into a [`TraceSummary`]. Works on any JSONL
/// produced by [`events_to_jsonl`] (including wall-stripped streams —
/// missing module payloads just contribute zero).
pub fn summarize_lines(lines: &[Json]) -> TraceSummary {
    let mut sum = TraceSummary {
        counts: KIND_NAMES.iter().map(|k| (k.to_string(), 0)).collect(),
        ..TraceSummary::default()
    };
    let f = |v: Option<&Json>| v.and_then(Json::as_f64).unwrap_or(0.0);
    for line in lines {
        let name = line.get("event").and_then(Json::as_str).unwrap_or("");
        if let Some(entry) = sum.counts.iter_mut().find(|(k, _)| k == name) {
            entry.1 += 1;
        }
        sum.iterations = sum.iterations.max(f(line.get("iter")) as u64);
        match name {
            "DecodeStep" | "PrefillChunk" => {
                sum.span_secs += f(line.get("secs"));
                if let Some(m) = line.get("modules") {
                    sum.modules.attn_s += f(m.get("attn_s"));
                    sum.modules.expert_s += f(m.get("expert_s"));
                    sum.modules.collective_s += f(m.get("collective_s"));
                    sum.modules.reshard_s += f(m.get("reshard_s"));
                    for (d, v) in
                        m.get("per_device_s").and_then(Json::as_arr).unwrap_or(&[]).iter().enumerate()
                    {
                        sum.modules.add_device(d, v.as_f64().unwrap_or(0.0));
                    }
                }
            }
            "Reshard" => sum.modules.reshard_s += f(line.get("secs")),
            _ => {}
        }
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_events() -> Vec<TraceEvent> {
        let mut r = Recorder::new();
        r.record(0, 1, EventKind::Admit { request: 1, slot: 0, prompt_tokens: 8 });
        r.record(
            0,
            1,
            EventKind::PrefillChunk {
                slot: 0,
                start: 0,
                len: 8,
                done: true,
                secs: 0.25,
                modules: ModuleTimes {
                    attn_s: 0.1,
                    expert_s: 0.1,
                    collective_s: 0.05,
                    reshard_s: 0.0,
                    per_device_s: vec![0.1, 0.1],
                },
            },
        );
        r.record(
            1,
            2,
            EventKind::DecodeStep {
                decoding: 1,
                capacity: 4,
                secs: 0.5,
                modules: ModuleTimes {
                    attn_s: 0.2,
                    expert_s: 0.2,
                    collective_s: 0.1,
                    reshard_s: 0.0,
                    per_device_s: vec![0.2, 0.2],
                },
            },
        );
        r.record(2, 3, EventKind::Reshard { count: 1, secs: 0.05 });
        r.record(
            3,
            4,
            EventKind::Retire { request: 1, slot: 0, tokens: 4, latency_s: 1.0, ttft_s: 0.3 },
        );
        r.take_events()
    }

    #[test]
    fn disabled_recorder_drops_events() {
        let mut r = Recorder::disabled();
        r.record(0, 0, EventKind::Cancel { request: 7 });
        assert!(r.is_empty());
        assert!(!r.is_enabled());
    }

    #[test]
    fn jsonl_lines_parse_and_envelope_is_ordered() {
        let text = events_to_jsonl(&demo_events());
        let mut prev_seq = -1i64;
        for line in text.lines() {
            let v = Json::parse(line).unwrap();
            let seq = v.get("seq").unwrap().as_f64().unwrap() as i64;
            assert!(seq > prev_seq, "seq must be strictly increasing");
            prev_seq = seq;
            assert!(v.get("event").and_then(Json::as_str).is_some());
        }
        assert_eq!(prev_seq, 4);
    }

    #[test]
    fn canonical_stream_is_wall_invariant() {
        // Two "runs" identical except for every wall payload.
        let mut a = demo_events();
        let b = demo_events();
        for e in &mut a {
            match &mut e.kind {
                EventKind::PrefillChunk { secs, modules, .. }
                | EventKind::DecodeStep { secs, modules, .. } => {
                    *secs *= 3.0;
                    modules.attn_s *= 2.0;
                    modules.per_device_s = vec![9.0];
                }
                EventKind::Reshard { secs, .. } => *secs += 1.0,
                EventKind::Retire { latency_s, ttft_s, .. } => {
                    *latency_s += 5.0;
                    *ttft_s += 5.0;
                }
                _ => {}
            }
        }
        let ca = canonical_stream(&events_to_jsonl(&a)).unwrap();
        let cb = canonical_stream(&events_to_jsonl(&b)).unwrap();
        assert_eq!(ca, cb, "wall fields must not leak into the canonical stream");
        assert!(!ca.contains("secs"), "stripped field name must be gone");
        // Deterministic payloads DO distinguish streams.
        let mut c = demo_events();
        if let EventKind::Admit { prompt_tokens, .. } = &mut c[0].kind {
            *prompt_tokens = 99;
        }
        let cc = canonical_stream(&events_to_jsonl(&c)).unwrap();
        assert_ne!(ca, cc);
    }

    #[test]
    fn consult_serializes_non_finite_as_null() {
        let c = PlanConsult {
            key: "ctx256/gen16/b8".into(),
            candidate: "EP2TP2".into(),
            cached: false,
            active: None,
            evaluated: false,
            predicted_active_s: f64::INFINITY,
            predicted_candidate_s: 0.5,
            predicted_s_tok: 0.01,
            measured_s_tok: None,
            mispredict_active: None,
            mispredict_candidate: Some(1.5),
            switch_cost_s: 0.0,
            expected_dwell: 32.0,
            decision: "adopt".into(),
            projected_savings_s: None,
        };
        let line = TraceEvent { iter: 0, op: 0, seq: 0, kind: EventKind::PlanConsult(c) }
            .to_json()
            .to_string_compact();
        let v = Json::parse(&line).expect("infinite predicted must serialize as null");
        assert_eq!(v.get("predicted_active_s"), Some(&Json::Null));
        assert_eq!(v.get("decision").and_then(Json::as_str), Some("adopt"));
    }

    #[test]
    fn summary_folds_counts_and_modules() {
        let text = events_to_jsonl(&demo_events());
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        let s = summarize_lines(&lines);
        assert_eq!(s.count("Admit"), 1);
        assert_eq!(s.count("DecodeStep"), 1);
        assert_eq!(s.count("Retire"), 1);
        assert_eq!(s.count("Cancel"), 0);
        assert_eq!(s.iterations, 3);
        assert!((s.modules.attn_s - 0.3).abs() < 1e-12);
        assert!((s.modules.reshard_s - 0.05).abs() < 1e-12);
        assert!((s.span_secs - 0.75).abs() < 1e-12);
        let shares = s.shares();
        let total: f64 = shares.iter().map(|(_, v)| v).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!(s.render().contains("module breakdown"));
        // Summary JSON round-trips through the parser.
        assert!(Json::parse(&s.to_json().to_string_pretty()).is_ok());
    }
}
