//! Node topology: devices and links.
//!
//! NVLink nodes are modeled as a full mesh (NVSwitch); PCIe nodes as a
//! star through the host bridge, where concurrent peer flows share the
//! per-device link and the collective pattern penalty captures bridge
//! contention (see [`crate::sim::microbench`]).

use crate::config::hardware::{GpuSpec, Interconnect, NodeConfig};

/// A device in the simulated node.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub spec: GpuSpec,
}

/// The node topology.
#[derive(Debug, Clone)]
pub struct Topology {
    pub devices: Vec<Device>,
    pub interconnect: Interconnect,
}

impl Topology {
    pub fn from_node(node: &NodeConfig) -> Topology {
        Topology {
            devices: (0..node.num_devices)
                .map(|id| Device { id, spec: node.gpu.clone() })
                .collect(),
            interconnect: node.gpu.interconnect,
        }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Point-to-point bandwidth between two distinct devices (bytes/s).
    pub fn p2p_bw(&self, a: usize, b: usize) -> f64 {
        assert_ne!(a, b);
        self.devices[a].spec.link_bw
    }

    /// Device groups for a strategy axis: `n` devices split into
    /// `groups` contiguous groups (TP groups innermost, standard
    /// Megatron layout).
    pub fn contiguous_groups(&self, groups: usize) -> Vec<Vec<usize>> {
        let n = self.len();
        assert_eq!(n % groups, 0);
        let per = n / groups;
        (0..groups)
            .map(|g| (g * per..(g + 1) * per).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NodeConfig;

    #[test]
    fn builds_from_node() {
        let t = Topology::from_node(&NodeConfig::a100x(8));
        assert_eq!(t.len(), 8);
        assert_eq!(t.interconnect, Interconnect::NvLink);
        assert_eq!(t.devices[5].id, 5);
    }

    #[test]
    fn groups_partition_devices() {
        let t = Topology::from_node(&NodeConfig::a6000x(4));
        let g = t.contiguous_groups(2);
        assert_eq!(g, vec![vec![0, 1], vec![2, 3]]);
        let all: Vec<usize> = g.into_iter().flatten().collect();
        assert_eq!(all, vec![0, 1, 2, 3]);
    }
}
