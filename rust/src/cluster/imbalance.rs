//! Expert-parallel load-imbalance model.
//!
//! Under EP, tokens route to the devices owning their top-k experts.
//! Expert popularity is not uniform (hot experts exist), and with few
//! tokens (the decode stage) the multinomial sampling noise is large —
//! the hottest device gets far more than the mean. The paper observes
//! exactly this: "the load imbalance introduced by EP leads to
//! inefficient computation of the Expert module" during decoding.
//!
//! `expected_imbalance` returns E[max_device_load / mean_device_load]
//! for routing `tokens × top_k` assignments over `ep` device groups,
//! combining a Zipf-skewed expert-popularity prior with an analytic
//! extreme-value approximation of the multinomial maximum; it is
//! validated against Monte Carlo in the tests.

use crate::util::rng::Rng;

/// Zipf-like expert popularity skew exponent. 0 = uniform. Empirically
/// MoE routers exhibit mild skew; 0.2 keeps prefill near-balanced while
/// reproducing the decode-stage EP penalty the paper measures.
pub const DEFAULT_SKEW: f64 = 0.2;

/// Per-expert routing probabilities under a Zipf(`skew`) prior.
pub fn expert_probs(num_experts: usize, skew: f64) -> Vec<f64> {
    let mut p: Vec<f64> = (1..=num_experts).map(|r| (r as f64).powf(-skew)).collect();
    let z: f64 = p.iter().sum();
    for x in &mut p {
        *x /= z;
    }
    p
}

/// Device-group probabilities: experts are assigned to `ep` groups
/// round-robin by popularity rank (the standard contiguity-free
/// placement that spreads hot experts).
pub fn group_probs(num_experts: usize, ep: usize, skew: f64) -> Vec<f64> {
    let p = expert_probs(num_experts, skew);
    let mut g = vec![0.0; ep];
    for (i, pi) in p.iter().enumerate() {
        g[i % ep] += pi;
    }
    g
}

/// Expected ratio of the hottest device's routed-token count to the
/// balanced share, for `assignments = tokens × top_k` total routings.
///
/// Uses a Gaussian extreme-value approximation: for group probability
/// `p_i` and `n` assignments, load_i ≈ Normal(n·p_i, n·p_i(1-p_i));
/// E[max_i load_i] ≈ max_i(n·p_i) + σ_max · √(2 ln ep).
pub fn expected_imbalance(num_experts: usize, ep: usize, tokens: usize, top_k: usize, skew: f64) -> f64 {
    if ep <= 1 || tokens == 0 {
        return 1.0;
    }
    let n = (tokens * top_k) as f64;
    let g = group_probs(num_experts, ep, skew);
    let mean_share = n / ep as f64;
    let max_mean = g.iter().cloned().fold(0.0, f64::max) * n;
    let sigma = g
        .iter()
        .map(|&p| (n * p * (1.0 - p)).sqrt())
        .fold(0.0, f64::max);
    let ev = max_mean + sigma * (2.0 * (ep as f64).ln()).sqrt();
    // Max load can't drop below the balanced share.
    (ev / mean_share).max(1.0)
}

/// Monte Carlo estimate of the same quantity (used for validation and
/// by the discrete-event engine when it wants sampled, not expected,
/// loads).
pub fn sampled_imbalance(
    num_experts: usize,
    ep: usize,
    tokens: usize,
    top_k: usize,
    skew: f64,
    rng: &mut Rng,
) -> f64 {
    if ep <= 1 || tokens == 0 {
        return 1.0;
    }
    let p = expert_probs(num_experts, skew);
    let mut loads = vec![0usize; ep];
    for _ in 0..tokens {
        // Draw top_k distinct experts per token (without replacement).
        let mut chosen: Vec<usize> = Vec::with_capacity(top_k);
        while chosen.len() < top_k {
            let e = rng.weighted(&p);
            if !chosen.contains(&e) {
                chosen.push(e);
            }
        }
        for e in chosen {
            loads[e % ep] += 1;
        }
    }
    let max = *loads.iter().max().unwrap() as f64;
    let mean = (tokens * top_k) as f64 / ep as f64;
    (max / mean).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_skew_zero() {
        let p = expert_probs(8, 0.0);
        for x in &p {
            assert!((x - 0.125).abs() < 1e-12);
        }
    }

    #[test]
    fn probs_sum_to_one() {
        for e in [8, 60, 64] {
            let s: f64 = expert_probs(e, DEFAULT_SKEW).iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn decode_imbalance_exceeds_prefill() {
        // Few tokens (decode) → high variance → worse imbalance than
        // many tokens (prefill). This is the paper's Fig 2 decode story.
        let dec = expected_imbalance(8, 4, 16, 2, DEFAULT_SKEW);
        let pre = expected_imbalance(8, 4, 16 * 2048, 2, DEFAULT_SKEW);
        assert!(dec > pre + 0.2, "decode {dec} vs prefill {pre}");
        assert!(pre < 1.15, "prefill {pre}");
        assert!(dec > 1.3, "decode {dec}");
    }

    #[test]
    fn single_group_is_balanced() {
        assert_eq!(expected_imbalance(8, 1, 100, 2, DEFAULT_SKEW), 1.0);
    }

    #[test]
    fn analytic_close_to_monte_carlo() {
        let mut rng = Rng::new(99);
        let trials = 300;
        let mc: f64 = (0..trials)
            .map(|_| sampled_imbalance(8, 4, 64, 2, DEFAULT_SKEW, &mut rng))
            .sum::<f64>()
            / trials as f64;
        let analytic = expected_imbalance(8, 4, 64, 2, DEFAULT_SKEW);
        let rel = (mc - analytic).abs() / mc;
        assert!(rel < 0.25, "mc {mc} vs analytic {analytic}");
    }

    #[test]
    fn more_groups_more_imbalance() {
        let e2 = expected_imbalance(64, 2, 128, 8, DEFAULT_SKEW);
        let e8 = expected_imbalance(64, 8, 128, 8, DEFAULT_SKEW);
        assert!(e8 > e2);
    }
}
