//! Simulated multi-GPU cluster substrate.
//!
//! The paper's testbed is a single node with 4–8 GPUs connected by
//! NVLink or PCIe. This module provides the substitute substrate
//! (DESIGN.md §2): device/link topology ([`topology`]), a discrete-event
//! execution timeline ([`event`]), collective schedules over real link
//! models ([`collective`]), and the expert load-imbalance model
//! ([`imbalance`]) that makes EP decode slower than TP decode (paper
//! Fig 2).

pub mod collective;
pub mod event;
pub mod imbalance;
pub mod topology;

pub use event::{EventSim, OpKind};
pub use topology::Topology;
