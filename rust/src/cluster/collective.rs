//! Collective schedules over the modeled topology.
//!
//! Turns abstract [`CommEvent`]s into group timings using the noise-free
//! ground-truth link model, including *imbalanced* All-to-All where
//! per-device send volumes differ (EP dispatch under skewed routing):
//! the op completes when the busiest link drains.

use crate::cluster::topology::Topology;
#[cfg(test)]
use crate::sim::comm::Collective;
use crate::sim::comm::CommEvent;

use crate::sim::microbench;

/// Ground-truth time of a (possibly imbalanced) collective on the
/// topology. `per_device_wire` overrides the event's uniform volume
/// when provided (one entry per group member).
pub fn collective_time(
    topo: &Topology,
    event: &CommEvent,
    per_device_wire: Option<&[f64]>,
) -> f64 {
    let gpu = &topo.devices[0].spec;
    match per_device_wire {
        None => microbench::true_comm_time(gpu, event),
        Some(wires) => {
            assert_eq!(wires.len(), event.group);
            // The collective drains when the hottest device's traffic
            // is done; keep the event's rounds for the latency floor.
            let max_wire = wires.iter().cloned().fold(0.0, f64::max);
            let ev = CommEvent { wire_bytes: max_wire, ..event.clone() };
            microbench::true_comm_time(gpu, &ev)
        }
    }
}

/// Per-device All-to-All send volumes for EP dispatch given per-group
/// routed token counts. `token_bytes` is bytes per routed token copy.
pub fn ep_dispatch_wires(group_loads: &[f64], total_tokens: f64, token_bytes: f64) -> Vec<f64> {
    let g = group_loads.len() as f64;
    // Each device owns total/g tokens and sends the fraction routed to
    // other groups; receiving-side hotness shows up via the load vector.
    group_loads
        .iter()
        .map(|&recv_load| {
            let send = total_tokens / g * (g - 1.0) / g;
            // The hot receiver's link also carries its inbound surplus.
            let recv = recv_load - total_tokens / g / g;
            (send.max(recv.max(0.0))) * token_bytes
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NodeConfig;
    use crate::sim::comm::CommEvent;

    fn event(wire: f64, group: usize) -> CommEvent {
        CommEvent {
            collective: Collective::AllToAll,
            group,
            wire_bytes: wire,
            rounds: group - 1,
            label: "t",
        }
    }

    #[test]
    fn balanced_matches_uniform() {
        let topo = Topology::from_node(&NodeConfig::a6000x(4));
        let ev = event(1e8, 4);
        let uniform = collective_time(&topo, &ev, None);
        let balanced = collective_time(&topo, &ev, Some(&[1e8, 1e8, 1e8, 1e8]));
        assert!((uniform - balanced).abs() / uniform < 1e-9);
    }

    #[test]
    fn hot_device_slows_collective() {
        let topo = Topology::from_node(&NodeConfig::a6000x(4));
        let ev = event(1e8, 4);
        let balanced = collective_time(&topo, &ev, Some(&[1e8; 4]));
        let skewed = collective_time(&topo, &ev, Some(&[1e8, 1e8, 1e8, 3e8]));
        assert!(skewed > balanced * 1.5);
    }

    #[test]
    fn dispatch_wires_reflect_hot_group() {
        let total = 4000.0;
        let loads = vec![1000.0, 1000.0, 1000.0, 1000.0];
        let w = ep_dispatch_wires(&loads, total, 2.0);
        // Balanced: send side dominates: 1000·(3/4)·2B = 1500B.
        for &x in &w {
            assert!((x - 1500.0).abs() < 1e-9, "{w:?}");
        }
        let hot = vec![400.0, 400.0, 400.0, 2800.0];
        let wh = ep_dispatch_wires(&hot, total, 2.0);
        assert!(wh[3] > wh[0], "{wh:?}");
    }
}
