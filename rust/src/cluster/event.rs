//! Discrete-event execution timeline.
//!
//! Tracks a clock per device; the engine issues per-device compute
//! spans and group-synchronous collectives. Collectives act as
//! barriers within their group: they start when the last participant
//! arrives and all participants leave together. Time per op category
//! is accumulated for breakdown reports (paper Fig 2).

use std::collections::HashMap;

/// Category of a simulated span (for breakdowns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Attention,
    Expert,
    Comm,
    Transition,
    Other,
}

/// One recorded span (device, category, start, duration).
#[derive(Debug, Clone)]
pub struct Span {
    pub device: usize,
    pub kind: OpKind,
    pub start: f64,
    pub dur: f64,
    pub label: &'static str,
}

/// Discrete-event simulator over `n` device timelines.
#[derive(Debug, Clone)]
pub struct EventSim {
    clocks: Vec<f64>,
    spans: Vec<Span>,
    /// Wall-clock time spent per category (max over devices per phase,
    /// accumulated — i.e. critical-path attribution).
    critical: HashMap<OpKind, f64>,
}

impl EventSim {
    pub fn new(n: usize) -> EventSim {
        EventSim { clocks: vec![0.0; n], spans: Vec::new(), critical: HashMap::new() }
    }

    pub fn num_devices(&self) -> usize {
        self.clocks.len()
    }

    /// Issue one compute span on a single device.
    pub fn compute(&mut self, device: usize, kind: OpKind, dur: f64, label: &'static str) {
        let start = self.clocks[device];
        self.clocks[device] += dur;
        self.spans.push(Span { device, kind, start, dur, label });
    }

    /// Issue per-device compute durations as one parallel phase and
    /// attribute the phase's critical path (max duration after sync
    /// skew) to `kind`.
    pub fn parallel_compute(&mut self, durs: &[(usize, f64)], kind: OpKind, label: &'static str) {
        let before = durs
            .iter()
            .map(|&(d, _)| self.clocks[d])
            .fold(0.0f64, f64::max);
        for &(device, dur) in durs {
            self.compute(device, kind, dur, label);
        }
        let after = durs
            .iter()
            .map(|&(d, _)| self.clocks[d])
            .fold(0.0f64, f64::max);
        *self.critical.entry(kind).or_insert(0.0) += after - before;
    }

    /// Group-synchronous collective: all `group` devices sync, then
    /// advance together by `dur`.
    pub fn collective(&mut self, group: &[usize], dur: f64, label: &'static str) {
        let start = group.iter().map(|&d| self.clocks[d]).fold(0.0f64, f64::max);
        for &d in group {
            self.spans.push(Span { device: d, kind: OpKind::Comm, start, dur, label });
            self.clocks[d] = start + dur;
        }
        *self.critical.entry(OpKind::Comm).or_insert(0.0) += dur;
    }

    /// Global barrier: align all clocks to the max.
    pub fn barrier(&mut self) {
        let t = self.now();
        for c in &mut self.clocks {
            *c = t;
        }
    }

    /// Charge a transition overhead on all devices (post-barrier).
    pub fn transition(&mut self, dur: f64, label: &'static str) {
        self.barrier();
        let start = self.now();
        for d in 0..self.clocks.len() {
            self.spans.push(Span { device: d, kind: OpKind::Transition, start, dur, label });
            self.clocks[d] = start + dur;
        }
        *self.critical.entry(OpKind::Transition).or_insert(0.0) += dur;
    }

    /// Current makespan (max device clock).
    pub fn now(&self) -> f64 {
        self.clocks.iter().cloned().fold(0.0, f64::max)
    }

    /// Critical-path time attributed to a category.
    pub fn critical_time(&self, kind: OpKind) -> f64 {
        *self.critical.get(&kind).unwrap_or(&0.0)
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Busy fraction of a device (busy time / makespan).
    pub fn utilization(&self, device: usize) -> f64 {
        let busy: f64 = self
            .spans
            .iter()
            .filter(|s| s.device == device && s.kind != OpKind::Comm)
            .map(|s| s.dur)
            .sum();
        let total = self.now();
        if total == 0.0 {
            0.0
        } else {
            busy / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_compute_advances_clock() {
        let mut sim = EventSim::new(2);
        sim.compute(0, OpKind::Attention, 1.0, "a");
        sim.compute(0, OpKind::Expert, 2.0, "e");
        assert_eq!(sim.now(), 3.0);
    }

    #[test]
    fn collective_waits_for_stragglers() {
        let mut sim = EventSim::new(2);
        sim.compute(0, OpKind::Attention, 1.0, "a");
        sim.compute(1, OpKind::Attention, 5.0, "a");
        sim.collective(&[0, 1], 1.0, "ar");
        assert_eq!(sim.now(), 6.0);
        // Device 0 idled 4 s waiting.
        assert!(sim.utilization(0) < sim.utilization(1));
    }

    #[test]
    fn parallel_compute_critical_path() {
        let mut sim = EventSim::new(4);
        sim.parallel_compute(&[(0, 1.0), (1, 3.0), (2, 2.0), (3, 1.5)], OpKind::Expert, "e");
        assert_eq!(sim.critical_time(OpKind::Expert), 3.0);
        assert_eq!(sim.now(), 3.0);
    }

    #[test]
    fn breakdown_accumulates() {
        let mut sim = EventSim::new(2);
        sim.parallel_compute(&[(0, 1.0), (1, 1.0)], OpKind::Attention, "a");
        sim.collective(&[0, 1], 0.5, "c");
        sim.parallel_compute(&[(0, 2.0), (1, 2.0)], OpKind::Expert, "e");
        assert_eq!(sim.critical_time(OpKind::Attention), 1.0);
        assert_eq!(sim.critical_time(OpKind::Comm), 0.5);
        assert_eq!(sim.critical_time(OpKind::Expert), 2.0);
        assert_eq!(sim.now(), 3.5);
    }

    #[test]
    fn transition_is_global() {
        let mut sim = EventSim::new(2);
        sim.compute(0, OpKind::Attention, 1.0, "a");
        sim.transition(0.3, "reshard");
        assert!((sim.now() - 1.3).abs() < 1e-12);
        assert_eq!(sim.critical_time(OpKind::Transition), 0.3);
    }
}
