//! Parallel strategies and the hierarchical search space (paper §III-C).
//!
//! The Attention module may use DP, TP, or DP×TP hybrids; the Expert
//! module may use EP, TP, or EP×TP hybrids (DP excluded for experts —
//! their weights dominate the model, so replication is memory-infeasible,
//! and the paper additionally prunes DP+EP+TP triples from prior
//! experience). TP degrees grow as powers of two.

pub mod space;

pub use space::{SearchSpace, StrategyPruning};

use crate::util::json::Json;
use std::fmt;

/// Per-stage iteration-loop execution mode: the classic module-
/// sequential loop, or the micro-chunk pipelined loop in which chunk
/// `i`'s expert FFN overlaps chunk `i−1`'s combine collective (see
/// [`crate::model::exec::ModelExecutor::set_pipeline_chunks`]). The
/// planner only enumerates `Pipelined` when it carries a calibrated
/// [`crate::sim::OverlapModel`]; token outputs are bit-identical either
/// way, so the axis is purely a latency decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecMode {
    /// One module at a time over the full batch.
    Sequential,
    /// Micro-chunk pipeline: expert compute overlaps combine comm.
    Pipelined,
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Sequential => "sequential",
            ExecMode::Pipelined => "pipelined",
        }
    }
}

impl fmt::Display for ExecMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Attention-module parallel strategy: `tp × dp = N` devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AttnStrategy {
    /// Tensor-parallel degree A_t (shards heads).
    pub tp: usize,
    /// Data-parallel degree A_d (replicates weights, splits batch).
    pub dp: usize,
}

impl AttnStrategy {
    pub fn new(tp: usize, dp: usize) -> Self {
        assert!(tp >= 1 && dp >= 1);
        AttnStrategy { tp, dp }
    }

    /// Total devices used.
    pub fn devices(&self) -> usize {
        self.tp * self.dp
    }

    /// Human-readable name matching the paper's plots (e.g. `TP4`,
    /// `DP2xTP2`, `DP4`).
    pub fn label(&self) -> String {
        match (self.dp, self.tp) {
            (1, t) => format!("TP{t}"),
            (d, 1) => format!("DP{d}"),
            (d, t) => format!("DP{d}xTP{t}"),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![("tp", self.tp.into()), ("dp", self.dp.into())])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        Some(AttnStrategy::new(
            j.get("tp")?.as_usize()?,
            j.get("dp")?.as_usize()?,
        ))
    }
}

impl fmt::Display for AttnStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// Expert-module parallel strategy: `tp × ep = N` devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExpertStrategy {
    /// Tensor-parallel degree E_t (shards every expert's intermediate dim).
    pub tp: usize,
    /// Expert-parallel degree E_e (distributes whole experts).
    pub ep: usize,
}

impl ExpertStrategy {
    pub fn new(tp: usize, ep: usize) -> Self {
        assert!(tp >= 1 && ep >= 1);
        ExpertStrategy { tp, ep }
    }

    pub fn devices(&self) -> usize {
        self.tp * self.ep
    }

    pub fn label(&self) -> String {
        match (self.ep, self.tp) {
            (1, t) => format!("TP{t}"),
            (e, 1) => format!("EP{e}"),
            (e, t) => format!("EP{e}xTP{t}"),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![("tp", self.tp.into()), ("ep", self.ep.into())])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        Some(ExpertStrategy::new(
            j.get("tp")?.as_usize()?,
            j.get("ep")?.as_usize()?,
        ))
    }
}

impl fmt::Display for ExpertStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(AttnStrategy::new(4, 1).label(), "TP4");
        assert_eq!(AttnStrategy::new(1, 4).label(), "DP4");
        assert_eq!(AttnStrategy::new(2, 2).label(), "DP2xTP2");
        assert_eq!(ExpertStrategy::new(1, 8).label(), "EP8");
        assert_eq!(ExpertStrategy::new(2, 4).label(), "EP4xTP2");
    }

    #[test]
    fn json_round_trip() {
        let a = AttnStrategy::new(2, 4);
        assert_eq!(AttnStrategy::from_json(&a.to_json()), Some(a));
        let e = ExpertStrategy::new(4, 2);
        assert_eq!(ExpertStrategy::from_json(&e.to_json()), Some(e));
    }
}
