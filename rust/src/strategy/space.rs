//! Search-space enumeration under the paper's constraints (eq. 5).
//!
//! Constraints enforced:
//! - `A_t × A_d = N` and `E_t × E_e = N` (all devices used; E_d = 1
//!   because expert DP is pruned for memory infeasibility);
//! - TP degrees are powers of two;
//! - divisibility: `A_t | q_heads`, `E_e | N_experts`, `E_t | Dim_exp`
//!   (the paper writes these with its `a | b` = "a divides b" notation);
//! - per-device memory: `(M_KV + A_d·M_attn + M_exp)/N + 2·M_act < M_gpu`
//!   with the EP activation upper bound doubling the TP footprint;
//! - pruning from prior experience: no DP×EP×TP triples for experts
//!   (already structural: expert strategies carry no DP axis).

use crate::config::{hardware::NodeConfig, model::MoEModelConfig, scenario::Scenario};
use crate::sim::memory::{self, MemoryModel};
use crate::strategy::{AttnStrategy, ExecMode, ExpertStrategy};

/// Why a candidate strategy was rejected (for `--verbose` output and
/// tests).
#[derive(Debug, Clone, PartialEq)]
pub enum StrategyPruning {
    /// A_t does not divide the query-head count.
    HeadsNotDivisible { tp: usize },
    /// E_e does not divide the expert count.
    ExpertsNotDivisible { ep: usize },
    /// E_t does not divide the expert intermediate size.
    InterNotDivisible { tp: usize },
    /// Per-device memory bound exceeded (bytes needed vs capacity).
    MemoryExceeded { needed: f64, capacity: f64 },
}

/// The enumerated, constraint-feasible search space for one
/// (model, node, scenario) triple.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Feasible Attention strategies (K_a entries).
    pub attn: Vec<AttnStrategy>,
    /// Feasible Expert strategies (K_e entries) — candidates for both
    /// prefill and decode stages.
    pub expert: Vec<ExpertStrategy>,
    /// Iteration-loop execution modes available per stage. Enumeration
    /// yields `[Sequential]`; a planner carrying a calibrated
    /// [`crate::sim::OverlapModel`] widens this to both modes so the
    /// ILP can choose the micro-chunk pipelined loop per stage.
    pub exec: Vec<ExecMode>,
    /// Rejected candidates with reasons (diagnostics).
    pub pruned: Vec<(String, StrategyPruning)>,
}

impl SearchSpace {
    /// Enumerate all feasible strategies.
    pub fn enumerate(
        model: &MoEModelConfig,
        node: &NodeConfig,
        scenario: &Scenario,
    ) -> SearchSpace {
        let n = node.num_devices;
        let mem = MemoryModel::new(model, scenario);
        let mut attn = Vec::new();
        let mut expert = Vec::new();
        let mut pruned = Vec::new();

        for tp in power_of_two_divisors(n) {
            let dp = n / tp;
            let cand = AttnStrategy::new(tp, dp);
            if model.q_heads % tp != 0 {
                pruned.push((cand.label(), StrategyPruning::HeadsNotDivisible { tp }));
                continue;
            }
            attn.push(cand);
        }

        for tp in power_of_two_divisors(n) {
            let ep = n / tp;
            let cand = ExpertStrategy::new(tp, ep);
            if model.num_experts % ep != 0 {
                pruned.push((cand.label(), StrategyPruning::ExpertsNotDivisible { ep }));
                continue;
            }
            if model.moe_inter_size % tp != 0 {
                pruned.push((cand.label(), StrategyPruning::InterNotDivisible { tp }));
                continue;
            }
            expert.push(cand);
        }

        // Memory feasibility of (attn, expert) pairs: a strategy is kept
        // only if it participates in at least one feasible pair.
        let gpu_cap = node.gpu.mem_bytes;
        let attn_ok: Vec<AttnStrategy> = attn
            .iter()
            .copied()
            .filter(|a| {
                expert.iter().any(|e| {
                    memory::pair_fits(&mem, a, e, n, gpu_cap)
                })
            })
            .collect();
        let expert_ok: Vec<ExpertStrategy> = expert
            .iter()
            .copied()
            .filter(|e| {
                attn_ok
                    .iter()
                    .any(|a| memory::pair_fits(&mem, a, e, n, gpu_cap))
            })
            .collect();
        if let Some(e0) = expert.first() {
            for a in &attn {
                if !attn_ok.contains(a) {
                    let needed = mem.per_device_bytes(a, e0, n);
                    pruned.push((
                        a.label(),
                        StrategyPruning::MemoryExceeded { needed, capacity: gpu_cap },
                    ));
                }
            }
        }
        if let Some(a0) = attn_ok.first() {
            for e in &expert {
                if !expert_ok.contains(e) {
                    let needed = mem.per_device_bytes(a0, e, n);
                    pruned.push((
                        e.label(),
                        StrategyPruning::MemoryExceeded { needed, capacity: gpu_cap },
                    ));
                }
            }
        }

        SearchSpace {
            attn: attn_ok,
            expert: expert_ok,
            exec: vec![ExecMode::Sequential],
            pruned,
        }
    }

    /// K_a — number of attention strategies.
    pub fn k_a(&self) -> usize {
        self.attn.len()
    }

    /// K_e — number of expert strategies.
    pub fn k_e(&self) -> usize {
        self.expert.len()
    }

    /// Size of the full decision space: attention strategy × expert
    /// prefill strategy × expert decode strategy × per-stage execution
    /// mode (the exec axis contributes 1 without an overlap model).
    pub fn decision_count(&self) -> usize {
        self.k_a() * self.k_e() * self.k_e() * self.exec.len() * self.exec.len()
    }

    /// True when the pipelined iteration loop is a candidate.
    pub fn has_pipelined(&self) -> bool {
        self.exec.contains(&ExecMode::Pipelined)
    }

    /// True if a memory-feasible (attn, expert) pairing exists.
    pub fn is_feasible(&self) -> bool {
        !self.attn.is_empty() && !self.expert.is_empty()
    }
}

/// Power-of-two divisors of `n` (n itself a power of two): 1, 2, ..., n.
pub fn power_of_two_divisors(n: usize) -> Vec<usize> {
    assert!(n.is_power_of_two());
    let mut v = Vec::new();
    let mut d = 1;
    while d <= n {
        v.push(d);
        d *= 2;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NodeConfig, Scenario};

    #[test]
    fn pow2_divisors() {
        assert_eq!(power_of_two_divisors(8), vec![1, 2, 4, 8]);
        assert_eq!(power_of_two_divisors(1), vec![1]);
    }

    #[test]
    fn mixtral_4gpu_space() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let s = SearchSpace::enumerate(&m, &node, &Scenario::short_constrained());
        // Attention: TP4, DP2xTP2, DP4 — all divide 32 heads. But DP4
        // replicates 4x attention weights; still fits in 48GB?
        assert!(s.attn.contains(&AttnStrategy::new(4, 1)));
        // Expert: TP4, EP2xTP2, EP4 all feasible for 8 experts.
        assert_eq!(s.k_e(), 3);
        assert!(s.is_feasible());
    }

    #[test]
    fn qwen_experts_not_divisible_by_large_ep() {
        // Qwen1.5 has 60 experts: EP8 does not divide 60 → pruned on an
        // 8-GPU node; EP4 and EP2 do divide.
        let m = MoEModelConfig::qwen15_moe_a27b();
        let node = NodeConfig::a100x(8);
        let s = SearchSpace::enumerate(&m, &node, &Scenario::short_constrained());
        assert!(!s.expert.iter().any(|e| e.ep == 8), "EP8 should be pruned: {:?}", s.expert);
        assert!(s.expert.iter().any(|e| e.ep == 4));
        assert!(s
            .pruned
            .iter()
            .any(|(_, r)| matches!(r, StrategyPruning::ExpertsNotDivisible { ep: 8 })));
    }

    #[test]
    fn v100_memory_prunes_attention_dp() {
        // Mixtral on 8×V100 (32 GB): full-DP attention replicates
        // attention weights 8×; combined with expert weights the
        // footprint must still fit — check the space stays feasible and
        // flags at least the most replicated configs when they overflow.
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::v100x(8);
        let s = SearchSpace::enumerate(&m, &node, &Scenario::fig8_v100());
        assert!(s.is_feasible());
        // 46.7GB of weights over 8 devices ≈ 5.8GB + KV; DP8 attention
        // adds ~8x the ~1.3GB attention weights — tight but checkable.
        for a in &s.attn {
            assert!(a.devices() == 8);
        }
    }

    #[test]
    fn all_strategies_use_all_devices() {
        let m = MoEModelConfig::qwen2_57b_a14b();
        let node = NodeConfig::a100x(4);
        let s = SearchSpace::enumerate(&m, &node, &Scenario::long_extended());
        for a in &s.attn {
            assert_eq!(a.devices(), 4);
        }
        for e in &s.expert {
            assert_eq!(e.devices(), 4);
        }
    }
}
