//! Host tensor ↔ XLA literal helpers.

use crate::Result;
use anyhow::anyhow;
use std::path::Path;

/// A simple host-side f32 tensor (row major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn elements(&self) -> usize {
        self.data.len()
    }

    /// Convert to an XLA literal with this shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    /// Read back from an XLA literal (f32).
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<HostTensor> {
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
        if data.len() != shape.iter().product::<usize>() {
            anyhow::bail!("literal has {} elements, shape wants {:?}", data.len(), shape);
        }
        Ok(HostTensor { shape, data })
    }

    /// Element-wise in-place add (the TP/EP "all-reduce" combine).
    pub fn add_assign(&mut self, other: &HostTensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Contiguous slice `[start, start+n)` along the leading axis (the
    /// DP batch split).
    pub fn slice_outer(&self, start: usize, n: usize) -> HostTensor {
        let outer = self.shape[0];
        assert!(start + n <= outer, "slice_outer {start}+{n} > {outer}");
        let inner: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = n;
        HostTensor::new(shape, self.data[start * inner..(start + n) * inner].to_vec())
    }

    /// Row-major slice of the last axis? Not needed; helpers below are
    /// shape-specific where used.
    pub fn view(&self) -> &[f32] {
        &self.data
    }
}

/// i32 tokens literal of a given shape.
pub fn tokens_literal(tokens: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), tokens.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(tokens)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape tokens: {e:?}"))
}

/// Scalar i32 literal (decode position).
pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read a raw little-endian f32 file.
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() % 4 != 0 {
        anyhow::bail!("{}: size {} not a multiple of 4", path.display(), bytes.len());
    }
    let mut out = Vec::with_capacity(bytes.len() / 4);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}

/// Argmax over the last axis of a [rows, cols] tensor (greedy decode).
pub fn argmax_rows(t: &HostTensor) -> Vec<usize> {
    assert_eq!(t.shape.len(), 2);
    let cols = t.shape[1];
    t.data
        .chunks_exact(cols)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_add() {
        let mut a = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::new(vec![2, 2], vec![10.0, 20.0, 30.0, 40.0]);
        a.add_assign(&b);
        assert_eq!(a.data, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn argmax() {
        let t = HostTensor::new(vec![2, 3], vec![0.1, 0.9, 0.2, 5.0, -1.0, 2.0]);
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }

    #[test]
    fn f32_file_round_trip() {
        let dir = std::env::temp_dir().join("hap_lit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let vals = [1.5f32, -2.25, 0.0, 3.75];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(read_f32_file(&path).unwrap(), vals);
    }

    #[test]
    fn literal_round_trip() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, vec![2, 3]).unwrap();
        assert_eq!(back, t);
    }
}
