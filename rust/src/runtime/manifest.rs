//! `artifacts/manifest.json` parsing (written by python/compile/aot.py).

use crate::util::json::Json;
use crate::Result;
use anyhow::anyhow;
use std::path::Path;

/// Tensor shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Option<TensorSpec> {
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Option<Vec<_>>>()?;
        let dtype = j.get("dtype")?.as_str()?.to_string();
        Some(TensorSpec { shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// "attention" | "expert" | "embed" | "head".
    pub module: String,
    /// "prefill" | "decode" | "both".
    pub stage: String,
    pub tp: usize,
    pub ep: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One tensor in weights.bin.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_floats: usize,
}

impl WeightEntry {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The tiny demo model's hyperparameters (mirrors model.py::TINY).
#[derive(Debug, Clone)]
pub struct TinyModelMeta {
    pub batch: usize,
    pub prefill_len: usize,
    pub max_len: usize,
    pub hidden: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub num_experts: usize,
    pub top_k: usize,
    pub inter: usize,
    pub vocab: usize,
    pub layers: usize,
}

impl TinyModelMeta {
    /// A reduced model shape for the artifact-free host backend: small
    /// enough that grid-engine tests and CI smoke runs finish in
    /// seconds, while keeping every axis the grid shards along (GQA
    /// heads, multiple experts, power-of-two batch) non-trivial.
    pub fn host_demo() -> TinyModelMeta {
        TinyModelMeta {
            batch: 4,
            prefill_len: 16,
            max_len: 48,
            hidden: 64,
            q_heads: 8,
            kv_heads: 4,
            head_dim: 8,
            num_experts: 8,
            top_k: 2,
            inter: 128,
            vocab: 128,
            layers: 2,
        }
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: TinyModelMeta,
    pub weights_file: String,
    pub weights: Vec<WeightEntry>,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let m = j.req("model").map_err(|e| anyhow!("{e}"))?;
        let geti = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest model.{k} missing"))
        };
        let model = TinyModelMeta {
            batch: geti("batch")?,
            prefill_len: geti("prefill_len")?,
            max_len: geti("max_len")?,
            hidden: geti("hidden")?,
            q_heads: geti("q_heads")?,
            kv_heads: geti("kv_heads")?,
            head_dim: geti("head_dim")?,
            num_experts: geti("num_experts")?,
            top_k: geti("top_k")?,
            inter: geti("inter")?,
            vocab: geti("vocab")?,
            layers: geti("layers")?,
        };
        let weights_file = j
            .get("weights_file")
            .and_then(|v| v.as_str())
            .unwrap_or("weights.bin")
            .to_string();
        let weights = j
            .get("weights")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|w| {
                Some(WeightEntry {
                    name: w.get("name")?.as_str()?.to_string(),
                    shape: w
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Option<Vec<_>>>()?,
                    offset_floats: w.get("offset_floats")?.as_usize()?,
                })
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("bad weights table"))?;
        let entries = j
            .get("entries")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|e| {
                let meta = e.get("meta")?;
                Some(ArtifactEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    file: e.get("file")?.as_str()?.to_string(),
                    module: meta.get("module")?.as_str()?.to_string(),
                    stage: meta.get("stage")?.as_str()?.to_string(),
                    tp: meta.get("tp").and_then(|v| v.as_usize()).unwrap_or(1),
                    ep: meta.get("ep").and_then(|v| v.as_usize()).unwrap_or(1),
                    inputs: e
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Option<Vec<_>>>()?,
                    outputs: e
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Option<Vec<_>>>()?,
                })
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("bad entries table"))?;
        Ok(Manifest { model, weights_file, weights, entries })
    }

    pub fn weight(&self, name: &str) -> Option<&WeightEntry> {
        self.weights.iter().find(|w| w.name == name)
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"name": "tiny-moe", "batch": 4, "prefill_len": 64,
                "max_len": 192, "hidden": 256, "q_heads": 8, "kv_heads": 4,
                "head_dim": 32, "num_experts": 8, "top_k": 2, "inter": 512,
                "vocab": 512, "layers": 4, "seed": 0},
      "weights_file": "weights.bin",
      "weights": [
        {"name": "embed", "shape": [512, 256], "offset_floats": 0},
        {"name": "layer0.ln1", "shape": [256], "offset_floats": 131072}
      ],
      "entries": [
        {"name": "head", "file": "head.hlo.txt",
         "meta": {"module": "head", "stage": "both"},
         "inputs": [{"shape": [4, 256], "dtype": "float32"}],
         "outputs": [{"shape": [4, 512], "dtype": "float32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model.hidden, 256);
        assert_eq!(m.weights.len(), 2);
        assert_eq!(m.weight("embed").unwrap().elements(), 512 * 256);
        let e = m.entry("head").unwrap();
        assert_eq!(e.module, "head");
        assert_eq!(e.inputs[0].shape, vec![4, 256]);
        assert_eq!(e.tp, 1);
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"model": {}}"#).is_err());
    }
}
