//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! request path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! The artifact set and their shapes come from `artifacts/manifest.json`
//! written by `python/compile/aot.py`; Python never runs here.
//!
//! Weights live as device buffers (`PjRtBuffer`) via
//! `buffer_from_host_literal`, uploaded once at load; per-step
//! activations go through `execute_b` so the hot loop never re-uploads
//! parameters.

pub mod literal;
pub mod manifest;

pub use manifest::{ArtifactEntry, Manifest, TinyModelMeta, WeightEntry};

use crate::Result;
use anyhow::{anyhow, Context};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded, compiled artifact set backed by one PJRT CPU client.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Load `artifacts/` (manifest + HLO files), compiling every entry.
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(PjrtRuntime { client, manifest, dir: dir.to_path_buf(), executables })
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute by name with literal inputs; returns the flattened tuple
    /// outputs as literals.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    /// Execute with device-resident buffers (hot path: weights stay on
    /// device). Returns output literals.
    pub fn execute_buffers(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    /// Upload a literal to the device once (for weights).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("uploading buffer: {e:?}"))
    }

    /// Read the raw weights file as f32s.
    pub fn read_weights(&self) -> Result<Vec<f32>> {
        let path = self.dir.join(&self.manifest.weights_file);
        literal::read_f32_file(&path)
    }
}
