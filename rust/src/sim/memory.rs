//! Memory-consumption model (paper §III-A2 and the constraint in eq. 5):
//!
//! `(M_KV + A_d × M_attn + M_exp) / N + 2 × M_act < M_gpu`
//!
//! - Attention DP replicates attention weights `A_d×`;
//! - Expert weights have identical per-device footprints across EP/TP;
//! - EP's imbalanced All-to-All dispatch gets the paper's conservative
//!   2× activation upper bound (we apply the 2× when the expert strategy
//!   uses EP, and the baseline activation footprint otherwise).

use crate::config::model::MoEModelConfig;
use crate::config::scenario::Scenario;
use crate::strategy::{AttnStrategy, ExpertStrategy};

/// Model-level memory quantities (bytes, whole model / whole batch).
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// KV cache for the full batch at max sequence length (M_KV).
    pub kv_bytes: f64,
    /// All attention weights (M_attn).
    pub attn_weight_bytes: f64,
    /// All expert + shared-expert weights (M_exp).
    pub expert_weight_bytes: f64,
    /// Baseline (TP) peak activation bytes per device (M_act).
    pub act_bytes: f64,
    /// Embedding + unembedding weights (replicated).
    pub embed_bytes: f64,
}

impl MemoryModel {
    pub fn new(model: &MoEModelConfig, scenario: &Scenario) -> Self {
        let dt = model.dtype_bytes as f64;
        let kv_bytes =
            (scenario.batch * scenario.total_len()) as f64 * model.kv_bytes_per_token() as f64;
        let attn_weight_bytes = (model.layers * model.attn_params_per_layer()) as f64 * dt;
        let expert_weight_bytes = (model.layers
            * (model.expert_params_per_layer() + model.shared_expert_params_per_layer()))
            as f64
            * dt;
        // Peak activations: a few live tensors of [batch, seq, hidden]
        // during prefill plus expert intermediates for routed tokens.
        let tokens = (scenario.batch * scenario.context) as f64;
        let act_bytes = dt
            * (4.0 * tokens * model.hidden as f64
                + tokens * model.top_k as f64 * model.moe_inter_size as f64 * 0.25);
        let embed_bytes = 2.0 * (model.vocab * model.hidden) as f64 * dt;
        MemoryModel { kv_bytes, attn_weight_bytes, expert_weight_bytes, act_bytes, embed_bytes }
    }

    /// Per-device bytes for an (attention, expert) strategy pair on an
    /// `n`-device node — the left side of the eq. 5 constraint.
    pub fn per_device_bytes(
        &self,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        n: usize,
    ) -> f64 {
        let nf = n as f64;
        let weights =
            (self.kv_bytes + attn.dp as f64 * self.attn_weight_bytes + self.expert_weight_bytes)
                / nf;
        // EP activation upper bound: double the TP baseline (paper's
        // conservative bound for All-to-All imbalance).
        let act_factor = if expert.ep > 1 { 2.0 } else { 1.0 };
        weights + act_factor * self.act_bytes + self.embed_bytes
    }
}

/// Does the (attn, expert) pair fit in per-device capacity `cap`?
pub fn pair_fits(
    mem: &MemoryModel,
    attn: &AttnStrategy,
    expert: &ExpertStrategy,
    n: usize,
    cap: f64,
) -> bool {
    mem.per_device_bytes(attn, expert, n) < cap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;

    fn model() -> MoEModelConfig {
        MoEModelConfig::mixtral_8x7b()
    }

    #[test]
    fn dp_multiplies_attention_weights() {
        let mem = MemoryModel::new(&model(), &Scenario::short_constrained());
        let tp = mem.per_device_bytes(&AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), 4);
        let dp = mem.per_device_bytes(&AttnStrategy::new(1, 4), &ExpertStrategy::new(4, 1), 4);
        let delta = dp - tp;
        let expected = 3.0 * mem.attn_weight_bytes / 4.0;
        assert!((delta - expected).abs() / expected < 1e-9);
    }

    #[test]
    fn ep_doubles_activations() {
        let mem = MemoryModel::new(&model(), &Scenario::short_constrained());
        let tp = mem.per_device_bytes(&AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), 4);
        let ep = mem.per_device_bytes(&AttnStrategy::new(4, 1), &ExpertStrategy::new(1, 4), 4);
        assert!((ep - tp - mem.act_bytes).abs() / mem.act_bytes < 1e-9);
    }

    #[test]
    fn expert_weights_strategy_invariant() {
        // Per-device expert weight footprint is the same for EP and TP
        // (paper III-A2): both divide total expert bytes by N.
        let mem = MemoryModel::new(&model(), &Scenario::short_constrained());
        // Same act_factor for both by comparing EP2xTP2 vs EP4 (both EP>1).
        let a = AttnStrategy::new(4, 1);
        let e1 = mem.per_device_bytes(&a, &ExpertStrategy::new(2, 2), 4);
        let e2 = mem.per_device_bytes(&a, &ExpertStrategy::new(1, 4), 4);
        assert!((e1 - e2).abs() < 1.0);
    }

    #[test]
    fn mixtral_fits_4xa6000_with_tp() {
        let mem = MemoryModel::new(&model(), &Scenario::short_constrained());
        let bytes =
            mem.per_device_bytes(&AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), 4);
        // 46.7B params × 2B / 4 devices ≈ 23.4 GB + KV + act < 48 GB.
        assert!(bytes < 48e9, "bytes {bytes}");
        assert!(bytes > 20e9, "bytes {bytes}");
    }

    #[test]
    fn long_context_grows_kv() {
        let short = MemoryModel::new(&model(), &Scenario::short_constrained());
        let long = MemoryModel::new(&model(), &Scenario::long_extended());
        assert!(long.kv_bytes > short.kv_bytes * 10.0);
    }
}
