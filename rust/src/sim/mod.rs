//! Inference latency simulation models (paper §III-B).
//!
//! The paper estimates per-module latency as
//! `T_cal = (F_module / Max_FLOPs) × η` and communication as
//! `T_comm = (V_data / Bandwidth) × ρ`, with η and ρ fitted by random
//! forest regressors over polynomial-expanded features, trained on
//! measured operator latencies.
//!
//! Here the "measured" latencies come from [`microbench`] — a synthetic
//! ground-truth operator model (roofline × occupancy × noise) standing
//! in for the paper's GPU benchmarking protocol (see DESIGN.md §2). The
//! regressors ([`forest`]) are trained on those samples and the
//! estimator ([`latency`]) mirrors eq. 1–3.

pub mod comm;
pub mod flops;
pub mod forest;
pub mod latency;
pub mod memory;
pub mod microbench;

pub use latency::{LatencyModel, LayerQuery, ModuleLatency, OverlapModel, StageLatency};
