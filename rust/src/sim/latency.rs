//! The inference latency estimator (paper §III-B, eq. 1–3):
//!
//! ```text
//! T_total   = T_prefill + T_decoding                       (1)
//! T_prefill = N_layer · (T_attn + T_experts + T_comm)      (2)
//! T_decoding = S_output · N_layer · (T_attn + T_experts + T_comm)   (3)
//! T_cal  = F_module / Max_FLOPs × η      (η: random forest)
//! T_comm = V_data / Bandwidth × ρ        (ρ: random forest)
//! ```
//!
//! [`LatencyModel`] owns the η regressors (one per module, as the paper
//! builds module-specific simulation models) and the ρ regressor,
//! trained at construction on [`microbench`] samples. Decode-stage cost
//! is integrated over the growing context length by sampling a few
//! quadrature points instead of simulating every step.

use crate::cluster::imbalance;
use crate::config::{hardware::GpuSpec, model::MoEModelConfig, scenario::Scenario};
use crate::sim::comm::{self, CommEvent};
use crate::sim::flops::{self, OpCost, Stage};
use crate::sim::forest::{ForestParams, RandomForest};
use crate::sim::microbench;
use crate::strategy::{AttnStrategy, ExpertStrategy};

/// Latency of one module class within one layer (seconds).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ModuleLatency {
    pub attn: f64,
    pub expert: f64,
    pub comm: f64,
}

impl ModuleLatency {
    pub fn total(&self) -> f64 {
        self.attn + self.expert + self.comm
    }

    pub fn scale(&self, k: f64) -> ModuleLatency {
        ModuleLatency { attn: self.attn * k, expert: self.expert * k, comm: self.comm * k }
    }

    pub fn add(&self, o: &ModuleLatency) -> ModuleLatency {
        ModuleLatency {
            attn: self.attn + o.attn,
            expert: self.expert + o.expert,
            comm: self.comm + o.comm,
        }
    }
}

/// Per-stage latency plus the end-to-end total for a scenario.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageLatency {
    /// Whole prefill stage (all layers).
    pub prefill: ModuleLatency,
    /// Whole decoding stage (all layers × S_output steps).
    pub decode: ModuleLatency,
}

impl StageLatency {
    pub fn total(&self) -> f64 {
        self.prefill.total() + self.decode.total()
    }
}

/// Module-specific inference latency simulation model.
pub struct LatencyModel {
    pub gpu: GpuSpec,
    eta_attn: RandomForest,
    eta_expert: RandomForest,
    rho: RandomForest,
    /// Number of decode quadrature points (see `decode_layer`).
    quad_points: usize,
}

impl LatencyModel {
    /// Train the η/ρ regressors for a GPU platform. Deterministic for a
    /// given seed; takes a few milliseconds.
    pub fn train(gpu: &GpuSpec, seed: u64) -> LatencyModel {
        let params = ForestParams { n_trees: 24, max_depth: 12, min_split: 3, ..Default::default() };
        // Module-specific training sets: attention sweeps lower
        // intensity (KV reads), experts sweep the full GEMM range. The
        // sets are disjoint draws from the same benchmarking protocol.
        let attn_set = microbench::compute_training_set(gpu, 900, seed ^ 0xA77);
        let expert_set = microbench::compute_training_set(gpu, 900, seed ^ 0xE4);
        // The ρ surface has a sharp latency-floor knee at small message
        // sizes — give it a denser sweep and a deeper forest.
        let comm_set = microbench::comm_training_set(gpu, 2000, seed ^ 0xC0);

        let fit = |rows: &[microbench::ComputeSample]| {
            let xs: Vec<Vec<f64>> = rows.iter().map(|s| s.features.clone()).collect();
            let ys: Vec<f64> = rows.iter().map(|s| s.eta.ln()).collect();
            RandomForest::fit(&xs, &ys, &params)
        };
        let eta_attn = fit(&attn_set);
        let eta_expert = fit(&expert_set);
        let xs: Vec<Vec<f64>> = comm_set.iter().map(|s| s.features.clone()).collect();
        let ys: Vec<f64> = comm_set.iter().map(|s| s.rho.ln()).collect();
        let rho_params = ForestParams { n_trees: 32, max_depth: 14, ..params.clone() };
        let rho = RandomForest::fit(&xs, &ys, &rho_params);

        LatencyModel { gpu: gpu.clone(), eta_attn, eta_expert, rho, quad_points: 8 }
    }

    /// T_cal for an attention-module invocation: `flops/peak × η̂`.
    pub fn attn_time(&self, cost: &OpCost) -> f64 {
        if cost.flops <= 0.0 {
            return 0.0;
        }
        let eta = self.eta_attn.predict(&microbench::compute_features(cost)).exp();
        cost.flops / self.gpu.peak_flops * eta
    }

    /// T_cal for an expert-module invocation.
    pub fn expert_time(&self, cost: &OpCost) -> f64 {
        if cost.flops <= 0.0 {
            return 0.0;
        }
        let eta = self.eta_expert.predict(&microbench::compute_features(cost)).exp();
        cost.flops / self.gpu.peak_flops * eta
    }

    /// T_comm for one collective: `V/BW × ρ̂`.
    pub fn comm_time(&self, event: &CommEvent) -> f64 {
        if event.wire_bytes <= 0.0 || event.group <= 1 {
            return 0.0;
        }
        let rho = self.rho.predict(&microbench::comm_features(event)).exp();
        event.wire_bytes / self.gpu.link_bw * rho
    }

    /// Total comm time of a layer's schedule.
    pub fn comm_time_all(&self, events: &[CommEvent]) -> f64 {
        events.iter().map(|e| self.comm_time(e)).sum()
    }

    /// Per-layer latency at one point of one stage.
    ///
    /// `seq` = prompt length for prefill, current context length for
    /// decode. The EP imbalance factor multiplies routed-expert work.
    pub fn layer_latency(
        &self,
        model: &MoEModelConfig,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        stage: Stage,
        batch: usize,
        seq: usize,
    ) -> ModuleLatency {
        let tokens = match stage {
            Stage::Prefill => batch * seq,
            Stage::Decode => batch,
        };
        let imb = imbalance::expected_imbalance(
            model.num_experts,
            expert.ep,
            tokens,
            model.top_k,
            imbalance::DEFAULT_SKEW,
        );
        let a_cost = flops::attention_cost(model, attn, stage, batch, seq);
        let e_cost = flops::expert_cost(model, expert, stage, batch, seq, imb);
        let events = comm::layer_comm_events(model, attn, expert, stage, batch, seq);
        ModuleLatency {
            attn: self.attn_time(&a_cost),
            expert: self.expert_time(&e_cost),
            comm: self.comm_time_all(&events),
        }
    }

    /// Whole-prefill latency (eq. 2).
    pub fn prefill_latency(
        &self,
        model: &MoEModelConfig,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        scenario: &Scenario,
    ) -> ModuleLatency {
        self.layer_latency(model, attn, expert, Stage::Prefill, scenario.batch, scenario.context)
            .scale(model.layers as f64)
    }

    /// Whole-decoding latency (eq. 3), integrating the growing context
    /// with `quad_points` midpoint-rule samples.
    pub fn decode_latency(
        &self,
        model: &MoEModelConfig,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        scenario: &Scenario,
    ) -> ModuleLatency {
        if scenario.generate == 0 {
            return ModuleLatency::default();
        }
        let q = self.quad_points.min(scenario.generate).max(1);
        let step = scenario.generate as f64 / q as f64;
        let mut acc = ModuleLatency::default();
        for i in 0..q {
            let ctx = scenario.context as f64 + (i as f64 + 0.5) * step;
            let per_layer = self.layer_latency(
                model,
                attn,
                expert,
                Stage::Decode,
                scenario.batch,
                ctx as usize,
            );
            acc = acc.add(&per_layer.scale(step));
        }
        acc.scale(model.layers as f64)
    }

    /// End-to-end latency (eq. 1) for a fixed strategy pair used in both
    /// stages (no transition).
    pub fn total_latency(
        &self,
        model: &MoEModelConfig,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        scenario: &Scenario,
    ) -> StageLatency {
        StageLatency {
            prefill: self.prefill_latency(model, attn, expert, scenario),
            decode: self.decode_latency(model, attn, expert, scenario),
        }
    }
}

/// Held-out prediction errors of the η and ρ regressors against fresh
/// "measured" samples (paper Fig 5's evaluation protocol). Returns
/// (compute relative errors, comm relative errors).
pub fn heldout_errors(lm: &LatencyModel, gpu: &GpuSpec, n: usize) -> (Vec<f64>, Vec<f64>) {
    let comp = microbench::compute_training_set(gpu, n, 0xDEAD_BEEF);
    let comm = microbench::comm_training_set(gpu, n, 0xFEED_FACE);
    let comp_err = comp
        .iter()
        .map(|s| {
            // Reconstruct the op from its features: [0]=ln flops,
            // [2]=ln intensity.
            let flops = s.features[0].exp();
            let bytes = flops / s.features[2].exp();
            let t = lm.expert_time(&OpCost { flops, bytes });
            let eta_hat = t * gpu.peak_flops / flops;
            ((eta_hat - s.eta) / s.eta).abs()
        })
        .collect();
    let comm_err = comm
        .iter()
        .map(|s| {
            let wire = s.features[0].exp();
            let group = s.features[1] as usize;
            let rounds = s.features[2] as usize;
            let collective = match s.features[3] as usize {
                0 => comm::Collective::AllReduce,
                1 => comm::Collective::AllGather,
                _ => comm::Collective::AllToAll,
            };
            let ev = CommEvent { collective, group, wire_bytes: wire, rounds, label: "heldout" };
            let t = lm.comm_time(&ev);
            let rho_hat = t * gpu.link_bw / wire;
            ((rho_hat - s.rho) / s.rho).abs()
        })
        .collect();
    (comp_err, comm_err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NodeConfig;

    fn model_for(gpu: &GpuSpec) -> LatencyModel {
        LatencyModel::train(gpu, 42)
    }

    #[test]
    fn eta_regressor_tracks_ground_truth() {
        let gpu = GpuSpec::a6000();
        let lm = model_for(&gpu);
        // Held-out op: a chunky prefill GEMM.
        let cost = OpCost { flops: 5e12, bytes: 4e10 };
        let truth = microbench::true_compute_time(&gpu, &cost);
        let pred = lm.expert_time(&cost);
        let rel = (pred - truth).abs() / truth;
        assert!(rel < 0.15, "rel err {rel}");
    }

    #[test]
    fn rho_regressor_tracks_ground_truth() {
        let gpu = GpuSpec::a6000();
        let lm = model_for(&gpu);
        let ev = CommEvent {
            collective: crate::sim::comm::Collective::AllReduce,
            group: 4,
            wire_bytes: 2e8,
            rounds: 6,
            label: "t",
        };
        let truth = microbench::true_comm_time(&gpu, &ev);
        let pred = lm.comm_time(&ev);
        let rel = (pred - truth).abs() / truth;
        assert!(rel < 0.12, "rel err {rel}");
    }

    #[test]
    fn fig2_shape_prefill_tp_comm_dominates_on_pcie() {
        // Paper Fig 2 (4×A6000, seq 2K): prefill TP has much higher comm
        // latency than EP.
        let node = NodeConfig::a6000x(4);
        let lm = model_for(&node.gpu);
        let m = MoEModelConfig::mixtral_8x7b();
        let sc = Scenario::new("fig2", 2048, 64, 16);
        // EP baseline pairs DP attention with EP experts (the
        // DeepSpeed-MoE deployment the paper benchmarks).
        let tp = lm.prefill_latency(&m, &AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), &sc);
        let ep = lm.prefill_latency(&m, &AttnStrategy::new(1, 4), &ExpertStrategy::new(1, 4), &sc);
        assert!(tp.comm > 1.5 * ep.comm, "TP comm {} vs EP comm {}", tp.comm, ep.comm);
    }

    #[test]
    fn fig2_shape_decode_ep_expert_slower() {
        // Paper Fig 2 decode: EP expert compute beats by load imbalance.
        let node = NodeConfig::a6000x(4);
        let lm = model_for(&node.gpu);
        let m = MoEModelConfig::mixtral_8x7b();
        let sc = Scenario::new("fig2", 2048, 64, 16);
        let tp = lm.decode_latency(&m, &AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), &sc);
        let ep = lm.decode_latency(&m, &AttnStrategy::new(1, 4), &ExpertStrategy::new(1, 4), &sc);
        assert!(
            ep.expert > 1.1 * tp.expert,
            "EP expert {} vs TP expert {}",
            ep.expert,
            tp.expert
        );
    }

    #[test]
    fn decode_scales_with_output_length() {
        let gpu = GpuSpec::a100();
        let lm = model_for(&gpu);
        let m = MoEModelConfig::mixtral_8x7b();
        let short = Scenario::new("s", 256, 64, 8);
        let long = Scenario::new("l", 256, 2048, 8);
        let a = AttnStrategy::new(4, 1);
        let e = ExpertStrategy::new(4, 1);
        let t_short = lm.decode_latency(&m, &a, &e, &short).total();
        let t_long = lm.decode_latency(&m, &a, &e, &long).total();
        let ratio = t_long / t_short;
        assert!(ratio > 20.0 && ratio < 50.0, "ratio {ratio}");
    }

    #[test]
    fn latencies_positive_and_finite() {
        let gpu = GpuSpec::v100();
        let lm = model_for(&gpu);
        let m = MoEModelConfig::qwen15_moe_a27b();
        let sc = Scenario::short_constrained();
        for (tp, dp) in [(1, 4), (2, 2), (4, 1)] {
            for (etp, eep) in [(1, 4), (2, 2), (4, 1)] {
                let t = lm.total_latency(
                    &m,
                    &AttnStrategy::new(tp, dp),
                    &ExpertStrategy::new(etp, eep),
                    &sc,
                );
                assert!(t.total().is_finite() && t.total() > 0.0);
            }
        }
    }
}
