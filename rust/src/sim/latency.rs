//! The inference latency estimator (paper §III-B, eq. 1–3):
//!
//! ```text
//! T_total   = T_prefill + T_decoding                       (1)
//! T_prefill = N_layer · (T_attn + T_experts + T_comm)      (2)
//! T_decoding = S_output · N_layer · (T_attn + T_experts + T_comm)   (3)
//! T_cal  = F_module / Max_FLOPs × η      (η: random forest)
//! T_comm = V_data / Bandwidth × ρ        (ρ: random forest)
//! ```
//!
//! [`LatencyModel`] owns the η regressors (one per module, as the paper
//! builds module-specific simulation models) and the ρ regressor,
//! trained at construction on [`microbench`] samples. Decode-stage cost
//! is integrated over the growing context length by sampling a few
//! quadrature points instead of simulating every step.
//!
//! # Batch API (planner hot path)
//!
//! The planner evaluates hundreds of (strategy, stage, context) points
//! per `plan()` call. Instead of walking the forests one query at a
//! time, callers assemble [`LayerQuery`] rows up front and call
//! [`LatencyModel::layer_latency_batch`]: all η_attn features go through
//! **one** [`RandomForest::predict_batch`] call, likewise η_expert and ρ
//! (comm events are flattened across queries with offsets). Lower-level
//! batch entry points ([`LatencyModel::attn_time_batch`],
//! [`LatencyModel::expert_time_batch`], [`LatencyModel::comm_time_batch`])
//! serve callers that only need one table family — the vectorized cost
//! tables use them directly so comm tables no longer pay for unused
//! compute predictions.
//!
//! The scalar [`LatencyModel::layer_latency`] remains as a thin wrapper
//! over the same feature assembly and **memoizes** η/ρ lookups keyed on
//! the quantized (bit-exact) feature vectors, so repeated scalar
//! queries — identical op shapes across table rows, repeated baselines —
//! hit a hash map instead of re-walking the forest. Memoized and batch
//! paths return bit-identical values (exact-match keys; the forest is
//! deterministic). `layer_latency_uncached` preserves the pre-batching
//! behavior for reference baselines and perf comparisons.
//!
//! Trained models are cached per (GpuSpec, seed) — see
//! [`LatencyModel::cached`] — so platform sweeps, benches, and the
//! serving router stop retraining identical forests.

use crate::cluster::imbalance;
use crate::config::{hardware::GpuSpec, model::MoEModelConfig, scenario::Scenario};
use crate::sim::comm::{self, CommEvent};
use crate::sim::flops::{self, OpCost, Stage};
use crate::sim::forest::{ForestParams, RandomForest};
use crate::sim::microbench;
use crate::strategy::{AttnStrategy, ExpertStrategy};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Latency of one module class within one layer (seconds).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ModuleLatency {
    pub attn: f64,
    pub expert: f64,
    pub comm: f64,
}

impl ModuleLatency {
    pub fn total(&self) -> f64 {
        self.attn + self.expert + self.comm
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj(vec![
            ("attn", self.attn.into()),
            ("expert", self.expert.into()),
            ("comm", self.comm.into()),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> Option<ModuleLatency> {
        Some(ModuleLatency {
            attn: j.get("attn")?.as_f64()?,
            expert: j.get("expert")?.as_f64()?,
            comm: j.get("comm")?.as_f64()?,
        })
    }

    pub fn scale(&self, k: f64) -> ModuleLatency {
        ModuleLatency { attn: self.attn * k, expert: self.expert * k, comm: self.comm * k }
    }

    pub fn add(&self, o: &ModuleLatency) -> ModuleLatency {
        ModuleLatency {
            attn: self.attn + o.attn,
            expert: self.expert + o.expert,
            comm: self.comm + o.comm,
        }
    }
}

/// Overlap-aware iteration-latency term for the executor's micro-chunk
/// pipeline (`--pipeline-chunks`): with the expert FFN of chunk `i`
/// overlapping chunk `i-1`'s combine, a layer's expert+comm span is no
/// longer `T_expert + T_comm` but
///
/// ```text
/// T_overlap = max(T_expert, T_comm) + ε·min(T_expert, T_comm) + o
/// ```
///
/// where `ε ∈ [0, 1]` is the residual serialization fraction (the
/// share of the shorter leg the pipeline fails to hide: first-chunk
/// fill and last-chunk drain, fold ordering) and `o ≥ 0` is a fixed
/// per-layer pipelining overhead (chunk fan-out, extra fold
/// scheduling) that lets the pipelined plan lose on compute-dominated
/// shapes. `ε = 1, o = 0` degenerates to the sequential sum, so a
/// planner carrying `Some(OverlapModel)` with those values ranks plans
/// exactly like one carrying `None`.
///
/// Both parameters are calibrated from measured traces
/// ([`OverlapModel::fit`]): the PR-7 recorder attributes per-module
/// seconds span-based under overlap (expert + collective can sum past
/// wall time; the excess IS the measured overlap share), which gives
/// per-iteration `(compute, comm, span)` samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverlapModel {
    /// Residual serialization fraction ε ∈ [0, 1].
    pub eps: f64,
    /// Fixed per-layer pipelining overhead `o` in seconds (≥ 0).
    pub overhead: f64,
}

impl OverlapModel {
    /// Clamps into the valid region (ε ∈ [0, 1], o ≥ 0) so a noisy fit
    /// can never produce a model claiming better-than-perfect overlap.
    pub fn new(eps: f64, overhead: f64) -> OverlapModel {
        OverlapModel { eps: eps.clamp(0.0, 1.0), overhead: overhead.max(0.0) }
    }

    /// The no-op model: ranks plans identically to no overlap model.
    pub fn sequential() -> OverlapModel {
        OverlapModel { eps: 1.0, overhead: 0.0 }
    }

    /// Overlapped span of an expert/comm pair (seconds).
    pub fn overlapped(&self, compute: f64, comm: f64) -> f64 {
        compute.max(comm) + self.eps * compute.min(comm) + self.overhead
    }

    /// The comm term that, summed sequentially with `lat.expert`,
    /// yields the overlapped span: `T_overlap − T_expert`. Non-negative
    /// (`max(e, c) ≥ e` and `o ≥ 0`), so it slots into any cost table
    /// or ILP objective built from additive per-module terms.
    pub fn effective_comm(&self, lat: &ModuleLatency) -> f64 {
        self.overlapped(lat.expert, lat.comm) - lat.expert
    }

    /// Rewrite a per-layer latency for pipelined execution: attn and
    /// expert unchanged, comm replaced by [`Self::effective_comm`], so
    /// `total()` is `attn + overlapped(expert, comm)`.
    pub fn pipelined(&self, lat: &ModuleLatency) -> ModuleLatency {
        ModuleLatency { attn: lat.attn, expert: lat.expert, comm: self.effective_comm(lat) }
    }

    /// Least-squares calibration from measured samples of
    /// `(compute_s, comm_s, overlapped_span_s)` — e.g. per-iteration
    /// expert seconds, collective seconds, and the measured wall span
    /// of the expert+combine phase from a pipelined-run trace. Solves
    /// `span − max(compute, comm) = ε·min(compute, comm) + o` in the
    /// two unknowns via the closed-form normal equations, then clamps
    /// into the valid region. Falls back to [`Self::sequential`] when
    /// the samples cannot identify ε (fewer than two points, or no
    /// variance in the min leg).
    pub fn fit(samples: &[(f64, f64, f64)]) -> OverlapModel {
        if samples.len() < 2 {
            return OverlapModel::sequential();
        }
        let n = samples.len() as f64;
        let (mut sx, mut sy, mut sxx, mut sxy) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for &(compute, comm, span) in samples {
            let x = compute.min(comm);
            let y = span - compute.max(comm);
            sx += x;
            sy += y;
            sxx += x * x;
            sxy += x * y;
        }
        let var = sxx - sx * sx / n;
        if var <= 0.0 || !var.is_finite() {
            return OverlapModel::sequential();
        }
        let eps = (sxy - sx * sy / n) / var;
        let overhead = (sy - eps * sx) / n;
        OverlapModel::new(eps, overhead)
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj(vec![
            ("eps", self.eps.into()),
            ("overhead", self.overhead.into()),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> Option<OverlapModel> {
        Some(OverlapModel { eps: j.get("eps")?.as_f64()?, overhead: j.get("overhead")?.as_f64()? })
    }

    /// Cache-key fingerprint: the exact parameter bits, so two models
    /// disagreeing in the last ulp never share cached plans.
    pub fn fingerprint(&self) -> String {
        format!("{:016x}/{:016x}", self.eps.to_bits(), self.overhead.to_bits())
    }
}

/// Per-stage latency plus the end-to-end total for a scenario.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageLatency {
    /// Whole prefill stage (all layers).
    pub prefill: ModuleLatency,
    /// Whole decoding stage (all layers × S_output steps).
    pub decode: ModuleLatency,
}

impl StageLatency {
    pub fn total(&self) -> f64 {
        self.prefill.total() + self.decode.total()
    }
}

/// One point of the per-layer latency surface: everything
/// [`LatencyModel::layer_latency`] takes besides the model config.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerQuery {
    pub attn: AttnStrategy,
    pub expert: ExpertStrategy,
    pub stage: Stage,
    pub batch: usize,
    pub seq: usize,
}

/// Feature-vector width of both regressor families.
const FEAT_DIM: usize = 5;

/// Memo key: the feature vector quantized to exact f64 bit patterns
/// (features are already log-scale, so exact-match keys capture every
/// genuine repeat without ever aliasing distinct queries).
type FeatKey = [u64; FEAT_DIM];

fn feat_key(feats: &[f64]) -> FeatKey {
    debug_assert!(feats.len() <= FEAT_DIM);
    let mut key = [0u64; FEAT_DIM];
    for (slot, f) in key.iter_mut().zip(feats) {
        *slot = f.to_bits();
    }
    key
}

/// Per-regressor prediction memos (raw forest outputs, pre-`exp`).
#[derive(Debug, Default)]
struct Memo {
    attn: Mutex<HashMap<FeatKey, f64>>,
    expert: Mutex<HashMap<FeatKey, f64>>,
    comm: Mutex<HashMap<FeatKey, f64>>,
}

/// Module-specific inference latency simulation model.
#[derive(Debug)]
pub struct LatencyModel {
    pub gpu: GpuSpec,
    eta_attn: RandomForest,
    eta_expert: RandomForest,
    rho: RandomForest,
    /// Number of decode quadrature points (see `decode_latency`).
    quad_points: usize,
    memo: Memo,
    /// Scalar-path memoization switch (on by default; reference
    /// baselines turn it off to reproduce pre-batching behavior).
    memo_enabled: bool,
}

/// Global (GpuSpec, seed) → trained model cache.
static MODEL_CACHE: OnceLock<Mutex<Vec<((GpuSpec, u64), Arc<LatencyModel>)>>> = OnceLock::new();

impl LatencyModel {
    /// Train the η/ρ regressors for a GPU platform. Deterministic for a
    /// given seed; takes a few milliseconds. Each forest fit shares one
    /// presorted set of feature columns across all its trees
    /// ([`crate::sim::forest::fit_presorted`] — bit-identical to the
    /// per-node re-sorting reference). The three forests are
    /// independent (disjoint seeded training sets), so they fit under
    /// `std::thread::scope` in parallel — bit-identical to the serial
    /// path kept as [`Self::train_serial`] (ROADMAP: batched microbench
    /// training).
    pub fn train(gpu: &GpuSpec, seed: u64) -> LatencyModel {
        Self::train_inner(gpu, seed, true)
    }

    /// The original serial training path (reference for the parallel
    /// fit's bit-exactness test; same forests, same order of draws).
    pub fn train_serial(gpu: &GpuSpec, seed: u64) -> LatencyModel {
        Self::train_inner(gpu, seed, false)
    }

    fn train_inner(gpu: &GpuSpec, seed: u64, parallel: bool) -> LatencyModel {
        let params = ForestParams { n_trees: 24, max_depth: 12, min_split: 3, ..Default::default() };
        // Module-specific training sets: attention sweeps lower
        // intensity (KV reads), experts sweep the full GEMM range. The
        // sets are disjoint draws from the same benchmarking protocol,
        // each seeded independently — which is what makes the parallel
        // fit trivially deterministic.
        let fit_compute = |set_seed: u64| {
            let rows = microbench::compute_training_set(gpu, 900, set_seed);
            let xs: Vec<Vec<f64>> = rows.iter().map(|s| s.features.clone()).collect();
            let ys: Vec<f64> = rows.iter().map(|s| s.eta.ln()).collect();
            RandomForest::fit(&xs, &ys, &params)
        };
        // The ρ surface has a sharp latency-floor knee at small message
        // sizes — give it a denser sweep and a deeper forest.
        let fit_comm = || {
            let comm_set = microbench::comm_training_set(gpu, 2000, seed ^ 0xC0);
            let xs: Vec<Vec<f64>> = comm_set.iter().map(|s| s.features.clone()).collect();
            let ys: Vec<f64> = comm_set.iter().map(|s| s.rho.ln()).collect();
            let rho_params = ForestParams { n_trees: 32, max_depth: 14, ..params.clone() };
            RandomForest::fit(&xs, &ys, &rho_params)
        };
        let (eta_attn, eta_expert, rho) = if parallel {
            std::thread::scope(|s| {
                let attn = s.spawn(|| fit_compute(seed ^ 0xA77));
                let expert = s.spawn(|| fit_compute(seed ^ 0xE4));
                // The ρ fit is the largest block — keep it on this
                // thread so the scope does useful work while joining.
                let rho = fit_comm();
                (attn.join().expect("attn fit thread"), expert.join().expect("expert fit thread"), rho)
            })
        } else {
            (fit_compute(seed ^ 0xA77), fit_compute(seed ^ 0xE4), fit_comm())
        };

        LatencyModel {
            gpu: gpu.clone(),
            eta_attn,
            eta_expert,
            rho,
            quad_points: 8,
            memo: Memo::default(),
            memo_enabled: true,
        }
    }

    /// Shared, trained model for a platform: trains on first use and
    /// returns the cached instance afterwards. Sweeps, benches, and the
    /// serving router all hit the same forests instead of retraining.
    pub fn cached(gpu: &GpuSpec, seed: u64) -> Arc<LatencyModel> {
        let cache = MODEL_CACHE.get_or_init(|| Mutex::new(Vec::new()));
        let mut guard = cache.lock().unwrap();
        if let Some((_, lm)) = guard.iter().find(|((g, s), _)| *s == seed && g == gpu) {
            return lm.clone();
        }
        // Training under the lock keeps concurrent callers from
        // duplicating the (few-ms) fit; contention here is cold-path.
        let lm = Arc::new(LatencyModel::train(gpu, seed));
        guard.push(((gpu.clone(), seed), lm.clone()));
        lm
    }

    /// Disable (or re-enable) the scalar-path η/ρ memo. Used by the
    /// perf baseline to reproduce the pre-batching code path; values
    /// are identical either way.
    pub fn set_memo_enabled(&mut self, on: bool) {
        self.memo_enabled = on;
    }

    fn predict_memo(
        &self,
        cache: &Mutex<HashMap<FeatKey, f64>>,
        forest: &RandomForest,
        feats: &[f64],
    ) -> f64 {
        if !self.memo_enabled {
            return forest.predict(feats);
        }
        let key = feat_key(feats);
        if let Some(&v) = cache.lock().unwrap().get(&key) {
            return v;
        }
        let v = forest.predict(feats);
        cache.lock().unwrap().insert(key, v);
        v
    }

    /// T_cal for an attention-module invocation: `flops/peak × η̂`.
    pub fn attn_time(&self, cost: &OpCost) -> f64 {
        if cost.flops <= 0.0 {
            return 0.0;
        }
        let eta =
            self.predict_memo(&self.memo.attn, &self.eta_attn, &microbench::compute_features(cost))
                .exp();
        cost.flops / self.gpu.peak_flops * eta
    }

    /// T_cal for an expert-module invocation.
    pub fn expert_time(&self, cost: &OpCost) -> f64 {
        if cost.flops <= 0.0 {
            return 0.0;
        }
        let eta = self
            .predict_memo(&self.memo.expert, &self.eta_expert, &microbench::compute_features(cost))
            .exp();
        cost.flops / self.gpu.peak_flops * eta
    }

    /// T_comm for one collective: `V/BW × ρ̂`.
    pub fn comm_time(&self, event: &CommEvent) -> f64 {
        if event.wire_bytes <= 0.0 || event.group <= 1 {
            return 0.0;
        }
        let rho = self
            .predict_memo(&self.memo.comm, &self.rho, &microbench::comm_features(event))
            .exp();
        event.wire_bytes / self.gpu.link_bw * rho
    }

    /// Total comm time of a layer's schedule.
    pub fn comm_time_all(&self, events: &[CommEvent]) -> f64 {
        events.iter().map(|e| self.comm_time(e)).sum()
    }

    /// Batched `attn_time` over many op costs: one `predict_batch`
    /// walk for every non-degenerate row.
    pub fn attn_time_batch(&self, costs: &[OpCost]) -> Vec<f64> {
        self.compute_time_batch(&self.eta_attn, costs)
    }

    /// Batched `expert_time`.
    pub fn expert_time_batch(&self, costs: &[OpCost]) -> Vec<f64> {
        self.compute_time_batch(&self.eta_expert, costs)
    }

    fn compute_time_batch(&self, forest: &RandomForest, costs: &[OpCost]) -> Vec<f64> {
        let mut rows: Vec<Vec<f64>> = Vec::with_capacity(costs.len());
        let mut live: Vec<usize> = Vec::with_capacity(costs.len());
        for (i, c) in costs.iter().enumerate() {
            if c.flops > 0.0 {
                live.push(i);
                rows.push(microbench::compute_features(c));
            }
        }
        let preds = forest.predict_batch(&rows);
        let mut out = vec![0.0; costs.len()];
        for (slot, &i) in live.iter().enumerate() {
            let eta = preds[slot].exp();
            out[i] = costs[i].flops / self.gpu.peak_flops * eta;
        }
        out
    }

    /// Batched `comm_time` over a flat event list.
    pub fn comm_time_batch(&self, events: &[CommEvent]) -> Vec<f64> {
        let mut rows: Vec<Vec<f64>> = Vec::with_capacity(events.len());
        let mut live: Vec<usize> = Vec::with_capacity(events.len());
        for (i, e) in events.iter().enumerate() {
            if e.wire_bytes > 0.0 && e.group > 1 {
                live.push(i);
                rows.push(microbench::comm_features(e));
            }
        }
        let preds = self.rho.predict_batch(&rows);
        let mut out = vec![0.0; events.len()];
        for (slot, &i) in live.iter().enumerate() {
            let rho = preds[slot].exp();
            out[i] = events[i].wire_bytes / self.gpu.link_bw * rho;
        }
        out
    }

    /// Assemble the analytic inputs of one layer query (shared by the
    /// scalar and batch paths so they stay numerically identical).
    fn query_parts(
        model: &MoEModelConfig,
        q: &LayerQuery,
    ) -> (OpCost, OpCost, Vec<CommEvent>) {
        let tokens = match q.stage {
            Stage::Prefill => q.batch * q.seq,
            Stage::Decode => q.batch,
        };
        let imb = imbalance::expected_imbalance(
            model.num_experts,
            q.expert.ep,
            tokens,
            model.top_k,
            imbalance::DEFAULT_SKEW,
        );
        let a_cost = flops::attention_cost(model, &q.attn, q.stage, q.batch, q.seq);
        let e_cost = flops::expert_cost(model, &q.expert, q.stage, q.batch, q.seq, imb);
        let events = comm::layer_comm_events(model, &q.attn, &q.expert, q.stage, q.batch, q.seq);
        (a_cost, e_cost, events)
    }

    /// Per-layer latency at one point of one stage (thin wrapper over
    /// the shared feature assembly, with memoized η/ρ lookups).
    ///
    /// `seq` = prompt length for prefill, current context length for
    /// decode. The EP imbalance factor multiplies routed-expert work.
    pub fn layer_latency(
        &self,
        model: &MoEModelConfig,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        stage: Stage,
        batch: usize,
        seq: usize,
    ) -> ModuleLatency {
        let q = LayerQuery { attn: *attn, expert: *expert, stage, batch, seq };
        let (a_cost, e_cost, events) = Self::query_parts(model, &q);
        ModuleLatency {
            attn: self.attn_time(&a_cost),
            expert: self.expert_time(&e_cost),
            comm: self.comm_time_all(&events),
        }
    }

    /// `layer_latency` without memoization — the pre-batching reference
    /// path, kept for perf baselines and equivalence tests.
    pub fn layer_latency_uncached(
        &self,
        model: &MoEModelConfig,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        stage: Stage,
        batch: usize,
        seq: usize,
    ) -> ModuleLatency {
        let q = LayerQuery { attn: *attn, expert: *expert, stage, batch, seq };
        let (a_cost, e_cost, events) = Self::query_parts(model, &q);
        let attn_t = if a_cost.flops <= 0.0 {
            0.0
        } else {
            a_cost.flops / self.gpu.peak_flops
                * self.eta_attn.predict(&microbench::compute_features(&a_cost)).exp()
        };
        let expert_t = if e_cost.flops <= 0.0 {
            0.0
        } else {
            e_cost.flops / self.gpu.peak_flops
                * self.eta_expert.predict(&microbench::compute_features(&e_cost)).exp()
        };
        let comm_t: f64 = events
            .iter()
            .map(|e| {
                if e.wire_bytes <= 0.0 || e.group <= 1 {
                    0.0
                } else {
                    e.wire_bytes / self.gpu.link_bw
                        * self.rho.predict(&microbench::comm_features(e)).exp()
                }
            })
            .sum();
        ModuleLatency { attn: attn_t, expert: expert_t, comm: comm_t }
    }

    /// Batched per-layer latency: all attention features go through one
    /// `predict_batch`, likewise expert features and (flattened) comm
    /// events. Bit-identical per query to [`Self::layer_latency`].
    pub fn layer_latency_batch(
        &self,
        model: &MoEModelConfig,
        queries: &[LayerQuery],
    ) -> Vec<ModuleLatency> {
        let n = queries.len();
        let mut a_costs = Vec::with_capacity(n);
        let mut e_costs = Vec::with_capacity(n);
        let mut events: Vec<CommEvent> = Vec::new();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        for q in queries {
            let (a, e, ev) = Self::query_parts(model, q);
            a_costs.push(a);
            e_costs.push(e);
            events.extend(ev);
            offsets.push(events.len());
        }
        let attn_t = self.attn_time_batch(&a_costs);
        let expert_t = self.expert_time_batch(&e_costs);
        let comm_t = self.comm_time_batch(&events);
        (0..n)
            .map(|i| ModuleLatency {
                attn: attn_t[i],
                expert: expert_t[i],
                comm: comm_t[offsets[i]..offsets[i + 1]].iter().sum(),
            })
            .collect()
    }

    /// Whole-prefill latency (eq. 2).
    pub fn prefill_latency(
        &self,
        model: &MoEModelConfig,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        scenario: &Scenario,
    ) -> ModuleLatency {
        self.layer_latency(model, attn, expert, Stage::Prefill, scenario.batch, scenario.context)
            .scale(model.layers as f64)
    }

    /// Whole-decoding latency (eq. 3), integrating the growing context
    /// with `quad_points` midpoint-rule samples — evaluated as one
    /// batch of quadrature points.
    pub fn decode_latency(
        &self,
        model: &MoEModelConfig,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        scenario: &Scenario,
    ) -> ModuleLatency {
        if scenario.generate == 0 {
            return ModuleLatency::default();
        }
        let q = self.quad_points.min(scenario.generate).max(1);
        let step = scenario.generate as f64 / q as f64;
        let queries: Vec<LayerQuery> = (0..q)
            .map(|i| {
                let ctx = scenario.context as f64 + (i as f64 + 0.5) * step;
                LayerQuery {
                    attn: *attn,
                    expert: *expert,
                    stage: Stage::Decode,
                    batch: scenario.batch,
                    seq: ctx as usize,
                }
            })
            .collect();
        let mut acc = ModuleLatency::default();
        for per_layer in self.layer_latency_batch(model, &queries) {
            acc = acc.add(&per_layer.scale(step));
        }
        acc.scale(model.layers as f64)
    }

    /// End-to-end latency (eq. 1) for a fixed strategy pair used in both
    /// stages (no transition).
    pub fn total_latency(
        &self,
        model: &MoEModelConfig,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        scenario: &Scenario,
    ) -> StageLatency {
        StageLatency {
            prefill: self.prefill_latency(model, attn, expert, scenario),
            decode: self.decode_latency(model, attn, expert, scenario),
        }
    }
}

/// Held-out prediction errors of the η and ρ regressors against fresh
/// "measured" samples (paper Fig 5's evaluation protocol). Returns
/// (compute relative errors, comm relative errors).
pub fn heldout_errors(lm: &LatencyModel, gpu: &GpuSpec, n: usize) -> (Vec<f64>, Vec<f64>) {
    let comp = microbench::compute_training_set(gpu, n, 0xDEAD_BEEF);
    let comm = microbench::comm_training_set(gpu, n, 0xFEED_FACE);
    let comp_err = comp
        .iter()
        .map(|s| {
            // Reconstruct the op from its features: [0]=ln flops,
            // [2]=ln intensity.
            let flops = s.features[0].exp();
            let bytes = flops / s.features[2].exp();
            let t = lm.expert_time(&OpCost { flops, bytes });
            let eta_hat = t * gpu.peak_flops / flops;
            ((eta_hat - s.eta) / s.eta).abs()
        })
        .collect();
    let comm_err = comm
        .iter()
        .map(|s| {
            let wire = s.features[0].exp();
            let group = s.features[1] as usize;
            let rounds = s.features[2] as usize;
            let collective = match s.features[3] as usize {
                0 => comm::Collective::AllReduce,
                1 => comm::Collective::AllGather,
                _ => comm::Collective::AllToAll,
            };
            let ev = CommEvent { collective, group, wire_bytes: wire, rounds, label: "heldout" };
            let t = lm.comm_time(&ev);
            let rho_hat = t * gpu.link_bw / wire;
            ((rho_hat - s.rho) / s.rho).abs()
        })
        .collect();
    (comp_err, comm_err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NodeConfig;

    fn model_for(gpu: &GpuSpec) -> LatencyModel {
        LatencyModel::train(gpu, 42)
    }

    #[test]
    fn eta_regressor_tracks_ground_truth() {
        let gpu = GpuSpec::a6000();
        let lm = model_for(&gpu);
        // Held-out op: a chunky prefill GEMM.
        let cost = OpCost { flops: 5e12, bytes: 4e10 };
        let truth = microbench::true_compute_time(&gpu, &cost);
        let pred = lm.expert_time(&cost);
        let rel = (pred - truth).abs() / truth;
        assert!(rel < 0.15, "rel err {rel}");
    }

    #[test]
    fn rho_regressor_tracks_ground_truth() {
        let gpu = GpuSpec::a6000();
        let lm = model_for(&gpu);
        let ev = CommEvent {
            collective: crate::sim::comm::Collective::AllReduce,
            group: 4,
            wire_bytes: 2e8,
            rounds: 6,
            label: "t",
        };
        let truth = microbench::true_comm_time(&gpu, &ev);
        let pred = lm.comm_time(&ev);
        let rel = (pred - truth).abs() / truth;
        assert!(rel < 0.12, "rel err {rel}");
    }

    #[test]
    fn fig2_shape_prefill_tp_comm_dominates_on_pcie() {
        // Paper Fig 2 (4×A6000, seq 2K): prefill TP has much higher comm
        // latency than EP.
        let node = NodeConfig::a6000x(4);
        let lm = model_for(&node.gpu);
        let m = MoEModelConfig::mixtral_8x7b();
        let sc = Scenario::new("fig2", 2048, 64, 16);
        // EP baseline pairs DP attention with EP experts (the
        // DeepSpeed-MoE deployment the paper benchmarks).
        let tp = lm.prefill_latency(&m, &AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), &sc);
        let ep = lm.prefill_latency(&m, &AttnStrategy::new(1, 4), &ExpertStrategy::new(1, 4), &sc);
        assert!(tp.comm > 1.5 * ep.comm, "TP comm {} vs EP comm {}", tp.comm, ep.comm);
    }

    #[test]
    fn fig2_shape_decode_ep_expert_slower() {
        // Paper Fig 2 decode: EP expert compute beats by load imbalance.
        let node = NodeConfig::a6000x(4);
        let lm = model_for(&node.gpu);
        let m = MoEModelConfig::mixtral_8x7b();
        let sc = Scenario::new("fig2", 2048, 64, 16);
        let tp = lm.decode_latency(&m, &AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), &sc);
        let ep = lm.decode_latency(&m, &AttnStrategy::new(1, 4), &ExpertStrategy::new(1, 4), &sc);
        assert!(
            ep.expert > 1.1 * tp.expert,
            "EP expert {} vs TP expert {}",
            ep.expert,
            tp.expert
        );
    }

    #[test]
    fn decode_scales_with_output_length() {
        let gpu = GpuSpec::a100();
        let lm = model_for(&gpu);
        let m = MoEModelConfig::mixtral_8x7b();
        let short = Scenario::new("s", 256, 64, 8);
        let long = Scenario::new("l", 256, 2048, 8);
        let a = AttnStrategy::new(4, 1);
        let e = ExpertStrategy::new(4, 1);
        let t_short = lm.decode_latency(&m, &a, &e, &short).total();
        let t_long = lm.decode_latency(&m, &a, &e, &long).total();
        let ratio = t_long / t_short;
        assert!(ratio > 20.0 && ratio < 50.0, "ratio {ratio}");
    }

    #[test]
    fn latencies_positive_and_finite() {
        let gpu = GpuSpec::v100();
        let lm = model_for(&gpu);
        let m = MoEModelConfig::qwen15_moe_a27b();
        let sc = Scenario::short_constrained();
        for (tp, dp) in [(1, 4), (2, 2), (4, 1)] {
            for (etp, eep) in [(1, 4), (2, 2), (4, 1)] {
                let t = lm.total_latency(
                    &m,
                    &AttnStrategy::new(tp, dp),
                    &ExpertStrategy::new(etp, eep),
                    &sc,
                );
                assert!(t.total().is_finite() && t.total() > 0.0);
            }
        }
    }

    #[test]
    fn batch_layer_latency_matches_scalar_bitwise() {
        let gpu = GpuSpec::a6000();
        let lm = model_for(&gpu);
        let m = MoEModelConfig::mixtral_8x7b();
        let mut queries = Vec::new();
        for (tp, dp) in [(4, 1), (1, 4), (2, 2)] {
            for stage in [Stage::Prefill, Stage::Decode] {
                queries.push(LayerQuery {
                    attn: AttnStrategy::new(tp, dp),
                    expert: ExpertStrategy::new(dp, tp),
                    stage,
                    batch: 16,
                    seq: 1024,
                });
            }
        }
        let batch = lm.layer_latency_batch(&m, &queries);
        for (q, b) in queries.iter().zip(&batch) {
            let s = lm.layer_latency(&m, &q.attn, &q.expert, q.stage, q.batch, q.seq);
            assert_eq!(s.attn.to_bits(), b.attn.to_bits(), "{q:?}");
            assert_eq!(s.expert.to_bits(), b.expert.to_bits(), "{q:?}");
            assert_eq!(s.comm.to_bits(), b.comm.to_bits(), "{q:?}");
            let u = lm.layer_latency_uncached(&m, &q.attn, &q.expert, q.stage, q.batch, q.seq);
            assert_eq!(u.total().to_bits(), s.total().to_bits(), "{q:?}");
        }
    }

    #[test]
    fn parallel_training_bit_identical_to_serial() {
        // ROADMAP satellite: the scoped-thread fit must reproduce the
        // serial path exactly — same seeded training sets, same forests.
        let gpu = GpuSpec::a6000();
        let par = LatencyModel::train(&gpu, 42);
        let ser = LatencyModel::train_serial(&gpu, 42);
        for &(flops, bytes) in
            &[(1e9, 1e7), (5e12, 4e10), (3e10, 2e8), (7e13, 9e10), (2e8, 5e6)]
        {
            let c = OpCost { flops, bytes };
            assert_eq!(par.attn_time(&c).to_bits(), ser.attn_time(&c).to_bits(), "attn {c:?}");
            assert_eq!(
                par.expert_time(&c).to_bits(),
                ser.expert_time(&c).to_bits(),
                "expert {c:?}"
            );
        }
        for (group, wire) in [(2usize, 1e6), (4, 2e8), (8, 5e9)] {
            let ev = CommEvent {
                collective: crate::sim::comm::Collective::AllReduce,
                group,
                wire_bytes: wire,
                rounds: group - 1,
                label: "par-vs-ser",
            };
            assert_eq!(par.comm_time(&ev).to_bits(), ser.comm_time(&ev).to_bits(), "{ev:?}");
        }
    }

    #[test]
    fn overlap_model_fit_recovers_parameters() {
        // Synthetic samples generated from a known (ε, o) must fit
        // back exactly (the normal equations are exact on noiseless
        // data), and the degenerate cases fall back to sequential.
        let truth = OverlapModel::new(0.25, 3e-4);
        let samples: Vec<(f64, f64, f64)> = [(2e-3, 1e-3), (1e-3, 4e-3), (5e-3, 5e-4), (2e-4, 9e-4)]
            .iter()
            .map(|&(e, c)| (e, c, truth.overlapped(e, c)))
            .collect();
        let fit = OverlapModel::fit(&samples);
        assert!((fit.eps - truth.eps).abs() < 1e-9, "eps {}", fit.eps);
        assert!((fit.overhead - truth.overhead).abs() < 1e-12, "o {}", fit.overhead);
        assert_eq!(OverlapModel::fit(&[]), OverlapModel::sequential());
        assert_eq!(OverlapModel::fit(&samples[..1]), OverlapModel::sequential());
        // No variance in the min leg → unidentifiable → sequential.
        let flat = vec![(1e-3, 2e-3, 3e-3), (1e-3, 5e-3, 6e-3)];
        assert_eq!(OverlapModel::fit(&flat), OverlapModel::sequential());
    }

    #[test]
    fn overlap_model_sequential_is_identity_and_comm_nonnegative() {
        let seq = OverlapModel::sequential();
        let lat = ModuleLatency { attn: 1e-3, expert: 2e-3, comm: 5e-4 };
        assert_eq!(seq.pipelined(&lat).total().to_bits(), lat.total().to_bits());
        for eps in [0.0, 0.3, 1.0] {
            for o in [0.0, 1e-4] {
                let m = OverlapModel::new(eps, o);
                assert!(m.effective_comm(&lat) >= 0.0);
                let round = OverlapModel::from_json(&m.to_json()).unwrap();
                assert_eq!(round.fingerprint(), m.fingerprint());
            }
        }
        assert_eq!(OverlapModel::new(7.0, -1.0), OverlapModel { eps: 1.0, overhead: 0.0 });
    }

    #[test]
    fn cached_models_are_shared() {
        let gpu = GpuSpec::a6000();
        let a = LatencyModel::cached(&gpu, 0x4A9);
        let b = LatencyModel::cached(&gpu, 0x4A9);
        assert!(Arc::ptr_eq(&a, &b));
        let c = LatencyModel::cached(&gpu, 0x4AA);
        assert!(!Arc::ptr_eq(&a, &c));
    }
}
