//! Collective-communication volume models (paper §III-B).
//!
//! Parallel strategies impose distinct communication patterns —
//! AllReduce for TP, All-to-All for EP (paper challenge #2). This module
//! derives, per layer and stage, the exact sequence of collectives a
//! given (Attention, Expert) strategy pair requires and their
//! per-device wire volumes `V_data`, which the latency model turns into
//! `T_comm = (V / Bandwidth) × ρ`.
//!
//! Layout conventions (single node, N devices):
//! - Attention TP groups of size `A_t`; DP groups of size `A_d`
//!   (`A_t × A_d = N`). After attention TP AllReduce, activations are
//!   replicated within each TP group; each DP group owns `B/A_d`
//!   sequences.
//! - Expert module spans all N devices as `E_e` expert groups × `E_t`
//!   tensor shards. Tokens are owner-partitioned evenly across devices
//!   for EP dispatch.
//!
//! Event sequence per layer:
//! 1. `A_t > 1`: AllReduce(group A_t) of local activations (post O-proj);
//! 2. expert **TP-only** (`E_e = 1`): if `A_d > 1`, AllGather(group A_d)
//!    so every device sees all tokens; then AllReduce(group E_t) of all
//!    tokens (post down-proj). Results end fully replicated — no
//!    return traffic.
//! 3. expert **EP** (`E_e > 1`): All-to-All dispatch of routed tokens
//!    (top-k copies), optional AllReduce(group E_t) for EP×TP hybrids,
//!    All-to-All combine back to owners, and — when `A_t > 1` — an
//!    AllGather(group A_t) to re-replicate within attention TP groups.

use crate::config::model::MoEModelConfig;
use crate::sim::flops::Stage;
use crate::strategy::{AttnStrategy, ExpertStrategy};

/// Collective kind (communication pattern).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    AllReduce,
    AllGather,
    AllToAll,
}

impl Collective {
    pub fn name(self) -> &'static str {
        match self {
            Collective::AllReduce => "all_reduce",
            Collective::AllGather => "all_gather",
            Collective::AllToAll => "all_to_all",
        }
    }
}

/// One collective operation in a layer's schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct CommEvent {
    pub collective: Collective,
    /// Participants.
    pub group: usize,
    /// Bytes crossing this device's link (send side), ring-style.
    pub wire_bytes: f64,
    /// Number of sequential message rounds (latency term multiplier).
    pub rounds: usize,
    /// Human-readable role, e.g. "attn-tp-allreduce".
    pub label: &'static str,
}

impl CommEvent {
    fn all_reduce(group: usize, payload: f64, label: &'static str) -> Self {
        // Ring AllReduce: 2(g-1)/g × payload per device, 2(g-1) rounds.
        CommEvent {
            collective: Collective::AllReduce,
            group,
            wire_bytes: 2.0 * (group as f64 - 1.0) / group as f64 * payload,
            rounds: 2 * (group - 1),
            label,
        }
    }

    fn all_gather(group: usize, shard_payload: f64, label: &'static str) -> Self {
        // Ring AllGather: (g-1) × shard per device, g-1 rounds.
        CommEvent {
            collective: Collective::AllGather,
            group,
            wire_bytes: (group as f64 - 1.0) * shard_payload,
            rounds: group - 1,
            label,
        }
    }

    fn all_to_all(group: usize, send_payload: f64, label: &'static str) -> Self {
        // Pairwise exchange: (g-1)/g of the payload leaves the device.
        CommEvent {
            collective: Collective::AllToAll,
            group,
            wire_bytes: (group as f64 - 1.0) / group as f64 * send_payload,
            rounds: group - 1,
            label,
        }
    }
}

/// Per-layer collective schedule for an (attention, expert) strategy
/// pair at a given stage. `batch` is global; `seq` is prompt length
/// (prefill) or 1 decode step's token count source (decode processes
/// `batch` single tokens).
pub fn layer_comm_events(
    m: &MoEModelConfig,
    attn: &AttnStrategy,
    expert: &ExpertStrategy,
    stage: Stage,
    batch: usize,
    seq: usize,
) -> Vec<CommEvent> {
    let dt = m.dtype_bytes as f64;
    let h = m.hidden as f64;
    let tokens_global = match stage {
        Stage::Prefill => (batch * seq) as f64,
        Stage::Decode => batch as f64,
    };
    let tokens_per_dp_group = tokens_global / attn.dp as f64;
    let mut events = Vec::new();

    // 1. Attention TP AllReduce of the local activation slice.
    if attn.tp > 1 {
        events.push(CommEvent::all_reduce(
            attn.tp,
            tokens_per_dp_group * h * dt,
            "attn-tp-allreduce",
        ));
    }

    if expert.ep == 1 {
        // 2. Expert TP-only path.
        if attn.dp > 1 {
            // Every device must see all tokens before the sharded FFN.
            events.push(CommEvent::all_gather(
                attn.dp,
                tokens_per_dp_group * h * dt,
                "dp-to-expert-allgather",
            ));
        }
        if expert.tp > 1 {
            events.push(CommEvent::all_reduce(
                expert.tp,
                tokens_global * h * dt,
                "expert-tp-allreduce",
            ));
        }
    } else {
        // 3. Expert EP path: owner-partitioned dispatch/combine.
        let n = expert.devices();
        let tokens_per_device = tokens_global / n as f64;
        // Each owned token is sent to top_k experts; all copies counted,
        // the (g-1)/g survival factor is applied inside all_to_all().
        let dispatch_payload = tokens_per_device * m.top_k as f64 * h * dt;
        events.push(CommEvent::all_to_all(expert.ep, dispatch_payload, "ep-dispatch-a2a"));
        if expert.tp > 1 {
            // EP×TP hybrid: reduce partial FFN outputs within each
            // expert's tensor shard group.
            let routed_here = tokens_global * m.top_k as f64 / expert.ep as f64;
            events.push(CommEvent::all_reduce(
                expert.tp,
                routed_here * h * dt,
                "expert-tp-allreduce",
            ));
        }
        events.push(CommEvent::all_to_all(expert.ep, dispatch_payload, "ep-combine-a2a"));
        if attn.tp > 1 {
            // Re-replicate combined outputs within attention TP groups.
            events.push(CommEvent::all_gather(
                attn.tp,
                tokens_per_dp_group / attn.tp as f64 * h * dt,
                "expert-to-attn-allgather",
            ));
        }
    }

    events
}

/// Total per-device wire bytes of a layer's schedule.
pub fn layer_comm_bytes(events: &[CommEvent]) -> f64 {
    events.iter().map(|e| e.wire_bytes).sum()
}

/// Total latency rounds of a layer's schedule.
pub fn layer_comm_rounds(events: &[CommEvent]) -> usize {
    events.iter().map(|e| e.rounds).sum()
}

/// Wire volume of resharding expert weights from `from` to `to`
/// strategies via collectives (the T_reshard input of eq. 6): every
/// device must end holding its new shard; with disjoint layouts this is
/// an AllGather-style redistribution of the per-device shard delta.
pub fn reshard_wire_bytes(m: &MoEModelConfig, from: &ExpertStrategy, to: &ExpertStrategy) -> f64 {
    if from == to {
        return 0.0;
    }
    let n = from.devices() as f64;
    let total_expert_bytes =
        (m.layers * m.expert_params_per_layer()) as f64 * m.dtype_bytes as f64;
    let per_device_new = total_expert_bytes / n;
    // Fraction of the new shard already resident locally: layouts
    // overlap by min(share) when both strategies slice the same tensor
    // dimension family; disjoint axes (EP vs TP) overlap by 1/n.
    let overlap = if from.ep == to.ep || from.tp == to.tp {
        1.0 / n * (from.tp.max(to.tp) as f64 / from.tp.min(to.tp).max(1) as f64).min(n)
    } else {
        1.0 / n
    };
    per_device_new * (1.0 - overlap.min(1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::MoEModelConfig;

    fn m() -> MoEModelConfig {
        MoEModelConfig::mixtral_8x7b()
    }

    fn total_bytes(attn: (usize, usize), exp: (usize, usize), stage: Stage) -> f64 {
        let events = layer_comm_events(
            &m(),
            &AttnStrategy::new(attn.0, attn.1),
            &ExpertStrategy::new(exp.0, exp.1),
            stage,
            16,
            2048,
        );
        layer_comm_bytes(&events)
    }

    #[test]
    fn prefill_tp_costs_more_than_ep() {
        // Paper Fig 2: during prefill TP incurs higher comm volume than
        // EP (with DP attention, EP dispatch moves only top-k copies of
        // owned tokens).
        let tp_tp = total_bytes((4, 1), (4, 1), Stage::Prefill);
        let dp_ep = total_bytes((1, 4), (1, 4), Stage::Prefill);
        assert!(
            tp_tp > 2.0 * dp_ep,
            "TP {tp_tp:.3e} should be ≫ DP+EP {dp_ep:.3e}"
        );
    }

    #[test]
    fn dp_attention_eliminates_attention_comm() {
        let events = layer_comm_events(
            &m(),
            &AttnStrategy::new(1, 4),
            &ExpertStrategy::new(1, 4),
            Stage::Prefill,
            16,
            2048,
        );
        assert!(events.iter().all(|e| e.label != "attn-tp-allreduce"));
    }

    #[test]
    fn decode_volumes_are_small() {
        // Decode moves only batch×hidden activations — orders of
        // magnitude below prefill.
        let pre = total_bytes((4, 1), (4, 1), Stage::Prefill);
        let dec = total_bytes((4, 1), (4, 1), Stage::Decode);
        assert!(pre / dec > 1000.0);
    }

    #[test]
    fn ep_tp_hybrid_has_all_three_patterns() {
        let events = layer_comm_events(
            &m(),
            &AttnStrategy::new(4, 1),
            &ExpertStrategy::new(2, 2),
            Stage::Prefill,
            16,
            1024,
        );
        let kinds: Vec<Collective> = events.iter().map(|e| e.collective).collect();
        assert!(kinds.contains(&Collective::AllReduce));
        assert!(kinds.contains(&Collective::AllToAll));
        assert!(kinds.contains(&Collective::AllGather));
    }

    #[test]
    fn allreduce_wire_formula() {
        let e = CommEvent::all_reduce(4, 1000.0, "t");
        assert!((e.wire_bytes - 1500.0).abs() < 1e-9);
        assert_eq!(e.rounds, 6);
    }

    #[test]
    fn reshard_zero_for_same_strategy() {
        let s = ExpertStrategy::new(4, 1);
        assert_eq!(reshard_wire_bytes(&m(), &s, &s), 0.0);
    }

    #[test]
    fn reshard_moves_most_of_the_shard() {
        // EP4 → TP4 reshard must move nearly the whole per-device shard.
        let bytes = reshard_wire_bytes(&m(), &ExpertStrategy::new(1, 4), &ExpertStrategy::new(4, 1));
        let per_dev = (m().layers * m().expert_params_per_layer() * 2) as f64 / 4.0;
        assert!(bytes > 0.7 * per_dev, "{bytes} vs {per_dev}");
    }

    #[test]
    fn comm_identity_strategy_is_free() {
        // Single device: no collectives at all.
        let events = layer_comm_events(
            &m(),
            &AttnStrategy::new(1, 1),
            &ExpertStrategy::new(1, 1),
            Stage::Prefill,
            4,
            128,
        );
        assert!(events.is_empty());
    }
}
