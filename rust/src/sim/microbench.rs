//! Synthetic ground-truth operator latencies.
//!
//! The paper trains its η/ρ correction regressors on *empirically
//! measured* operator runtimes "acquired through systematic benchmarking
//! protocols" (§III-B). No GPUs exist in this environment, so this
//! module is the documented substitution (DESIGN.md §2): a physically
//! grounded operator-latency generator that reproduces the phenomena the
//! regressors must learn —
//!
//! - **roofline**: `t ≥ max(flops / peak, bytes / hbm_bw)`;
//! - **occupancy/efficiency**: small ops cannot saturate the device
//!   (wave quantization, launch overhead), so achieved FLOPs approach
//!   peak only asymptotically with op size;
//! - **bandwidth ramp**: collectives reach link bandwidth only for
//!   large messages; each round pays a latency floor;
//! - **measurement noise**: log-normal jitter on every sample.
//!
//! The engine/cluster simulator uses the *noise-free* ground truth; the
//! η/ρ regressors are trained on *noisy* samples and evaluated against
//! held-out noisy samples (paper Fig 5).

use crate::config::hardware::GpuSpec;
use crate::sim::comm::{Collective, CommEvent};
use crate::sim::flops::OpCost;
use crate::util::rng::Rng;

/// Compute-efficiency curve: fraction of peak FLOP/s achievable for an
/// op of `flops` total work at arithmetic intensity `intensity`.
///
/// Saturating form `eff = max_eff · f/(f + f_half)` models occupancy:
/// ops below ~`f_half` FLOPs leave the device underutilized. Intensity
/// below the machine balance point shifts the bound to memory.
fn compute_efficiency(gpu: &GpuSpec, flops: f64) -> f64 {
    // Work needed to fill the device for ~50 µs at peak — a reasonable
    // proxy for "enough waves to hide latency".
    let f_half = gpu.peak_flops * 20e-6;
    let max_eff = 0.62; // achieved/peak ceiling for real GEMM pipelines
    max_eff * flops / (flops + f_half)
}

/// Memory-efficiency curve: fraction of HBM bandwidth achievable when
/// streaming `bytes`.
fn memory_efficiency(gpu: &GpuSpec, bytes: f64) -> f64 {
    let b_half = gpu.hbm_bw * 4e-6;
    let max_eff = 0.78;
    max_eff * bytes / (bytes + b_half)
}

/// Kernel launch + scheduling overhead per fused module invocation.
const LAUNCH_OVERHEAD: f64 = 8e-6;

/// Noise-free ground-truth compute time for one module invocation.
pub fn true_compute_time(gpu: &GpuSpec, cost: &OpCost) -> f64 {
    if cost.flops == 0.0 && cost.bytes == 0.0 {
        return 0.0;
    }
    let t_flops = cost.flops / (gpu.peak_flops * compute_efficiency(gpu, cost.flops).max(1e-3));
    let t_bytes = cost.bytes / (gpu.hbm_bw * memory_efficiency(gpu, cost.bytes).max(1e-3));
    t_flops.max(t_bytes) + LAUNCH_OVERHEAD
}

/// Link-efficiency curve for collective payloads.
fn link_efficiency(gpu: &GpuSpec, wire_bytes: f64) -> f64 {
    let b_half = gpu.link_bw * 30e-6;
    let max_eff = 0.85;
    max_eff * wire_bytes / (wire_bytes + b_half)
}

/// Collective-pattern penalty: All-to-All on PCIe suffers from host-
/// bridge contention (many simultaneous peer flows); AllReduce pipelines
/// well on rings.
fn pattern_factor(gpu: &GpuSpec, collective: Collective) -> f64 {
    use crate::config::hardware::Interconnect;
    match (gpu.interconnect, collective) {
        (Interconnect::Pcie, Collective::AllToAll) => 1.35,
        (Interconnect::Pcie, _) => 1.15,
        (Interconnect::NvLink, Collective::AllToAll) => 1.05,
        (Interconnect::NvLink, _) => 1.0,
    }
}

/// Noise-free ground-truth time for one collective event.
pub fn true_comm_time(gpu: &GpuSpec, event: &CommEvent) -> f64 {
    if event.wire_bytes == 0.0 || event.group <= 1 {
        return 0.0;
    }
    let eff = link_efficiency(gpu, event.wire_bytes).max(1e-3);
    let bw_time = event.wire_bytes / (gpu.link_bw * eff);
    bw_time * pattern_factor(gpu, event.collective) + event.rounds as f64 * gpu.link_latency
}

/// A "measured" (noisy) compute sample, as the benchmarking protocol
/// would record it.
pub fn measured_compute_time(gpu: &GpuSpec, cost: &OpCost, rng: &mut Rng) -> f64 {
    true_compute_time(gpu, cost) * rng.lognormal_noise(0.03)
}

/// A "measured" (noisy) collective sample.
pub fn measured_comm_time(gpu: &GpuSpec, event: &CommEvent, rng: &mut Rng) -> f64 {
    true_comm_time(gpu, event) * rng.lognormal_noise(0.025)
}

/// One row of the compute-regressor training set: features + target η,
/// where `t = flops / peak × η` (paper's formulation solved for η).
#[derive(Debug, Clone)]
pub struct ComputeSample {
    pub features: Vec<f64>,
    pub eta: f64,
}

/// One row of the comm-regressor training set: features + target ρ,
/// where `t = wire_bytes / link_bw × ρ`.
#[derive(Debug, Clone)]
pub struct CommSample {
    pub features: Vec<f64>,
    pub rho: f64,
}

/// Feature vector for a compute op: raw + log + ratio features; the
/// forest handles interactions, matching the paper's "polynomial
/// feature expansion" in expressive power.
///
/// This is the row format consumed one-at-a-time by the scalar
/// `LatencyModel` predictors and row-by-row by the batched API
/// (`attn_time_batch` / `expert_time_batch` assemble one `Vec` per op
/// and make a single `RandomForest::predict_batch` call). Keep it in
/// sync with [`comm_features`]' width: both regressor families share
/// the 5-wide layout the latency memo keys on.
pub fn compute_features(cost: &OpCost) -> Vec<f64> {
    let f = cost.flops.max(1.0);
    let b = cost.bytes.max(1.0);
    vec![
        f.ln(),
        b.ln(),
        (f / b).ln(),      // arithmetic intensity
        f.sqrt().ln(),     // sub-linear size feature
        (f * b).ln() / 2.0 // geometric mean of work and traffic
    ]
}

/// Feature vector for a collective event (5-wide, see
/// [`compute_features`]); the ρ batch path flattens many layers'
/// events into one `predict_batch` call over these rows.
pub fn comm_features(event: &CommEvent) -> Vec<f64> {
    let v = event.wire_bytes.max(1.0);
    vec![
        v.ln(),
        event.group as f64,
        event.rounds as f64,
        match event.collective {
            Collective::AllReduce => 0.0,
            Collective::AllGather => 1.0,
            Collective::AllToAll => 2.0,
        },
        v.ln() * event.group as f64, // interaction term
    ]
}

/// Generate a compute training set by sweeping op sizes log-uniformly,
/// mimicking the paper's operator benchmarking sweep.
pub fn compute_training_set(gpu: &GpuSpec, samples: usize, seed: u64) -> Vec<ComputeSample> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        // FLOPs from 10^7 (tiny decode op) to 10^14 (huge prefill GEMM).
        let flops = 10f64.powf(rng.range_f64(7.0, 14.0));
        // Intensity from 1 (memory bound) to 300 (compute bound).
        let intensity = 10f64.powf(rng.range_f64(0.0, 2.5));
        let cost = OpCost { flops, bytes: flops / intensity };
        let t = measured_compute_time(gpu, &cost, &mut rng);
        let eta = t * gpu.peak_flops / flops;
        out.push(ComputeSample { features: compute_features(&cost), eta });
    }
    out
}

/// Generate a collective training set across patterns/sizes/groups.
pub fn comm_training_set(gpu: &GpuSpec, samples: usize, seed: u64) -> Vec<CommSample> {
    let mut rng = Rng::new(seed);
    let kinds = [Collective::AllReduce, Collective::AllGather, Collective::AllToAll];
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let group = 1usize << rng.range(1, 3); // 2, 4, 8
        let wire = 10f64.powf(rng.range_f64(3.0, 10.0)); // 1 KB .. 10 GB
        let collective = kinds[rng.below(3)];
        let rounds = match collective {
            Collective::AllReduce => 2 * (group - 1),
            _ => group - 1,
        };
        let event = CommEvent { collective, group, wire_bytes: wire, rounds, label: "bench" };
        let t = measured_comm_time(gpu, &event, &mut rng);
        let rho = t * gpu.link_bw / wire;
        out.push(CommSample { features: comm_features(&event), rho });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::GpuSpec;

    #[test]
    fn roofline_lower_bound() {
        let gpu = GpuSpec::a100();
        let cost = OpCost { flops: 1e13, bytes: 1e10 };
        let t = true_compute_time(&gpu, &cost);
        assert!(t >= cost.flops / gpu.peak_flops);
        assert!(t >= cost.bytes / gpu.hbm_bw);
    }

    #[test]
    fn big_ops_reach_decent_efficiency() {
        let gpu = GpuSpec::a100();
        let cost = OpCost { flops: 1e14, bytes: 1e10 };
        let t = true_compute_time(&gpu, &cost);
        let achieved = cost.flops / t;
        assert!(achieved > 0.5 * gpu.peak_flops, "achieved {:.2e}", achieved);
    }

    #[test]
    fn small_ops_are_overhead_dominated() {
        let gpu = GpuSpec::a100();
        let cost = OpCost { flops: 1e7, bytes: 1e6 };
        let t = true_compute_time(&gpu, &cost);
        // 1e7 FLOPs at peak would be 32 ns; overheads push ≥ 8 µs.
        assert!(t > 100.0 * (cost.flops / gpu.peak_flops));
    }

    #[test]
    fn pcie_alltoall_penalized() {
        let a6000 = GpuSpec::a6000();
        let mk = |c| CommEvent { collective: c, group: 4, wire_bytes: 1e8, rounds: 3, label: "t" };
        let a2a = true_comm_time(&a6000, &mk(Collective::AllToAll));
        let ag = true_comm_time(&a6000, &mk(Collective::AllGather));
        assert!(a2a > ag);
    }

    #[test]
    fn nvlink_much_faster_than_pcie() {
        let ev = CommEvent {
            collective: Collective::AllReduce,
            group: 4,
            wire_bytes: 1e9,
            rounds: 6,
            label: "t",
        };
        let t_a100 = true_comm_time(&GpuSpec::a100(), &ev);
        let t_v100 = true_comm_time(&GpuSpec::v100(), &ev);
        assert!(t_v100 / t_a100 > 10.0);
    }

    #[test]
    fn noise_is_small_and_unbiased() {
        let gpu = GpuSpec::a6000();
        let cost = OpCost { flops: 1e12, bytes: 1e10 };
        let truth = true_compute_time(&gpu, &cost);
        let mut rng = Rng::new(42);
        let n = 2000;
        let mean: f64 = (0..n)
            .map(|_| measured_compute_time(&gpu, &cost, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((mean / truth - 1.0).abs() < 0.01, "bias {}", mean / truth);
    }

    #[test]
    fn training_sets_have_positive_targets() {
        let gpu = GpuSpec::v100();
        for s in compute_training_set(&gpu, 200, 1) {
            assert!(s.eta.is_finite() && s.eta > 0.0);
            assert_eq!(s.features.len(), 5);
        }
        for s in comm_training_set(&gpu, 200, 2) {
            assert!(s.rho.is_finite() && s.rho > 0.0);
            assert_eq!(s.features.len(), 5);
        }
    }

    #[test]
    fn eta_decreases_with_op_size() {
        // η (inefficiency multiplier vs peak) should be far larger for
        // tiny ops than for huge compute-bound ops.
        let gpu = GpuSpec::a100();
        let small = OpCost { flops: 1e8, bytes: 1e6 };
        let big = OpCost { flops: 1e14, bytes: 1e11 };
        let eta_small = true_compute_time(&gpu, &small) * gpu.peak_flops / small.flops;
        let eta_big = true_compute_time(&gpu, &big) * gpu.peak_flops / big.flops;
        assert!(eta_small > 10.0 * eta_big);
    }
}
