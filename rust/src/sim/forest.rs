//! Random-forest regression from scratch (CART trees + bagging), laid
//! out for the planner's batch-evaluation hot path.
//!
//! The paper fits the η and ρ correction factors with "an efficient
//! random forest regression model" over polynomially expanded features.
//! This is that regressor: variance-reduction split search over sorted
//! feature columns, bootstrap-bagged ensemble, deterministic under a
//! seed. Fitting a few hundred samples with 16 trees takes < 10 ms.
//!
//! # Storage layout (SoA)
//!
//! Trees are built into a conventional enum-node arena
//! ([`reference::ArenaForest`]) and then **flattened** into one
//! structure-of-arrays over all trees: parallel `feature` / `threshold`
//! / `left` / `right` vectors indexed by a forest-global node id, plus
//! one root id per tree. Leaves are encoded with the sentinel
//! `feature == LEAF_SENTINEL` and store their value in `threshold`, so
//! traversal touches exactly two small arrays per step instead of
//! pattern-matching 40-byte enum nodes scattered across per-tree
//! allocations.
//!
//! # Batch evaluation
//!
//! [`RandomForest::predict_batch`] walks **tree-major** over a whole
//! batch of feature rows: each tree's (hot, contiguous) node range is
//! reused across all rows before moving to the next tree, which is what
//! makes the planner's vectorized cost tables cheap. Per-row results
//! are bit-identical to [`RandomForest::predict`] — both accumulate
//! per-tree predictions in tree order and divide once — and the
//! property tests in `rust/tests/prop_invariants.rs` pin that down.

/// Hyperparameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    pub n_trees: usize,
    pub max_depth: usize,
    /// Minimum samples to attempt a split.
    pub min_split: usize,
    /// Features considered per split (None = all).
    pub max_features: Option<usize>,
    pub seed: u64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams { n_trees: 24, max_depth: 10, min_split: 4, max_features: None, seed: 7 }
    }
}

/// `feature` value marking a leaf node (its `threshold` is the value).
const LEAF_SENTINEL: u32 = u32::MAX;

/// Best variance-reduction split for one feature: returns (threshold,
/// weighted child SSE).
fn best_split_on_feature(
    xs: &[Vec<f64>],
    ys: &[f64],
    idx: &[usize],
    feature: usize,
) -> Option<(f64, f64)> {
    let mut pairs: Vec<(f64, f64)> = idx.iter().map(|&i| (xs[i][feature], ys[i])).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let n = pairs.len();
    let total_sum: f64 = pairs.iter().map(|p| p.1).sum();
    let total_sq: f64 = pairs.iter().map(|p| p.1 * p.1).sum();
    let mut left_sum = 0.0;
    let mut left_sq = 0.0;
    let mut best: Option<(f64, f64)> = None;
    for i in 0..n - 1 {
        left_sum += pairs[i].1;
        left_sq += pairs[i].1 * pairs[i].1;
        // Skip ties — can't split between equal feature values.
        if pairs[i].0 == pairs[i + 1].0 {
            continue;
        }
        let nl = (i + 1) as f64;
        let nr = (n - i - 1) as f64;
        let right_sum = total_sum - left_sum;
        let right_sq = total_sq - left_sq;
        let sse = (left_sq - left_sum * left_sum / nl) + (right_sq - right_sum * right_sum / nr);
        let thr = 0.5 * (pairs[i].0 + pairs[i + 1].0);
        if best.map_or(true, |(_, s)| sse < s) {
            best = Some((thr, sse));
        }
    }
    best
}

/// The pre-flattening enum-arena representation. Kept as the build
/// intermediate and as the reference implementation the SoA layout is
/// validated against (see `prop_soa_forest_matches_arena_reference`).
pub mod reference {
    use super::{best_split_on_feature, ForestParams};
    use crate::util::rng::Rng;

    #[derive(Debug, Clone)]
    pub(super) enum Node {
        Leaf {
            value: f64,
        },
        Split {
            feature: usize,
            threshold: f64,
            left: usize,  // node index
            right: usize, // node index
        },
    }

    /// One CART regression tree stored as a flat arena of enum nodes.
    #[derive(Debug, Clone)]
    pub struct Tree {
        pub(super) nodes: Vec<Node>,
    }

    impl Tree {
        pub(super) fn fit(
            xs: &[Vec<f64>],
            ys: &[f64],
            idx: &mut [usize],
            params: &ForestParams,
            rng: &mut Rng,
        ) -> Tree {
            let mut tree = Tree { nodes: Vec::new() };
            tree.build(xs, ys, idx, 0, params, rng);
            tree
        }

        fn build(
            &mut self,
            xs: &[Vec<f64>],
            ys: &[f64],
            idx: &mut [usize],
            depth: usize,
            params: &ForestParams,
            rng: &mut Rng,
        ) -> usize {
            let mean = idx.iter().map(|&i| ys[i]).sum::<f64>() / idx.len() as f64;
            if depth >= params.max_depth || idx.len() < params.min_split {
                self.nodes.push(Node::Leaf { value: mean });
                return self.nodes.len() - 1;
            }
            let n_features = xs[0].len();
            let k = params.max_features.unwrap_or(n_features).min(n_features);
            // Sample candidate features without replacement.
            let mut feats: Vec<usize> = (0..n_features).collect();
            rng.shuffle(&mut feats);
            feats.truncate(k);

            let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, score)
            for &f in &feats {
                if let Some((thr, score)) = best_split_on_feature(xs, ys, idx, f) {
                    if best.map_or(true, |(_, _, s)| score < s) {
                        best = Some((f, thr, score));
                    }
                }
            }
            let Some((feature, threshold, _)) = best else {
                self.nodes.push(Node::Leaf { value: mean });
                return self.nodes.len() - 1;
            };
            // Partition indices in place.
            let mut lo = 0;
            let mut hi = idx.len();
            while lo < hi {
                if xs[idx[lo]][feature] <= threshold {
                    lo += 1;
                } else {
                    hi -= 1;
                    idx.swap(lo, hi);
                }
            }
            if lo == 0 || lo == idx.len() {
                self.nodes.push(Node::Leaf { value: mean });
                return self.nodes.len() - 1;
            }
            // Reserve our slot, then build children.
            let my_slot = self.nodes.len();
            self.nodes.push(Node::Leaf { value: mean }); // placeholder
            let (left_idx, right_idx) = {
                let (l, r) = idx.split_at_mut(lo);
                let li = self.build(xs, ys, l, depth + 1, params, rng);
                let ri = self.build(xs, ys, r, depth + 1, params, rng);
                (li, ri)
            };
            self.nodes[my_slot] =
                Node::Split { feature, threshold, left: left_idx, right: right_idx };
            my_slot
        }

        /// The root is the slot reserved by the outermost `build` call:
        /// index 0 whether leaf or split.
        fn predict(&self, x: &[f64]) -> f64 {
            let mut node = 0;
            loop {
                match &self.nodes[node] {
                    Node::Leaf { value } => return *value,
                    Node::Split { feature, threshold, left, right } => {
                        node = if x[*feature] <= *threshold { *left } else { *right };
                    }
                }
            }
        }
    }

    /// Bagged ensemble over enum-arena trees — the pre-SoA
    /// implementation, kept for equivalence testing and as the build
    /// intermediate.
    #[derive(Debug, Clone)]
    pub struct ArenaForest {
        pub(super) trees: Vec<Tree>,
    }

    impl ArenaForest {
        /// Fit on feature rows `xs` and targets `ys`. Consumes the RNG
        /// stream exactly like [`super::RandomForest::fit`], so the two
        /// produce identical ensembles for identical params.
        pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: &ForestParams) -> ArenaForest {
            assert_eq!(xs.len(), ys.len());
            assert!(!xs.is_empty(), "empty training set");
            let mut rng = Rng::new(params.seed);
            let n = xs.len();
            let trees = (0..params.n_trees)
                .map(|_| {
                    // Bootstrap sample.
                    let mut idx: Vec<usize> = (0..n).map(|_| rng.below(n)).collect();
                    Tree::fit(xs, ys, &mut idx, params, &mut rng)
                })
                .collect();
            ArenaForest { trees }
        }

        /// Mean prediction across trees.
        pub fn predict(&self, x: &[f64]) -> f64 {
            let s: f64 = self.trees.iter().map(|t| t.predict(x)).sum();
            s / self.trees.len() as f64
        }

        pub fn n_trees(&self) -> usize {
            self.trees.len()
        }
    }
}

/// Bagged ensemble of CART regression trees in the flattened SoA
/// layout (see the module docs).
#[derive(Debug, Clone)]
pub struct RandomForest {
    /// Split feature per node; [`LEAF_SENTINEL`] marks a leaf.
    feature: Vec<u32>,
    /// Split threshold per node; the leaf *value* at leaf nodes.
    threshold: Vec<f64>,
    /// Child node ids (forest-global indices); 0 at leaves.
    left: Vec<u32>,
    right: Vec<u32>,
    /// Root node id of each tree.
    roots: Vec<u32>,
}

impl RandomForest {
    /// Fit on feature rows `xs` and targets `ys`.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: &ForestParams) -> RandomForest {
        Self::flatten(&reference::ArenaForest::fit(xs, ys, params))
    }

    /// Flatten an enum-arena ensemble into the SoA layout. Node order
    /// within each tree is preserved, with per-tree indices rebased by
    /// the tree's offset in the global arrays.
    pub fn flatten(arena: &reference::ArenaForest) -> RandomForest {
        let total: usize = arena.trees.iter().map(|t| t.nodes.len()).sum();
        let mut f = RandomForest {
            feature: Vec::with_capacity(total),
            threshold: Vec::with_capacity(total),
            left: Vec::with_capacity(total),
            right: Vec::with_capacity(total),
            roots: Vec::with_capacity(arena.trees.len()),
        };
        for tree in &arena.trees {
            let base = f.feature.len() as u32;
            f.roots.push(base); // arena root is always slot 0
            for node in &tree.nodes {
                match node {
                    reference::Node::Leaf { value } => {
                        f.feature.push(LEAF_SENTINEL);
                        f.threshold.push(*value);
                        f.left.push(0);
                        f.right.push(0);
                    }
                    reference::Node::Split { feature, threshold, left, right } => {
                        f.feature.push(*feature as u32);
                        f.threshold.push(*threshold);
                        f.left.push(base + *left as u32);
                        f.right.push(base + *right as u32);
                    }
                }
            }
        }
        f
    }

    /// Walk one tree for one row.
    #[inline]
    fn predict_tree(&self, root: u32, x: &[f64]) -> f64 {
        let mut i = root as usize;
        loop {
            let f = self.feature[i];
            let t = self.threshold[i];
            if f == LEAF_SENTINEL {
                return t;
            }
            i = if x[f as usize] <= t { self.left[i] as usize } else { self.right[i] as usize };
        }
    }

    /// Mean prediction across trees.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let s: f64 = self.roots.iter().map(|&r| self.predict_tree(r, x)).sum();
        s / self.roots.len() as f64
    }

    /// Batch prediction, traversing tree-major for cache locality.
    /// Per-row results are bit-identical to [`Self::predict`].
    pub fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        let mut acc = vec![0.0f64; xs.len()];
        for &root in &self.roots {
            for (a, x) in acc.iter_mut().zip(xs) {
                *a += self.predict_tree(root, x);
            }
        }
        let n = self.roots.len() as f64;
        for a in &mut acc {
            // Same final op as `predict` (divide, not multiply-by-inverse)
            // to stay bit-identical.
            *a /= n;
        }
        acc
    }

    pub fn n_trees(&self) -> usize {
        self.roots.len()
    }

    /// Total nodes across all trees (diagnostics).
    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats;

    fn make_dataset(n: usize, seed: u64, f: impl Fn(f64, f64) -> f64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.range_f64(-3.0, 3.0);
            let b = rng.range_f64(-3.0, 3.0);
            xs.push(vec![a, b]);
            ys.push(f(a, b));
        }
        (xs, ys)
    }

    #[test]
    fn fits_smooth_function() {
        let (xs, ys) = make_dataset(800, 1, |a, b| (a * 1.5).sin() + 0.3 * b * b);
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        let (txs, tys) = make_dataset(200, 2, |a, b| (a * 1.5).sin() + 0.3 * b * b);
        let preds: Vec<f64> = txs.iter().map(|x| forest.predict(x)).collect();
        let r2 = stats::r2(&preds, &tys);
        assert!(r2 > 0.9, "r2 {r2}");
    }

    #[test]
    fn deterministic_under_seed() {
        let (xs, ys) = make_dataset(200, 3, |a, b| a + b);
        let f1 = RandomForest::fit(&xs, &ys, &ForestParams::default());
        let f2 = RandomForest::fit(&xs, &ys, &ForestParams::default());
        for x in xs.iter().take(50) {
            assert_eq!(f1.predict(x), f2.predict(x));
        }
    }

    #[test]
    fn constant_target_predicts_constant() {
        let (xs, _) = make_dataset(100, 4, |_, _| 0.0);
        let ys = vec![5.5; 100];
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!((forest.predict(&[0.0, 0.0]) - 5.5).abs() < 1e-9);
    }

    #[test]
    fn step_function_learned() {
        let (xs, ys) = make_dataset(600, 5, |a, _| if a > 0.5 { 10.0 } else { 1.0 });
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!(forest.predict(&[2.0, 0.0]) > 8.0);
        assert!(forest.predict(&[-2.0, 0.0]) < 3.0);
    }

    #[test]
    fn handles_single_feature_duplicates() {
        let xs = vec![vec![1.0], vec![1.0], vec![1.0], vec![2.0], vec![2.0]];
        let ys = vec![1.0, 1.0, 1.0, 4.0, 4.0];
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!(forest.predict(&[1.0]) < 2.5);
        assert!(forest.predict(&[2.0]) > 2.5);
    }

    #[test]
    fn batch_matches_scalar_bitwise() {
        let (xs, ys) = make_dataset(400, 6, |a, b| a * b + (b * 0.7).cos());
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        let (qs, _) = make_dataset(97, 7, |a, b| a + b);
        let batch = forest.predict_batch(&qs);
        assert_eq!(batch.len(), qs.len());
        for (x, &b) in qs.iter().zip(&batch) {
            assert_eq!(forest.predict(x).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn soa_matches_arena_reference() {
        let (xs, ys) = make_dataset(300, 8, |a, b| (a + 2.0 * b).tanh());
        let params = ForestParams { n_trees: 12, max_depth: 8, ..Default::default() };
        let arena = reference::ArenaForest::fit(&xs, &ys, &params);
        let soa = RandomForest::fit(&xs, &ys, &params);
        assert_eq!(arena.n_trees(), soa.n_trees());
        for x in xs.iter().take(64) {
            assert_eq!(arena.predict(x).to_bits(), soa.predict(x).to_bits());
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let (xs, ys) = make_dataset(50, 9, |a, _| a);
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!(forest.predict_batch(&[]).is_empty());
    }
}
