//! Random-forest regression from scratch (CART trees + bagging).
//!
//! The paper fits the η and ρ correction factors with "an efficient
//! random forest regression model" over polynomially expanded features.
//! This is that regressor: variance-reduction split search over sorted
//! feature columns, bootstrap-bagged ensemble, deterministic under a
//! seed. Fitting a few hundred samples with 16 trees takes < 10 ms.

use crate::util::rng::Rng;

/// Hyperparameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    pub n_trees: usize,
    pub max_depth: usize,
    /// Minimum samples to attempt a split.
    pub min_split: usize,
    /// Features considered per split (None = all).
    pub max_features: Option<usize>,
    pub seed: u64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams { n_trees: 24, max_depth: 10, min_split: 4, max_features: None, seed: 7 }
    }
}

#[derive(Debug, Clone)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,  // node index
        right: usize, // node index
    },
}

/// One CART regression tree stored as a flat arena.
#[derive(Debug, Clone)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn fit(
        xs: &[Vec<f64>],
        ys: &[f64],
        idx: &mut [usize],
        params: &ForestParams,
        rng: &mut Rng,
    ) -> Tree {
        let mut tree = Tree { nodes: Vec::new() };
        tree.build(xs, ys, idx, 0, params, rng);
        tree
    }

    fn build(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[f64],
        idx: &mut [usize],
        depth: usize,
        params: &ForestParams,
        rng: &mut Rng,
    ) -> usize {
        let mean = idx.iter().map(|&i| ys[i]).sum::<f64>() / idx.len() as f64;
        if depth >= params.max_depth || idx.len() < params.min_split {
            self.nodes.push(Node::Leaf { value: mean });
            return self.nodes.len() - 1;
        }
        let n_features = xs[0].len();
        let k = params.max_features.unwrap_or(n_features).min(n_features);
        // Sample candidate features without replacement.
        let mut feats: Vec<usize> = (0..n_features).collect();
        rng.shuffle(&mut feats);
        feats.truncate(k);

        let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, score)
        for &f in &feats {
            if let Some((thr, score)) = best_split_on_feature(xs, ys, idx, f) {
                if best.map_or(true, |(_, _, s)| score < s) {
                    best = Some((f, thr, score));
                }
            }
        }
        let Some((feature, threshold, _)) = best else {
            self.nodes.push(Node::Leaf { value: mean });
            return self.nodes.len() - 1;
        };
        // Partition indices in place.
        let mut lo = 0;
        let mut hi = idx.len();
        while lo < hi {
            if xs[idx[lo]][feature] <= threshold {
                lo += 1;
            } else {
                hi -= 1;
                idx.swap(lo, hi);
            }
        }
        if lo == 0 || lo == idx.len() {
            self.nodes.push(Node::Leaf { value: mean });
            return self.nodes.len() - 1;
        }
        // Reserve our slot, then build children.
        let my_slot = self.nodes.len();
        self.nodes.push(Node::Leaf { value: mean }); // placeholder
        let (left_idx, right_idx) = {
            let (l, r) = idx.split_at_mut(lo);
            let li = self.build(xs, ys, l, depth + 1, params, rng);
            let ri = self.build(xs, ys, r, depth + 1, params, rng);
            (li, ri)
        };
        self.nodes[my_slot] = Node::Split { feature, threshold, left: left_idx, right: right_idx };
        my_slot
    }

    fn predict(&self, x: &[f64]) -> f64 {
        // Root is the first node pushed for the full index set — but our
        // recursive build pushes leaves before parents; track the root
        // explicitly: the *last* call frame's slot is node 0 only when
        // the root is a leaf. We store root at build time instead.
        self.predict_from(self.root(), x)
    }

    fn root(&self) -> usize {
        // The root is the first slot reserved in `build`'s outermost
        // call: a leaf pushed at index 0 (pure leaf tree) or the
        // placeholder slot 0 (split). Either way it is index 0.
        0
    }

    fn predict_from(&self, mut node: usize, x: &[f64]) -> f64 {
        loop {
            match &self.nodes[node] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    node = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

/// Best variance-reduction split for one feature: returns (threshold,
/// weighted child SSE).
fn best_split_on_feature(
    xs: &[Vec<f64>],
    ys: &[f64],
    idx: &[usize],
    feature: usize,
) -> Option<(f64, f64)> {
    let mut pairs: Vec<(f64, f64)> = idx.iter().map(|&i| (xs[i][feature], ys[i])).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let n = pairs.len();
    let total_sum: f64 = pairs.iter().map(|p| p.1).sum();
    let total_sq: f64 = pairs.iter().map(|p| p.1 * p.1).sum();
    let mut left_sum = 0.0;
    let mut left_sq = 0.0;
    let mut best: Option<(f64, f64)> = None;
    for i in 0..n - 1 {
        left_sum += pairs[i].1;
        left_sq += pairs[i].1 * pairs[i].1;
        // Skip ties — can't split between equal feature values.
        if pairs[i].0 == pairs[i + 1].0 {
            continue;
        }
        let nl = (i + 1) as f64;
        let nr = (n - i - 1) as f64;
        let right_sum = total_sum - left_sum;
        let right_sq = total_sq - left_sq;
        let sse = (left_sq - left_sum * left_sum / nl) + (right_sq - right_sum * right_sum / nr);
        let thr = 0.5 * (pairs[i].0 + pairs[i + 1].0);
        if best.map_or(true, |(_, s)| sse < s) {
            best = Some((thr, sse));
        }
    }
    best
}

/// Bagged ensemble of CART regression trees.
#[derive(Debug, Clone)]
pub struct RandomForest {
    trees: Vec<Tree>,
}

impl RandomForest {
    /// Fit on feature rows `xs` and targets `ys`.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: &ForestParams) -> RandomForest {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty(), "empty training set");
        let mut rng = Rng::new(params.seed);
        let n = xs.len();
        let trees = (0..params.n_trees)
            .map(|_| {
                // Bootstrap sample.
                let mut idx: Vec<usize> = (0..n).map(|_| rng.below(n)).collect();
                Tree::fit(xs, ys, &mut idx, params, &mut rng)
            })
            .collect();
        RandomForest { trees }
    }

    /// Mean prediction across trees.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let s: f64 = self.trees.iter().map(|t| t.predict(x)).sum();
        s / self.trees.len() as f64
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn make_dataset(n: usize, seed: u64, f: impl Fn(f64, f64) -> f64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.range_f64(-3.0, 3.0);
            let b = rng.range_f64(-3.0, 3.0);
            xs.push(vec![a, b]);
            ys.push(f(a, b));
        }
        (xs, ys)
    }

    #[test]
    fn fits_smooth_function() {
        let (xs, ys) = make_dataset(800, 1, |a, b| (a * 1.5).sin() + 0.3 * b * b);
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        let (txs, tys) = make_dataset(200, 2, |a, b| (a * 1.5).sin() + 0.3 * b * b);
        let preds: Vec<f64> = txs.iter().map(|x| forest.predict(x)).collect();
        let r2 = stats::r2(&preds, &tys);
        assert!(r2 > 0.9, "r2 {r2}");
    }

    #[test]
    fn deterministic_under_seed() {
        let (xs, ys) = make_dataset(200, 3, |a, b| a + b);
        let f1 = RandomForest::fit(&xs, &ys, &ForestParams::default());
        let f2 = RandomForest::fit(&xs, &ys, &ForestParams::default());
        for x in xs.iter().take(50) {
            assert_eq!(f1.predict(x), f2.predict(x));
        }
    }

    #[test]
    fn constant_target_predicts_constant() {
        let (xs, _) = make_dataset(100, 4, |_, _| 0.0);
        let ys = vec![5.5; 100];
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!((forest.predict(&[0.0, 0.0]) - 5.5).abs() < 1e-9);
    }

    #[test]
    fn step_function_learned() {
        let (xs, ys) = make_dataset(600, 5, |a, _| if a > 0.5 { 10.0 } else { 1.0 });
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!(forest.predict(&[2.0, 0.0]) > 8.0);
        assert!(forest.predict(&[-2.0, 0.0]) < 3.0);
    }

    #[test]
    fn handles_single_feature_duplicates() {
        let xs = vec![vec![1.0], vec![1.0], vec![1.0], vec![2.0], vec![2.0]];
        let ys = vec![1.0, 1.0, 1.0, 4.0, 4.0];
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!(forest.predict(&[1.0]) < 2.5);
        assert!(forest.predict(&[2.0]) > 2.5);
    }
}
