//! Random-forest regression from scratch (CART trees + bagging), laid
//! out for the planner's batch-evaluation hot path.
//!
//! The paper fits the η and ρ correction factors with "an efficient
//! random forest regression model" over polynomially expanded features.
//! This is that regressor: variance-reduction split search over sorted
//! feature columns, bootstrap-bagged ensemble, deterministic under a
//! seed. Fitting a few hundred samples with 16 trees takes < 10 ms.
//!
//! # Storage layout (SoA)
//!
//! Trees are built into a conventional enum-node arena
//! ([`reference::ArenaForest`]) and then **flattened** into one
//! structure-of-arrays over all trees: parallel `feature` / `threshold`
//! / `left` / `right` vectors indexed by a forest-global node id, plus
//! one root id per tree. Leaves are encoded with the sentinel
//! `feature == LEAF_SENTINEL` and store their value in `threshold`, so
//! traversal touches exactly two small arrays per step instead of
//! pattern-matching 40-byte enum nodes scattered across per-tree
//! allocations.
//!
//! # Shared-presort training
//!
//! [`RandomForest::fit`] trains through [`fit_presorted`]: every
//! feature column is argsorted **once per fit** and shared by all
//! trees; each tree derives its root's ordered member lists from the
//! global order in O(n·F), and every split partitions the parent's
//! lists order-preservingly — no node ever sorts. The per-node
//! re-sorting path is retained in [`reference::ArenaForest::fit`];
//! both produce members in the canonical (value, global id, slot)
//! order at every node, so the fitted trees are **bit-identical**
//! (asserted in `presorted_fit_matches_reference_bitwise`).
//!
//! # Batch evaluation
//!
//! [`RandomForest::predict_batch`] dispatches on batch size: planner-
//! sized batches (≥ 16 rows) take the **levelized breadth-first** walk
//! (all in-flight rows advance one level per pass, so the dependent
//! node loads pipeline across rows), smaller ones the **tree-major**
//! walk (each tree's hot, contiguous node range is reused across all
//! rows). Per-row results are bit-identical to
//! [`RandomForest::predict`] in both — all paths accumulate per-tree
//! predictions in tree order and divide once — and the property tests
//! in `rust/tests/prop_invariants.rs` pin that down.

/// Hyperparameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    pub n_trees: usize,
    pub max_depth: usize,
    /// Minimum samples to attempt a split.
    pub min_split: usize,
    /// Features considered per split (None = all).
    pub max_features: Option<usize>,
    pub seed: u64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams { n_trees: 24, max_depth: 10, min_split: 4, max_features: None, seed: 7 }
    }
}

use crate::util::rng::Rng;

/// `feature` value marking a leaf node (its `threshold` is the value).
const LEAF_SENTINEL: u32 = u32::MAX;

/// Batch size at which [`RandomForest::predict_batch`] switches from
/// the tree-major walk to the levelized breadth-first walk.
const LEVELIZED_MIN_BATCH: usize = 16;

/// Prefix-scan split search over one feature's members in **canonical
/// order** — the shared scoring core of the per-node re-sorting
/// reference path and the presorted production path. `members` yields
/// (feature value, global sample id) in (value, global id, slot) order;
/// both paths produce exactly that sequence, so the prefix sums — and
/// therefore the chosen thresholds — are bit-identical. Returns
/// (threshold, weighted child SSE).
fn best_split_scan(pairs: &[(f64, usize)], ys: &[f64]) -> Option<(f64, f64)> {
    let n = pairs.len();
    let total_sum: f64 = pairs.iter().map(|p| ys[p.1]).sum();
    let total_sq: f64 = pairs.iter().map(|p| ys[p.1] * ys[p.1]).sum();
    let mut left_sum = 0.0;
    let mut left_sq = 0.0;
    let mut best: Option<(f64, f64)> = None;
    for i in 0..n - 1 {
        let y = ys[pairs[i].1];
        left_sum += y;
        left_sq += y * y;
        // Skip ties — can't split between equal feature values.
        if pairs[i].0 == pairs[i + 1].0 {
            continue;
        }
        let nl = (i + 1) as f64;
        let nr = (n - i - 1) as f64;
        let right_sum = total_sum - left_sum;
        let right_sq = total_sq - left_sq;
        let sse = (left_sq - left_sum * left_sum / nl) + (right_sq - right_sum * right_sum / nr);
        let thr = 0.5 * (pairs[i].0 + pairs[i + 1].0);
        if best.map_or(true, |(_, s)| sse < s) {
            best = Some((thr, sse));
        }
    }
    best
}

/// Best variance-reduction split for one feature, re-sorting the node's
/// members (the reference path). `idx` arrives in bootstrap-slot order
/// and the sort is stable, so ties land in (value, global id, slot)
/// order — the canonical order the presorted path reproduces.
fn best_split_on_feature(
    xs: &[Vec<f64>],
    ys: &[f64],
    idx: &[usize],
    feature: usize,
) -> Option<(f64, f64)> {
    let mut pairs: Vec<(f64, usize)> = idx.iter().map(|&i| (xs[i][feature], i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    best_split_scan(&pairs, ys)
}

/// The pre-flattening enum-arena representation. Kept as the build
/// intermediate and as the reference implementation the SoA layout is
/// validated against (see `prop_soa_forest_matches_arena_reference`).
pub mod reference {
    use super::{best_split_on_feature, ForestParams};
    use crate::util::rng::Rng;

    #[derive(Debug, Clone)]
    pub(super) enum Node {
        Leaf {
            value: f64,
        },
        Split {
            feature: usize,
            threshold: f64,
            left: usize,  // node index
            right: usize, // node index
        },
    }

    /// One CART regression tree stored as a flat arena of enum nodes.
    #[derive(Debug, Clone)]
    pub struct Tree {
        pub(super) nodes: Vec<Node>,
    }

    impl Tree {
        pub(super) fn fit(
            xs: &[Vec<f64>],
            ys: &[f64],
            idx: &mut [usize],
            params: &ForestParams,
            rng: &mut Rng,
        ) -> Tree {
            let mut tree = Tree { nodes: Vec::new() };
            tree.build(xs, ys, idx, 0, params, rng);
            tree
        }

        fn build(
            &mut self,
            xs: &[Vec<f64>],
            ys: &[f64],
            idx: &mut [usize],
            depth: usize,
            params: &ForestParams,
            rng: &mut Rng,
        ) -> usize {
            let mean = idx.iter().map(|&i| ys[i]).sum::<f64>() / idx.len() as f64;
            if depth >= params.max_depth || idx.len() < params.min_split {
                self.nodes.push(Node::Leaf { value: mean });
                return self.nodes.len() - 1;
            }
            let n_features = xs[0].len();
            let k = params.max_features.unwrap_or(n_features).min(n_features);
            // Sample candidate features without replacement.
            let mut feats: Vec<usize> = (0..n_features).collect();
            rng.shuffle(&mut feats);
            feats.truncate(k);

            let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, score)
            for &f in &feats {
                if let Some((thr, score)) = best_split_on_feature(xs, ys, idx, f) {
                    if best.map_or(true, |(_, _, s)| score < s) {
                        best = Some((f, thr, score));
                    }
                }
            }
            let Some((feature, threshold, _)) = best else {
                self.nodes.push(Node::Leaf { value: mean });
                return self.nodes.len() - 1;
            };
            // Order-preserving partition: children keep bootstrap-slot
            // order, so every node's member list stays in the canonical
            // order the presorted fast path reproduces (see
            // [`super::fit_presorted`]).
            let mut buf: Vec<usize> = Vec::with_capacity(idx.len());
            buf.extend(idx.iter().copied().filter(|&i| xs[i][feature] <= threshold));
            let lo = buf.len();
            buf.extend(idx.iter().copied().filter(|&i| xs[i][feature] > threshold));
            idx.copy_from_slice(&buf);
            if lo == 0 || lo == idx.len() {
                self.nodes.push(Node::Leaf { value: mean });
                return self.nodes.len() - 1;
            }
            // Reserve our slot, then build children.
            let my_slot = self.nodes.len();
            self.nodes.push(Node::Leaf { value: mean }); // placeholder
            let (left_idx, right_idx) = {
                let (l, r) = idx.split_at_mut(lo);
                let li = self.build(xs, ys, l, depth + 1, params, rng);
                let ri = self.build(xs, ys, r, depth + 1, params, rng);
                (li, ri)
            };
            self.nodes[my_slot] =
                Node::Split { feature, threshold, left: left_idx, right: right_idx };
            my_slot
        }

        /// The root is the slot reserved by the outermost `build` call:
        /// index 0 whether leaf or split.
        fn predict(&self, x: &[f64]) -> f64 {
            let mut node = 0;
            loop {
                match &self.nodes[node] {
                    Node::Leaf { value } => return *value,
                    Node::Split { feature, threshold, left, right } => {
                        node = if x[*feature] <= *threshold { *left } else { *right };
                    }
                }
            }
        }
    }

    /// Bagged ensemble over enum-arena trees — the pre-SoA
    /// implementation, kept for equivalence testing and as the build
    /// intermediate.
    #[derive(Debug, Clone)]
    pub struct ArenaForest {
        pub(super) trees: Vec<Tree>,
    }

    impl ArenaForest {
        /// Fit on feature rows `xs` and targets `ys`. Consumes the RNG
        /// stream exactly like [`super::RandomForest::fit`], so the two
        /// produce identical ensembles for identical params.
        pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: &ForestParams) -> ArenaForest {
            assert_eq!(xs.len(), ys.len());
            assert!(!xs.is_empty(), "empty training set");
            let mut rng = Rng::new(params.seed);
            let n = xs.len();
            let trees = (0..params.n_trees)
                .map(|_| {
                    // Bootstrap sample.
                    let mut idx: Vec<usize> = (0..n).map(|_| rng.below(n)).collect();
                    Tree::fit(xs, ys, &mut idx, params, &mut rng)
                })
                .collect();
            ArenaForest { trees }
        }

        /// Mean prediction across trees.
        pub fn predict(&self, x: &[f64]) -> f64 {
            let s: f64 = self.trees.iter().map(|t| t.predict(x)).sum();
            s / self.trees.len() as f64
        }

        pub fn n_trees(&self) -> usize {
            self.trees.len()
        }
    }
}

// ---------------------------------------------------------------------------
// Shared-presort training (the production fit path)
// ---------------------------------------------------------------------------

/// Per-fit shared feature presort: for every feature, the sample ids
/// `0..n` ordered by (feature value, sample id). Computed **once per
/// fit** and shared by every tree — each tree derives its root's
/// ordered member lists from it in O(n·F), and every split partitions
/// the parent's lists order-preservingly, so no node ever sorts.
fn presort_columns(xs: &[Vec<f64>]) -> Vec<Vec<u32>> {
    (0..xs[0].len())
        .map(|f| {
            let mut order: Vec<u32> = (0..xs.len() as u32).collect();
            order.sort_by(|&a, &b| {
                xs[a as usize][f]
                    .partial_cmp(&xs[b as usize][f])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            order
        })
        .collect()
}

/// Fit a forest **sharing sorted feature columns across all trees** —
/// bit-identical to [`reference::ArenaForest::fit`] (same RNG stream,
/// same canonical (value, global id, slot) member order at every node,
/// same arena layout) with the per-node `O(n log n)` sorts replaced by
/// `O(n)` order-preserving partitions of the presorted columns.
/// [`RandomForest::fit`] trains through this path.
pub fn fit_presorted(xs: &[Vec<f64>], ys: &[f64], params: &ForestParams) -> reference::ArenaForest {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty(), "empty training set");
    let presort = presort_columns(xs);
    let mut rng = Rng::new(params.seed);
    let n = xs.len();
    let trees = (0..params.n_trees)
        .map(|_| {
            // Bootstrap sample (same RNG draws as the reference fit).
            let idx: Vec<usize> = (0..n).map(|_| rng.below(n)).collect();
            // Bootstrap-duplicate slots of each sample, ascending.
            let mut slots_of: Vec<Vec<u32>> = vec![Vec::new(); n];
            for (s, &g) in idx.iter().enumerate() {
                slots_of[g].push(s as u32);
            }
            // Root member lists: global presort order with duplicate
            // slots emitted ascending → (value, global id, slot) order.
            let cols: Vec<Vec<u32>> = presort
                .iter()
                .map(|order| {
                    order
                        .iter()
                        .flat_map(|&g| slots_of[g as usize].iter().copied())
                        .collect()
                })
                .collect();
            let slots: Vec<u32> = (0..n as u32).collect();
            let mut tree = reference::Tree { nodes: Vec::new() };
            build_presorted(&mut tree, xs, ys, &idx, slots, cols, 0, params, &mut rng);
            tree
        })
        .collect();
    reference::ArenaForest { trees }
}

#[allow(clippy::too_many_arguments)]
fn build_presorted(
    tree: &mut reference::Tree,
    xs: &[Vec<f64>],
    ys: &[f64],
    idx: &[usize],
    slots: Vec<u32>,
    cols: Vec<Vec<u32>>,
    depth: usize,
    params: &ForestParams,
    rng: &mut Rng,
) -> usize {
    let mean = slots.iter().map(|&s| ys[idx[s as usize]]).sum::<f64>() / slots.len() as f64;
    if depth >= params.max_depth || slots.len() < params.min_split {
        tree.nodes.push(reference::Node::Leaf { value: mean });
        return tree.nodes.len() - 1;
    }
    let n_features = xs[0].len();
    let k = params.max_features.unwrap_or(n_features).min(n_features);
    let mut feats: Vec<usize> = (0..n_features).collect();
    rng.shuffle(&mut feats);
    feats.truncate(k);

    let mut best: Option<(usize, f64, f64)> = None;
    let mut pairs: Vec<(f64, usize)> = Vec::with_capacity(slots.len());
    for &f in &feats {
        pairs.clear();
        pairs.extend(cols[f].iter().map(|&s| (xs[idx[s as usize]][f], idx[s as usize])));
        if let Some((thr, score)) = best_split_scan(&pairs, ys) {
            if best.map_or(true, |(_, _, s)| score < s) {
                best = Some((f, thr, score));
            }
        }
    }
    let Some((feature, threshold, _)) = best else {
        tree.nodes.push(reference::Node::Leaf { value: mean });
        return tree.nodes.len() - 1;
    };
    let goes_left = |s: u32| xs[idx[s as usize]][feature] <= threshold;
    let (left_slots, right_slots): (Vec<u32>, Vec<u32>) =
        slots.iter().copied().partition(|&s| goes_left(s));
    if left_slots.is_empty() || right_slots.is_empty() {
        tree.nodes.push(reference::Node::Leaf { value: mean });
        return tree.nodes.len() - 1;
    }
    // Order-preserving column partition: each child's per-feature list
    // stays in (value, global id, slot) order — no re-sorting, ever.
    let mut left_cols = Vec::with_capacity(cols.len());
    let mut right_cols = Vec::with_capacity(cols.len());
    for col in &cols {
        let (l, r): (Vec<u32>, Vec<u32>) = col.iter().copied().partition(|&s| goes_left(s));
        left_cols.push(l);
        right_cols.push(r);
    }
    let my_slot = tree.nodes.len();
    tree.nodes.push(reference::Node::Leaf { value: mean }); // placeholder
    let li = build_presorted(tree, xs, ys, idx, left_slots, left_cols, depth + 1, params, rng);
    let ri = build_presorted(tree, xs, ys, idx, right_slots, right_cols, depth + 1, params, rng);
    tree.nodes[my_slot] = reference::Node::Split { feature, threshold, left: li, right: ri };
    my_slot
}

/// Bagged ensemble of CART regression trees in the flattened SoA
/// layout (see the module docs).
#[derive(Debug, Clone)]
pub struct RandomForest {
    /// Split feature per node; [`LEAF_SENTINEL`] marks a leaf.
    feature: Vec<u32>,
    /// Split threshold per node; the leaf *value* at leaf nodes.
    threshold: Vec<f64>,
    /// Child node ids (forest-global indices); 0 at leaves.
    left: Vec<u32>,
    right: Vec<u32>,
    /// Root node id of each tree.
    roots: Vec<u32>,
}

impl RandomForest {
    /// Fit on feature rows `xs` and targets `ys`, training through the
    /// shared-presort path ([`fit_presorted`]) — bit-identical trees to
    /// the re-sorting [`reference::ArenaForest::fit`].
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: &ForestParams) -> RandomForest {
        Self::flatten(&fit_presorted(xs, ys, params))
    }

    /// Flatten an enum-arena ensemble into the SoA layout. Node order
    /// within each tree is preserved, with per-tree indices rebased by
    /// the tree's offset in the global arrays.
    pub fn flatten(arena: &reference::ArenaForest) -> RandomForest {
        let total: usize = arena.trees.iter().map(|t| t.nodes.len()).sum();
        let mut f = RandomForest {
            feature: Vec::with_capacity(total),
            threshold: Vec::with_capacity(total),
            left: Vec::with_capacity(total),
            right: Vec::with_capacity(total),
            roots: Vec::with_capacity(arena.trees.len()),
        };
        for tree in &arena.trees {
            let base = f.feature.len() as u32;
            f.roots.push(base); // arena root is always slot 0
            for node in &tree.nodes {
                match node {
                    reference::Node::Leaf { value } => {
                        f.feature.push(LEAF_SENTINEL);
                        f.threshold.push(*value);
                        f.left.push(0);
                        f.right.push(0);
                    }
                    reference::Node::Split { feature, threshold, left, right } => {
                        f.feature.push(*feature as u32);
                        f.threshold.push(*threshold);
                        f.left.push(base + *left as u32);
                        f.right.push(base + *right as u32);
                    }
                }
            }
        }
        f
    }

    /// Walk one tree for one row.
    #[inline]
    fn predict_tree(&self, root: u32, x: &[f64]) -> f64 {
        let mut i = root as usize;
        loop {
            let f = self.feature[i];
            let t = self.threshold[i];
            if f == LEAF_SENTINEL {
                return t;
            }
            i = if x[f as usize] <= t { self.left[i] as usize } else { self.right[i] as usize };
        }
    }

    /// Mean prediction across trees.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let s: f64 = self.roots.iter().map(|&r| self.predict_tree(r, x)).sum();
        s / self.roots.len() as f64
    }

    /// Batch prediction. Dispatches on batch size: planner-sized
    /// batches (≥ 16 rows) take the levelized breadth-first walk,
    /// smaller ones the tree-major walk. Per-row results are
    /// bit-identical to [`Self::predict`] either way — both accumulate
    /// one leaf value per tree in tree order and divide once.
    pub fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        if xs.len() >= LEVELIZED_MIN_BATCH {
            self.predict_batch_levelized(xs)
        } else {
            self.predict_batch_tree_major(xs)
        }
    }

    /// Tree-major batch walk: each tree's (hot, contiguous) node range
    /// is reused across all rows before moving to the next tree.
    pub fn predict_batch_tree_major(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        let mut acc = vec![0.0f64; xs.len()];
        for &root in &self.roots {
            for (a, x) in acc.iter_mut().zip(xs) {
                *a += self.predict_tree(root, x);
            }
        }
        let n = self.roots.len() as f64;
        for a in &mut acc {
            // Same final op as `predict` (divide, not multiply-by-inverse)
            // to stay bit-identical.
            *a /= n;
        }
        acc
    }

    /// Levelized breadth-first batch walk: per tree, every in-flight
    /// row advances one level per pass, so the inner loop is a run of
    /// independent row steps over one shallow node front instead of a
    /// full dependent pointer chase per row — the loads pipeline across
    /// rows. Rows retire from the front as they reach a leaf.
    pub fn predict_batch_levelized(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        let mut acc = vec![0.0f64; xs.len()];
        let mut cursor: Vec<u32> = vec![0; xs.len()];
        let mut front: Vec<u32> = Vec::with_capacity(xs.len());
        let mut next: Vec<u32> = Vec::with_capacity(xs.len());
        for &root in &self.roots {
            cursor.iter_mut().for_each(|c| *c = root);
            front.clear();
            front.extend(0..xs.len() as u32);
            while !front.is_empty() {
                next.clear();
                for &row in &front {
                    let i = cursor[row as usize] as usize;
                    let f = self.feature[i];
                    let t = self.threshold[i];
                    if f == LEAF_SENTINEL {
                        acc[row as usize] += t;
                    } else {
                        cursor[row as usize] = if xs[row as usize][f as usize] <= t {
                            self.left[i]
                        } else {
                            self.right[i]
                        };
                        next.push(row);
                    }
                }
                std::mem::swap(&mut front, &mut next);
            }
        }
        let n = self.roots.len() as f64;
        for a in &mut acc {
            // Same final op as `predict` (divide, not multiply-by-inverse)
            // to stay bit-identical.
            *a /= n;
        }
        acc
    }

    pub fn n_trees(&self) -> usize {
        self.roots.len()
    }

    /// Total nodes across all trees (diagnostics).
    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats;

    fn make_dataset(n: usize, seed: u64, f: impl Fn(f64, f64) -> f64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.range_f64(-3.0, 3.0);
            let b = rng.range_f64(-3.0, 3.0);
            xs.push(vec![a, b]);
            ys.push(f(a, b));
        }
        (xs, ys)
    }

    #[test]
    fn fits_smooth_function() {
        let (xs, ys) = make_dataset(800, 1, |a, b| (a * 1.5).sin() + 0.3 * b * b);
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        let (txs, tys) = make_dataset(200, 2, |a, b| (a * 1.5).sin() + 0.3 * b * b);
        let preds: Vec<f64> = txs.iter().map(|x| forest.predict(x)).collect();
        let r2 = stats::r2(&preds, &tys);
        assert!(r2 > 0.9, "r2 {r2}");
    }

    #[test]
    fn deterministic_under_seed() {
        let (xs, ys) = make_dataset(200, 3, |a, b| a + b);
        let f1 = RandomForest::fit(&xs, &ys, &ForestParams::default());
        let f2 = RandomForest::fit(&xs, &ys, &ForestParams::default());
        for x in xs.iter().take(50) {
            assert_eq!(f1.predict(x), f2.predict(x));
        }
    }

    #[test]
    fn constant_target_predicts_constant() {
        let (xs, _) = make_dataset(100, 4, |_, _| 0.0);
        let ys = vec![5.5; 100];
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!((forest.predict(&[0.0, 0.0]) - 5.5).abs() < 1e-9);
    }

    #[test]
    fn step_function_learned() {
        let (xs, ys) = make_dataset(600, 5, |a, _| if a > 0.5 { 10.0 } else { 1.0 });
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!(forest.predict(&[2.0, 0.0]) > 8.0);
        assert!(forest.predict(&[-2.0, 0.0]) < 3.0);
    }

    #[test]
    fn handles_single_feature_duplicates() {
        let xs = vec![vec![1.0], vec![1.0], vec![1.0], vec![2.0], vec![2.0]];
        let ys = vec![1.0, 1.0, 1.0, 4.0, 4.0];
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!(forest.predict(&[1.0]) < 2.5);
        assert!(forest.predict(&[2.0]) > 2.5);
    }

    #[test]
    fn batch_matches_scalar_bitwise() {
        let (xs, ys) = make_dataset(400, 6, |a, b| a * b + (b * 0.7).cos());
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        let (qs, _) = make_dataset(97, 7, |a, b| a + b);
        let batch = forest.predict_batch(&qs);
        assert_eq!(batch.len(), qs.len());
        for (x, &b) in qs.iter().zip(&batch) {
            assert_eq!(forest.predict(x).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn soa_matches_arena_reference() {
        let (xs, ys) = make_dataset(300, 8, |a, b| (a + 2.0 * b).tanh());
        let params = ForestParams { n_trees: 12, max_depth: 8, ..Default::default() };
        let arena = reference::ArenaForest::fit(&xs, &ys, &params);
        let soa = RandomForest::fit(&xs, &ys, &params);
        assert_eq!(arena.n_trees(), soa.n_trees());
        for x in xs.iter().take(64) {
            assert_eq!(arena.predict(x).to_bits(), soa.predict(x).to_bits());
        }
    }

    #[test]
    fn presorted_fit_matches_reference_bitwise() {
        // Duplicated feature values stress the tie-break: both paths
        // must order ties by (value, global id, slot).
        let (mut xs, ys) = make_dataset(300, 10, |a, b| a * 0.5 - b);
        for row in xs.iter_mut().step_by(3) {
            row[0] = row[0].round(); // force cross-sample duplicates
        }
        let params = ForestParams { n_trees: 12, max_depth: 8, ..Default::default() };
        let resorted = RandomForest::flatten(&reference::ArenaForest::fit(&xs, &ys, &params));
        let presorted = RandomForest::flatten(&fit_presorted(&xs, &ys, &params));
        assert_eq!(resorted.roots, presorted.roots);
        assert_eq!(resorted.feature, presorted.feature);
        assert_eq!(resorted.left, presorted.left);
        assert_eq!(resorted.right, presorted.right);
        let same_thresholds = resorted
            .threshold
            .iter()
            .zip(&presorted.threshold)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same_thresholds, "presorted fit drifted from the re-sorting reference");
    }

    #[test]
    fn levelized_matches_tree_major_bitwise() {
        let (xs, ys) = make_dataset(400, 11, |a, b| (a - b).sin() + a * 0.1);
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        for rows in [1usize, 5, 16, 97] {
            let (qs, _) = make_dataset(rows, 12, |a, b| a + b);
            let tree_major = forest.predict_batch_tree_major(&qs);
            let levelized = forest.predict_batch_levelized(&qs);
            let dispatched = forest.predict_batch(&qs);
            for ((a, b), c) in tree_major.iter().zip(&levelized).zip(&dispatched) {
                assert_eq!(a.to_bits(), b.to_bits(), "levelized diverged at {rows} rows");
                assert_eq!(a.to_bits(), c.to_bits(), "dispatch diverged at {rows} rows");
            }
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let (xs, ys) = make_dataset(50, 9, |a, _| a);
        let forest = RandomForest::fit(&xs, &ys, &ForestParams::default());
        assert!(forest.predict_batch(&[]).is_empty());
    }
}
