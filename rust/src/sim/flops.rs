//! Analytic FLOP and byte counts for the Attention and Expert modules.
//!
//! These are the `F_module` inputs of the paper's computational
//! simulation model. Counts are *per layer* and *per device* given a
//! parallel strategy; byte counts feed the roofline term that dominates
//! the memory-bound decode stage.

use crate::config::model::MoEModelConfig;
use crate::strategy::{AttnStrategy, ExpertStrategy};

/// Inference stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Prompt processing: `seq` tokens per sequence, compute-bound.
    Prefill,
    /// Single-token generation against a KV cache of length `seq`,
    /// memory-bound.
    Decode,
}

/// FLOPs + memory traffic of one module invocation on one device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    /// Floating-point operations.
    pub flops: f64,
    /// Bytes read+written from/to HBM (weights + activations + KV).
    pub bytes: f64,
}

impl OpCost {
    pub const ZERO: OpCost = OpCost { flops: 0.0, bytes: 0.0 };

    pub fn add(self, other: OpCost) -> OpCost {
        OpCost { flops: self.flops + other.flops, bytes: self.bytes + other.bytes }
    }

    /// Arithmetic intensity (FLOPs per byte).
    pub fn intensity(self) -> f64 {
        if self.bytes == 0.0 {
            f64::INFINITY
        } else {
            self.flops / self.bytes
        }
    }
}

/// Per-device Attention-module cost for one layer.
///
/// `batch` is the *global* batch; DP divides it, TP divides heads.
/// `seq` is the prompt length (prefill) or current context length
/// (decode). GQA: K/V projections use `kv_heads`.
pub fn attention_cost(
    m: &MoEModelConfig,
    s: &AttnStrategy,
    stage: Stage,
    batch: usize,
    seq: usize,
) -> OpCost {
    let b = (batch as f64 / s.dp as f64).ceil();
    let hd = m.head_dim as f64;
    let qh = m.q_heads as f64 / s.tp as f64; // heads per device
    let kvh = (m.kv_heads as f64 / s.tp as f64).max(1.0); // replicated if tp > kv_heads
    let h = m.hidden as f64;
    let dt = m.dtype_bytes as f64;

    let (tokens, ctx) = match stage {
        Stage::Prefill => (seq as f64, seq as f64),
        Stage::Decode => (1.0, seq as f64),
    };

    // Projections: Q (h -> qh*hd), K,V (h -> kvh*hd), O (qh*hd -> h).
    let proj_flops = 2.0 * b * tokens * h * (qh * hd + 2.0 * kvh * hd + qh * hd);
    let proj_weight_bytes = dt * h * (qh * hd + 2.0 * kvh * hd + qh * hd);
    let proj_act_bytes = dt * b * tokens * (2.0 * h + qh * hd + 2.0 * kvh * hd + qh * hd);

    // Score + value matmuls. Causal prefill does ~half the s×s work.
    let causal = match stage {
        Stage::Prefill => 0.5,
        Stage::Decode => 1.0,
    };
    let attn_flops = 2.0 * 2.0 * b * qh * hd * tokens * ctx * causal;
    // KV traffic: decode re-reads the whole cache each step.
    let kv_bytes = dt * b * 2.0 * kvh * hd * ctx;
    let attn_act_bytes = dt * b * tokens * qh * (hd + ctx * causal).min(1e18);

    OpCost {
        flops: proj_flops + attn_flops,
        bytes: proj_weight_bytes + proj_act_bytes + kv_bytes + attn_act_bytes,
    }
}

/// Per-device Expert-module cost for one layer under a given strategy.
///
/// `imbalance` multiplies the routed-token count on the hottest device
/// (1.0 = perfectly balanced; EP decode typically > 1, see
/// [`crate::cluster::imbalance`]). TP shards every expert's intermediate
/// dim, so it sees all tokens but `inter/tp` columns; EP assigns
/// `num_experts/ep` whole experts per device.
pub fn expert_cost(
    m: &MoEModelConfig,
    s: &ExpertStrategy,
    stage: Stage,
    batch: usize,
    seq: usize,
    imbalance: f64,
) -> OpCost {
    let tokens_global = match stage {
        Stage::Prefill => (batch * seq) as f64,
        Stage::Decode => batch as f64,
    };
    let h = m.hidden as f64;
    let inter = m.moe_inter_size as f64 / s.tp as f64;
    let dt = m.dtype_bytes as f64;

    // Routed expert work: token-expert pairs this device processes.
    // EP: tokens route to experts held here — balanced share × imbalance.
    let pairs_here = tokens_global * m.top_k as f64 / s.ep as f64 * imbalance;
    // SwiGLU: gate, up, down = 3 matmuls of (h × inter).
    let routed_flops = 2.0 * 3.0 * pairs_here * h * inter;

    // Weight traffic: which experts actually get touched on this device.
    let experts_here = (m.num_experts as f64 / s.ep as f64).min(m.num_experts as f64);
    // During decode only a few experts are hit; cap by pairs.
    let touched = experts_here.min(pairs_here.max(1.0));
    // Capacity-factor padding under EP: the grouped GEMM pads every
    // owned expert's token block to the hottest load, re-streaming
    // weight panels for overflow blocks — the hot device's effective
    // weight traffic scales with the imbalance (this is the decode-stage
    // EP inefficiency of paper Fig 2).
    let weight_factor = if s.ep > 1 { imbalance } else { 1.0 };
    let routed_weight_bytes = dt * touched * 3.0 * h * inter * weight_factor;
    let routed_act_bytes = dt * pairs_here * (2.0 * h + 2.0 * inter);

    // Shared experts: always active for every token; sharded by TP only
    // (they are replicated across EP groups).
    let (shared_flops, shared_bytes) = if m.shared_experts > 0 {
        let sh_inter = m.shared_inter_size as f64 / s.tp as f64;
        let tokens_here = tokens_global / s.ep as f64; // data-split across EP group
        (
            2.0 * 3.0 * tokens_here * h * sh_inter,
            dt * (3.0 * h * sh_inter + tokens_here * (2.0 * h + 2.0 * sh_inter)),
        )
    } else {
        (0.0, 0.0)
    };

    // Gating network: tokens × num_experts logits (tiny but real).
    let gate_flops = 2.0 * tokens_global / s.ep as f64 * h * m.num_experts as f64;

    OpCost {
        flops: routed_flops + shared_flops + gate_flops,
        bytes: routed_weight_bytes + routed_act_bytes + shared_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy::{AttnStrategy, ExpertStrategy};

    fn mixtral() -> MoEModelConfig {
        MoEModelConfig::mixtral_8x7b()
    }

    #[test]
    fn tp_divides_attention_flops() {
        let m = mixtral();
        let full = attention_cost(&m, &AttnStrategy::new(1, 1), Stage::Prefill, 4, 1024);
        let tp4 = attention_cost(&m, &AttnStrategy::new(4, 1), Stage::Prefill, 4, 1024);
        let ratio = full.flops / tp4.flops;
        assert!((ratio - 4.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn dp_divides_attention_flops() {
        let m = mixtral();
        let full = attention_cost(&m, &AttnStrategy::new(1, 1), Stage::Prefill, 8, 512);
        let dp4 = attention_cost(&m, &AttnStrategy::new(1, 4), Stage::Prefill, 8, 512);
        assert!((full.flops / dp4.flops - 4.0).abs() < 0.05);
    }

    #[test]
    fn decode_is_memory_bound_prefill_compute_bound() {
        let m = mixtral();
        let s = AttnStrategy::new(1, 1);
        let pre = attention_cost(&m, &s, Stage::Prefill, 4, 2048);
        let dec = attention_cost(&m, &s, Stage::Decode, 4, 2048);
        assert!(pre.intensity() > 100.0, "prefill intensity {}", pre.intensity());
        assert!(dec.intensity() < 10.0, "decode intensity {}", dec.intensity());
    }

    #[test]
    fn expert_tp_and_ep_equal_when_balanced() {
        // With perfect balance, TP-4 and EP-4 do the same routed FLOPs.
        let m = mixtral();
        let tp = expert_cost(&m, &ExpertStrategy::new(4, 1), Stage::Prefill, 4, 1024, 1.0);
        let ep = expert_cost(&m, &ExpertStrategy::new(1, 4), Stage::Prefill, 4, 1024, 1.0);
        let rel = (tp.flops - ep.flops).abs() / tp.flops;
        assert!(rel < 0.02, "rel {rel}");
    }

    #[test]
    fn imbalance_increases_ep_compute() {
        let m = mixtral();
        let bal = expert_cost(&m, &ExpertStrategy::new(1, 4), Stage::Decode, 8, 512, 1.0);
        let imb = expert_cost(&m, &ExpertStrategy::new(1, 4), Stage::Decode, 8, 512, 1.8);
        assert!(imb.flops > bal.flops * 1.7);
    }

    #[test]
    fn decode_weight_traffic_dominated_by_touched_experts() {
        // Decode with tiny batch should not charge all 8 experts' weights
        // under EP-1 (TP): only top_k experts per token are touched.
        let m = mixtral();
        let c = expert_cost(&m, &ExpertStrategy::new(4, 1), Stage::Decode, 1, 512, 1.0);
        let one_expert_bytes =
            (m.dtype_bytes * 3 * m.hidden * m.moe_inter_size / 4) as f64;
        assert!(c.bytes < one_expert_bytes * 3.0, "bytes {}", c.bytes);
    }

    #[test]
    fn shared_experts_add_cost() {
        let q = MoEModelConfig::qwen15_moe_a27b();
        let with = expert_cost(&q, &ExpertStrategy::new(1, 4), Stage::Prefill, 4, 256, 1.0);
        let mut no_shared = q.clone();
        no_shared.shared_experts = 0;
        let without =
            expert_cost(&no_shared, &ExpertStrategy::new(1, 4), Stage::Prefill, 4, 256, 1.0);
        assert!(with.flops > without.flops);
    }
}
