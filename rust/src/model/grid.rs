//! `ShardPlan` → `DeviceGrid` lowering: the logical description of a
//! hybrid parallel layout and its concrete per-device realization.
//!
//! A [`ShardPlan`] is the logical `(AttnStrategy, ExpertStrategy)` pair
//! the planner emits for one stage. [`DeviceGrid::lower`] turns it into
//! per-device roles — `(dp_rank, tp_rank)` for the attention module and
//! `(ep_rank, etp_rank)` for the expert module — plus the collective
//! groups each role participates in:
//!
//! - **partial-sum** groups (TP): members hold column/row shards of the
//!   same weights; their module outputs *sum* to the unsharded output;
//! - **contribution-sum** group (EP): each expert block contributes the
//!   routed output of the experts it owns; block outputs *sum*;
//! - **batch-split** group (DP): each attention replica group owns a
//!   contiguous slice of the batch; group outputs *concatenate*.
//!
//! The lowering is pure math over device indices — no runtime, no
//! tensors — so every grid the [`crate::strategy::SearchSpace`] emits
//! can be checked for well-formedness in plain unit tests (roles
//! partition devices; groups are disjoint and complete).

use crate::runtime::manifest::TinyModelMeta;
use crate::strategy::{AttnStrategy, ExpertStrategy};
use crate::Result;
use std::fmt;

/// The logical per-stage execution layout: one attention strategy and
/// one expert strategy over the same device set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShardPlan {
    pub attn: AttnStrategy,
    pub expert: ExpertStrategy,
}

impl ShardPlan {
    pub fn new(attn: AttnStrategy, expert: ExpertStrategy) -> ShardPlan {
        ShardPlan { attn, expert }
    }

    /// Static TP-n: attention TP, experts TP, n devices.
    pub fn tp(n: usize) -> ShardPlan {
        ShardPlan {
            attn: AttnStrategy::new(n, 1),
            expert: ExpertStrategy::new(n, 1),
        }
    }

    /// Devices the plan spans (attention side; [`DeviceGrid::lower`]
    /// errors when the expert side disagrees).
    pub fn devices(&self) -> usize {
        self.attn.devices()
    }

    pub fn expert_label(&self) -> String {
        self.expert.label()
    }

    pub fn label(&self) -> String {
        format!("attn={} experts={}", self.attn.label(), self.expert.label())
    }
}

impl fmt::Display for ShardPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// One device's position in both module grids.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceRole {
    pub device: usize,
    /// Attention data-parallel group (owns batch slice `dp_rank`).
    pub dp_rank: usize,
    /// Attention tensor rank within the DP group (head shard).
    pub tp_rank: usize,
    /// Expert block (owns experts `[ep_rank·E/ep, (ep_rank+1)·E/ep)`).
    pub ep_rank: usize,
    /// Expert tensor rank within the block (intermediate-dim shard).
    pub etp_rank: usize,
}

/// What a collective group does with its members' outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupKind {
    /// TP combine: member outputs sum element-wise.
    PartialSum,
    /// EP combine: owned-expert contributions sum element-wise.
    ContributionSum,
    /// DP combine: member outputs concatenate along the batch axis.
    BatchSplit,
}

/// An ordered collective group (member order fixes the combine order,
/// which keeps parallel and sequential execution bit-identical).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollectiveGroup {
    pub kind: GroupKind,
    pub members: Vec<usize>,
}

/// A lowered plan: per-device roles plus the collective groups.
#[derive(Debug, Clone)]
pub struct DeviceGrid {
    pub plan: ShardPlan,
    pub devices: usize,
    pub roles: Vec<DeviceRole>,
    /// One partial-sum group per attention DP rank (members ordered by
    /// tp_rank). Indexed by `dp_rank`.
    pub attn_reduce: Vec<CollectiveGroup>,
    /// Batch-split group: the leader (tp_rank 0) of each DP group, in
    /// dp_rank order. Concatenating their reduced outputs restores the
    /// full batch.
    pub batch_split: CollectiveGroup,
    /// One partial-sum group per expert block (members ordered by
    /// etp_rank). Indexed by `ep_rank`.
    pub expert_reduce: Vec<CollectiveGroup>,
    /// Contribution-sum group: the leader (etp_rank 0) of each expert
    /// block, in ep_rank order.
    pub expert_combine: CollectiveGroup,
}

impl DeviceGrid {
    /// Lower a logical plan onto its device set. Fails when the two
    /// module strategies disagree on the device count (the paper's
    /// search space always uses all devices for both modules).
    pub fn lower(plan: &ShardPlan) -> Result<DeviceGrid> {
        let n = plan.attn.devices();
        if plan.expert.devices() != n {
            anyhow::bail!(
                "plan spans {} attention devices but {} expert devices ({})",
                n,
                plan.expert.devices(),
                plan.label()
            );
        }
        if n == 0 {
            anyhow::bail!("plan spans zero devices");
        }
        let at = plan.attn.tp;
        let et = plan.expert.tp;
        let roles: Vec<DeviceRole> = (0..n)
            .map(|d| DeviceRole {
                device: d,
                dp_rank: d / at,
                tp_rank: d % at,
                ep_rank: d / et,
                etp_rank: d % et,
            })
            .collect();
        let attn_reduce: Vec<CollectiveGroup> = (0..plan.attn.dp)
            .map(|g| CollectiveGroup {
                kind: GroupKind::PartialSum,
                members: (g * at..(g + 1) * at).collect(),
            })
            .collect();
        let batch_split = CollectiveGroup {
            kind: GroupKind::BatchSplit,
            members: attn_reduce.iter().map(|g| g.members[0]).collect(),
        };
        let expert_reduce: Vec<CollectiveGroup> = (0..plan.expert.ep)
            .map(|g| CollectiveGroup {
                kind: GroupKind::PartialSum,
                members: (g * et..(g + 1) * et).collect(),
            })
            .collect();
        let expert_combine = CollectiveGroup {
            kind: GroupKind::ContributionSum,
            members: expert_reduce.iter().map(|g| g.members[0]).collect(),
        };
        Ok(DeviceGrid {
            plan: *plan,
            devices: n,
            roles,
            attn_reduce,
            batch_split,
            expert_reduce,
            expert_combine,
        })
    }

    /// Divisibility checks against raw model dimensions: the grid is
    /// executable iff every shard is well-formed.
    pub fn check_dims(
        &self,
        q_heads: usize,
        kv_heads: usize,
        num_experts: usize,
        inter: usize,
        batch: usize,
    ) -> Result<()> {
        let a = &self.plan.attn;
        let e = &self.plan.expert;
        if q_heads % a.tp != 0 {
            anyhow::bail!("attn TP{} does not divide {} query heads", a.tp, q_heads);
        }
        if a.tp > kv_heads && a.tp % kv_heads != 0 {
            anyhow::bail!(
                "attn TP{} cannot replicate {} kv heads evenly (GQA)",
                a.tp,
                kv_heads
            );
        }
        if batch % a.dp != 0 {
            anyhow::bail!("attn DP{} does not divide batch {}", a.dp, batch);
        }
        if num_experts % e.ep != 0 {
            anyhow::bail!("EP{} does not divide {} experts", e.ep, num_experts);
        }
        if inter % e.tp != 0 {
            anyhow::bail!("expert TP{} does not divide intermediate size {}", e.tp, inter);
        }
        Ok(())
    }

    /// [`Self::check_dims`] against the serving model's metadata.
    pub fn check_meta(&self, m: &TinyModelMeta) -> Result<()> {
        self.check_dims(m.q_heads, m.kv_heads, m.num_experts, m.inter, m.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tp_plan_lowers_to_single_groups() {
        let g = DeviceGrid::lower(&ShardPlan::tp(4)).unwrap();
        assert_eq!(g.devices, 4);
        assert_eq!(g.attn_reduce.len(), 1);
        assert_eq!(g.attn_reduce[0].members, vec![0, 1, 2, 3]);
        assert_eq!(g.batch_split.members, vec![0]);
        assert_eq!(g.expert_reduce.len(), 1);
        assert_eq!(g.expert_combine.members, vec![0]);
        for (d, r) in g.roles.iter().enumerate() {
            assert_eq!(r.device, d);
            assert_eq!(r.dp_rank, 0);
            assert_eq!(r.tp_rank, d);
        }
    }

    #[test]
    fn hybrid_grid_roles_and_groups() {
        // attn DP2xTP2, experts EP2xTP2 on 4 devices.
        let plan = ShardPlan::new(AttnStrategy::new(2, 2), ExpertStrategy::new(2, 2));
        let g = DeviceGrid::lower(&plan).unwrap();
        assert_eq!(g.attn_reduce.len(), 2);
        assert_eq!(g.attn_reduce[0].members, vec![0, 1]);
        assert_eq!(g.attn_reduce[1].members, vec![2, 3]);
        assert_eq!(g.batch_split.members, vec![0, 2]);
        assert_eq!(g.expert_reduce[1].members, vec![2, 3]);
        assert_eq!(g.expert_combine.members, vec![0, 2]);
        assert_eq!(g.roles[3].dp_rank, 1);
        assert_eq!(g.roles[3].tp_rank, 1);
        assert_eq!(g.roles[3].ep_rank, 1);
        assert_eq!(g.roles[3].etp_rank, 1);
    }

    #[test]
    fn device_count_mismatch_rejected() {
        let plan = ShardPlan::new(AttnStrategy::new(2, 1), ExpertStrategy::new(2, 2));
        assert!(DeviceGrid::lower(&plan).is_err());
    }

    #[test]
    fn dims_checked() {
        let plan = ShardPlan::new(AttnStrategy::new(2, 2), ExpertStrategy::new(2, 2));
        let g = DeviceGrid::lower(&plan).unwrap();
        assert!(g.check_dims(8, 4, 8, 512, 4).is_ok());
        // Batch 3 not divisible by DP2.
        assert!(g.check_dims(8, 4, 8, 512, 3).is_err());
        // 3 experts not divisible by EP2.
        assert!(g.check_dims(8, 4, 3, 512, 4).is_err());
        // Inter 511 not divisible by expert TP2.
        assert!(g.check_dims(8, 4, 8, 511, 4).is_err());
    }
}
