//! Host-math kernels for the tiny-MoE modules, mirroring the JAX
//! reference in `python/compile/kernels/ref.py` (RMS norm, causal GQA
//! attention, Mixtral-style top-k gating, SwiGLU expert FFN).
//!
//! These are the per-device module bodies of the grid engine's **host
//! backend**: each device role runs one of these on its weight shard,
//! and [`crate::model::collectives`] combines the outputs. Because they
//! are plain `HostTensor` math, the whole execution stack — sharding,
//! per-device compute, collectives, KV caches, plan transitions — is
//! testable without PJRT artifacts.
//!
//! Shard tensor layouts (the `WeightStore::shard` contract):
//! - attention: `[ln, wq, wk, wv, wo]`;
//! - experts, pure TP (`ep == 1`): `[ln, router, wg, wu, wd]`;
//! - experts, EP or EP×TP (`ep > 1`): `[ln, router, sel, wg, wu, wd]`
//!   where `sel: [E_local, E]` selects the block's experts from the
//!   full gate matrix.

use crate::runtime::literal::HostTensor;
use crate::Result;

const RMS_EPS: f32 = 1e-5;

/// RMS norm over the last axis: `x · rsqrt(mean(x²) + ε) · scale`.
pub fn rms_norm(x: &HostTensor, scale: &HostTensor) -> HostTensor {
    let h = *x.shape.last().expect("rms_norm on scalar");
    assert_eq!(scale.data.len(), h, "rms_norm scale length");
    let mut out = vec![0f32; x.data.len()];
    for (row_o, row_x) in out.chunks_mut(h).zip(x.data.chunks(h)) {
        let mut ss = 0f32;
        for &v in row_x {
            ss += v * v;
        }
        let inv = 1.0 / (ss / h as f32 + RMS_EPS).sqrt();
        for i in 0..h {
            row_o[i] = row_x[i] * inv * scale.data[i];
        }
    }
    HostTensor::new(x.shape.clone(), out)
}

/// Row-major matmul: `a [rows, k] @ b [k, cols] → [rows, cols]`.
pub fn matmul(a: &[f32], rows: usize, k: usize, b: &[f32], cols: usize) -> Vec<f32> {
    assert_eq!(a.len(), rows * k, "matmul lhs size");
    assert_eq!(b.len(), k * cols, "matmul rhs size");
    let mut out = vec![0f32; rows * cols];
    for r in 0..rows {
        let ar = &a[r * k..(r + 1) * k];
        let or = &mut out[r * cols..(r + 1) * cols];
        for (i, &av) in ar.iter().enumerate() {
            let br = &b[i * cols..(i + 1) * cols];
            for c in 0..cols {
                or[c] += av * br[c];
            }
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x * (1.0 / (1.0 + (-x).exp()))
}

/// Token embedding lookup: `tokens [B·S] → [B, S, H]`.
pub fn embed_lookup(tokens: &[i32], table: &HostTensor, b: usize, s: usize) -> Result<HostTensor> {
    let (v, h) = (table.shape[0], table.shape[1]);
    if tokens.len() != b * s {
        anyhow::bail!("embed expects {}x{} tokens, got {}", b, s, tokens.len());
    }
    let mut out = Vec::with_capacity(b * s * h);
    for &t in tokens {
        let t = t as usize;
        if t >= v {
            anyhow::bail!("token {t} out of vocab {v}");
        }
        out.extend_from_slice(&table.data[t * h..(t + 1) * h]);
    }
    Ok(HostTensor::new(vec![b, s, h], out))
}

/// Final norm + unembed on the last-position residual:
/// `x_last [B, H] → logits [B, V]`.
pub fn head(x_last: &HostTensor, ln_f: &HostTensor, unembed: &HostTensor) -> HostTensor {
    let (b, h) = (x_last.shape[0], x_last.shape[1]);
    let v = unembed.shape[1];
    let xn = rms_norm(x_last, ln_f);
    HostTensor::new(vec![b, v], matmul(&xn.data, b, h, &unembed.data, v))
}

/// Mixtral top-k gate: dense routing weights `[T, E]`, softmax over the
/// selected experts' logits, zero elsewhere, renormalized over the set.
pub fn topk_gate(xn: &HostTensor, router: &HostTensor, top_k: usize) -> HostTensor {
    let (t, h) = (xn.shape[0], xn.shape[1]);
    let e = router.shape[1];
    assert!(top_k >= 1 && top_k <= e, "top_k {top_k} out of range for {e} experts");
    let logits = matmul(&xn.data, t, h, &router.data, e);
    let mut gates = vec![0f32; t * e];
    for r in 0..t {
        let lr = &logits[r * e..(r + 1) * e];
        let mut sorted = lr.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).expect("router logits are finite"));
        let thresh = sorted[top_k - 1];
        // Softmax over the masked set (ties at the threshold are all
        // included, matching ref.topk_gate).
        let mut mx = f32::NEG_INFINITY;
        for &v in lr {
            if v >= thresh && v > mx {
                mx = v;
            }
        }
        let gr = &mut gates[r * e..(r + 1) * e];
        let mut sum = 0f32;
        for (i, &v) in lr.iter().enumerate() {
            if v >= thresh {
                let w = (v - mx).exp();
                gr[i] = w;
                sum += w;
            }
        }
        let denom = sum.max(1e-9);
        for g in gr.iter_mut() {
            *g /= denom;
        }
    }
    HostTensor::new(vec![t, e], gates)
}

/// SwiGLU routed FFN over a block of experts: for each local expert
/// `e`, `y_e = (silu(xn·Wg_e) ⊙ (xn·Wu_e))·Wd_e`, accumulated as
/// `Σ_e gates_local[:, e] · y_e`.
fn expert_ffn(
    xn: &HostTensor,
    gates_local: &[f32],
    wg: &HostTensor,
    wu: &HostTensor,
    wd: &HostTensor,
) -> HostTensor {
    let (t, h) = (xn.shape[0], xn.shape[1]);
    let e_l = wg.shape[0];
    let i_l = wg.shape[2];
    assert_eq!(gates_local.len(), t * e_l, "gate table size");
    let mut out = vec![0f32; t * h];
    for e in 0..e_l {
        let wg_e = &wg.data[e * h * i_l..(e + 1) * h * i_l];
        let wu_e = &wu.data[e * h * i_l..(e + 1) * h * i_l];
        let wd_e = &wd.data[e * i_l * h..(e + 1) * i_l * h];
        let g = matmul(&xn.data, t, h, wg_e, i_l);
        let u = matmul(&xn.data, t, h, wu_e, i_l);
        let mut act = vec![0f32; t * i_l];
        for j in 0..t * i_l {
            act[j] = silu(g[j]) * u[j];
        }
        let y = matmul(&act, t, i_l, wd_e, h);
        for r in 0..t {
            let gate = gates_local[r * e_l + e];
            if gate != 0.0 {
                for c in 0..h {
                    out[r * h + c] += gate * y[r * h + c];
                }
            }
        }
    }
    HostTensor::new(vec![t, h], out)
}

/// One device's expert-module contribution for its `(ep, tp)` shard:
/// `x [T, H]` combined residual → partial output `[T, H]`. Partial-sum
/// over the block's TP ranks, then contribution-sum over blocks,
/// reconstructs the full routed output.
pub fn expert_module(x: &HostTensor, shard: &[HostTensor], ep: usize, top_k: usize) -> Result<HostTensor> {
    let expected = if ep > 1 { 6 } else { 5 };
    if shard.len() != expected {
        anyhow::bail!("expert shard has {} tensors, expected {expected}", shard.len());
    }
    let xn = rms_norm(x, &shard[0]);
    let gates = topk_gate(&xn, &shard[1], top_k);
    if ep == 1 {
        Ok(expert_ffn(&xn, &gates.data, &shard[2], &shard[3], &shard[4]))
    } else {
        // gates_local = gates @ selᵀ: pick the block's expert columns.
        let sel = &shard[2];
        let (e_l, e) = (sel.shape[0], sel.shape[1]);
        let t = xn.shape[0];
        let mut gl = vec![0f32; t * e_l];
        for r in 0..t {
            for j in 0..e_l {
                let mut s = 0f32;
                for c in 0..e {
                    s += gates.data[r * e + c] * sel.data[j * e + c];
                }
                gl[r * e_l + j] = s;
            }
        }
        Ok(expert_ffn(&xn, &gl, &shard[3], &shard[4], &shard[5]))
    }
}

/// Causal GQA prefill attention for one head shard.
///
/// `x [B, S, H]` residual → `(partial_out [B, S, H], k [B, S, KVH_l, D],
/// v [B, S, KVH_l, D])`; partial outputs sum over the TP group.
pub fn attention_prefill(
    x: &HostTensor,
    shard: &[HostTensor],
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<(HostTensor, HostTensor, HostTensor)> {
    let (b, s, h) = (x.shape[0], x.shape[1], x.shape[2]);
    let xn = rms_norm(x, &shard[0]);
    let q = matmul(&xn.data, b * s, h, &shard[1].data, q_heads * hd);
    let k = matmul(&xn.data, b * s, h, &shard[2].data, kv_heads * hd);
    let v = matmul(&xn.data, b * s, h, &shard[3].data, kv_heads * hd);
    let rep = q_heads / kv_heads;
    if rep * kv_heads != q_heads {
        anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
    }
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = vec![0f32; b * s * q_heads * hd];
    let mut scores = vec![0f32; s];
    for bi in 0..b {
        for head in 0..q_heads {
            let kvh = head / rep;
            for qi in 0..s {
                let qoff = ((bi * s + qi) * q_heads + head) * hd;
                let mut mx = f32::NEG_INFINITY;
                for (ki, sc) in scores.iter_mut().enumerate().take(qi + 1) {
                    let koff = ((bi * s + ki) * kv_heads + kvh) * hd;
                    let mut dot = 0f32;
                    for d in 0..hd {
                        dot += q[qoff + d] * k[koff + d];
                    }
                    *sc = dot * scale;
                    if *sc > mx {
                        mx = *sc;
                    }
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut().take(qi + 1) {
                    *sc = (*sc - mx).exp();
                    denom += *sc;
                }
                let coff = ((bi * s + qi) * q_heads + head) * hd;
                for ki in 0..=qi {
                    let p = scores[ki] / denom;
                    let voff = ((bi * s + ki) * kv_heads + kvh) * hd;
                    for d in 0..hd {
                        ctx[coff + d] += p * v[voff + d];
                    }
                }
            }
        }
    }
    let out = matmul(&ctx, b * s, q_heads * hd, &shard[4].data, h);
    Ok((
        HostTensor::new(vec![b, s, h], out),
        HostTensor::new(vec![b, s, kv_heads, hd], k),
        HostTensor::new(vec![b, s, kv_heads, hd], v),
    ))
}

/// Causal GQA prefill attention for **one chunk of one sequence**,
/// resuming against a padded per-slot KV cache.
///
/// `x [1, C, H]` is the chunk's residual (prompt positions
/// `start..start+C` of batch row `row` in the group cache
/// `[B_g, M, KVH_l, D]`). The chunk's K/V are written into the cache at
/// positions `start..start+C`, and each chunk query at global position
/// `p = start + qi` attends causally to cache positions `0..=p` — the
/// earlier positions having been written by previous chunks of the same
/// prompt. Returns the partial attention output `[1, C, H]` (summed
/// over the TP group by the caller).
///
/// **Bit-equivalence.** The loop structure (score order, running max,
/// exp/normalize split, context accumulation order) mirrors
/// [`attention_prefill`] exactly, and every per-row quantity (rms_norm,
/// q/k/v projections) is row-independent, so splitting a prompt into
/// chunks — any chunk sizes — produces outputs and KV bit-identical to
/// the one-shot kernel. Asserted by `chunked_prefill_bit_identical`.
pub fn attention_prefill_ranged(
    x: &HostTensor,
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    row: usize,
    start: usize,
    shard: &[HostTensor],
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<HostTensor> {
    let (b, c, h) = (x.shape[0], x.shape[1], x.shape[2]);
    if b != 1 {
        anyhow::bail!("ranged prefill takes one sequence, got batch {b}");
    }
    let m = k_cache.shape[1];
    if start + c > m {
        anyhow::bail!("chunk {start}..{} outside KV budget {m}", start + c);
    }
    let rep = q_heads / kv_heads;
    if rep * kv_heads != q_heads {
        anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
    }
    let xn = rms_norm(x, &shard[0]);
    let q = matmul(&xn.data, c, h, &shard[1].data, q_heads * hd);
    let k_new = matmul(&xn.data, c, h, &shard[2].data, kv_heads * hd);
    let v_new = matmul(&xn.data, c, h, &shard[3].data, kv_heads * hd);
    // Write the chunk's K/V into the slot's cache rows first, so the
    // causal scan below reads every position — earlier chunks and this
    // one — from a single place.
    let kvrow = kv_heads * hd;
    let dst = (row * m + start) * kvrow;
    k_cache.data[dst..dst + c * kvrow].copy_from_slice(&k_new[..c * kvrow]);
    v_cache.data[dst..dst + c * kvrow].copy_from_slice(&v_new[..c * kvrow]);

    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = vec![0f32; c * q_heads * hd];
    let mut scores = vec![0f32; start + c];
    for head in 0..q_heads {
        let kvh = head / rep;
        for qi in 0..c {
            let p = start + qi; // global prompt position of this query
            let qoff = (qi * q_heads + head) * hd;
            let mut mx = f32::NEG_INFINITY;
            for (ki, sc) in scores.iter_mut().enumerate().take(p + 1) {
                let koff = (row * m + ki) * kvrow + kvh * hd;
                let mut dot = 0f32;
                for d in 0..hd {
                    dot += q[qoff + d] * k_cache.data[koff + d];
                }
                *sc = dot * scale;
                if *sc > mx {
                    mx = *sc;
                }
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut().take(p + 1) {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            let coff = (qi * q_heads + head) * hd;
            for ki in 0..=p {
                let pr = scores[ki] / denom;
                let voff = (row * m + ki) * kvrow + kvh * hd;
                for d in 0..hd {
                    ctx[coff + d] += pr * v_cache.data[voff + d];
                }
            }
        }
    }
    let out = matmul(&ctx, c, q_heads * hd, &shard[4].data, h);
    Ok(HostTensor::new(vec![1, c, h], out))
}

/// One decode step against a padded KV cache (`[B, M, KVH_l, D]`); the
/// new token writes at index `pos` and positions `0..=pos` are attended.
/// Updates the caches in place (device-resident state) and returns the
/// partial output `[B, 1, H]`.
///
/// Delegates to [`attention_decode_slots`] with every row active at the
/// same position, so the gang path and the streaming per-slot path
/// share one copy of the float-order-sensitive attention math — the
/// engine's per-request bit-equivalence holds by construction.
pub fn attention_decode(
    x: &HostTensor,
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    pos: usize,
    shard: &[HostTensor],
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<HostTensor> {
    let b = x.shape[0];
    let m = k_cache.shape[1];
    if pos >= m {
        anyhow::bail!("decode position {pos} outside KV budget {m}");
    }
    attention_decode_slots(
        x,
        k_cache,
        v_cache,
        &vec![pos; b],
        &vec![true; b],
        shard,
        q_heads,
        kv_heads,
        hd,
    )
}

/// One decode step with **per-slot positions** against a padded KV
/// cache (`[B, M, KVH_l, D]`): row `bi` writes its new token at
/// `pos[bi]` and attends positions `0..=pos[bi]`. Rows with
/// `active[bi] == false` are skipped entirely — their KV rows are not
/// touched and their output rows are zero. This is the continuous-
/// batching variant of [`attention_decode`]: because every kernel in
/// the stack is row-independent, an active row computes bit-identically
/// to a gang-scheduled batch whose global position equals that row's
/// `pos[bi]`, regardless of what the other slots are doing.
pub fn attention_decode_slots(
    x: &HostTensor,
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    pos: &[usize],
    active: &[bool],
    shard: &[HostTensor],
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<HostTensor> {
    let (b, h) = (x.shape[0], x.shape[2]);
    let m = k_cache.shape[1];
    if pos.len() != b || active.len() != b {
        anyhow::bail!("slot decode expects {b} positions/activity flags");
    }
    let rep = q_heads / kv_heads;
    if rep * kv_heads != q_heads {
        anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
    }
    let xn = rms_norm(x, &shard[0]);
    let q = matmul(&xn.data, b, h, &shard[1].data, q_heads * hd);
    let k_new = matmul(&xn.data, b, h, &shard[2].data, kv_heads * hd);
    let v_new = matmul(&xn.data, b, h, &shard[3].data, kv_heads * hd);
    let row = kv_heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = vec![0f32; b * q_heads * hd];
    for bi in 0..b {
        if !active[bi] {
            continue;
        }
        let p = pos[bi];
        if p >= m {
            anyhow::bail!("slot {bi} decode position {p} outside KV budget {m}");
        }
        let dst = (bi * m + p) * row;
        k_cache.data[dst..dst + row].copy_from_slice(&k_new[bi * row..(bi + 1) * row]);
        v_cache.data[dst..dst + row].copy_from_slice(&v_new[bi * row..(bi + 1) * row]);
        let mut scores = vec![0f32; p + 1];
        for head in 0..q_heads {
            let kvh = head / rep;
            let qoff = (bi * q_heads + head) * hd;
            let mut mx = f32::NEG_INFINITY;
            for (ki, sc) in scores.iter_mut().enumerate() {
                let koff = (bi * m + ki) * row + kvh * hd;
                let mut dot = 0f32;
                for d in 0..hd {
                    dot += q[qoff + d] * k_cache.data[koff + d];
                }
                *sc = dot * scale;
                if *sc > mx {
                    mx = *sc;
                }
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            for (ki, sc) in scores.iter().enumerate() {
                let p_attn = sc / denom;
                let voff = (bi * m + ki) * row + kvh * hd;
                for d in 0..hd {
                    ctx[qoff + d] += p_attn * v_cache.data[voff + d];
                }
            }
        }
    }
    let out = matmul(&ctx, b, q_heads * hd, &shard[4].data, h);
    Ok(HostTensor::new(vec![b, 1, h], out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rms_norm_unit_scale_normalizes() {
        let x = HostTensor::new(vec![1, 4], vec![2.0, 2.0, 2.0, 2.0]);
        let scale = HostTensor::new(vec![4], vec![1.0; 4]);
        let n = rms_norm(&x, &scale);
        // mean(x²) = 4 → rsqrt ≈ 0.5.
        for v in &n.data {
            assert!((v - 1.0).abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn matmul_matches_hand_product() {
        // [2,3] @ [3,2]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = matmul(&a, 2, 3, &b, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn topk_gate_selects_k_and_normalizes() {
        // Identity-ish router so logits = xn (h == e == 3).
        let xn = HostTensor::new(vec![1, 3], vec![1.0, 3.0, 2.0]);
        let mut router = HostTensor::zeros(vec![3, 3]);
        for i in 0..3 {
            router.data[i * 3 + i] = 1.0;
        }
        let g = topk_gate(&xn, &router, 2);
        assert_eq!(g.data[0], 0.0, "lowest logit must be masked");
        let sum: f32 = g.data.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(g.data[1] > g.data[2]);
    }

    #[test]
    fn expert_tp_slices_sum_to_full() {
        // [T=2, H=2], one expert, I=4: full output equals the sum of
        // the two I/2 slices (the TP partial-sum identity).
        let x = HostTensor::new(vec![2, 2], vec![0.3, -0.2, 0.7, 0.1]);
        let ln = HostTensor::new(vec![2], vec![1.0, 1.0]);
        let router = HostTensor::new(vec![2, 1], vec![1.0, 1.0]);
        let wg = HostTensor::new(vec![1, 2, 4], (0..8).map(|i| 0.1 * i as f32).collect());
        let wu = HostTensor::new(vec![1, 2, 4], (0..8).map(|i| 0.05 * i as f32).collect());
        let wd = HostTensor::new(vec![1, 4, 2], (0..8).map(|i| 0.02 * i as f32).collect());
        let full = expert_module(&x, &[ln.clone(), router.clone(), wg.clone(), wu.clone(), wd.clone()], 1, 1)
            .unwrap();
        let slice = |t: &HostTensor, i0: usize| -> HostTensor {
            // last-axis slice of [1,2,4] → [1,2,2]
            let mut d = Vec::new();
            for r in 0..2 {
                d.extend_from_slice(&t.data[r * 4 + i0..r * 4 + i0 + 2]);
            }
            HostTensor::new(vec![1, 2, 2], d)
        };
        let slice_rows = |t: &HostTensor, i0: usize| -> HostTensor {
            HostTensor::new(vec![1, 2, 2], t.data[i0 * 2..(i0 + 2) * 2].to_vec())
        };
        let mut sum: Option<HostTensor> = None;
        for d0 in [0usize, 2] {
            let part = expert_module(
                &x,
                &[ln.clone(), router.clone(), slice(&wg, d0), slice(&wu, d0), slice_rows(&wd, d0)],
                1,
                1,
            )
            .unwrap();
            match &mut sum {
                None => sum = Some(part),
                Some(acc) => acc.add_assign(&part),
            }
        }
        let got = sum.unwrap();
        for (a, b) in full.data.iter().zip(&got.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn slot_decode_matches_gang_decode_and_skips_inactive_rows() {
        // b=2, one head, hd=1: row 0 decoded via the per-slot kernel at
        // the same position as a gang decode must be bit-identical; the
        // inactive row 1 must leave its KV untouched and output zero.
        let ln = HostTensor::new(vec![2], vec![1.0, 1.0]);
        let wq = HostTensor::new(vec![2, 1], vec![0.4, -0.1]);
        let wk = HostTensor::new(vec![2, 1], vec![0.2, 0.3]);
        let wv = HostTensor::new(vec![2, 1], vec![1.0, -0.5]);
        let wo = HostTensor::new(vec![1, 2], vec![1.0, 0.7]);
        let shard = [ln, wq, wk, wv, wo];
        let x = HostTensor::new(vec![2, 1, 2], vec![3.0, -1.0, 0.5, 2.0]);
        let mut kc = HostTensor::new(vec![2, 4, 1, 1], (0..8).map(|i| 0.1 * i as f32).collect());
        let mut vc = HostTensor::new(vec![2, 4, 1, 1], (0..8).map(|i| 0.2 * i as f32).collect());
        let mut kc_gang = kc.clone();
        let mut vc_gang = vc.clone();
        let gang =
            attention_decode(&x, &mut kc_gang, &mut vc_gang, 2, &shard, 1, 1, 1).unwrap();
        let slots = attention_decode_slots(
            &x,
            &mut kc,
            &mut vc,
            &[2, 3],
            &[true, false],
            &shard,
            1,
            1,
            1,
        )
        .unwrap();
        assert_eq!(slots.shape, gang.shape);
        // Output row 0 ([2,1,2] → data[0..2]) is bit-identical.
        assert_eq!(slots.data[0].to_bits(), gang.data[0].to_bits());
        assert_eq!(slots.data[1].to_bits(), gang.data[1].to_bits());
        assert_eq!(&slots.data[2..4], &[0.0, 0.0], "inactive row must output zero");
        // Active row 0 wrote position 2; inactive row 1 wrote nothing.
        assert_eq!(kc.data[..4], kc_gang.data[..4]);
        assert_eq!(kc.data[4..], (4..8).map(|i| 0.1 * i as f32).collect::<Vec<_>>()[..]);
        assert_eq!(vc.data[4..], (4..8).map(|i| 0.2 * i as f32).collect::<Vec<_>>()[..]);
        // Out-of-budget position errors.
        assert!(attention_decode_slots(
            &x,
            &mut kc,
            &mut vc,
            &[9, 0],
            &[true, false],
            &shard,
            1,
            1,
            1
        )
        .is_err());
    }

    #[test]
    fn chunked_prefill_bit_identical() {
        // One prompt pushed through `attention_prefill` in one shot vs
        // the same prompt split into uneven chunks through
        // `attention_prefill_ranged`: partial outputs and the KV the
        // two paths produce must match bit-for-bit (the precondition
        // for the engine's multi-iteration chunked prefill).
        let (h, qh, kvh, hd, s, m) = (4usize, 2usize, 1usize, 2usize, 6usize, 8usize);
        let ln = HostTensor::new(vec![h], vec![1.0, 0.9, 1.1, 1.0]);
        let fill = |n: usize, k: f32| -> Vec<f32> {
            (0..n).map(|i| ((i * 7 + 3) % 11) as f32 * k - 0.4).collect()
        };
        let wq = HostTensor::new(vec![h, qh * hd], fill(h * qh * hd, 0.11));
        let wk = HostTensor::new(vec![h, kvh * hd], fill(h * kvh * hd, 0.07));
        let wv = HostTensor::new(vec![h, kvh * hd], fill(h * kvh * hd, 0.05));
        let wo = HostTensor::new(vec![qh * hd, h], fill(qh * hd * h, 0.09));
        let shard = [ln, wq, wk, wv, wo];
        let x = HostTensor::new(vec![1, s, h], fill(s * h, 0.13));

        let (full_out, full_k, full_v) =
            attention_prefill(&x, &shard, qh, kvh, hd).unwrap();

        let mut kc = HostTensor::zeros(vec![1, m, kvh, hd]);
        let mut vc = HostTensor::zeros(vec![1, m, kvh, hd]);
        let mut chunked = Vec::new();
        let mut start = 0usize;
        for c in [2usize, 3, 1] {
            let xc = HostTensor::new(
                vec![1, c, h],
                x.data[start * h..(start + c) * h].to_vec(),
            );
            let out = attention_prefill_ranged(
                &xc, &mut kc, &mut vc, 0, start, &shard, qh, kvh, hd,
            )
            .unwrap();
            chunked.extend_from_slice(&out.data);
            start += c;
        }
        assert_eq!(start, s);
        for (i, (a, b)) in full_out.data.iter().zip(&chunked).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "output diverged at {i}");
        }
        let kvrow = kvh * hd;
        for (i, a) in full_k.data.iter().enumerate() {
            assert_eq!(a.to_bits(), kc.data[i].to_bits(), "k cache diverged at {i}");
        }
        for (i, a) in full_v.data.iter().enumerate() {
            assert_eq!(a.to_bits(), vc.data[i].to_bits(), "v cache diverged at {i}");
        }
        assert!(kc.data[s * kvrow..].iter().all(|&v| v == 0.0), "cache tail touched");
        // A chunk past the budget is rejected.
        let xc = HostTensor::new(vec![1, 3, h], x.data[..3 * h].to_vec());
        assert!(attention_prefill_ranged(
            &xc, &mut kc, &mut vc, 0, m - 1, &shard, qh, kvh, hd
        )
        .is_err());
    }

    #[test]
    fn decode_attends_only_written_positions() {
        // Single head, hd 1: with k ≡ 0 the scores are uniform over
        // 0..=pos, so the context is the mean of the written v's.
        let ln = HostTensor::new(vec![2], vec![1.0, 1.0]);
        let wq = HostTensor::new(vec![2, 1], vec![0.0, 0.0]);
        let wk = HostTensor::new(vec![2, 1], vec![0.0, 0.0]);
        let wv = HostTensor::new(vec![2, 1], vec![1.0, 0.0]);
        let wo = HostTensor::new(vec![1, 2], vec![1.0, 0.0]);
        let shard = [ln, wq, wk, wv, wo];
        let mut kc = HostTensor::zeros(vec![1, 4, 1, 1]);
        let mut vc = HostTensor::zeros(vec![1, 4, 1, 1]);
        vc.data[0] = 5.0; // position 0 already cached
        let x = HostTensor::new(vec![1, 1, 2], vec![3.0, 0.0]);
        let out = attention_decode(&x, &mut kc, &mut vc, 1, &shard, 1, 1, 1).unwrap();
        // v@pos1 = normalize(3,0)·wv ≈ 1.0·rms-normed value; positions
        // 2..3 (zeros) must not contribute.
        let xn0 = 3.0 / ((9.0f32 / 2.0 + 1e-5).sqrt());
        let expect = (5.0 + xn0) / 2.0;
        assert!((out.data[0] - expect).abs() < 1e-4, "{} vs {expect}", out.data[0]);
        assert!(attention_decode(&x, &mut kc, &mut vc, 9, &shard, 1, 1, 1).is_err());
    }
}
