//! Host-math kernels for the tiny-MoE modules, mirroring the JAX
//! reference in `python/compile/kernels/ref.py` (RMS norm, causal GQA
//! attention, Mixtral-style top-k gating, SwiGLU expert FFN).
//!
//! These are the per-device module bodies of the grid engine's **host
//! backend**: each device role runs one of these on its weight shard,
//! and [`crate::model::collectives`] combines the outputs. Because they
//! are plain `HostTensor` math, the whole execution stack — sharding,
//! per-device compute, collectives, KV caches, plan transitions — is
//! testable without PJRT artifacts.
//!
//! # Two implementations, one set of bits
//!
//! The module carries two complete kernel paths:
//!
//! - [`reference`] — the original scalar triple-loop kernels over raw
//!   shard tensor slices. Slow, obviously correct, and retained as the
//!   oracle for every equivalence test.
//! - The **blocked path** (top-level functions) — the serving hot path.
//!   Every matmul right-hand side is packed once per shard into
//!   [`PackedRhs`]: column panels of [`NB`] output columns, panel-major
//!   `[panel][k][NB]`, so the inner loop is an in-order fused
//!   multiply-accumulate over `NB` contiguous lanes that the
//!   autovectorizer (or the explicit `simd` feature, below) chews
//!   through. Typed shard bundles ([`AttnWeights`], [`ExpertWeights`],
//!   [`HeadWeights`]) cache the packing for the lifetime of a resident
//!   shard.
//!
//! **Accumulation-order invariant.** The scalar matmul computes each
//! output element with a single accumulator, adding `a[r][i] · b[i][c]`
//! for `i = 0, 1, …, k-1` in order. The blocked core keeps exactly one
//! accumulator per output element (a register-tile lane) and fills it
//! in the same increasing-`i` order — blocking only re-tiles *which*
//! elements are in flight, never the per-element order — so every
//! output is bit-identical IEEE f32 to the scalar path. The explicit
//! SIMD variant vectorizes across output columns (one lane = one
//! accumulator) with separate multiply and add (no FMA contraction),
//! which preserves the same per-lane rounding. The sparse expert-FFN
//! gather is bit-exact for the same reason: the dense reference only
//! accumulates rows whose gate is non-zero, and matmul rows are
//! independent, so skipping gate-zero rows changes no observed value.
//! Everything *around* the matmuls — gate softmax, attention
//! score/softmax/context loops — is shared code between both paths.
//!
//! # Quantized serving
//!
//! [`PackedRhs`] optionally stores int8/int4 per-(row, group) affine
//! codes ([`PackedQuant`], group width [`QUANT_GROUP`]) instead of f32
//! panels, dequantizing on the fly inside the packed matmul: one
//! `(scale, bias)` pair lookup per `(i, panel)` since the group width
//! is a multiple of the panel width. The fused kernel is bit-identical
//! to running the reference matmul over
//! [`PackedQuant::dequantized`] weights — asserted in the
//! `kernel_equivalence` suite — which is what makes end-to-end
//! quantized serving (`hap serve --quant int8|int4`) testable: greedy
//! tokens agree with f32 exactly whenever the dequantized weights
//! round-trip exactly.
//!
//! Shard tensor layouts (the `WeightStore::shard` contract):
//! - attention: `[ln, wq, wk, wv, wo]`;
//! - experts, pure TP (`ep == 1`): `[ln, router, wg, wu, wd]`;
//! - experts, EP or EP×TP (`ep > 1`): `[ln, router, sel, wg, wu, wd]`
//!   where `sel: [E_local, E]` selects the block's experts from the
//!   full gate matrix.

use crate::quant::{self, QuantKind};
use crate::runtime::literal::HostTensor;
use crate::Result;

const RMS_EPS: f32 = 1e-5;

/// Packed-panel width: output columns per tile. The SIMD lane kernel
/// assumes a multiple of 4; [`QUANT_GROUP`] must be a multiple of this.
pub const NB: usize = 16;

/// Register-tile height: LHS rows accumulated per panel pass.
const MR: usize = 4;

/// Quantization group width (columns per `(scale, bias)` pair). A
/// multiple of [`NB`], so a packed panel never straddles a group
/// boundary and the fused matmul does one affine lookup per
/// `(row, panel)`.
pub const QUANT_GROUP: usize = 64;

/// RMS norm over the last axis: `x · rsqrt(mean(x²) + ε) · scale`.
pub fn rms_norm(x: &HostTensor, scale: &HostTensor) -> HostTensor {
    let h = *x.shape.last().expect("rms_norm on scalar");
    assert_eq!(scale.data.len(), h, "rms_norm scale length");
    let mut out = vec![0f32; x.data.len()];
    for (row_o, row_x) in out.chunks_mut(h).zip(x.data.chunks(h)) {
        let mut ss = 0f32;
        for &v in row_x {
            ss += v * v;
        }
        let inv = 1.0 / (ss / h as f32 + RMS_EPS).sqrt();
        for i in 0..h {
            row_o[i] = row_x[i] * inv * scale.data[i];
        }
    }
    HostTensor::new(x.shape.clone(), out)
}

fn silu(x: f32) -> f32 {
    x * (1.0 / (1.0 + (-x).exp()))
}

/// Token embedding lookup: `tokens [B·S] → [B, S, H]`.
pub fn embed_lookup(tokens: &[i32], table: &HostTensor, b: usize, s: usize) -> Result<HostTensor> {
    let (v, h) = (table.shape[0], table.shape[1]);
    if tokens.len() != b * s {
        anyhow::bail!("embed expects {}x{} tokens, got {}", b, s, tokens.len());
    }
    let mut out = Vec::with_capacity(b * s * h);
    for &t in tokens {
        let t = t as usize;
        if t >= v {
            anyhow::bail!("token {t} out of vocab {v}");
        }
        out.extend_from_slice(&table.data[t * h..(t + 1) * h]);
    }
    Ok(HostTensor::new(vec![b, s, h], out))
}

// ---------------------------------------------------------------------------
// Shared float-order-sensitive cores. Both kernel paths call these, so
// their bit-equivalence reduces to the matmul equivalence proved above.
// ---------------------------------------------------------------------------

/// Top-k gate rows from precomputed router logits `[T, E]`: softmax over
/// the selected experts' logits (ties at the threshold all included,
/// matching `ref.topk_gate`), zero elsewhere, renormalized over the set.
fn gate_rows(logits: &[f32], t: usize, e: usize, top_k: usize) -> Vec<f32> {
    assert!(top_k >= 1 && top_k <= e, "top_k {top_k} out of range for {e} experts");
    let mut gates = vec![0f32; t * e];
    for r in 0..t {
        let lr = &logits[r * e..(r + 1) * e];
        let mut sorted = lr.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).expect("router logits are finite"));
        let thresh = sorted[top_k - 1];
        let mut mx = f32::NEG_INFINITY;
        for &v in lr {
            if v >= thresh && v > mx {
                mx = v;
            }
        }
        let gr = &mut gates[r * e..(r + 1) * e];
        let mut sum = 0f32;
        for (i, &v) in lr.iter().enumerate() {
            if v >= thresh {
                let w = (v - mx).exp();
                gr[i] = w;
                sum += w;
            }
        }
        let denom = sum.max(1e-9);
        for g in gr.iter_mut() {
            *g /= denom;
        }
    }
    gates
}

/// `gates_local = gates @ selᵀ`: pick an EP block's expert columns from
/// the full `[T, E]` gate table via the shard's `sel [E_local, E]`.
fn select_gates(gates: &[f32], sel: &HostTensor, t: usize) -> Vec<f32> {
    let (e_l, e) = (sel.shape[0], sel.shape[1]);
    let mut gl = vec![0f32; t * e_l];
    for r in 0..t {
        for j in 0..e_l {
            let mut s = 0f32;
            for c in 0..e {
                s += gates[r * e + c] * sel.data[j * e + c];
            }
            gl[r * e_l + j] = s;
        }
    }
    gl
}

/// Causal GQA score/softmax/context loop for whole-batch prefill:
/// projected `q/k/v` in, context `[B, S, QH, D]` out.
fn prefill_ctx(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    s: usize,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Vec<f32> {
    let rep = q_heads / kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = vec![0f32; b * s * q_heads * hd];
    let mut scores = vec![0f32; s];
    for bi in 0..b {
        for head in 0..q_heads {
            let kvh = head / rep;
            for qi in 0..s {
                let qoff = ((bi * s + qi) * q_heads + head) * hd;
                let mut mx = f32::NEG_INFINITY;
                for (ki, sc) in scores.iter_mut().enumerate().take(qi + 1) {
                    let koff = ((bi * s + ki) * kv_heads + kvh) * hd;
                    let mut dot = 0f32;
                    for d in 0..hd {
                        dot += q[qoff + d] * k[koff + d];
                    }
                    *sc = dot * scale;
                    if *sc > mx {
                        mx = *sc;
                    }
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut().take(qi + 1) {
                    *sc = (*sc - mx).exp();
                    denom += *sc;
                }
                let coff = ((bi * s + qi) * q_heads + head) * hd;
                for ki in 0..=qi {
                    let p = scores[ki] / denom;
                    let voff = ((bi * s + ki) * kv_heads + kvh) * hd;
                    for d in 0..hd {
                        ctx[coff + d] += p * v[voff + d];
                    }
                }
            }
        }
    }
    ctx
}

/// Score/softmax/context loop for one ranged prefill chunk: queries at
/// global positions `start..start+c` of cache row `row`, attending
/// cache positions `0..=p`. The chunk's K/V must already be written.
fn ranged_ctx(
    q: &[f32],
    k_cache: &HostTensor,
    v_cache: &HostTensor,
    row: usize,
    start: usize,
    c: usize,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Vec<f32> {
    let m = k_cache.shape[1];
    let rep = q_heads / kv_heads;
    let kvrow = kv_heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = vec![0f32; c * q_heads * hd];
    let mut scores = vec![0f32; start + c];
    for head in 0..q_heads {
        let kvh = head / rep;
        for qi in 0..c {
            let p = start + qi; // global prompt position of this query
            let qoff = (qi * q_heads + head) * hd;
            let mut mx = f32::NEG_INFINITY;
            for (ki, sc) in scores.iter_mut().enumerate().take(p + 1) {
                let koff = (row * m + ki) * kvrow + kvh * hd;
                let mut dot = 0f32;
                for d in 0..hd {
                    dot += q[qoff + d] * k_cache.data[koff + d];
                }
                *sc = dot * scale;
                if *sc > mx {
                    mx = *sc;
                }
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut().take(p + 1) {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            let coff = (qi * q_heads + head) * hd;
            for ki in 0..=p {
                let pr = scores[ki] / denom;
                let voff = (row * m + ki) * kvrow + kvh * hd;
                for d in 0..hd {
                    ctx[coff + d] += pr * v_cache.data[voff + d];
                }
            }
        }
    }
    ctx
}

/// Per-slot decode KV write + score/softmax/context loop: row `bi`
/// writes its projected K/V at `pos[bi]` and attends `0..=pos[bi]`;
/// inactive rows are skipped entirely (no KV write, zero context).
#[allow(clippy::too_many_arguments)]
fn slot_ctx(
    q: &[f32],
    k_new: &[f32],
    v_new: &[f32],
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    pos: &[usize],
    active: &[bool],
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<Vec<f32>> {
    let b = pos.len();
    let m = k_cache.shape[1];
    let rep = q_heads / kv_heads;
    let row = kv_heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = vec![0f32; b * q_heads * hd];
    for bi in 0..b {
        if !active[bi] {
            continue;
        }
        let p = pos[bi];
        if p >= m {
            anyhow::bail!("slot {bi} decode position {p} outside KV budget {m}");
        }
        let dst = (bi * m + p) * row;
        k_cache.data[dst..dst + row].copy_from_slice(&k_new[bi * row..(bi + 1) * row]);
        v_cache.data[dst..dst + row].copy_from_slice(&v_new[bi * row..(bi + 1) * row]);
        let mut scores = vec![0f32; p + 1];
        for head in 0..q_heads {
            let kvh = head / rep;
            let qoff = (bi * q_heads + head) * hd;
            let mut mx = f32::NEG_INFINITY;
            for (ki, sc) in scores.iter_mut().enumerate() {
                let koff = (bi * m + ki) * row + kvh * hd;
                let mut dot = 0f32;
                for d in 0..hd {
                    dot += q[qoff + d] * k_cache.data[koff + d];
                }
                *sc = dot * scale;
                if *sc > mx {
                    mx = *sc;
                }
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            for (ki, sc) in scores.iter().enumerate() {
                let p_attn = sc / denom;
                let voff = (bi * m + ki) * row + kvh * hd;
                for d in 0..hd {
                    ctx[qoff + d] += p_attn * v_cache.data[voff + d];
                }
            }
        }
    }
    Ok(ctx)
}

/// Paged twin of [`ranged_ctx`]: identical loop structure and
/// accumulation order, but K/V offsets gather through `table` over a
/// block-granular cache `[num_blocks, block_size, kv_heads, hd]`
/// instead of a contiguous padded row — logical position `ki` lives at
/// physical position `table[ki / block_size] * block_size +
/// ki % block_size`. Only the offset arithmetic differs from the
/// padded core, so identical inputs produce bit-identical context.
#[allow(clippy::too_many_arguments)]
fn ranged_ctx_paged(
    q: &[f32],
    k_cache: &HostTensor,
    v_cache: &HostTensor,
    table: &[usize],
    block_size: usize,
    start: usize,
    c: usize,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Vec<f32> {
    let rep = q_heads / kv_heads;
    let kvrow = kv_heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = vec![0f32; c * q_heads * hd];
    let mut scores = vec![0f32; start + c];
    for head in 0..q_heads {
        let kvh = head / rep;
        for qi in 0..c {
            let p = start + qi; // global prompt position of this query
            let qoff = (qi * q_heads + head) * hd;
            let mut mx = f32::NEG_INFINITY;
            for (ki, sc) in scores.iter_mut().enumerate().take(p + 1) {
                let koff = (table[ki / block_size] * block_size + ki % block_size) * kvrow
                    + kvh * hd;
                let mut dot = 0f32;
                for d in 0..hd {
                    dot += q[qoff + d] * k_cache.data[koff + d];
                }
                *sc = dot * scale;
                if *sc > mx {
                    mx = *sc;
                }
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut().take(p + 1) {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            let coff = (qi * q_heads + head) * hd;
            for ki in 0..=p {
                let pr = scores[ki] / denom;
                let voff = (table[ki / block_size] * block_size + ki % block_size) * kvrow
                    + kvh * hd;
                for d in 0..hd {
                    ctx[coff + d] += pr * v_cache.data[voff + d];
                }
            }
        }
    }
    ctx
}

/// Paged twin of [`slot_ctx`]: per-slot decode over block tables.
/// `tables` is `b` concatenated tables of `tstride` entries each; row
/// `bi` writes K/V at logical `pos[bi]` through its table and attends
/// `0..=pos[bi]`. Loop structure and accumulation order match
/// [`slot_ctx`] exactly — only the offset arithmetic differs.
#[allow(clippy::too_many_arguments)]
fn slot_ctx_paged(
    q: &[f32],
    k_new: &[f32],
    v_new: &[f32],
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    pos: &[usize],
    active: &[bool],
    tables: &[usize],
    tstride: usize,
    block_size: usize,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<Vec<f32>> {
    let b = pos.len();
    let nb = k_cache.shape[0];
    let rep = q_heads / kv_heads;
    let row = kv_heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = vec![0f32; b * q_heads * hd];
    for bi in 0..b {
        if !active[bi] {
            continue;
        }
        let p = pos[bi];
        if p / block_size >= tstride {
            anyhow::bail!("slot {bi} decode position {p} outside block table ({tstride} blocks)");
        }
        let bt = &tables[bi * tstride..(bi + 1) * tstride];
        if let Some(bad) = bt[..p / block_size + 1].iter().position(|&blk| blk >= nb) {
            anyhow::bail!("slot {bi} block {bad} unmapped at decode position {p}");
        }
        let dst = (bt[p / block_size] * block_size + p % block_size) * row;
        k_cache.data[dst..dst + row].copy_from_slice(&k_new[bi * row..(bi + 1) * row]);
        v_cache.data[dst..dst + row].copy_from_slice(&v_new[bi * row..(bi + 1) * row]);
        let mut scores = vec![0f32; p + 1];
        for head in 0..q_heads {
            let kvh = head / rep;
            let qoff = (bi * q_heads + head) * hd;
            let mut mx = f32::NEG_INFINITY;
            for (ki, sc) in scores.iter_mut().enumerate() {
                let koff =
                    (bt[ki / block_size] * block_size + ki % block_size) * row + kvh * hd;
                let mut dot = 0f32;
                for d in 0..hd {
                    dot += q[qoff + d] * k_cache.data[koff + d];
                }
                *sc = dot * scale;
                if *sc > mx {
                    mx = *sc;
                }
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            for (ki, sc) in scores.iter().enumerate() {
                let p_attn = sc / denom;
                let voff =
                    (bt[ki / block_size] * block_size + ki % block_size) * row + kvh * hd;
                for d in 0..hd {
                    ctx[qoff + d] += p_attn * v_cache.data[voff + d];
                }
            }
        }
    }
    Ok(ctx)
}

/// Shared guard for the paged prefill wrappers: the table must map
/// every block the chunk reads or writes into the pool.
fn check_prefill_table(table: &[usize], num_blocks: usize, end: usize, block_size: usize) -> Result<()> {
    let need = end.div_ceil(block_size);
    if need > table.len() {
        anyhow::bail!("block table has {} entries, chunk needs {need}", table.len());
    }
    if let Some(bad) = table[..need].iter().position(|&blk| blk >= num_blocks) {
        anyhow::bail!("block table entry {bad} unmapped or outside the {num_blocks}-block pool");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Scalar reference path
// ---------------------------------------------------------------------------

/// The original scalar kernels over raw shard tensor slices, retained
/// verbatim as the oracle for the blocked/SIMD/quantized paths. Slow by
/// design; every equivalence test in `tests/kernel_equivalence.rs` (and
/// the engine-level `KernelMode::Reference` executor) pins the fast
/// path against these bit-for-bit.
pub mod reference {
    use super::{
        check_prefill_table, gate_rows, prefill_ctx, ranged_ctx, ranged_ctx_paged, select_gates,
        silu, slot_ctx, slot_ctx_paged,
    };
    pub use super::{embed_lookup, rms_norm};
    use crate::runtime::literal::HostTensor;
    use crate::Result;

    /// Row-major scalar matmul: `a [rows, k] @ b [k, cols] → [rows,
    /// cols]`. One accumulator per output element, `i` ascending — the
    /// accumulation order every fast path must reproduce.
    pub fn matmul(a: &[f32], rows: usize, k: usize, b: &[f32], cols: usize) -> Vec<f32> {
        assert_eq!(a.len(), rows * k, "matmul lhs size");
        assert_eq!(b.len(), k * cols, "matmul rhs size");
        let mut out = vec![0f32; rows * cols];
        for r in 0..rows {
            let ar = &a[r * k..(r + 1) * k];
            let or = &mut out[r * cols..(r + 1) * cols];
            for (i, &av) in ar.iter().enumerate() {
                let br = &b[i * cols..(i + 1) * cols];
                for c in 0..cols {
                    or[c] += av * br[c];
                }
            }
        }
        out
    }

    /// Final norm + unembed on the last-position residual:
    /// `x_last [B, H] → logits [B, V]`.
    pub fn head(x_last: &HostTensor, ln_f: &HostTensor, unembed: &HostTensor) -> HostTensor {
        let (b, h) = (x_last.shape[0], x_last.shape[1]);
        let v = unembed.shape[1];
        let xn = rms_norm(x_last, ln_f);
        HostTensor::new(vec![b, v], matmul(&xn.data, b, h, &unembed.data, v))
    }

    /// Mixtral top-k gate: dense routing weights `[T, E]`, softmax over
    /// the selected experts' logits, zero elsewhere, renormalized.
    pub fn topk_gate(xn: &HostTensor, router: &HostTensor, top_k: usize) -> HostTensor {
        let (t, h) = (xn.shape[0], xn.shape[1]);
        let e = router.shape[1];
        let logits = matmul(&xn.data, t, h, &router.data, e);
        HostTensor::new(vec![t, e], gate_rows(&logits, t, e, top_k))
    }

    /// SwiGLU routed FFN over a block of experts: for each local expert
    /// `e`, `y_e = (silu(xn·Wg_e) ⊙ (xn·Wu_e))·Wd_e`, accumulated as
    /// `Σ_e gates_local[:, e] · y_e`. Dense: every expert processes
    /// every token, gate-zero rows contribute nothing.
    fn expert_ffn(
        xn: &HostTensor,
        gates_local: &[f32],
        wg: &HostTensor,
        wu: &HostTensor,
        wd: &HostTensor,
    ) -> HostTensor {
        let (t, h) = (xn.shape[0], xn.shape[1]);
        let e_l = wg.shape[0];
        let i_l = wg.shape[2];
        assert_eq!(gates_local.len(), t * e_l, "gate table size");
        let mut out = vec![0f32; t * h];
        for e in 0..e_l {
            let wg_e = &wg.data[e * h * i_l..(e + 1) * h * i_l];
            let wu_e = &wu.data[e * h * i_l..(e + 1) * h * i_l];
            let wd_e = &wd.data[e * i_l * h..(e + 1) * i_l * h];
            let g = matmul(&xn.data, t, h, wg_e, i_l);
            let u = matmul(&xn.data, t, h, wu_e, i_l);
            let mut act = vec![0f32; t * i_l];
            for j in 0..t * i_l {
                act[j] = silu(g[j]) * u[j];
            }
            let y = matmul(&act, t, i_l, wd_e, h);
            for r in 0..t {
                let gate = gates_local[r * e_l + e];
                if gate != 0.0 {
                    for c in 0..h {
                        out[r * h + c] += gate * y[r * h + c];
                    }
                }
            }
        }
        HostTensor::new(vec![t, h], out)
    }

    /// One device's expert-module contribution for its `(ep, tp)`
    /// shard: `x [T, H]` combined residual → partial output `[T, H]`.
    pub fn expert_module(
        x: &HostTensor,
        shard: &[HostTensor],
        ep: usize,
        top_k: usize,
    ) -> Result<HostTensor> {
        let expected = if ep > 1 { 6 } else { 5 };
        if shard.len() != expected {
            anyhow::bail!("expert shard has {} tensors, expected {expected}", shard.len());
        }
        let xn = rms_norm(x, &shard[0]);
        let gates = topk_gate(&xn, &shard[1], top_k);
        if ep == 1 {
            Ok(expert_ffn(&xn, &gates.data, &shard[2], &shard[3], &shard[4]))
        } else {
            let gl = select_gates(&gates.data, &shard[2], xn.shape[0]);
            Ok(expert_ffn(&xn, &gl, &shard[3], &shard[4], &shard[5]))
        }
    }

    /// [`expert_module`] over one contiguous **row range** of the token
    /// batch: rows `start..start + len` of `x [T, H]` → partial output
    /// `[len, H]`. Every expert-path quantity (RMS norm, gating, FFN,
    /// per-row gate accumulation) is row-independent, so the ranged
    /// output rows are bit-identical to the corresponding rows of the
    /// full-batch call — the kernel-level contract the executor's
    /// micro-chunk pipeline is built on.
    pub fn expert_module_ranged(
        x: &HostTensor,
        shard: &[HostTensor],
        ep: usize,
        top_k: usize,
        start: usize,
        len: usize,
    ) -> Result<HostTensor> {
        let (t, h) = (x.shape[0], x.shape[1]);
        if start + len > t {
            anyhow::bail!("expert chunk {start}..{} outside batch {t}", start + len);
        }
        let rows = HostTensor::new(
            vec![len, h],
            x.data[start * h..(start + len) * h].to_vec(),
        );
        expert_module(&rows, shard, ep, top_k)
    }

    /// Causal GQA prefill attention for one head shard:
    /// `x [B, S, H]` → `(partial_out [B, S, H], k, v [B, S, KVH_l, D])`.
    pub fn attention_prefill(
        x: &HostTensor,
        shard: &[HostTensor],
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let (b, s, h) = (x.shape[0], x.shape[1], x.shape[2]);
        if (q_heads / kv_heads) * kv_heads != q_heads {
            anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
        }
        let xn = rms_norm(x, &shard[0]);
        let q = matmul(&xn.data, b * s, h, &shard[1].data, q_heads * hd);
        let k = matmul(&xn.data, b * s, h, &shard[2].data, kv_heads * hd);
        let v = matmul(&xn.data, b * s, h, &shard[3].data, kv_heads * hd);
        let ctx = prefill_ctx(&q, &k, &v, b, s, q_heads, kv_heads, hd);
        let out = matmul(&ctx, b * s, q_heads * hd, &shard[4].data, h);
        Ok((
            HostTensor::new(vec![b, s, h], out),
            HostTensor::new(vec![b, s, kv_heads, hd], k),
            HostTensor::new(vec![b, s, kv_heads, hd], v),
        ))
    }

    /// Causal GQA prefill for one chunk of one sequence, resuming
    /// against a padded per-slot KV cache (see the blocked twin for the
    /// chunking bit-equivalence argument).
    #[allow(clippy::too_many_arguments)]
    pub fn attention_prefill_ranged(
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        row: usize,
        start: usize,
        shard: &[HostTensor],
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        let (b, c, h) = (x.shape[0], x.shape[1], x.shape[2]);
        if b != 1 {
            anyhow::bail!("ranged prefill takes one sequence, got batch {b}");
        }
        let m = k_cache.shape[1];
        if start + c > m {
            anyhow::bail!("chunk {start}..{} outside KV budget {m}", start + c);
        }
        if (q_heads / kv_heads) * kv_heads != q_heads {
            anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
        }
        let xn = rms_norm(x, &shard[0]);
        let q = matmul(&xn.data, c, h, &shard[1].data, q_heads * hd);
        let k_new = matmul(&xn.data, c, h, &shard[2].data, kv_heads * hd);
        let v_new = matmul(&xn.data, c, h, &shard[3].data, kv_heads * hd);
        let kvrow = kv_heads * hd;
        let dst = (row * m + start) * kvrow;
        k_cache.data[dst..dst + c * kvrow].copy_from_slice(&k_new[..c * kvrow]);
        v_cache.data[dst..dst + c * kvrow].copy_from_slice(&v_new[..c * kvrow]);
        let ctx = ranged_ctx(&q, k_cache, v_cache, row, start, c, q_heads, kv_heads, hd);
        let out = matmul(&ctx, c, q_heads * hd, &shard[4].data, h);
        Ok(HostTensor::new(vec![1, c, h], out))
    }

    /// One decode step against a padded KV cache; delegates to
    /// [`attention_decode_slots`] with every row active.
    #[allow(clippy::too_many_arguments)]
    pub fn attention_decode(
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        pos: usize,
        shard: &[HostTensor],
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        let b = x.shape[0];
        let m = k_cache.shape[1];
        if pos >= m {
            anyhow::bail!("decode position {pos} outside KV budget {m}");
        }
        attention_decode_slots(
            x,
            k_cache,
            v_cache,
            &vec![pos; b],
            &vec![true; b],
            shard,
            q_heads,
            kv_heads,
            hd,
        )
    }

    /// One decode step with per-slot positions; inactive rows are
    /// skipped entirely (no KV write, zero output rows).
    #[allow(clippy::too_many_arguments)]
    pub fn attention_decode_slots(
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        pos: &[usize],
        active: &[bool],
        shard: &[HostTensor],
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        let (b, h) = (x.shape[0], x.shape[2]);
        if pos.len() != b || active.len() != b {
            anyhow::bail!("slot decode expects {b} positions/activity flags");
        }
        if (q_heads / kv_heads) * kv_heads != q_heads {
            anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
        }
        let xn = rms_norm(x, &shard[0]);
        let q = matmul(&xn.data, b, h, &shard[1].data, q_heads * hd);
        let k_new = matmul(&xn.data, b, h, &shard[2].data, kv_heads * hd);
        let v_new = matmul(&xn.data, b, h, &shard[3].data, kv_heads * hd);
        let ctx =
            slot_ctx(&q, &k_new, &v_new, k_cache, v_cache, pos, active, q_heads, kv_heads, hd)?;
        let out = matmul(&ctx, b, q_heads * hd, &shard[4].data, h);
        Ok(HostTensor::new(vec![b, 1, h], out))
    }

    /// Paged twin of [`attention_prefill_ranged`]: K/V for the chunk
    /// write per-position through `table` into a block-granular cache
    /// `[num_blocks, block_size, kv_heads, hd]`, and the context
    /// gathers through the same table. Projection math, loop
    /// structure, and accumulation order are identical to the padded
    /// kernel, so a slot whose table maps its logical blocks in any
    /// pool order produces bit-identical output.
    #[allow(clippy::too_many_arguments)]
    pub fn attention_prefill_ranged_paged(
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        table: &[usize],
        block_size: usize,
        start: usize,
        shard: &[HostTensor],
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        let (b, c, h) = (x.shape[0], x.shape[1], x.shape[2]);
        if b != 1 {
            anyhow::bail!("ranged prefill takes one sequence, got batch {b}");
        }
        check_prefill_table(table, k_cache.shape[0], start + c, block_size)?;
        if (q_heads / kv_heads) * kv_heads != q_heads {
            anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
        }
        let xn = rms_norm(x, &shard[0]);
        let q = matmul(&xn.data, c, h, &shard[1].data, q_heads * hd);
        let k_new = matmul(&xn.data, c, h, &shard[2].data, kv_heads * hd);
        let v_new = matmul(&xn.data, c, h, &shard[3].data, kv_heads * hd);
        let kvrow = kv_heads * hd;
        for i in 0..c {
            let p = start + i;
            let dst = (table[p / block_size] * block_size + p % block_size) * kvrow;
            k_cache.data[dst..dst + kvrow].copy_from_slice(&k_new[i * kvrow..(i + 1) * kvrow]);
            v_cache.data[dst..dst + kvrow].copy_from_slice(&v_new[i * kvrow..(i + 1) * kvrow]);
        }
        let ctx = ranged_ctx_paged(
            &q, k_cache, v_cache, table, block_size, start, c, q_heads, kv_heads, hd,
        );
        let out = matmul(&ctx, c, q_heads * hd, &shard[4].data, h);
        Ok(HostTensor::new(vec![1, c, h], out))
    }

    /// Paged twin of [`attention_decode_slots`]: per-slot block tables
    /// (`tables` = `b × tstride` entries) route each row's KV write
    /// and gather; inactive rows are skipped entirely.
    #[allow(clippy::too_many_arguments)]
    pub fn attention_decode_slots_paged(
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        pos: &[usize],
        active: &[bool],
        tables: &[usize],
        tstride: usize,
        block_size: usize,
        shard: &[HostTensor],
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        let (b, h) = (x.shape[0], x.shape[2]);
        if pos.len() != b || active.len() != b {
            anyhow::bail!("slot decode expects {b} positions/activity flags");
        }
        if tables.len() != b * tstride {
            anyhow::bail!("block tables cover {} entries, expected {}", tables.len(), b * tstride);
        }
        if (q_heads / kv_heads) * kv_heads != q_heads {
            anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
        }
        let xn = rms_norm(x, &shard[0]);
        let q = matmul(&xn.data, b, h, &shard[1].data, q_heads * hd);
        let k_new = matmul(&xn.data, b, h, &shard[2].data, kv_heads * hd);
        let v_new = matmul(&xn.data, b, h, &shard[3].data, kv_heads * hd);
        let ctx = slot_ctx_paged(
            &q, &k_new, &v_new, k_cache, v_cache, pos, active, tables, tstride, block_size,
            q_heads, kv_heads, hd,
        )?;
        let out = matmul(&ctx, b, q_heads * hd, &shard[4].data, h);
        Ok(HostTensor::new(vec![b, 1, h], out))
    }
}

// ---------------------------------------------------------------------------
// Blocked packed-RHS matmul core
// ---------------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd {
    //! Explicit SSE2/AVX2 lane kernels behind the `simd` cargo feature.
    //! SSE2 is part of the x86_64 baseline, so it needs no runtime
    //! detection; AVX2 is probed once via `is_x86_feature_detected!`
    //! (the result is cached by std, so steady state pays one relaxed
    //! load per call). On other architectures the portable loop
    //! compiles in. Both widths map lanes ≡ output columns with
    //! separate rounded multiply and add, so the choice of width can
    //! never change any element's bits.
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
        _mm_add_ps, _mm_loadu_ps, _mm_mul_ps, _mm_set1_ps, _mm_storeu_ps,
    };

    /// `acc[j] += av * w[j]` over `NB = 16` lanes. Multiply and add are
    /// separate rounded ops (never contracted to an FMA), so every lane
    /// is bit-identical to the portable scalar expression.
    ///
    /// # Safety
    /// `acc` and `w` must each point at 16 readable (and for `acc`,
    /// writable) `f32` lanes.
    #[inline(always)]
    pub unsafe fn fmadd16(acc: *mut f32, w: *const f32, av: f32) {
        let a = _mm_set1_ps(av);
        for q in 0..4 {
            let wv = _mm_loadu_ps(w.add(q * 4));
            let cv = _mm_loadu_ps(acc.add(q * 4));
            _mm_storeu_ps(acc.add(q * 4), _mm_add_ps(cv, _mm_mul_ps(a, wv)));
        }
    }

    /// AVX2 8-lane variant of [`fmadd16`]: two 256-bit quads instead of
    /// four 128-bit ones. Same lane ≡ column mapping, same separate
    /// multiply/add (`_mm256_mul_ps` + `_mm256_add_ps`, never FMA), so
    /// each lane's rounding sequence is identical to the SSE2 and
    /// portable paths.
    ///
    /// # Safety
    /// `acc` and `w` must each point at 16 readable (and for `acc`,
    /// writable) `f32` lanes, and the CPU must support AVX2 (checked at
    /// runtime by [`fmadd_lanes`](super::fmadd_lanes)).
    #[target_feature(enable = "avx2")]
    pub unsafe fn fmadd16_avx2(acc: *mut f32, w: *const f32, av: f32) {
        let a = _mm256_set1_ps(av);
        for q in 0..2 {
            let wv = _mm256_loadu_ps(w.add(q * 8));
            let cv = _mm256_loadu_ps(acc.add(q * 8));
            _mm256_storeu_ps(acc.add(q * 8), _mm256_add_ps(cv, _mm256_mul_ps(a, wv)));
        }
    }
}

/// `acc[j] += av * w[j]` over the panel's [`NB`] lanes: the one
/// multiply-accumulate step both packed matmuls are built from. Lanes
/// are independent output-element accumulators, so vectorizing across
/// them (auto or explicit) cannot change any element's rounding.
#[inline(always)]
fn fmadd_lanes(acc: &mut [f32; NB], w: &[f32], av: f32) {
    debug_assert!(w.len() >= NB);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    // SAFETY: both buffers hold at least NB = 16 f32 lanes; the AVX2
    // path is only taken when the CPU reports the feature.
    unsafe {
        if is_x86_feature_detected!("avx2") {
            simd::fmadd16_avx2(acc.as_mut_ptr(), w.as_ptr(), av);
        } else {
            simd::fmadd16(acc.as_mut_ptr(), w.as_ptr(), av);
        }
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    for j in 0..NB {
        acc[j] += av * w[j];
    }
}

/// An f32 matmul right-hand side `[k, cols]`, repacked into
/// column-panel-major tiles: `panels[(p·k + i)·NB + j] = b[i][p·NB + j]`
/// (ragged tail panel zero-padded; padded lanes are computed but never
/// written back). Packing happens once per resident shard, so steady-
/// state serving never touches the row-major layout again.
#[derive(Debug, Clone)]
pub struct PackedMat {
    k: usize,
    cols: usize,
    panels: Vec<f32>,
}

impl PackedMat {
    pub fn pack(b: &[f32], k: usize, cols: usize) -> PackedMat {
        assert_eq!(b.len(), k * cols, "pack rhs size");
        assert!(k > 0 && cols > 0, "pack on empty matrix");
        let np = cols.div_ceil(NB);
        let mut panels = vec![0f32; np * k * NB];
        for p in 0..np {
            let c0 = p * NB;
            let nb = NB.min(cols - c0);
            for i in 0..k {
                let dst = (p * k + i) * NB;
                panels[dst..dst + nb].copy_from_slice(&b[i * cols + c0..i * cols + c0 + nb]);
            }
        }
        PackedMat { k, cols, panels }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Actual resident bytes (including tail-panel padding).
    pub fn weight_bytes(&self) -> usize {
        self.panels.len() * 4
    }

    /// Row-major `[k, cols]` reconstruction (drops panel padding).
    pub fn dequantized(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.k * self.cols];
        for p in 0..self.cols.div_ceil(NB) {
            let c0 = p * NB;
            let nb = NB.min(self.cols - c0);
            for i in 0..self.k {
                let src = (p * self.k + i) * NB;
                out[i * self.cols + c0..i * self.cols + c0 + nb]
                    .copy_from_slice(&self.panels[src..src + nb]);
            }
        }
        out
    }

    /// `a [rows, k] @ self → out [rows, cols]`, bit-identical to
    /// [`reference::matmul`]: each output element keeps one accumulator
    /// (a lane of the MR×NB register tile) filled in ascending-`i`
    /// order.
    fn matmul_into(&self, a: &[f32], rows: usize, out: &mut [f32]) {
        let (k, cols) = (self.k, self.cols);
        assert_eq!(a.len(), rows * k, "matmul lhs size");
        assert_eq!(out.len(), rows * cols, "matmul out size");
        let np = cols.div_ceil(NB);
        let mut r = 0;
        while r < rows {
            let rt = MR.min(rows - r);
            for p in 0..np {
                let c0 = p * NB;
                let nb = NB.min(cols - c0);
                let panel = &self.panels[p * k * NB..(p + 1) * k * NB];
                let mut acc = [[0f32; NB]; MR];
                for i in 0..k {
                    let prow = &panel[i * NB..i * NB + NB];
                    for rr in 0..rt {
                        fmadd_lanes(&mut acc[rr], prow, a[(r + rr) * k + i]);
                    }
                }
                for rr in 0..rt {
                    let dst = (r + rr) * cols + c0;
                    out[dst..dst + nb].copy_from_slice(&acc[rr][..nb]);
                }
            }
            r += rt;
        }
    }
}

/// Sign-extended int4 code values, indexed by the two's-complement
/// nibble: `I4_LUT[code & 0xF] == code as f32` for codes in `[-8, 7]`.
const I4_LUT: [f32; 16] = [
    0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, -8.0, -7.0, -6.0, -5.0, -4.0, -3.0, -2.0, -1.0,
];

/// An int8/int4 per-group quantized matmul right-hand side in the same
/// panel-major layout as [`PackedMat`], dequantized on the fly inside
/// the matmul: codes are affine per `(row, group)` with the group width
/// [`QUANT_GROUP`] a multiple of [`NB`], so each `(row, panel)` pass
/// does exactly one `(scale, bias)` lookup. The fused matmul is
/// bit-identical to [`reference::matmul`] over [`Self::dequantized`]
/// because the dequantized lane value is computed with the identical
/// expression (`code · scale + bias`) before the identical
/// multiply-accumulate.
#[derive(Debug, Clone)]
pub struct PackedQuant {
    k: usize,
    cols: usize,
    kind: QuantKind,
    ngroups: usize,
    /// int8: one code byte per lane, `[(p·k + i)·NB + j]`;
    /// int4: two lanes per byte (low nibble = even lane),
    /// `[(p·k + i)·NB/2 + j/2]`.
    codes: Vec<u8>,
    /// Per-`(row, group)` affine: `value = code·scale + bias`.
    scales: Vec<f32>,
    biases: Vec<f32>,
}

impl PackedQuant {
    /// Quantize a row-major `[k, cols]` weight matrix. Each `(row,
    /// group)` gets its own affine range (the last group may be ragged
    /// when `cols % QUANT_GROUP != 0`), mirroring
    /// [`crate::quant::affine_params`] / [`crate::quant::encode_signed`]
    /// exactly.
    pub fn quantize(b: &[f32], k: usize, cols: usize, kind: QuantKind) -> PackedQuant {
        assert_eq!(b.len(), k * cols, "quantize rhs size");
        assert!(k > 0 && cols > 0, "quantize on empty matrix");
        const _: () = assert!(QUANT_GROUP % NB == 0);
        let np = cols.div_ceil(NB);
        let ngroups = cols.div_ceil(QUANT_GROUP);
        let lane_bytes = match kind {
            QuantKind::Int8 => NB,
            QuantKind::Int4 => NB / 2,
        };
        let mut codes = vec![0u8; np * k * lane_bytes];
        let mut scales = vec![0f32; k * ngroups];
        let mut biases = vec![0f32; k * ngroups];
        for i in 0..k {
            for g in 0..ngroups {
                let g0 = g * QUANT_GROUP;
                let g1 = cols.min(g0 + QUANT_GROUP);
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for &v in &b[i * cols + g0..i * cols + g1] {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                let (scale, inv_scale, zero) = quant::affine_params(kind, lo, hi);
                scales[i * ngroups + g] = scale;
                biases[i * ngroups + g] = -zero * scale;
                for c in g0..g1 {
                    let code = quant::encode_signed(kind, b[i * cols + c], inv_scale, zero);
                    let (p, j) = (c / NB, c % NB);
                    match kind {
                        QuantKind::Int8 => codes[(p * k + i) * NB + j] = code as u8,
                        QuantKind::Int4 => {
                            let byte = &mut codes[(p * k + i) * (NB / 2) + j / 2];
                            *byte |= (code as u8 & 0x0F) << (4 * (j % 2));
                        }
                    }
                }
            }
        }
        PackedQuant { k, cols, kind, ngroups, codes, scales, biases }
    }

    pub fn kind(&self) -> QuantKind {
        self.kind
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Actual resident bytes: packed codes plus the affine tables.
    pub fn weight_bytes(&self) -> usize {
        self.codes.len() + (self.scales.len() + self.biases.len()) * 4
    }

    /// Dequantize one panel row into `NB` lane values — the single
    /// shared decode expression for [`Self::matmul_into`] and
    /// [`Self::dequantized`], which is what makes "fused ≡ reference on
    /// dequantized weights" hold bitwise.
    #[inline(always)]
    fn decode_panel_row(&self, p: usize, i: usize, w: &mut [f32; NB]) {
        let g = (p * NB) / QUANT_GROUP;
        let scale = self.scales[i * self.ngroups + g];
        let bias = self.biases[i * self.ngroups + g];
        match self.kind {
            QuantKind::Int8 => {
                let crow = &self.codes[(p * self.k + i) * NB..(p * self.k + i) * NB + NB];
                for j in 0..NB {
                    w[j] = crow[j] as i8 as f32 * scale + bias;
                }
            }
            QuantKind::Int4 => {
                let base = (p * self.k + i) * (NB / 2);
                let crow = &self.codes[base..base + NB / 2];
                for (jb, &byte) in crow.iter().enumerate() {
                    w[2 * jb] = I4_LUT[(byte & 0x0F) as usize] * scale + bias;
                    w[2 * jb + 1] = I4_LUT[(byte >> 4) as usize] * scale + bias;
                }
            }
        }
    }

    /// Row-major `[k, cols]` dequantized weights: the exact f32 matrix
    /// the fused matmul multiplies by.
    pub fn dequantized(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.k * self.cols];
        let mut w = [0f32; NB];
        for p in 0..self.cols.div_ceil(NB) {
            let c0 = p * NB;
            let nb = NB.min(self.cols - c0);
            for i in 0..self.k {
                self.decode_panel_row(p, i, &mut w);
                out[i * self.cols + c0..i * self.cols + c0 + nb].copy_from_slice(&w[..nb]);
            }
        }
        out
    }

    /// `a [rows, k] @ dequant(self) → out [rows, cols]`, dequantizing
    /// each panel row once and sharing it across the register tile.
    fn matmul_into(&self, a: &[f32], rows: usize, out: &mut [f32]) {
        let (k, cols) = (self.k, self.cols);
        assert_eq!(a.len(), rows * k, "matmul lhs size");
        assert_eq!(out.len(), rows * cols, "matmul out size");
        let np = cols.div_ceil(NB);
        let mut w = [0f32; NB];
        let mut r = 0;
        while r < rows {
            let rt = MR.min(rows - r);
            for p in 0..np {
                let c0 = p * NB;
                let nb = NB.min(cols - c0);
                let mut acc = [[0f32; NB]; MR];
                for i in 0..k {
                    self.decode_panel_row(p, i, &mut w);
                    for rr in 0..rt {
                        fmadd_lanes(&mut acc[rr], &w, a[(r + rr) * k + i]);
                    }
                }
                for rr in 0..rt {
                    let dst = (r + rr) * cols + c0;
                    out[dst..dst + nb].copy_from_slice(&acc[rr][..nb]);
                }
            }
            r += rt;
        }
    }
}

/// A packed matmul right-hand side: full-precision panels or
/// dequant-on-the-fly quantized codes, one matmul entry point.
#[derive(Debug, Clone)]
pub enum PackedRhs {
    F32(PackedMat),
    Quant(PackedQuant),
}

impl PackedRhs {
    /// Pack a row-major weight slice `[k, cols]`, quantizing when a
    /// kind is given.
    pub fn pack_slice(b: &[f32], k: usize, cols: usize, quant: Option<QuantKind>) -> PackedRhs {
        match quant {
            None => PackedRhs::F32(PackedMat::pack(b, k, cols)),
            Some(kind) => PackedRhs::Quant(PackedQuant::quantize(b, k, cols, kind)),
        }
    }

    /// Pack a weight tensor, collapsing leading axes into rows (the
    /// last axis is the output-column axis).
    pub fn pack(t: &HostTensor, quant: Option<QuantKind>) -> PackedRhs {
        let cols = *t.shape.last().expect("pack on scalar tensor");
        Self::pack_slice(&t.data, t.data.len() / cols, cols, quant)
    }

    pub fn k(&self) -> usize {
        match self {
            PackedRhs::F32(m) => m.k(),
            PackedRhs::Quant(q) => q.k(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            PackedRhs::F32(m) => m.cols(),
            PackedRhs::Quant(q) => q.cols(),
        }
    }

    pub fn weight_bytes(&self) -> usize {
        match self {
            PackedRhs::F32(m) => m.weight_bytes(),
            PackedRhs::Quant(q) => q.weight_bytes(),
        }
    }

    /// Row-major `[k, cols]` view of the effective weights (for f32,
    /// the original matrix; for quant, the dequantized one).
    pub fn dequantized(&self) -> Vec<f32> {
        match self {
            PackedRhs::F32(m) => m.dequantized(),
            PackedRhs::Quant(q) => q.dequantized(),
        }
    }

    /// `a [rows, k] @ self → [rows, cols]`.
    pub fn matmul(&self, a: &[f32], rows: usize) -> Vec<f32> {
        let mut out = vec![0f32; rows * self.cols()];
        match self {
            PackedRhs::F32(m) => m.matmul_into(a, rows, &mut out),
            PackedRhs::Quant(q) => q.matmul_into(a, rows, &mut out),
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Typed packed shard bundles
// ---------------------------------------------------------------------------

/// One attention shard (`[ln, wq, wk, wv, wo]`) with every projection
/// packed. `quant` applies to all four projections; `ln` stays f32.
#[derive(Debug, Clone)]
pub struct AttnWeights {
    pub ln: HostTensor,
    pub wq: PackedRhs,
    pub wk: PackedRhs,
    pub wv: PackedRhs,
    pub wo: PackedRhs,
}

impl AttnWeights {
    pub fn from_shard(shard: &[HostTensor], quant: Option<QuantKind>) -> Result<AttnWeights> {
        if shard.len() != 5 {
            anyhow::bail!("attention shard has {} tensors, expected 5", shard.len());
        }
        Ok(AttnWeights {
            ln: shard[0].clone(),
            wq: PackedRhs::pack(&shard[1], quant),
            wk: PackedRhs::pack(&shard[2], quant),
            wv: PackedRhs::pack(&shard[3], quant),
            wo: PackedRhs::pack(&shard[4], quant),
        })
    }

    pub fn weight_bytes(&self) -> usize {
        self.ln.data.len() * 4
            + self.wq.weight_bytes()
            + self.wk.weight_bytes()
            + self.wv.weight_bytes()
            + self.wo.weight_bytes()
    }
}

/// One expert shard (`[ln, router, (sel,) wg, wu, wd]`) with the
/// per-expert FFN matrices packed individually (so the sparse gather
/// runs one compact matmul per routed expert). `quant` applies to
/// `wg/wu/wd`; `ln`, `router`, and `sel` stay f32 — the router decides
/// *where* tokens go and is tiny, so quantizing it would risk routing
/// flips for no memory win.
#[derive(Debug, Clone)]
pub struct ExpertWeights {
    pub ln: HostTensor,
    pub router: PackedRhs,
    /// `Some` iff `ep > 1` (the EP block's expert selector).
    pub sel: Option<HostTensor>,
    pub wg: Vec<PackedRhs>,
    pub wu: Vec<PackedRhs>,
    pub wd: Vec<PackedRhs>,
}

impl ExpertWeights {
    pub fn from_shard(
        shard: &[HostTensor],
        ep: usize,
        quant: Option<QuantKind>,
    ) -> Result<ExpertWeights> {
        let expected = if ep > 1 { 6 } else { 5 };
        if shard.len() != expected {
            anyhow::bail!("expert shard has {} tensors, expected {expected}", shard.len());
        }
        let off = if ep > 1 { 1 } else { 0 };
        let (wg, wu, wd) = (&shard[2 + off], &shard[3 + off], &shard[4 + off]);
        let e_l = wg.shape[0];
        let (h, i_l) = (wg.shape[1], wg.shape[2]);
        let pack_experts = |t: &HostTensor, k: usize, cols: usize| -> Vec<PackedRhs> {
            (0..e_l)
                .map(|e| {
                    let w = &t.data[e * k * cols..(e + 1) * k * cols];
                    PackedRhs::pack_slice(w, k, cols, quant)
                })
                .collect()
        };
        Ok(ExpertWeights {
            ln: shard[0].clone(),
            router: PackedRhs::pack(&shard[1], None),
            sel: (ep > 1).then(|| shard[2].clone()),
            wg: pack_experts(wg, h, i_l),
            wu: pack_experts(wu, h, i_l),
            wd: pack_experts(wd, i_l, h),
        })
    }

    pub fn weight_bytes(&self) -> usize {
        let ffn: usize = self
            .wg
            .iter()
            .chain(&self.wu)
            .chain(&self.wd)
            .map(PackedRhs::weight_bytes)
            .sum();
        (self.ln.data.len() + self.sel.as_ref().map_or(0, |s| s.data.len())) * 4
            + self.router.weight_bytes()
            + ffn
    }
}

/// Final-head weights (`ln_f` + packed unembed); always f32 — the
/// unembed projection directly picks the argmax token.
#[derive(Debug, Clone)]
pub struct HeadWeights {
    pub ln: HostTensor,
    pub unembed: PackedRhs,
}

impl HeadWeights {
    pub fn new(ln_f: &HostTensor, unembed: &HostTensor) -> HeadWeights {
        HeadWeights { ln: ln_f.clone(), unembed: PackedRhs::pack(unembed, None) }
    }
}

/// A device role's packed resident shard: what `WeightStore::shard_packed`
/// produces and the executor caches per `(family, layer)`.
#[derive(Debug, Clone)]
pub enum ShardWeights {
    Attn(AttnWeights),
    Expert(ExpertWeights),
}

impl ShardWeights {
    pub fn weight_bytes(&self) -> usize {
        match self {
            ShardWeights::Attn(w) => w.weight_bytes(),
            ShardWeights::Expert(w) => w.weight_bytes(),
        }
    }
}

// ---------------------------------------------------------------------------
// Blocked kernels (the serving hot path)
// ---------------------------------------------------------------------------

/// Final norm + unembed on the last-position residual:
/// `x_last [B, H] → logits [B, V]`.
pub fn head(x_last: &HostTensor, w: &HeadWeights) -> HostTensor {
    let b = x_last.shape[0];
    let v = w.unembed.cols();
    let xn = rms_norm(x_last, &w.ln);
    HostTensor::new(vec![b, v], w.unembed.matmul(&xn.data, b))
}

/// Mixtral top-k gate over a packed router (see
/// [`reference::topk_gate`]).
pub fn topk_gate(xn: &HostTensor, router: &PackedRhs, top_k: usize) -> HostTensor {
    let t = xn.shape[0];
    let e = router.cols();
    let logits = router.matmul(&xn.data, t);
    HostTensor::new(vec![t, e], gate_rows(&logits, t, e, top_k))
}

/// SwiGLU routed FFN with a **sparse expert gather**: for each local
/// expert, only the rows with a non-zero gate are gathered into a
/// compact batch, pushed through that expert's packed matmuls, and
/// scatter-accumulated. Bit-identical to the dense reference because
/// matmul rows are independent and the reference skips gate-zero rows
/// at accumulation time anyway; cuts expert compute by ~`E / top_k`.
fn expert_ffn_packed(
    xn: &HostTensor,
    gates_local: &[f32],
    wg: &[PackedRhs],
    wu: &[PackedRhs],
    wd: &[PackedRhs],
) -> HostTensor {
    let (t, h) = (xn.shape[0], xn.shape[1]);
    let e_l = wg.len();
    assert_eq!(gates_local.len(), t * e_l, "gate table size");
    let mut out = vec![0f32; t * h];
    let mut rows: Vec<usize> = Vec::with_capacity(t);
    for e in 0..e_l {
        rows.clear();
        rows.extend((0..t).filter(|&r| gates_local[r * e_l + e] != 0.0));
        if rows.is_empty() {
            continue;
        }
        let mt = rows.len();
        let mut xa = Vec::with_capacity(mt * h);
        for &r in &rows {
            xa.extend_from_slice(&xn.data[r * h..(r + 1) * h]);
        }
        let i_l = wg[e].cols();
        let g = wg[e].matmul(&xa, mt);
        let u = wu[e].matmul(&xa, mt);
        let mut act = vec![0f32; mt * i_l];
        for j in 0..mt * i_l {
            act[j] = silu(g[j]) * u[j];
        }
        let y = wd[e].matmul(&act, mt);
        for (j, &r) in rows.iter().enumerate() {
            let gate = gates_local[r * e_l + e];
            for c in 0..h {
                out[r * h + c] += gate * y[j * h + c];
            }
        }
    }
    HostTensor::new(vec![t, h], out)
}

/// One device's expert-module contribution for its packed `(ep, tp)`
/// shard: `x [T, H]` combined residual → partial output `[T, H]`.
pub fn expert_module(x: &HostTensor, w: &ExpertWeights, top_k: usize) -> Result<HostTensor> {
    let xn = rms_norm(x, &w.ln);
    let gates = topk_gate(&xn, &w.router, top_k);
    match &w.sel {
        None => Ok(expert_ffn_packed(&xn, &gates.data, &w.wg, &w.wu, &w.wd)),
        Some(sel) => {
            let gl = select_gates(&gates.data, sel, xn.shape[0]);
            Ok(expert_ffn_packed(&xn, &gl, &w.wg, &w.wu, &w.wd))
        }
    }
}

/// [`expert_module`] over one contiguous **row range** of the token
/// batch: rows `start..start + len` of `x [T, H]` → partial output
/// `[len, H]`. Bit-identical to the corresponding rows of the
/// full-batch call because RMS norm, gating, the sparse expert gather,
/// and per-row gate accumulation are all row-independent (the packed
/// matmul keeps one accumulator per output element regardless of how
/// many rows are in flight). This is the blocked-family half of the
/// micro-chunk contract; [`reference::expert_module_ranged`] is the
/// scalar oracle.
pub fn expert_module_ranged(
    x: &HostTensor,
    w: &ExpertWeights,
    top_k: usize,
    start: usize,
    len: usize,
) -> Result<HostTensor> {
    let (t, h) = (x.shape[0], x.shape[1]);
    if start + len > t {
        anyhow::bail!("expert chunk {start}..{} outside batch {t}", start + len);
    }
    let rows = HostTensor::new(vec![len, h], x.data[start * h..(start + len) * h].to_vec());
    expert_module(&rows, w, top_k)
}

/// Causal GQA prefill attention for one packed head shard (see
/// [`reference::attention_prefill`]).
pub fn attention_prefill(
    x: &HostTensor,
    w: &AttnWeights,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<(HostTensor, HostTensor, HostTensor)> {
    let (b, s) = (x.shape[0], x.shape[1]);
    if (q_heads / kv_heads) * kv_heads != q_heads {
        anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
    }
    let xn = rms_norm(x, &w.ln);
    let q = w.wq.matmul(&xn.data, b * s);
    let k = w.wk.matmul(&xn.data, b * s);
    let v = w.wv.matmul(&xn.data, b * s);
    let ctx = prefill_ctx(&q, &k, &v, b, s, q_heads, kv_heads, hd);
    let out = w.wo.matmul(&ctx, b * s);
    Ok((
        HostTensor::new(vec![b, s, w.wo.cols()], out),
        HostTensor::new(vec![b, s, kv_heads, hd], k),
        HostTensor::new(vec![b, s, kv_heads, hd], v),
    ))
}

/// Causal GQA prefill attention for **one chunk of one sequence**,
/// resuming against a padded per-slot KV cache.
///
/// `x [1, C, H]` is the chunk's residual (prompt positions
/// `start..start+C` of batch row `row` in the group cache
/// `[B_g, M, KVH_l, D]`). The chunk's K/V are written into the cache
/// first, then each chunk query at global position `p = start + qi`
/// attends causally to cache positions `0..=p`. Splitting a prompt into
/// chunks — any sizes — is bit-identical to the one-shot kernel because
/// every per-row quantity is row-independent and the score/softmax/
/// context loop ([`ranged_ctx`]) is shared; asserted by
/// `chunked_prefill_bit_identical`.
#[allow(clippy::too_many_arguments)]
pub fn attention_prefill_ranged(
    x: &HostTensor,
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    row: usize,
    start: usize,
    w: &AttnWeights,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<HostTensor> {
    let (b, c) = (x.shape[0], x.shape[1]);
    if b != 1 {
        anyhow::bail!("ranged prefill takes one sequence, got batch {b}");
    }
    let m = k_cache.shape[1];
    if start + c > m {
        anyhow::bail!("chunk {start}..{} outside KV budget {m}", start + c);
    }
    if (q_heads / kv_heads) * kv_heads != q_heads {
        anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
    }
    let xn = rms_norm(x, &w.ln);
    let q = w.wq.matmul(&xn.data, c);
    let k_new = w.wk.matmul(&xn.data, c);
    let v_new = w.wv.matmul(&xn.data, c);
    let kvrow = kv_heads * hd;
    let dst = (row * m + start) * kvrow;
    k_cache.data[dst..dst + c * kvrow].copy_from_slice(&k_new[..c * kvrow]);
    v_cache.data[dst..dst + c * kvrow].copy_from_slice(&v_new[..c * kvrow]);
    let ctx = ranged_ctx(&q, k_cache, v_cache, row, start, c, q_heads, kv_heads, hd);
    let out = w.wo.matmul(&ctx, c);
    Ok(HostTensor::new(vec![1, c, w.wo.cols()], out))
}

/// One decode step against a padded KV cache (`[B, M, KVH_l, D]`);
/// delegates to [`attention_decode_slots`] with every row active, so
/// the gang path and the streaming per-slot path share one copy of the
/// float-order-sensitive attention math.
#[allow(clippy::too_many_arguments)]
pub fn attention_decode(
    x: &HostTensor,
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    pos: usize,
    w: &AttnWeights,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<HostTensor> {
    let b = x.shape[0];
    let m = k_cache.shape[1];
    if pos >= m {
        anyhow::bail!("decode position {pos} outside KV budget {m}");
    }
    attention_decode_slots(
        x,
        k_cache,
        v_cache,
        &vec![pos; b],
        &vec![true; b],
        w,
        q_heads,
        kv_heads,
        hd,
    )
}

/// One decode step with **per-slot positions** against a padded KV
/// cache: row `bi` writes its new token at `pos[bi]` and attends
/// `0..=pos[bi]`; rows with `active[bi] == false` are skipped entirely.
#[allow(clippy::too_many_arguments)]
pub fn attention_decode_slots(
    x: &HostTensor,
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    pos: &[usize],
    active: &[bool],
    w: &AttnWeights,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<HostTensor> {
    let b = x.shape[0];
    if pos.len() != b || active.len() != b {
        anyhow::bail!("slot decode expects {b} positions/activity flags");
    }
    if (q_heads / kv_heads) * kv_heads != q_heads {
        anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
    }
    let xn = rms_norm(x, &w.ln);
    let q = w.wq.matmul(&xn.data, b);
    let k_new = w.wk.matmul(&xn.data, b);
    let v_new = w.wv.matmul(&xn.data, b);
    let ctx = slot_ctx(&q, &k_new, &v_new, k_cache, v_cache, pos, active, q_heads, kv_heads, hd)?;
    let out = w.wo.matmul(&ctx, b);
    Ok(HostTensor::new(vec![b, 1, w.wo.cols()], out))
}

/// Paged twin of [`attention_prefill_ranged`] for the packed fast
/// path: the chunk's K/V write per-position through the slot's block
/// `table` into a block-granular cache `[NB, BS, KVH_l, D]`, and the
/// context gathers through the same table ([`ranged_ctx_paged`]).
/// Projection math and accumulation order are identical to the padded
/// kernel, so output is bit-identical for any table that maps the
/// chunk's logical blocks.
#[allow(clippy::too_many_arguments)]
pub fn attention_prefill_ranged_paged(
    x: &HostTensor,
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    table: &[usize],
    block_size: usize,
    start: usize,
    w: &AttnWeights,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<HostTensor> {
    let (b, c) = (x.shape[0], x.shape[1]);
    if b != 1 {
        anyhow::bail!("ranged prefill takes one sequence, got batch {b}");
    }
    check_prefill_table(table, k_cache.shape[0], start + c, block_size)?;
    if (q_heads / kv_heads) * kv_heads != q_heads {
        anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
    }
    let xn = rms_norm(x, &w.ln);
    let q = w.wq.matmul(&xn.data, c);
    let k_new = w.wk.matmul(&xn.data, c);
    let v_new = w.wv.matmul(&xn.data, c);
    let kvrow = kv_heads * hd;
    for i in 0..c {
        let p = start + i;
        let dst = (table[p / block_size] * block_size + p % block_size) * kvrow;
        k_cache.data[dst..dst + kvrow].copy_from_slice(&k_new[i * kvrow..(i + 1) * kvrow]);
        v_cache.data[dst..dst + kvrow].copy_from_slice(&v_new[i * kvrow..(i + 1) * kvrow]);
    }
    let ctx =
        ranged_ctx_paged(&q, k_cache, v_cache, table, block_size, start, c, q_heads, kv_heads, hd);
    let out = w.wo.matmul(&ctx, c);
    Ok(HostTensor::new(vec![1, c, w.wo.cols()], out))
}

/// Paged twin of [`attention_decode_slots`] for the packed fast path:
/// per-slot block tables (`tables` = `b × tstride` entries) route each
/// active row's KV write and gather ([`slot_ctx_paged`]).
#[allow(clippy::too_many_arguments)]
pub fn attention_decode_slots_paged(
    x: &HostTensor,
    k_cache: &mut HostTensor,
    v_cache: &mut HostTensor,
    pos: &[usize],
    active: &[bool],
    tables: &[usize],
    tstride: usize,
    block_size: usize,
    w: &AttnWeights,
    q_heads: usize,
    kv_heads: usize,
    hd: usize,
) -> Result<HostTensor> {
    let b = x.shape[0];
    if pos.len() != b || active.len() != b {
        anyhow::bail!("slot decode expects {b} positions/activity flags");
    }
    if tables.len() != b * tstride {
        anyhow::bail!("block tables cover {} entries, expected {}", tables.len(), b * tstride);
    }
    if (q_heads / kv_heads) * kv_heads != q_heads {
        anyhow::bail!("GQA ratio {q_heads}/{kv_heads} is not integral");
    }
    let xn = rms_norm(x, &w.ln);
    let q = w.wq.matmul(&xn.data, b);
    let k_new = w.wk.matmul(&xn.data, b);
    let v_new = w.wv.matmul(&xn.data, b);
    let ctx = slot_ctx_paged(
        &q, &k_new, &v_new, k_cache, v_cache, pos, active, tables, tstride, block_size, q_heads,
        kv_heads, hd,
    )?;
    let out = w.wo.matmul(&ctx, b);
    Ok(HostTensor::new(vec![b, 1, w.wo.cols()], out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(n: usize, k: f32) -> Vec<f32> {
        (0..n).map(|i| ((i * 7 + 3) % 11) as f32 * k - 0.4).collect()
    }

    #[test]
    fn rms_norm_unit_scale_normalizes() {
        let x = HostTensor::new(vec![1, 4], vec![2.0, 2.0, 2.0, 2.0]);
        let scale = HostTensor::new(vec![4], vec![1.0; 4]);
        let n = rms_norm(&x, &scale);
        // mean(x²) = 4 → rsqrt ≈ 0.5.
        for v in &n.data {
            assert!((v - 1.0).abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn matmul_matches_hand_product() {
        // [2,3] @ [3,2]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = reference::matmul(&a, 2, 3, &b, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn packed_matmul_bit_identical_on_ragged_shape() {
        // rows, k, cols all off the MR/NB grid.
        let (rows, k, cols) = (5usize, 7usize, 21usize);
        let a = fill(rows * k, 0.13);
        let b = fill(k * cols, 0.07);
        let want = reference::matmul(&a, rows, k, &b, cols);
        let got = PackedRhs::pack_slice(&b, k, cols, None).matmul(&a, rows);
        for (i, (x, y)) in want.iter().zip(&got).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "diverged at {i}");
        }
    }

    #[test]
    fn fused_quant_matmul_matches_reference_on_dequantized_weights() {
        let (rows, k, cols) = (3usize, 9usize, 70usize); // ragged group + panel
        let a = fill(rows * k, 0.11);
        let b = fill(k * cols, 0.05);
        for kind in [QuantKind::Int8, QuantKind::Int4] {
            let q = PackedQuant::quantize(&b, k, cols, kind);
            let want = reference::matmul(&a, rows, k, &q.dequantized(), cols);
            let mut got = vec![0f32; rows * cols];
            q.matmul_into(&a, rows, &mut got);
            for (i, (x, y)) in want.iter().zip(&got).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{kind:?} diverged at {i}");
            }
        }
    }

    #[test]
    fn topk_gate_selects_k_and_normalizes() {
        // Identity-ish router so logits = xn (h == e == 3).
        let xn = HostTensor::new(vec![1, 3], vec![1.0, 3.0, 2.0]);
        let mut router = HostTensor::zeros(vec![3, 3]);
        for i in 0..3 {
            router.data[i * 3 + i] = 1.0;
        }
        let g = reference::topk_gate(&xn, &router, 2);
        assert_eq!(g.data[0], 0.0, "lowest logit must be masked");
        let sum: f32 = g.data.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(g.data[1] > g.data[2]);
        // Packed router produces the same gates bit-for-bit.
        let packed = topk_gate(&xn, &PackedRhs::pack(&router, None), 2);
        for (a, b) in g.data.iter().zip(&packed.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn expert_tp_slices_sum_to_full() {
        // [T=2, H=2], one expert, I=4: full output equals the sum of
        // the two I/2 slices (the TP partial-sum identity).
        let x = HostTensor::new(vec![2, 2], vec![0.3, -0.2, 0.7, 0.1]);
        let ln = HostTensor::new(vec![2], vec![1.0, 1.0]);
        let router = HostTensor::new(vec![2, 1], vec![1.0, 1.0]);
        let wg = HostTensor::new(vec![1, 2, 4], (0..8).map(|i| 0.1 * i as f32).collect());
        let wu = HostTensor::new(vec![1, 2, 4], (0..8).map(|i| 0.05 * i as f32).collect());
        let wd = HostTensor::new(vec![1, 4, 2], (0..8).map(|i| 0.02 * i as f32).collect());
        let full = reference::expert_module(
            &x,
            &[ln.clone(), router.clone(), wg.clone(), wu.clone(), wd.clone()],
            1,
            1,
        )
        .unwrap();
        let slice = |t: &HostTensor, i0: usize| -> HostTensor {
            // last-axis slice of [1,2,4] → [1,2,2]
            let mut d = Vec::new();
            for r in 0..2 {
                d.extend_from_slice(&t.data[r * 4 + i0..r * 4 + i0 + 2]);
            }
            HostTensor::new(vec![1, 2, 2], d)
        };
        let slice_rows = |t: &HostTensor, i0: usize| -> HostTensor {
            HostTensor::new(vec![1, 2, 2], t.data[i0 * 2..(i0 + 2) * 2].to_vec())
        };
        let mut sum: Option<HostTensor> = None;
        for d0 in [0usize, 2] {
            let part = reference::expert_module(
                &x,
                &[ln.clone(), router.clone(), slice(&wg, d0), slice(&wu, d0), slice_rows(&wd, d0)],
                1,
                1,
            )
            .unwrap();
            match &mut sum {
                None => sum = Some(part),
                Some(acc) => acc.add_assign(&part),
            }
        }
        let got = sum.unwrap();
        for (a, b) in full.data.iter().zip(&got.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn blocked_expert_module_bit_identical_to_reference() {
        // 4 experts, top-2: the sparse gather must reproduce the dense
        // reference exactly, including rows each expert never sees.
        let (t, h, i, e) = (5usize, 6usize, 10usize, 4usize);
        let x = HostTensor::new(vec![t, h], fill(t * h, 0.09));
        let shard = vec![
            HostTensor::new(vec![h], vec![1.0; h]),
            HostTensor::new(vec![h, e], fill(h * e, 0.21)),
            HostTensor::new(vec![e, h, i], fill(e * h * i, 0.03)),
            HostTensor::new(vec![e, h, i], fill(e * h * i, 0.05)),
            HostTensor::new(vec![e, i, h], fill(e * i * h, 0.02)),
        ];
        let want = reference::expert_module(&x, &shard, 1, 2).unwrap();
        let w = ExpertWeights::from_shard(&shard, 1, None).unwrap();
        let got = expert_module(&x, &w, 2).unwrap();
        assert_eq!(want.shape, got.shape);
        for (idx, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "diverged at {idx}");
        }
    }

    #[test]
    fn blocked_attention_prefill_bit_identical_to_reference() {
        let (h, qh, kvh, hd, b, s) = (6usize, 4usize, 2usize, 3usize, 2usize, 5usize);
        let shard = vec![
            HostTensor::new(vec![h], fill(h, 0.1).iter().map(|v| v + 1.0).collect()),
            HostTensor::new(vec![h, qh * hd], fill(h * qh * hd, 0.11)),
            HostTensor::new(vec![h, kvh * hd], fill(h * kvh * hd, 0.07)),
            HostTensor::new(vec![h, kvh * hd], fill(h * kvh * hd, 0.05)),
            HostTensor::new(vec![qh * hd, h], fill(qh * hd * h, 0.09)),
        ];
        let x = HostTensor::new(vec![b, s, h], fill(b * s * h, 0.13));
        let (want_o, want_k, want_v) =
            reference::attention_prefill(&x, &shard, qh, kvh, hd).unwrap();
        let w = AttnWeights::from_shard(&shard, None).unwrap();
        let (got_o, got_k, got_v) = attention_prefill(&x, &w, qh, kvh, hd).unwrap();
        for (want, got) in [(&want_o, &got_o), (&want_k, &got_k), (&want_v, &got_v)] {
            assert_eq!(want.shape, got.shape);
            for (idx, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "diverged at {idx}");
            }
        }
    }

    #[test]
    fn slot_decode_matches_gang_decode_and_skips_inactive_rows() {
        // b=2, one head, hd=1: row 0 decoded via the per-slot kernel at
        // the same position as a gang decode must be bit-identical; the
        // inactive row 1 must leave its KV untouched and output zero.
        let ln = HostTensor::new(vec![2], vec![1.0, 1.0]);
        let wq = HostTensor::new(vec![2, 1], vec![0.4, -0.1]);
        let wk = HostTensor::new(vec![2, 1], vec![0.2, 0.3]);
        let wv = HostTensor::new(vec![2, 1], vec![1.0, -0.5]);
        let wo = HostTensor::new(vec![1, 2], vec![1.0, 0.7]);
        let shard = [ln, wq, wk, wv, wo];
        let x = HostTensor::new(vec![2, 1, 2], vec![3.0, -1.0, 0.5, 2.0]);
        let mut kc = HostTensor::new(vec![2, 4, 1, 1], (0..8).map(|i| 0.1 * i as f32).collect());
        let mut vc = HostTensor::new(vec![2, 4, 1, 1], (0..8).map(|i| 0.2 * i as f32).collect());
        let mut kc_gang = kc.clone();
        let mut vc_gang = vc.clone();
        let gang =
            reference::attention_decode(&x, &mut kc_gang, &mut vc_gang, 2, &shard, 1, 1, 1)
                .unwrap();
        let slots = reference::attention_decode_slots(
            &x,
            &mut kc,
            &mut vc,
            &[2, 3],
            &[true, false],
            &shard,
            1,
            1,
            1,
        )
        .unwrap();
        assert_eq!(slots.shape, gang.shape);
        // Output row 0 ([2,1,2] → data[0..2]) is bit-identical.
        assert_eq!(slots.data[0].to_bits(), gang.data[0].to_bits());
        assert_eq!(slots.data[1].to_bits(), gang.data[1].to_bits());
        assert_eq!(&slots.data[2..4], &[0.0, 0.0], "inactive row must output zero");
        // Active row 0 wrote position 2; inactive row 1 wrote nothing.
        assert_eq!(kc.data[..4], kc_gang.data[..4]);
        assert_eq!(kc.data[4..], (4..8).map(|i| 0.1 * i as f32).collect::<Vec<_>>()[..]);
        assert_eq!(vc.data[4..], (4..8).map(|i| 0.2 * i as f32).collect::<Vec<_>>()[..]);
        // The blocked kernel agrees with the scalar one bit-for-bit.
        let w = AttnWeights::from_shard(&shard, None).unwrap();
        let mut kc_b = HostTensor::new(vec![2, 4, 1, 1], (0..8).map(|i| 0.1 * i as f32).collect());
        let mut vc_b = HostTensor::new(vec![2, 4, 1, 1], (0..8).map(|i| 0.2 * i as f32).collect());
        let blocked = attention_decode_slots(
            &x,
            &mut kc_b,
            &mut vc_b,
            &[2, 3],
            &[true, false],
            &w,
            1,
            1,
            1,
        )
        .unwrap();
        for (a, b) in slots.data.iter().zip(&blocked.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(kc.data, kc_b.data);
        // Out-of-budget position errors.
        assert!(reference::attention_decode_slots(
            &x,
            &mut kc,
            &mut vc,
            &[9, 0],
            &[true, false],
            &shard,
            1,
            1,
            1
        )
        .is_err());
    }

    #[test]
    fn chunked_prefill_bit_identical() {
        // One prompt pushed through `attention_prefill` in one shot vs
        // the same prompt split into uneven chunks through
        // `attention_prefill_ranged`: partial outputs and the KV the
        // two paths produce must match bit-for-bit (the precondition
        // for the engine's multi-iteration chunked prefill).
        let (h, qh, kvh, hd, s, m) = (4usize, 2usize, 1usize, 2usize, 6usize, 8usize);
        let ln = HostTensor::new(vec![h], vec![1.0, 0.9, 1.1, 1.0]);
        let wq = HostTensor::new(vec![h, qh * hd], fill(h * qh * hd, 0.11));
        let wk = HostTensor::new(vec![h, kvh * hd], fill(h * kvh * hd, 0.07));
        let wv = HostTensor::new(vec![h, kvh * hd], fill(h * kvh * hd, 0.05));
        let wo = HostTensor::new(vec![qh * hd, h], fill(qh * hd * h, 0.09));
        let shard = [ln, wq, wk, wv, wo];
        let x = HostTensor::new(vec![1, s, h], fill(s * h, 0.13));

        let (full_out, full_k, full_v) =
            reference::attention_prefill(&x, &shard, qh, kvh, hd).unwrap();

        let mut kc = HostTensor::zeros(vec![1, m, kvh, hd]);
        let mut vc = HostTensor::zeros(vec![1, m, kvh, hd]);
        let mut chunked = Vec::new();
        let mut start = 0usize;
        for c in [2usize, 3, 1] {
            let xc = HostTensor::new(vec![1, c, h], x.data[start * h..(start + c) * h].to_vec());
            let out = reference::attention_prefill_ranged(
                &xc, &mut kc, &mut vc, 0, start, &shard, qh, kvh, hd,
            )
            .unwrap();
            chunked.extend_from_slice(&out.data);
            start += c;
        }
        assert_eq!(start, s);
        for (i, (a, b)) in full_out.data.iter().zip(&chunked).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "output diverged at {i}");
        }
        let kvrow = kvh * hd;
        for (i, a) in full_k.data.iter().enumerate() {
            assert_eq!(a.to_bits(), kc.data[i].to_bits(), "k cache diverged at {i}");
        }
        for (i, a) in full_v.data.iter().enumerate() {
            assert_eq!(a.to_bits(), vc.data[i].to_bits(), "v cache diverged at {i}");
        }
        assert!(kc.data[s * kvrow..].iter().all(|&v| v == 0.0), "cache tail touched");
        // The blocked ranged kernel reproduces the same chunks.
        let w = AttnWeights::from_shard(&shard, None).unwrap();
        let mut kc_b = HostTensor::zeros(vec![1, m, kvh, hd]);
        let mut vc_b = HostTensor::zeros(vec![1, m, kvh, hd]);
        let mut start = 0usize;
        let mut chunked_b = Vec::new();
        for c in [2usize, 3, 1] {
            let xc = HostTensor::new(vec![1, c, h], x.data[start * h..(start + c) * h].to_vec());
            let out =
                attention_prefill_ranged(&xc, &mut kc_b, &mut vc_b, 0, start, &w, qh, kvh, hd)
                    .unwrap();
            chunked_b.extend_from_slice(&out.data);
            start += c;
        }
        for (i, (a, b)) in chunked.iter().zip(&chunked_b).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "blocked chunk diverged at {i}");
        }
        assert_eq!(kc.data, kc_b.data);
        assert_eq!(vc.data, vc_b.data);
        // A chunk past the budget is rejected.
        let xc = HostTensor::new(vec![1, 3, h], x.data[..3 * h].to_vec());
        assert!(reference::attention_prefill_ranged(
            &xc, &mut kc, &mut vc, 0, m - 1, &shard, qh, kvh, hd
        )
        .is_err());
    }

    #[test]
    fn decode_attends_only_written_positions() {
        // Single head, hd 1: with k ≡ 0 the scores are uniform over
        // 0..=pos, so the context is the mean of the written v's.
        let ln = HostTensor::new(vec![2], vec![1.0, 1.0]);
        let wq = HostTensor::new(vec![2, 1], vec![0.0, 0.0]);
        let wk = HostTensor::new(vec![2, 1], vec![0.0, 0.0]);
        let wv = HostTensor::new(vec![2, 1], vec![1.0, 0.0]);
        let wo = HostTensor::new(vec![1, 2], vec![1.0, 0.0]);
        let shard = [ln, wq, wk, wv, wo];
        let mut kc = HostTensor::zeros(vec![1, 4, 1, 1]);
        let mut vc = HostTensor::zeros(vec![1, 4, 1, 1]);
        vc.data[0] = 5.0; // position 0 already cached
        let x = HostTensor::new(vec![1, 1, 2], vec![3.0, 0.0]);
        let out = reference::attention_decode(&x, &mut kc, &mut vc, 1, &shard, 1, 1, 1).unwrap();
        // v@pos1 = normalize(3,0)·wv ≈ 1.0·rms-normed value; positions
        // 2..3 (zeros) must not contribute.
        let xn0 = 3.0 / ((9.0f32 / 2.0 + 1e-5).sqrt());
        let expect = (5.0 + xn0) / 2.0;
        assert!((out.data[0] - expect).abs() < 1e-4, "{} vs {expect}", out.data[0]);
        assert!(reference::attention_decode(&x, &mut kc, &mut vc, 9, &shard, 1, 1, 1).is_err());
    }

    #[test]
    fn head_packed_matches_reference() {
        let (b, h, v) = (3usize, 5usize, 17usize);
        let x = HostTensor::new(vec![b, h], fill(b * h, 0.12));
        let ln_f = HostTensor::new(vec![h], vec![1.0; h]);
        let unembed = HostTensor::new(vec![h, v], fill(h * v, 0.04));
        let want = reference::head(&x, &ln_f, &unembed);
        let got = head(&x, &HeadWeights::new(&ln_f, &unembed));
        assert_eq!(want.shape, got.shape);
        for (a, b) in want.data.iter().zip(&got.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// Attention shard tensors for the paged twin tests.
    fn attn_shard(h: usize, qh: usize, kvh: usize, hd: usize) -> Vec<HostTensor> {
        vec![
            HostTensor::new(vec![h], fill(h, 0.2)),
            HostTensor::new(vec![h, qh * hd], fill(h * qh * hd, 0.05)),
            HostTensor::new(vec![h, kvh * hd], fill(h * kvh * hd, 0.07)),
            HostTensor::new(vec![h, kvh * hd], fill(h * kvh * hd, 0.03)),
            HostTensor::new(vec![qh * hd, h], fill(qh * hd * h, 0.06)),
        ]
    }

    #[test]
    fn paged_prefill_and_decode_bit_identical_to_padded() {
        // A scrambled block table over a block-granular cache must
        // reproduce the padded kernels bit-for-bit — chunked prefill,
        // then one decode step, in both the reference and packed
        // families.
        let (h, qh, kvh, hd) = (6usize, 4usize, 2usize, 3usize);
        let (m, bs, nb) = (8usize, 2usize, 8usize);
        let shard = attn_shard(h, qh, kvh, hd);
        let w = AttnWeights::from_shard(&shard, None).unwrap();
        let x = HostTensor::new(vec![1, m, h], fill(m * h, 0.09));

        // Padded oracle: two uneven chunks into row 0 of a [1, M+1, ...]
        // cache (one spare position for the decode step).
        let mut kp = HostTensor::zeros(vec![1, m + 1, kvh, hd]);
        let mut vp = HostTensor::zeros(vec![1, m + 1, kvh, hd]);
        let x0 = HostTensor::new(vec![1, 5, h], x.data[..5 * h].to_vec());
        let x1 = HostTensor::new(vec![1, m - 5, h], x.data[5 * h..].to_vec());
        let mut want = reference::attention_prefill_ranged(
            &x0, &mut kp, &mut vp, 0, 0, &shard, qh, kvh, hd,
        )
        .unwrap();
        let want1 = reference::attention_prefill_ranged(
            &x1, &mut kp, &mut vp, 0, 5, &shard, qh, kvh, hd,
        )
        .unwrap();
        want.data.extend_from_slice(&want1.data);

        // Paged: logical blocks scattered across the pool out of order.
        let table = [5usize, 0, 6, 2, 3];
        let mut kb = HostTensor::zeros(vec![nb, bs, kvh, hd]);
        let mut vb = HostTensor::zeros(vec![nb, bs, kvh, hd]);
        let mut got = reference::attention_prefill_ranged_paged(
            &x0, &mut kb, &mut vb, &table[..3], bs, 0, &shard, qh, kvh, hd,
        )
        .unwrap();
        let got1 = reference::attention_prefill_ranged_paged(
            &x1, &mut kb, &mut vb, &table[..4], bs, 5, &shard, qh, kvh, hd,
        )
        .unwrap();
        got.data.extend_from_slice(&got1.data);
        for (i, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "reference prefill diverged at {i}");
        }

        // Packed family over the same tensors and table.
        let mut kq = HostTensor::zeros(vec![nb, bs, kvh, hd]);
        let mut vq = HostTensor::zeros(vec![nb, bs, kvh, hd]);
        let mut fast = attention_prefill_ranged_paged(
            &x0, &mut kq, &mut vq, &table[..3], bs, 0, &w, qh, kvh, hd,
        )
        .unwrap();
        let fast1 = attention_prefill_ranged_paged(
            &x1, &mut kq, &mut vq, &table[..4], bs, 5, &w, qh, kvh, hd,
        )
        .unwrap();
        fast.data.extend_from_slice(&fast1.data);
        for (i, (a, b)) in want.data.iter().zip(&fast.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "packed prefill diverged at {i}");
        }

        // One decode step at position m through the tables.
        let xd = HostTensor::new(vec![1, 1, h], fill(h, 0.21));
        let want_d = reference::attention_decode_slots(
            &xd, &mut kp, &mut vp, &[m], &[true], &shard, qh, kvh, hd,
        )
        .unwrap();
        let got_d = reference::attention_decode_slots_paged(
            &xd, &mut kb, &mut vb, &[m], &[true], &table, 5, bs, &shard, qh, kvh, hd,
        )
        .unwrap();
        let fast_d = attention_decode_slots_paged(
            &xd, &mut kq, &mut vq, &[m], &[true], &table, 5, bs, &w, qh, kvh, hd,
        )
        .unwrap();
        for (i, (a, b)) in want_d.data.iter().zip(&got_d.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "reference decode diverged at {i}");
        }
        for (i, (a, b)) in want_d.data.iter().zip(&fast_d.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "packed decode diverged at {i}");
        }
    }

    #[test]
    fn paged_kernels_reject_unmapped_blocks() {
        let (h, qh, kvh, hd) = (4usize, 2usize, 1usize, 2usize);
        let shard = attn_shard(h, qh, kvh, hd);
        let mut kb = HostTensor::zeros(vec![4, 2, kvh, hd]);
        let mut vb = HostTensor::zeros(vec![4, 2, kvh, hd]);
        let x = HostTensor::new(vec![1, 3, h], fill(3 * h, 0.1));
        // Entry 1 is NO_BLOCK-style unmapped (>= pool size).
        let table = [0usize, usize::MAX];
        assert!(reference::attention_prefill_ranged_paged(
            &x, &mut kb, &mut vb, &table, 2, 0, &shard, qh, kvh, hd,
        )
        .is_err());
        let xd = HostTensor::new(vec![1, 1, h], fill(h, 0.1));
        assert!(reference::attention_decode_slots_paged(
            &xd, &mut kb, &mut vb, &[3], &[true], &table, 2, 2, &shard, qh, kvh, hd,
        )
        .is_err());
    }
}
