//! # Paged KV cache: block pool, per-slot block tables, prefix trie.
//!
//! The streaming engine's padded KV layout gives every slot a fixed
//! `[max_len]` row, so concurrency is capped at `slots × max_len`
//! tokens no matter how short the sequences are. This module is the
//! memory model that removes the cap: device KV is a flat pool of
//! fixed-size **blocks** (`block_size` tokens each), every slot owns a
//! **block table** mapping logical block index → pool block, and a
//! **prefix trie** lets requests with a common prompt prefix share both
//! the blocks and the prefill work that filled them.
//!
//! The pieces here are backend-agnostic bookkeeping — `ModelExecutor`
//! owns the actual `[num_blocks, block_size, kv_heads, head_dim]`
//! device arrays and the paged attention kernels gather through the
//! tables (`kernels::attention_prefill_ranged_paged` /
//! `attention_decode_slots_paged`, bit-identical twins of the padded
//! kernels).
//!
//! ## Invariants
//!
//! - **Single ownership per reference.** A pool block is either on the
//!   free list (refcount 0) or held by ≥1 owners (a slot's block table
//!   entry, or a trie node). [`BlockPool::alloc`] hands out a block
//!   with refcount 1; every additional owner must [`BlockPool::retain`]
//!   it; [`BlockPool::release`] returns it to the free list exactly
//!   when the last owner lets go. No block is ever on the free list
//!   and in a table/trie at once.
//! - **Deterministic allocation.** The free list is LIFO, seeded in
//!   descending order so a fresh pool allocates `0, 1, 2, …`; a freed
//!   block is the next one reused. Identical seeded request schedules
//!   therefore produce identical block placements (asserted by the
//!   `paged_kv` property tests).
//! - **Tables are sparse.** Unmapped entries hold [`NO_BLOCK`];
//!   blocks are allocated lazily when prefill/decode first writes into
//!   their token range, so a slot's physical footprint tracks its
//!   cursor, not `max_len`.
//! - **Trie references are evictable cache.** Registered prefix blocks
//!   are retained by the trie, which makes them cache, not commitment:
//!   when the pool runs dry [`PrefixTrie::evict_leaf`] drops leaves in
//!   a deterministic order (highest arena index first) until a block
//!   frees. Slot-owned references are never evicted.
//!
//! ## Copy-on-write contract
//!
//! A shared block (refcount > 1) is **read-only**. Before writing a
//! token position inside a shared block, the writer must allocate a
//! fresh block, byte-copy the shared block's K/V contents on every
//! device that holds them, repoint its own table entry, and release
//! its reference to the original — the sibling owners' tables still
//! point at the untouched original, so their token streams are
//! unperturbed. K/V at position `p` depends only on tokens `0..=p`
//! (causal attention), and the kernels are deterministic, so a COW
//! copy followed by a recompute of the same prefix writes identical
//! bytes: prefix sharing is exact, not approximate.
//!
//! ## Prefix sharing
//!
//! The trie is keyed on **padded prompt rows** at block granularity:
//! each node holds one `block_size`-token chunk and the pool block
//! caching its K/V. Because the batcher left-pads every prompt to
//! `prefill_len`, two requests share a node chain exactly when their
//! padded rows agree on a block-aligned prefix (including the shared
//! all-zero padding blocks of short prompts). Only *full* blocks are
//! registered — a partial tail block stays private and writable. On a
//! hit, the matching blocks are retained into the joiner's table and
//! prefill resumes at `min(matched, prefill_len − 1)`: the final
//! prompt position is always recomputed because its logits seed the
//! request's first generated token.

use crate::runtime::manifest::TinyModelMeta;

/// Sentinel for an unmapped block-table entry.
pub const NO_BLOCK: usize = usize::MAX;

/// KV-cache layout for the streaming engine's sessions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    /// One padded `[max_len]` KV row per slot (the reference layout).
    Padded,
    /// Block-pool layout: `num_blocks` blocks of `block_size` tokens,
    /// per-slot block tables, copy-on-write prefix sharing.
    /// `num_blocks == 0` means *auto*: size the pool to exactly the
    /// padded layout's token capacity (`batch × max_len` tokens), so
    /// paged-vs-padded comparisons run at an equal memory budget.
    Paged { block_size: usize, num_blocks: usize },
}

impl Default for KvLayout {
    fn default() -> Self {
        KvLayout::Padded
    }
}

impl KvLayout {
    pub fn is_paged(&self) -> bool {
        matches!(self, KvLayout::Paged { .. })
    }

    /// Pool size for a session over `meta` (`None` for the padded
    /// layout; resolves `num_blocks == 0` auto-sizing).
    pub fn resolved_blocks(&self, meta: &TinyModelMeta) -> Option<usize> {
        match *self {
            KvLayout::Padded => None,
            KvLayout::Paged { block_size, num_blocks } => Some(if num_blocks == 0 {
                (meta.batch * meta.max_len).div_ceil(block_size)
            } else {
                num_blocks
            }),
        }
    }
}

/// Result of attaching a prompt row to a slot (prefix-trie consult).
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefixAttach {
    /// Prefill cursor after the attach: positions `0..start` are
    /// served from shared blocks and skipped (0 on a miss).
    pub start: usize,
    /// Shared blocks retained into the slot's table.
    pub shared_blocks: usize,
}

/// Block-level accounting snapshot (exported into trace events and
/// the metrics registry by the streaming engine).
#[derive(Debug, Clone, Copy, Default)]
pub struct PagedKvStats {
    pub block_size: usize,
    pub num_blocks: usize,
    pub blocks_in_use: usize,
    pub blocks_free: usize,
    pub allocs: u64,
    pub frees: u64,
    pub cow_copies: u64,
    pub prefix_hits: u64,
    pub prefix_shared_tokens: u64,
}

/// Refcounted free-list allocator over a fixed pool of KV blocks.
#[derive(Debug, Clone)]
pub struct BlockPool {
    refcounts: Vec<u32>,
    /// LIFO free list, seeded descending so a fresh pool hands out
    /// blocks in ascending id order and a freed block is reused next.
    free: Vec<usize>,
    allocs: u64,
    frees: u64,
    cow_copies: u64,
}

impl BlockPool {
    pub fn new(num_blocks: usize) -> BlockPool {
        BlockPool {
            refcounts: vec![0; num_blocks],
            free: (0..num_blocks).rev().collect(),
            allocs: 0,
            frees: 0,
            cow_copies: 0,
        }
    }

    pub fn num_blocks(&self) -> usize {
        self.refcounts.len()
    }

    /// Take a block off the free list with refcount 1 (`None` when the
    /// pool is dry — the caller evicts prefix-cache leaves and retries).
    pub fn alloc(&mut self) -> Option<usize> {
        let block = self.free.pop()?;
        debug_assert_eq!(self.refcounts[block], 0, "free-listed block {block} had owners");
        self.refcounts[block] = 1;
        self.allocs += 1;
        Some(block)
    }

    /// Add an owner to an allocated block (prefix sharing).
    pub fn retain(&mut self, block: usize) {
        assert!(self.refcounts[block] > 0, "retain of free block {block}");
        self.refcounts[block] += 1;
    }

    /// Drop one owner; returns `true` when that was the last owner and
    /// the block went back on the free list.
    pub fn release(&mut self, block: usize) -> bool {
        assert!(self.refcounts[block] > 0, "release of free block {block}");
        self.refcounts[block] -= 1;
        if self.refcounts[block] == 0 {
            self.free.push(block);
            self.frees += 1;
            true
        } else {
            false
        }
    }

    pub fn refcount(&self, block: usize) -> u32 {
        self.refcounts[block]
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn in_use(&self) -> usize {
        self.num_blocks() - self.free.len()
    }

    /// Count a copy-on-write block copy (accounting only).
    pub fn note_cow(&mut self) {
        self.cow_copies += 1;
    }

    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    pub fn frees(&self) -> u64 {
        self.frees
    }

    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }
}

#[derive(Debug, Clone)]
struct TrieNode {
    /// Exactly one `block_size`-token chunk of a padded prompt row.
    tokens: Vec<i32>,
    /// Pool block caching this chunk's K/V (the trie holds one
    /// refcount on it).
    block: usize,
    parent: Option<usize>,
    children: Vec<usize>,
}

/// Prompt-prefix trie at block granularity. One trie per DP group:
/// a cached block's data lives only on that group's devices.
///
/// Arena-backed (`nodes[i] = None` after eviction) so node identity is
/// a stable index and eviction order is deterministic: the alive leaf
/// with the **highest arena index** — the most recently registered
/// frontier — goes first, which peels chains back from their tips.
#[derive(Debug, Clone, Default)]
pub struct PrefixTrie {
    nodes: Vec<Option<TrieNode>>,
    roots: Vec<usize>,
}

impl PrefixTrie {
    pub fn new() -> PrefixTrie {
        PrefixTrie::default()
    }

    /// Alive (non-evicted) nodes.
    pub fn len(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.iter().all(|n| n.is_none())
    }

    fn find_child(&self, list: &[usize], chunk: &[i32]) -> Option<usize> {
        list.iter().copied().find(|&ci| {
            self.nodes[ci].as_ref().map(|n| n.tokens.as_slice() == chunk).unwrap_or(false)
        })
    }

    /// Longest registered block-aligned prefix of `row`: the cached
    /// block ids for `row[0..k*block_size]`, shallowest first. The
    /// caller must [`BlockPool::retain`] every returned block before
    /// using it.
    pub fn lookup(&self, row: &[i32], block_size: usize) -> Vec<usize> {
        let mut blocks = Vec::new();
        let mut list: &[usize] = &self.roots;
        for chunk in row.chunks_exact(block_size) {
            match self.find_child(list, chunk) {
                Some(ci) => {
                    let node = self.nodes[ci].as_ref().unwrap();
                    blocks.push(node.block);
                    list = &node.children;
                }
                None => break,
            }
        }
        blocks
    }

    /// Register `row`'s full blocks under the given pool block ids
    /// (`blocks[i]` caches chunk `i`). Chunks already present descend
    /// into the existing node — first registration wins, so duplicate
    /// sibling chunks never exist and lookups are unambiguous; two
    /// identical prompts prefilled concurrently simply leave the
    /// second's private blocks to be freed at its release. Returns the
    /// block ids of **newly created** nodes; the caller must
    /// [`BlockPool::retain`] each (the trie now owns a reference).
    pub fn register(&mut self, row: &[i32], blocks: &[usize], block_size: usize) -> Vec<usize> {
        let mut newly = Vec::new();
        let mut parent: Option<usize> = None;
        for (depth, chunk) in row.chunks_exact(block_size).enumerate() {
            if depth >= blocks.len() {
                break;
            }
            let list = match parent {
                Some(p) => self.nodes[p].as_ref().unwrap().children.as_slice(),
                None => self.roots.as_slice(),
            };
            match self.find_child(list, chunk) {
                Some(ci) => parent = Some(ci),
                None => {
                    let idx = self.nodes.len();
                    self.nodes.push(Some(TrieNode {
                        tokens: chunk.to_vec(),
                        block: blocks[depth],
                        parent,
                        children: Vec::new(),
                    }));
                    match parent {
                        Some(p) => self.nodes[p].as_mut().unwrap().children.push(idx),
                        None => self.roots.push(idx),
                    }
                    newly.push(blocks[depth]);
                    parent = Some(idx);
                }
            }
        }
        newly
    }

    /// Evict one leaf deterministically (alive childless node with the
    /// highest arena index) and return its block id — the caller
    /// releases the trie's reference. `None` when the trie is empty.
    pub fn evict_leaf(&mut self) -> Option<usize> {
        let victim = (0..self.nodes.len()).rev().find(|&i| {
            self.nodes[i].as_ref().map(|n| n.children.is_empty()).unwrap_or(false)
        })?;
        let node = self.nodes[victim].take().unwrap();
        match node.parent {
            Some(p) => self.nodes[p].as_mut().unwrap().children.retain(|&c| c != victim),
            None => self.roots.retain(|&r| r != victim),
        }
        Some(node.block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_allocates_ascending_and_reuses_freed_first() {
        let mut pool = BlockPool::new(4);
        assert_eq!(pool.alloc(), Some(0));
        assert_eq!(pool.alloc(), Some(1));
        assert_eq!(pool.alloc(), Some(2));
        assert!(pool.release(1));
        assert_eq!(pool.alloc(), Some(1), "freed block is reused next (LIFO)");
        assert_eq!(pool.alloc(), Some(3));
        assert_eq!(pool.alloc(), None, "pool dry");
        assert_eq!(pool.in_use(), 4);
        assert_eq!(pool.allocs(), 5);
        assert_eq!(pool.frees(), 1);
    }

    #[test]
    fn refcount_frees_exactly_on_last_release() {
        let mut pool = BlockPool::new(2);
        let b = pool.alloc().unwrap();
        pool.retain(b);
        pool.retain(b);
        assert_eq!(pool.refcount(b), 3);
        assert!(!pool.release(b));
        assert!(!pool.release(b));
        assert_eq!(pool.free_blocks(), 1, "shared block must not free early");
        assert!(pool.release(b), "last owner frees");
        assert_eq!(pool.free_blocks(), 2);
    }

    #[test]
    #[should_panic(expected = "release of free block")]
    fn over_release_panics() {
        let mut pool = BlockPool::new(1);
        let b = pool.alloc().unwrap();
        pool.release(b);
        pool.release(b);
    }

    #[test]
    fn trie_shares_block_aligned_prefixes_only() {
        let mut trie = PrefixTrie::new();
        let row_a: Vec<i32> = vec![1, 2, 3, 4, 5, 6];
        // bs=2 → chunks [1,2][3,4][5,6] cached as blocks 7, 8, 9.
        let newly = trie.register(&row_a, &[7, 8, 9], 2);
        assert_eq!(newly, vec![7, 8, 9]);
        // Same prefix, divergent tail: matches two chunks.
        let row_b: Vec<i32> = vec![1, 2, 3, 4, 9, 9];
        assert_eq!(trie.lookup(&row_b, 2), vec![7, 8]);
        // Partial tail chunks never match (full blocks only).
        assert_eq!(trie.lookup(&[1, 2, 3], 2), vec![7]);
        // Divergent first chunk: no sharing.
        assert!(trie.lookup(&[9, 9, 9, 9], 2).is_empty());
    }

    #[test]
    fn register_is_first_wins_and_returns_only_new_nodes() {
        let mut trie = PrefixTrie::new();
        assert_eq!(trie.register(&[1, 2, 3, 4], &[0, 1], 2), vec![0, 1]);
        // A concurrent identical prompt re-registers with its own
        // blocks: the existing chain wins, nothing new is referenced.
        assert!(trie.register(&[1, 2, 3, 4], &[5, 6], 2).is_empty());
        // Shared head, new tail: only the tail node is created.
        assert_eq!(trie.register(&[1, 2, 7, 7], &[5, 6], 2), vec![6]);
        assert_eq!(trie.lookup(&[1, 2, 3, 4], 2), vec![0, 1]);
        assert_eq!(trie.lookup(&[1, 2, 7, 7], 2), vec![0, 6]);
        assert_eq!(trie.len(), 3);
    }

    #[test]
    fn eviction_peels_tips_first_deterministically() {
        let mut trie = PrefixTrie::new();
        trie.register(&[1, 2, 3, 4], &[0, 1], 2);
        trie.register(&[1, 2, 7, 7], &[9, 2], 2); // shares the head node
        // Highest-index alive leaf first: the [7,7] node (block 2),
        // then [3,4] (block 1), then the now-childless head (block 0).
        assert_eq!(trie.evict_leaf(), Some(2));
        assert_eq!(trie.evict_leaf(), Some(1));
        assert_eq!(trie.lookup(&[1, 2, 3, 4], 2), vec![0], "head survives its leaves");
        assert_eq!(trie.evict_leaf(), Some(0));
        assert_eq!(trie.evict_leaf(), None);
        assert!(trie.is_empty());
    }

    #[test]
    fn layout_resolves_auto_pool_to_padded_capacity() {
        let m = TinyModelMeta::host_demo(); // batch 4 × max_len 48
        let auto = KvLayout::Paged { block_size: 8, num_blocks: 0 };
        assert_eq!(auto.resolved_blocks(&m), Some(24));
        let fixed = KvLayout::Paged { block_size: 8, num_blocks: 10 };
        assert_eq!(fixed.resolved_blocks(&m), Some(10));
        assert_eq!(KvLayout::Padded.resolved_blocks(&m), None);
        assert!(auto.is_paged() && !KvLayout::Padded.is_paged());
    }
}
