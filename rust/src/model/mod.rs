//! Real tiny-MoE execution: weights, sharding, and the per-layer
//! composition of AOT artifacts under a hybrid parallel plan.
//!
//! The Rust side plays the role of the multi-GPU runtime: it holds one
//! logical device per shard, calls each device's artifact, and performs
//! the combines (sum for TP partials and EP contributions — the
//! "collectives" of the demo node). Simulated communication time for
//! the modeled platform can be charged on top by callers that want
//! platform-shaped latencies; the numerics are exact either way.

pub mod exec;
pub mod weights;

pub use exec::{ModelExecutor, StageStrategy};
pub use weights::WeightStore;
