//! Real tiny-MoE execution: weights, grid sharding, host kernels, and
//! the per-layer composition of device shards under a hybrid plan.
//!
//! The stack is layered exactly along the paper's decomposition:
//!
//! - [`grid`] — `ShardPlan` (logical `(AttnStrategy, ExpertStrategy)`)
//!   lowers to a `DeviceGrid` of per-device roles + collective groups;
//! - [`weights`] — one generic `WeightStore::shard(spec)` slices the
//!   shard any role needs (EP blocks × TP slices for experts, TP head
//!   shards for attention, DP replicated);
//! - [`kernels`] — the module math on `HostTensor` (mirrors
//!   `python/compile/kernels/ref.py`), so every grid is executable —
//!   and testable — without PJRT;
//! - [`collectives`] — order-deterministic combines (partial-sum,
//!   contribution-sum, batch-split) shared by both backends;
//! - [`exec`] — the persistent executor: per-device shard + KV state
//!   held across batches, scoped-thread parallel host execution with a
//!   sequential bit-equivalence reference, and measured resharding on
//!   plan switches;
//! - [`fault`] — deterministic device-fault injection: seeded
//!   `(device, iteration)` fault schedules the executor consults once
//!   per compute op, so crash/stall/transient failures (and the
//!   serving engine's recovery from them) replay bit-identically;
//! - [`paged_kv`] — the block-pool KV memory model for streaming
//!   sessions: refcounted free-list allocator, per-slot block tables,
//!   and the copy-on-write prompt-prefix trie (`--kv paged`).

pub mod collectives;
pub mod exec;
pub mod fault;
pub mod grid;
pub mod kernels;
pub mod paged_kv;
pub mod weights;

pub use exec::{EngineMode, ExecStats, KernelMode, ModelExecutor};
pub use fault::{DeviceFault, FaultEvent, FaultKind, FaultPlan};
pub use grid::{CollectiveGroup, DeviceGrid, DeviceRole, GroupKind, ShardPlan};
pub use paged_kv::{BlockPool, KvLayout, PagedKvStats, PrefixAttach, PrefixTrie, NO_BLOCK};
pub use weights::{ShardSpec, WeightStore};
