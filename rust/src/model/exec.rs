//! The model executor: composes per-device AOT artifacts into full
//! prefill/decode steps under a hybrid parallel plan.
//!
//! One logical device per shard; combines (TP partial sums, EP
//! contribution sums) are performed on host between artifact calls —
//! the demo node's "collectives". The attention strategy is pinned
//! across stages (KV cache layout); the expert strategy may differ
//! between prefill and decode, exercising the paper's dynamic
//! parallelism transition on the real compute path.

use crate::runtime::literal::{self, HostTensor};
use crate::runtime::PjrtRuntime;
use crate::strategy::ExpertStrategy;
use crate::Result;
use anyhow::anyhow;
use std::collections::HashMap;

/// Per-stage execution strategy on the demo node.
///
/// The real-compute path supports TP for attention (DP needs per-group
/// batches, which the artifact set fixes at B — covered by the
/// simulation stack instead; see DESIGN.md) and TP *or* EP for experts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageStrategy {
    pub attn_tp: usize,
    pub expert: ExpertStrategy,
}

impl StageStrategy {
    pub fn tp(n: usize) -> StageStrategy {
        StageStrategy { attn_tp: n, expert: ExpertStrategy::new(n, 1) }
    }

    pub fn expert_label(&self) -> String {
        self.expert.label()
    }
}

/// KV cache for one layer on one device: padded [B, M, KVH_local, D].
struct LayerCache {
    k: HostTensor,
    v: HostTensor,
}

/// The executor. Weight literals are sliced and cached per
/// (strategy, layer, device) on first use; the per-token hot path only
/// builds activation literals.
pub struct ModelExecutor<'rt> {
    pub rt: &'rt PjrtRuntime,
    pub weights: super::WeightStore,
    /// (kind, layer, device) → device-resident weight buffers. kind
    /// encodes the artifact family + shard degree, e.g. "attn_tp2",
    /// "expert_ep4". Uploaded once (§Perf: keeps ~50 MB of parameters
    /// off the per-step H2D path). The source literal is retained with
    /// its buffer: `BufferFromHostLiteral` is asynchronous, so the
    /// literal must outlive the transfer.
    weight_cache: HashMap<(String, usize, usize), Vec<(xla::Literal, xla::PjRtBuffer)>>,
    /// Embedding/head buffers (uploaded once; literal retained).
    embed_buf: Option<(xla::Literal, xla::PjRtBuffer)>,
    head_bufs: Option<[(xla::Literal, xla::PjRtBuffer); 2]>,
    /// Per-layer per-device caches (attention shards).
    caches: Vec<Vec<LayerCache>>,
    /// Current sequence position (tokens stored so far).
    pub pos: usize,
    attn_tp: Option<usize>,
}

impl<'rt> ModelExecutor<'rt> {
    pub fn new(rt: &'rt PjrtRuntime) -> Result<ModelExecutor<'rt>> {
        let blob = rt.read_weights()?;
        let weights = super::WeightStore::from_blob(&rt.manifest, &blob)?;
        Ok(ModelExecutor {
            rt,
            weights,
            weight_cache: HashMap::new(),
            embed_buf: None,
            head_bufs: None,
            caches: Vec::new(),
            pos: 0,
            attn_tp: None,
        })
    }

    fn meta(&self) -> &crate::runtime::TinyModelMeta {
        &self.rt.manifest.model
    }

    fn weight_pairs(
        &mut self,
        kind: &str,
        layer: usize,
        device: usize,
    ) -> Result<&Vec<(xla::Literal, xla::PjRtBuffer)>> {
        let key = (kind.to_string(), layer, device);
        if !self.weight_cache.contains_key(&key) {
            let tensors = if let Some(t) = kind.strip_prefix("attn_tp") {
                self.weights.shard_attn(layer, t.parse()?, device)?
            } else if let Some(t) = kind.strip_prefix("expert_tp") {
                self.weights.shard_expert_tp(layer, t.parse()?, device)?
            } else if let Some(e) = kind.strip_prefix("expert_ep") {
                self.weights.shard_expert_ep(layer, e.parse()?, device)?
            } else {
                anyhow::bail!("unknown weight kind {kind}");
            };
            let bufs = tensors
                .iter()
                .map(|t| {
                    let lit = t.to_literal()?;
                    let buf = self.rt.to_device(&lit)?;
                    Ok((lit, buf))
                })
                .collect::<Result<Vec<_>>>()?;
            self.weight_cache.insert(key.clone(), bufs);
        }
        Ok(&self.weight_cache[&key])
    }

    fn weight_buffers(
        &mut self,
        kind: &str,
        layer: usize,
        device: usize,
    ) -> Result<()> {
        self.weight_pairs(kind, layer, device).map(|_| ())
    }

    fn embed_buffer(&mut self) -> Result<()> {
        if self.embed_buf.is_none() {
            let lit = self.weights.get("embed")?.to_literal()?;
            let buf = self.rt.to_device(&lit)?;
            self.embed_buf = Some((lit, buf));
        }
        Ok(())
    }

    /// Run prefill for a [B, S] token batch; returns last-position
    /// logits [B, V]. Initializes the KV caches under `strategy`.
    pub fn prefill(&mut self, tokens: &[i32], strategy: &StageStrategy) -> Result<HostTensor> {
        let m = self.meta().clone();
        let (b, s) = (m.batch, m.prefill_len);
        if tokens.len() != b * s {
            anyhow::bail!("prefill expects {}x{} tokens, got {}", b, s, tokens.len());
        }
        self.validate(strategy)?;
        self.attn_tp = Some(strategy.attn_tp);

        // Embed (embedding table resident on device).
        let tok_lit = literal::tokens_literal(tokens, &[b, s])?;
        let tok_buf = self.rt.to_device(&tok_lit)?;
        self.embed_buffer()?;
        let outs = {
            let embed = &self.embed_buf.as_ref().unwrap().1;
            self.rt.execute_buffers("embed_prefill", &[&tok_buf, embed])?
        };
        let mut x = HostTensor::from_literal(&outs[0], vec![b, s, m.hidden])?;

        // Layers.
        self.caches.clear();
        let t = strategy.attn_tp;
        let kv_l = (m.kv_heads / t).max(1);
        for l in 0..m.layers {
            // Attention module: sum TP partials, collect KV shards.
            let x_lit = x.to_literal()?;
            let x_buf = self.rt.to_device(&x_lit)?;
            let mut a_sum: Option<HostTensor> = None;
            let mut layer_caches = Vec::with_capacity(t);
            for d in 0..t {
                let kind = format!("attn_tp{t}");
                self.weight_buffers(&kind, l, d)?;
                let w = &self.weight_cache[&(kind, l, d)];
                let mut inputs: Vec<&xla::PjRtBuffer> = vec![&x_buf];
                inputs.extend(w.iter().map(|(_, b)| b));
                let outs = self.rt.execute_buffers(&format!("attn_prefill_tp{t}"), &inputs)?;
                let partial = HostTensor::from_literal(&outs[0], vec![b, s, m.hidden])?;
                match &mut a_sum {
                    None => a_sum = Some(partial),
                    Some(acc) => acc.add_assign(&partial),
                }
                // Pad prefill KV [B,S,kv_l,D] into [B,M,kv_l,D].
                let k = HostTensor::from_literal(&outs[1], vec![b, s, kv_l, m.head_dim])?;
                let v = HostTensor::from_literal(&outs[2], vec![b, s, kv_l, m.head_dim])?;
                layer_caches.push(LayerCache {
                    k: pad_cache(&k, m.max_len),
                    v: pad_cache(&v, m.max_len),
                });
            }
            self.caches.push(layer_caches);
            x.add_assign(&a_sum.expect("t >= 1"));

            // Expert module: sum shard outputs.
            let e_out = self.expert_module(&x, l, strategy, "prefill")?;
            x.add_assign(&e_out);
        }

        self.pos = s;
        self.head(&x)
    }

    /// One decode step: `last_tokens` [B] (previous outputs), returns
    /// logits [B, V]. `strategy.attn_tp` must match prefill's.
    pub fn decode_step(
        &mut self,
        last_tokens: &[i32],
        strategy: &StageStrategy,
    ) -> Result<HostTensor> {
        let m = self.meta().clone();
        let b = m.batch;
        if last_tokens.len() != b {
            anyhow::bail!("decode expects {} tokens, got {}", b, last_tokens.len());
        }
        if self.pos + 1 > m.max_len {
            anyhow::bail!("KV cache exhausted at pos {}", self.pos);
        }
        self.validate(strategy)?;
        let t = self.attn_tp.ok_or_else(|| anyhow!("decode before prefill"))?;
        if strategy.attn_tp != t {
            anyhow::bail!("attention strategy is pinned by the KV cache (tp{t})");
        }

        // Embed one token per sequence.
        let tok_lit = literal::tokens_literal(last_tokens, &[b, 1])?;
        let tok_buf = self.rt.to_device(&tok_lit)?;
        self.embed_buffer()?;
        let outs = {
            let embed = &self.embed_buf.as_ref().unwrap().1;
            self.rt.execute_buffers("embed_decode", &[&tok_buf, embed])?
        };
        let mut x = HostTensor::from_literal(&outs[0], vec![b, 1, m.hidden])?;

        let kv_l = (m.kv_heads / t).max(1);
        let pos_lit = literal::scalar_i32(self.pos as i32);
        let pos_buf = self.rt.to_device(&pos_lit)?;
        for l in 0..m.layers {
            let x_lit = x.to_literal()?;
            let x_buf = self.rt.to_device(&x_lit)?;
            let mut a_sum: Option<HostTensor> = None;
            for d in 0..t {
                let kind = format!("attn_tp{t}");
                // Assemble inputs: x, k_cache, v_cache, pos, ln, wq..wo.
                let k_lit = self.caches[l][d].k.to_literal()?;
                let v_lit = self.caches[l][d].v.to_literal()?;
                let k_buf = self.rt.to_device(&k_lit)?;
                let v_buf = self.rt.to_device(&v_lit)?;
                self.weight_buffers(&kind, l, d)?;
                let w = &self.weight_cache[&(kind, l, d)];
                let mut inputs: Vec<&xla::PjRtBuffer> = vec![&x_buf, &k_buf, &v_buf, &pos_buf];
                inputs.extend(w.iter().map(|(_, b)| b));
                let outs = self.rt.execute_buffers(&format!("attn_decode_tp{t}"), &inputs)?;
                let partial = HostTensor::from_literal(&outs[0], vec![b, 1, m.hidden])?;
                match &mut a_sum {
                    None => a_sum = Some(partial),
                    Some(acc) => acc.add_assign(&partial),
                }
                self.caches[l][d].k =
                    HostTensor::from_literal(&outs[1], vec![b, m.max_len, kv_l, m.head_dim])?;
                self.caches[l][d].v =
                    HostTensor::from_literal(&outs[2], vec![b, m.max_len, kv_l, m.head_dim])?;
            }
            x.add_assign(&a_sum.expect("t >= 1"));
            let e_out = self.expert_module(&x, l, strategy, "decode")?;
            x.add_assign(&e_out);
        }

        self.pos += 1;
        self.head(&x)
    }

    /// Expert module under the stage strategy: returns the combined
    /// output with the same shape as `x` ([B, S|1, H]).
    fn expert_module(
        &mut self,
        x: &HostTensor,
        layer: usize,
        strategy: &StageStrategy,
        stage: &str,
    ) -> Result<HostTensor> {
        let m = self.meta().clone();
        let tokens: usize = x.shape[..2].iter().product();
        let x2 = HostTensor::new(vec![tokens, m.hidden], x.data.clone());
        let x2_lit = x2.to_literal()?;
        let x_buf = self.rt.to_device(&x2_lit)?;
        let (kind, artifact, devices) = if strategy.expert.ep > 1 {
            let e = strategy.expert.ep;
            (format!("expert_ep{e}"), format!("expert_{stage}_ep{e}"), e)
        } else {
            let t = strategy.expert.tp;
            (format!("expert_tp{t}"), format!("expert_{stage}_tp{t}"), t)
        };
        let mut sum: Option<HostTensor> = None;
        for d in 0..devices {
            self.weight_buffers(&kind, layer, d)?;
            let w = &self.weight_cache[&(kind.clone(), layer, d)];
            let mut inputs: Vec<&xla::PjRtBuffer> = vec![&x_buf];
            inputs.extend(w.iter().map(|(_, b)| b));
            let outs = self.rt.execute_buffers(&artifact, &inputs)?;
            let partial = HostTensor::from_literal(&outs[0], vec![tokens, m.hidden])?;
            match &mut sum {
                None => sum = Some(partial),
                Some(acc) => acc.add_assign(&partial),
            }
        }
        let out = sum.expect("devices >= 1");
        Ok(HostTensor::new(x.shape.clone(), out.data))
    }

    /// Final norm + unembed on the last position.
    fn head(&mut self, x: &HostTensor) -> Result<HostTensor> {
        let m = self.meta();
        let (b, h, v) = (m.batch, m.hidden, m.vocab);
        let s = x.shape[1];
        // Slice last position [B, H].
        let mut last = Vec::with_capacity(b * h);
        for bi in 0..b {
            let base = (bi * s + (s - 1)) * h;
            last.extend_from_slice(&x.data[base..base + h]);
        }
        let last = HostTensor::new(vec![b, h], last);
        if self.head_bufs.is_none() {
            let ln_lit = self.weights.get("ln_f")?.to_literal()?;
            let ln = self.rt.to_device(&ln_lit)?;
            let un_lit = self.weights.get("unembed")?.to_literal()?;
            let un = self.rt.to_device(&un_lit)?;
            self.head_bufs = Some([(ln_lit, ln), (un_lit, un)]);
        }
        let last_lit = last.to_literal()?;
        let last_buf = self.rt.to_device(&last_lit)?;
        let [(_, ln), (_, un)] = self.head_bufs.as_ref().unwrap();
        let outs = self.rt.execute_buffers("head", &[&last_buf, ln, un])?;
        HostTensor::from_literal(&outs[0], vec![b, v])
    }

    fn validate(&self, strategy: &StageStrategy) -> Result<()> {
        let ok_attn = matches!(strategy.attn_tp, 1 | 2 | 4);
        let e = &strategy.expert;
        let ok_expert = (e.ep == 1 && matches!(e.tp, 1 | 2 | 4)) || (e.tp == 1 && matches!(e.ep, 2 | 4));
        if !ok_attn || !ok_expert {
            anyhow::bail!(
                "unsupported demo strategy attn_tp={} expert={} (artifact set covers attn tp 1/2/4, expert tp 1/2/4 or ep 2/4)",
                strategy.attn_tp,
                e.label()
            );
        }
        Ok(())
    }
}

/// Pad a [B, S, KVH, D] prefill cache to [B, M, KVH, D] with zeros.
fn pad_cache(c: &HostTensor, max_len: usize) -> HostTensor {
    let (b, s, kvh, d) = (c.shape[0], c.shape[1], c.shape[2], c.shape[3]);
    let mut out = HostTensor::zeros(vec![b, max_len, kvh, d]);
    let row = kvh * d;
    for bi in 0..b {
        let src = bi * s * row;
        let dst = bi * max_len * row;
        out.data[dst..dst + s * row].copy_from_slice(&c.data[src..src + s * row]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_cache_places_rows() {
        let c = HostTensor::new(vec![1, 2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let p = pad_cache(&c, 4);
        assert_eq!(p.shape, vec![1, 4, 1, 2]);
        assert_eq!(p.data, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn stage_strategy_labels() {
        let s = StageStrategy::tp(4);
        assert_eq!(s.expert_label(), "TP4");
        let e = StageStrategy { attn_tp: 2, expert: ExpertStrategy::new(1, 4) };
        assert_eq!(e.expert_label(), "EP4");
    }
}
