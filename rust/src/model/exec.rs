//! The grid execution engine: persistent per-device shard state driving
//! full prefill/decode steps under a hybrid `ShardPlan`.
//!
//! A [`ShardPlan`] lowers to a [`DeviceGrid`] of per-device roles
//! (`dp_rank`/`tp_rank` for attention, `ep_rank`/`etp_rank` for
//! experts). Each device owns its weight shards and its device-resident
//! KV shard; module outputs are merged by the factored
//! [`crate::model::collectives`] (partial-sum per TP group,
//! contribution-sum across EP blocks, batch-split concat across DP
//! groups), with a fixed member order so parallel and sequential
//! execution are bit-identical.
//!
//! Two backends share the engine:
//!
//! - **Host** — the module math runs as Rust [`crate::model::kernels`]
//!   on `HostTensor`s. Per-device compute runs under
//!   `std::thread::scope` ([`EngineMode::Parallel`]) or a plain loop
//!   ([`EngineMode::Sequential`], the retained reference path); the
//!   combines always run on the coordinator in group order. This
//!   backend needs no artifacts and is what the runtime-free grid tests
//!   and `hap serve --backend host` exercise.
//! - **Pjrt** — per-device compute calls the AOT artifacts through the
//!   PJRT client (FFI handles are not `Send`, so devices execute
//!   sequentially on the demo node). The fixed artifact shapes are
//!   bridged exactly: DP groups run the full-batch attention artifact
//!   on a zero-padded sub-batch and keep their rows; hybrid EP×TP
//!   experts run the EP-family artifact with the intermediate slice
//!   zero-padded to full width (exact, because the padded gate/up
//!   columns contribute `act·0 = 0`).
//!
//! **Micro-chunk pipelining** (`set_pipeline_chunks`, host backend): at
//! K ≥ 2 every expert call splits its token batch into K contiguous
//! row chunks through the ranged kernel entry points, and under
//! [`EngineMode::Parallel`] chunk `c`'s expert FFN compute overlaps
//! chunk `c-1`'s combine collectives (the fold runs on the coordinator
//! between spawning and joining the chunk's device threads).
//! [`EngineMode::Sequential`] runs the same chunk loop without the
//! overlap, so it stays the bit-equivalence oracle at every K: chunk
//! outputs are explicit row ranges stitched in chunk order, per-row
//! accumulation order never changes, and the fault clock still ticks
//! once per op (chunking is internal to an op). `prefill_slots` is the
//! op-level half: same-range joiner chunks batch into one ranged
//! prefill call, so peer decode steps and joiner prefill share
//! iterations instead of queueing behind each other.
//!
//! **State is persistent across batches**: weight shards stay resident
//! (uploaded/materialized once per layout) and only a *plan switch*
//! evicts the outgoing layout and materializes the incoming one — that
//! resharding work is measured in [`ExecStats`], which is what makes
//! `Metrics.transitions` and the adapt controller's switch-cost
//! economics describe real weight movement. Per-batch sequence state
//! (positions, KV caches) resets in `prefill`.
//!
//! **Fault injection**: an installed [`crate::model::fault::FaultPlan`]
//! is ticked once per compute op; a faulted device raises a structured
//! `fault[kind]` error from `map_devices` before its closure runs.
//! Ops fail *before* any cursor advances (`slot_pos` moves only after
//! a fully successful op), so a retried op replays bit-identically —
//! the property the serving engine's recovery state machine builds on.

use crate::model::collectives;
use crate::model::fault::{fault_message, FaultPlan};
use crate::model::grid::{DeviceGrid, ShardPlan};
use crate::model::kernels::{self, AttnWeights, ExpertWeights, HeadWeights, ShardWeights};
use crate::model::paged_kv::{BlockPool, KvLayout, PagedKvStats, PrefixAttach, PrefixTrie, NO_BLOCK};
use crate::model::weights::ShardSpec;
use crate::obs::ModuleTimes;
use crate::quant::QuantKind;
use crate::runtime::literal::{self, HostTensor};
use crate::runtime::{PjrtRuntime, TinyModelMeta};
use crate::strategy::AttnStrategy;
use crate::Result;
use anyhow::anyhow;
use std::collections::{HashMap, HashSet};
use std::time::Instant;

/// How the host backend schedules per-device module compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// One scoped thread per device (production path).
    Parallel,
    /// Plain loop over devices — the bit-equivalence reference.
    Sequential,
}

/// Which host kernel family the executor dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Packed-tile blocked kernels (the serving hot path; default).
    /// Bit-identical to `Reference` by the accumulation-order invariant.
    Blocked,
    /// The retained scalar kernels in [`kernels::reference`] — the
    /// equivalence baseline and the bench's "scalar" side.
    Reference,
}

#[derive(Clone, Copy)]
enum Backend<'rt> {
    Pjrt(&'rt PjrtRuntime),
    Host,
}

/// KV cache shard for one layer on one device. Host backend: the
/// device's batch slice `[B_g, M, KVH_l, D]`; PJRT backend: padded to
/// the full artifact batch `[B, M, KVH_l, D]`.
struct LayerCache {
    k: HostTensor,
    v: HostTensor,
}

/// A device-resident weight shard in whichever form the backend and
/// [`KernelMode`] need: packed (and optionally quantized) tiles for the
/// blocked host path, raw slice tensors for the reference host path, or
/// a marker for PJRT (where the real bytes live in `DeviceState::bufs`).
/// The dispatch methods below are the single seam between the execution
/// engine and the two host kernel families.
enum ResidentShard {
    Packed(ShardWeights),
    Raw(Vec<HostTensor>),
    Uploaded,
}

impl ResidentShard {
    fn attn_packed(&self) -> Result<&AttnWeights> {
        match self {
            ResidentShard::Packed(ShardWeights::Attn(w)) => Ok(w),
            _ => Err(anyhow!("resident shard is not a packed attention shard")),
        }
    }

    fn raw(&self) -> Result<&[HostTensor]> {
        match self {
            ResidentShard::Raw(t) => Ok(t),
            _ => Err(anyhow!("resident shard holds no host tensors")),
        }
    }

    fn attn_prefill(
        &self,
        x: &HostTensor,
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        match self {
            ResidentShard::Packed(_) => {
                kernels::attention_prefill(x, self.attn_packed()?, q_heads, kv_heads, hd)
            }
            _ => kernels::reference::attention_prefill(x, self.raw()?, q_heads, kv_heads, hd),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn attn_prefill_ranged(
        &self,
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        row: usize,
        start: usize,
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        match self {
            ResidentShard::Packed(_) => kernels::attention_prefill_ranged(
                x,
                k_cache,
                v_cache,
                row,
                start,
                self.attn_packed()?,
                q_heads,
                kv_heads,
                hd,
            ),
            _ => kernels::reference::attention_prefill_ranged(
                x,
                k_cache,
                v_cache,
                row,
                start,
                self.raw()?,
                q_heads,
                kv_heads,
                hd,
            ),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn attn_decode(
        &self,
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        pos: usize,
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        match self {
            ResidentShard::Packed(_) => kernels::attention_decode(
                x,
                k_cache,
                v_cache,
                pos,
                self.attn_packed()?,
                q_heads,
                kv_heads,
                hd,
            ),
            _ => kernels::reference::attention_decode(
                x,
                k_cache,
                v_cache,
                pos,
                self.raw()?,
                q_heads,
                kv_heads,
                hd,
            ),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn attn_decode_slots(
        &self,
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        pos: &[usize],
        active: &[bool],
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        match self {
            ResidentShard::Packed(_) => kernels::attention_decode_slots(
                x,
                k_cache,
                v_cache,
                pos,
                active,
                self.attn_packed()?,
                q_heads,
                kv_heads,
                hd,
            ),
            _ => kernels::reference::attention_decode_slots(
                x,
                k_cache,
                v_cache,
                pos,
                active,
                self.raw()?,
                q_heads,
                kv_heads,
                hd,
            ),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn attn_prefill_ranged_paged(
        &self,
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        table: &[usize],
        block_size: usize,
        start: usize,
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        match self {
            ResidentShard::Packed(_) => kernels::attention_prefill_ranged_paged(
                x,
                k_cache,
                v_cache,
                table,
                block_size,
                start,
                self.attn_packed()?,
                q_heads,
                kv_heads,
                hd,
            ),
            _ => kernels::reference::attention_prefill_ranged_paged(
                x,
                k_cache,
                v_cache,
                table,
                block_size,
                start,
                self.raw()?,
                q_heads,
                kv_heads,
                hd,
            ),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn attn_decode_slots_paged(
        &self,
        x: &HostTensor,
        k_cache: &mut HostTensor,
        v_cache: &mut HostTensor,
        pos: &[usize],
        active: &[bool],
        tables: &[usize],
        tstride: usize,
        block_size: usize,
        q_heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> Result<HostTensor> {
        match self {
            ResidentShard::Packed(_) => kernels::attention_decode_slots_paged(
                x,
                k_cache,
                v_cache,
                pos,
                active,
                tables,
                tstride,
                block_size,
                self.attn_packed()?,
                q_heads,
                kv_heads,
                hd,
            ),
            _ => kernels::reference::attention_decode_slots_paged(
                x,
                k_cache,
                v_cache,
                pos,
                active,
                tables,
                tstride,
                block_size,
                self.raw()?,
                q_heads,
                kv_heads,
                hd,
            ),
        }
    }

    fn expert_module(&self, x: &HostTensor, ep: usize, top_k: usize) -> Result<HostTensor> {
        match self {
            ResidentShard::Packed(ShardWeights::Expert(w)) => kernels::expert_module(x, w, top_k),
            ResidentShard::Packed(_) => Err(anyhow!("resident shard is not an expert shard")),
            _ => kernels::reference::expert_module(x, self.raw()?, ep, top_k),
        }
    }

    /// Expert module over one contiguous row range of the token batch
    /// (the micro-chunk pipeline's per-chunk compute).
    fn expert_module_ranged(
        &self,
        x: &HostTensor,
        ep: usize,
        top_k: usize,
        start: usize,
        len: usize,
    ) -> Result<HostTensor> {
        match self {
            ResidentShard::Packed(ShardWeights::Expert(w)) => {
                kernels::expert_module_ranged(x, w, top_k, start, len)
            }
            ResidentShard::Packed(_) => Err(anyhow!("resident shard is not an expert shard")),
            _ => kernels::reference::expert_module_ranged(x, self.raw()?, ep, top_k, start, len),
        }
    }

    /// Host-resident weight bytes (PJRT uploads hold no host copy).
    fn weight_bytes(&self) -> usize {
        match self {
            ResidentShard::Packed(w) => w.weight_bytes(),
            ResidentShard::Raw(t) => t.iter().map(|t| t.data.len() * 4).sum(),
            ResidentShard::Uploaded => 0,
        }
    }
}

/// One logical device: its resident weight shards (and, on the PJRT
/// backend, the uploaded buffers) plus its KV shards.
struct DeviceState {
    device: usize,
    /// (family, layer) → resident shard, e.g. family `attn_tp2` or
    /// `expert_ep2tp2`.
    shards: HashMap<(String, usize), ResidentShard>,
    /// PJRT-uploaded buffers parallel to `shards`. The source literal
    /// is retained with its buffer: `BufferFromHostLiteral` is
    /// asynchronous, so the literal must outlive the transfer.
    bufs: HashMap<(String, usize), Vec<(xla::Literal, xla::PjRtBuffer)>>,
    kv: Vec<Option<LayerCache>>,
    /// Injected fault verdict for the current op (the structured
    /// `fault[kind]` message), stamped by `ModelExecutor::fault_tick`
    /// and raised by `map_devices` before the device closure runs.
    fault: Option<String>,
}

impl DeviceState {
    fn new(device: usize) -> DeviceState {
        DeviceState {
            device,
            shards: HashMap::new(),
            bufs: HashMap::new(),
            kv: Vec::new(),
            fault: None,
        }
    }
}

/// Cumulative shard/upload accounting — the measurable resharding work.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExecStats {
    /// Shards sliced + made device-resident ("weight uploads"): one per
    /// (device, family, layer) materialization event.
    pub materializations: usize,
    /// Resident shard entries dropped by plan switches.
    pub evictions: usize,
    /// f32 elements of logical shard data materialized.
    pub uploaded_floats: usize,
    /// `begin_batch` calls that changed the resident layout — evicted
    /// shards, or materialized new ones while others were already
    /// resident. The first batch's cold materialization is not a
    /// reshard.
    pub reshards: usize,
    /// Wall-clock seconds spent slicing/uploading shards.
    pub reshard_seconds: f64,
}

/// Bookkeeping for one paged streaming session. The device-side KV
/// arrays are the `LayerCache`s reinterpreted as block pools
/// `[num_blocks, block_size, KVH_l, D]`; block ids are global (the
/// same id addresses the same offset on every device), but a block's
/// *data* lives only on the DP group of the slot that wrote it — so
/// prefix sharing runs per group ([`PrefixTrie`] per DP rank) while
/// the [`BlockPool`] itself is global.
struct PagedSession {
    block_size: usize,
    num_blocks: usize,
    /// Block-table entries per slot (`ceil(max_len / block_size)`).
    tstride: usize,
    pool: BlockPool,
    /// Per-slot block tables; unmapped entries hold [`NO_BLOCK`].
    tables: Vec<Vec<usize>>,
    /// Padded prompt rows recorded at attach, registered into the
    /// group trie when the slot's prefill completes.
    prompts: Vec<Option<Vec<i32>>>,
    /// One prompt-prefix trie per DP group.
    tries: Vec<PrefixTrie>,
    prefix_hits: u64,
    prefix_shared_tokens: u64,
}

/// The executor. Construct once per serving run; feed it batches.
pub struct ModelExecutor<'rt> {
    backend: Backend<'rt>,
    mode: EngineMode,
    /// Host kernel family ([`KernelMode::Blocked`] by default).
    kernel_mode: KernelMode,
    /// Weight quantization for packed host shards (`None` = f32).
    quant: Option<QuantKind>,
    /// Lazily packed head weights (blocked host path; always f32).
    packed_head: Option<HeadWeights>,
    pub weights: super::WeightStore,
    devices: Vec<DeviceState>,
    /// Embedding/head buffers (PJRT; uploaded once, literal retained).
    embed_buf: Option<(xla::Literal, xla::PjRtBuffer)>,
    head_bufs: Option<[(xla::Literal, xla::PjRtBuffer); 2]>,
    /// Current sequence position (tokens stored so far).
    pub pos: usize,
    /// Attention strategy pinned by the live KV caches (set by
    /// `prefill`/`begin_session`, enforced by the decode paths).
    attn: Option<AttnStrategy>,
    /// Plans `begin_batch` validated and made resident — lets the
    /// per-token path skip re-validation and the residency scan.
    batch_plans: Option<(ShardPlan, ShardPlan)>,
    /// Streaming-session slot state (host backend): per-slot sequence
    /// positions and liveness. Valid while `session` is true; gang
    /// `prefill` tears the session down.
    slot_pos: Vec<usize>,
    slot_live: Vec<bool>,
    session: bool,
    /// KV-cache layout for streaming sessions ([`KvLayout::Padded`] by
    /// default). Takes effect at the next `begin_session`.
    kv_layout: KvLayout,
    /// Live paged-session bookkeeping (block pool, per-slot block
    /// tables, per-DP-group prefix tries). `Some` exactly while a
    /// paged session is active.
    paged: Option<PagedSession>,
    stats: ExecStats,
    /// Deterministic fault-injection schedule (host backend chaos
    /// testing): ticked once per compute op; verdicts are stamped into
    /// the device states and surfaced by `map_devices` as structured
    /// `fault[kind]` errors. `None` = healthy run (zero overhead).
    fault: Option<FaultPlan>,
    /// Cumulative per-module / per-device time attribution (attention,
    /// expert FFN, collective combines, reshard) — the observability
    /// layer reads deltas of this around each op.
    times: ModuleTimes,
    /// Micro-chunk pipeline depth K for the host expert path (1 =
    /// module-sequential, the default). See [`Self::set_pipeline_chunks`].
    pipeline_chunks: usize,
}

impl<'rt> ModelExecutor<'rt> {
    /// PJRT-backed executor over a loaded artifact set.
    pub fn new(rt: &'rt PjrtRuntime) -> Result<ModelExecutor<'rt>> {
        let blob = rt.read_weights()?;
        let weights = super::WeightStore::from_blob(&rt.manifest, &blob)?;
        Ok(ModelExecutor {
            backend: Backend::Pjrt(rt),
            mode: EngineMode::Sequential,
            kernel_mode: KernelMode::Blocked,
            quant: None,
            packed_head: None,
            weights,
            devices: Vec::new(),
            embed_buf: None,
            head_bufs: None,
            pos: 0,
            attn: None,
            batch_plans: None,
            slot_pos: Vec::new(),
            slot_live: Vec::new(),
            session: false,
            kv_layout: KvLayout::Padded,
            paged: None,
            stats: ExecStats::default(),
            fault: None,
            times: ModuleTimes::default(),
            pipeline_chunks: 1,
        })
    }

    /// Artifact-free executor running the host kernels (parallel
    /// per-device threads by default).
    pub fn host(weights: super::WeightStore) -> ModelExecutor<'static> {
        Self::host_with_mode(weights, EngineMode::Parallel)
    }

    /// Host executor with an explicit scheduling mode (the sequential
    /// mode is the bit-equivalence reference path).
    pub fn host_with_mode(weights: super::WeightStore, mode: EngineMode) -> ModelExecutor<'static> {
        ModelExecutor {
            backend: Backend::Host,
            mode,
            kernel_mode: KernelMode::Blocked,
            quant: None,
            packed_head: None,
            weights,
            devices: Vec::new(),
            embed_buf: None,
            head_bufs: None,
            pos: 0,
            attn: None,
            batch_plans: None,
            slot_pos: Vec::new(),
            slot_live: Vec::new(),
            session: false,
            kv_layout: KvLayout::Padded,
            paged: None,
            stats: ExecStats::default(),
            fault: None,
            times: ModuleTimes::default(),
            pipeline_chunks: 1,
        }
    }

    pub fn meta(&self) -> &TinyModelMeta {
        &self.weights.meta
    }

    pub fn stats(&self) -> ExecStats {
        self.stats
    }

    /// Select weight quantization for the packed host shards. Changing
    /// the setting evicts every resident shard (the next batch
    /// re-materializes in the new representation) — that reshard is
    /// measured like any plan switch. Host + blocked kernels only: the
    /// PJRT artifacts and the scalar reference path consume f32 shard
    /// tensors.
    pub fn set_quant(&mut self, quant: Option<QuantKind>) -> Result<()> {
        if quant.is_some() && matches!(self.backend, Backend::Pjrt(_)) {
            anyhow::bail!("quantized serving runs on the host backend (PJRT artifacts are f32)");
        }
        if quant.is_some() && self.kernel_mode == KernelMode::Reference {
            anyhow::bail!("quantized serving needs the blocked kernels (KernelMode::Blocked)");
        }
        if quant != self.quant {
            self.quant = quant;
            self.evict_all_shards();
        }
        Ok(())
    }

    /// The active weight quantization (`None` = f32).
    pub fn quant(&self) -> Option<QuantKind> {
        self.quant
    }

    /// Select the KV-cache layout for streaming sessions. Host backend
    /// only for [`KvLayout::Paged`] (the fixed-shape PJRT artifacts
    /// take padded per-batch KV). A change takes effect at the next
    /// `begin_session`; a live session's caches are torn down so stale
    /// layouts can never mix.
    pub fn set_kv_layout(&mut self, layout: KvLayout) -> Result<()> {
        if let KvLayout::Paged { block_size, .. } = layout {
            if matches!(self.backend, Backend::Pjrt(_)) {
                anyhow::bail!("paged KV runs on the host backend (PJRT artifacts take padded KV)");
            }
            if block_size == 0 {
                anyhow::bail!("paged KV needs a block size of at least 1 token");
            }
        }
        if layout != self.kv_layout {
            self.kv_layout = layout;
            self.paged = None;
            if self.session {
                self.session = false;
                self.slot_pos.clear();
                self.slot_live.clear();
                for st in &mut self.devices {
                    st.kv.clear();
                }
            }
        }
        Ok(())
    }

    /// The configured KV-cache layout.
    pub fn kv_layout(&self) -> KvLayout {
        self.kv_layout
    }

    /// Block-level accounting snapshot of the live paged session
    /// (`None` under the padded layout or between sessions).
    pub fn paged_stats(&self) -> Option<PagedKvStats> {
        self.paged.as_ref().map(|sess| PagedKvStats {
            block_size: sess.block_size,
            num_blocks: sess.num_blocks,
            blocks_in_use: sess.pool.in_use(),
            blocks_free: sess.pool.free_blocks(),
            allocs: sess.pool.allocs(),
            frees: sess.pool.frees(),
            cow_copies: sess.pool.cow_copies(),
            prefix_hits: sess.prefix_hits,
            prefix_shared_tokens: sess.prefix_shared_tokens,
        })
    }

    /// Select the host kernel family. Changing it evicts every resident
    /// shard so the next batch re-materializes in the matching
    /// representation (packed tiles vs raw slice tensors).
    pub fn set_kernel_mode(&mut self, mode: KernelMode) -> Result<()> {
        if mode == KernelMode::Reference && self.quant.is_some() {
            anyhow::bail!("the scalar reference kernels consume f32 shards; unset quant first");
        }
        if mode != self.kernel_mode {
            self.kernel_mode = mode;
            self.packed_head = None;
            self.evict_all_shards();
        }
        Ok(())
    }

    /// The active host kernel family.
    pub fn kernel_mode(&self) -> KernelMode {
        self.kernel_mode
    }

    /// Set the micro-chunk pipeline depth `k` for the host expert path
    /// (1 = module-sequential execution, the default). At `k >= 2` the
    /// token batch of every expert call splits into `k` contiguous row
    /// chunks; under [`EngineMode::Parallel`] chunk `c`'s expert FFN
    /// compute overlaps chunk `c-1`'s combine collectives, while
    /// [`EngineMode::Sequential`] runs the same chunk loop without the
    /// overlap — so the sequential engine stays the bit-equivalence
    /// oracle at every `k`. Tokens are bit-identical for any `k` by the
    /// chunking contract on `expert_layer_chunked`. Host backend only:
    /// the PJRT artifacts are monolithic full-batch programs.
    pub fn set_pipeline_chunks(&mut self, k: usize) -> Result<()> {
        if k == 0 {
            anyhow::bail!("the pipeline needs at least one micro-chunk (k >= 1)");
        }
        if k > 1 && matches!(self.backend, Backend::Pjrt(_)) {
            anyhow::bail!(
                "micro-chunk pipelining runs on the host backend (the PJRT artifacts are \
                 monolithic full-batch programs)"
            );
        }
        self.pipeline_chunks = k;
        Ok(())
    }

    /// The configured micro-chunk pipeline depth (1 = sequential).
    pub fn pipeline_chunks(&self) -> usize {
        self.pipeline_chunks
    }

    /// Host-resident weight bytes across all devices — the memory-
    /// footprint side of the quantization trade (PJRT uploads hold no
    /// host copy and report 0).
    pub fn resident_weight_bytes(&self) -> usize {
        self.devices
            .iter()
            .flat_map(|st| st.shards.values())
            .map(ResidentShard::weight_bytes)
            .sum()
    }

    fn evict_all_shards(&mut self) {
        let dropped: usize = self.devices.iter().map(|d| d.shards.len()).sum();
        if dropped > 0 {
            self.stats.evictions += dropped;
            self.stats.reshards += 1;
        }
        for st in &mut self.devices {
            st.shards.clear();
            st.bufs.clear();
        }
        self.batch_plans = None;
    }

    /// Cumulative per-module / per-device time attribution. Callers
    /// wanting per-op numbers snapshot this before an op and take
    /// [`ModuleTimes::delta_since`] after it.
    pub fn module_times(&self) -> &ModuleTimes {
        &self.times
    }

    /// Install a deterministic fault-injection schedule. Host backend
    /// only in effect: the PJRT per-device loops do not run through
    /// `map_devices`, so faults are never raised there.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.fault = Some(plan);
    }

    /// The installed fault plan, if any.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.fault.as_ref()
    }

    /// Devices the fault plan has permanently crashed (logical ids of
    /// the current grid), sorted. Empty when no plan is installed.
    pub fn crashed_devices(&self) -> &[usize] {
        self.fault.as_ref().map(|f| f.crashed()).unwrap_or(&[])
    }

    /// Logical devices currently instantiated.
    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    /// Forget crashed devices after a degraded re-plan renumbers the
    /// grid onto `n_devices` survivors: the fault plan drops stale /
    /// out-of-range events ([`FaultPlan::compact_for`]) and every
    /// stamped verdict is cleared.
    pub fn compact_faults(&mut self, n_devices: usize) {
        if let Some(f) = self.fault.as_mut() {
            f.compact_for(n_devices);
        }
        for st in &mut self.devices {
            st.fault = None;
        }
    }

    /// Advance the fault clock by one compute op and stamp per-device
    /// verdicts. Called once at the top of every executor compute op
    /// (`prefill`, `decode_step`, `prefill_slot`, `decode_slots`), so
    /// fault schedules are keyed by a deterministic op counter — no
    /// wall clocks, no run-time randomness.
    fn fault_tick(&mut self) {
        let Some(fp) = self.fault.as_mut() else {
            return;
        };
        let verdicts = fp.tick(self.devices.len());
        let iter = fp.iteration();
        for st in &mut self.devices {
            st.fault = verdicts
                .get(st.device)
                .copied()
                .flatten()
                .map(|k| fault_message(k, st.device, iter));
        }
    }

    /// A plan is executable when it lowers to a well-formed grid for
    /// this model. (Artifact coverage is checked at call time on the
    /// PJRT backend, so the error names the missing artifact.)
    pub fn validate(&self, plan: &ShardPlan) -> Result<()> {
        let grid = DeviceGrid::lower(plan)?;
        grid.check_meta(self.meta())
    }

    /// Declare the batch's (prefill, decode) plans: evicts shard
    /// layouts neither stage needs, then materializes both stages'
    /// shards — the measured resharding work of a plan switch.
    pub fn begin_batch(&mut self, prefill: &ShardPlan, decode: &ShardPlan) -> Result<()> {
        self.validate(prefill)?;
        self.validate(decode)?;
        if prefill.attn != decode.attn {
            anyhow::bail!(
                "attention strategy must match across stages ({} vs {})",
                prefill.attn,
                decode.attn
            );
        }
        let n = prefill.devices();
        self.ensure_devices(n);
        let needed: HashSet<String> = [
            attn_family(&prefill.attn),
            expert_family(prefill),
            expert_family(decode),
        ]
        .into_iter()
        .collect();
        let t0 = Instant::now();
        let had_resident = self.devices.iter().any(|st| !st.shards.is_empty());
        let mut evicted = 0usize;
        for st in &mut self.devices {
            let before = st.shards.len();
            st.shards.retain(|(fam, _), _| needed.contains(fam));
            st.bufs.retain(|(fam, _), _| needed.contains(fam));
            evicted += before - st.shards.len();
        }
        self.stats.evictions += evicted;
        let mats_before = self.stats.materializations;
        self.ensure_resident(prefill)?;
        self.ensure_resident(decode)?;
        let materialized = self.stats.materializations - mats_before;
        // A reshard is any layout change after the cold start: shards
        // evicted, or new shards joining an already-resident set (a
        // superset switch, e.g. a new decode-stage layout).
        if evicted > 0 || (had_resident && materialized > 0) {
            self.stats.reshards += 1;
        }
        let reshard_s = t0.elapsed().as_secs_f64();
        self.stats.reshard_seconds += reshard_s;
        self.times.reshard_s += reshard_s;
        self.batch_plans = Some((*prefill, *decode));
        Ok(())
    }

    /// True when `begin_batch` already validated this plan and made its
    /// shards resident for the current batch.
    fn plan_ready(&self, plan: &ShardPlan) -> bool {
        self.batch_plans
            .map_or(false, |(p, d)| p == *plan || d == *plan)
    }

    fn ensure_devices(&mut self, n: usize) {
        if self.devices.len() != n {
            let dropped: usize = self.devices.iter().map(|d| d.shards.len()).sum();
            if dropped > 0 {
                self.stats.evictions += dropped;
                self.stats.reshards += 1;
            }
            self.devices = (0..n).map(DeviceState::new).collect();
            self.attn = None;
            self.batch_plans = None;
            self.session = false;
            self.paged = None;
            self.slot_pos.clear();
            self.slot_live.clear();
        }
    }

    /// Materialize (and on PJRT upload) every shard the plan's grid
    /// needs that is not already resident.
    fn ensure_resident(&mut self, plan: &ShardPlan) -> Result<()> {
        self.ensure_devices(plan.devices());
        let m = self.meta().clone();
        let attn_fam = attn_family(&plan.attn);
        let exp_fam = expert_family(plan);
        let backend = self.backend;
        let kmode = self.kernel_mode;
        let quant = self.quant;
        let weights = &self.weights;
        let stats = &mut self.stats;
        for st in &mut self.devices {
            let d = st.device;
            for l in 0..m.layers {
                let specs: [(&String, ShardSpec); 2] = [
                    (&attn_fam, ShardSpec::Attn { layer: l, tp: plan.attn.tp, rank: d % plan.attn.tp }),
                    (
                        &exp_fam,
                        ShardSpec::Expert {
                            layer: l,
                            ep: plan.expert.ep,
                            tp: plan.expert.tp,
                            ep_rank: d / plan.expert.tp,
                            tp_rank: d % plan.expert.tp,
                        },
                    ),
                ];
                for (fam, spec) in specs {
                    let key = (fam.clone(), l);
                    if st.shards.contains_key(&key) {
                        continue;
                    }
                    let tensors = weights.shard(&spec)?;
                    stats.materializations += 1;
                    stats.uploaded_floats += tensors.iter().map(|t| t.elements()).sum::<usize>();
                    if let Backend::Pjrt(rt) = backend {
                        let upload = match spec {
                            ShardSpec::Expert { ep, tp, tp_rank, .. } if ep > 1 && tp > 1 => {
                                pad_expert_for_artifact(&tensors, m.inter, tp, tp_rank)
                            }
                            _ => tensors.clone(),
                        };
                        let bufs = upload
                            .iter()
                            .map(|t| {
                                let lit = t.to_literal()?;
                                let buf = rt.to_device(&lit)?;
                                Ok((lit, buf))
                            })
                            .collect::<Result<Vec<_>>>()?;
                        st.bufs.insert(key.clone(), bufs);
                    }
                    let resident = match (backend, kmode) {
                        (Backend::Pjrt(_), _) => ResidentShard::Uploaded,
                        (Backend::Host, KernelMode::Reference) => ResidentShard::Raw(tensors),
                        (Backend::Host, KernelMode::Blocked) => ResidentShard::Packed(match spec {
                            ShardSpec::Attn { .. } => {
                                ShardWeights::Attn(AttnWeights::from_shard(&tensors, quant)?)
                            }
                            ShardSpec::Expert { ep, .. } => ShardWeights::Expert(
                                ExpertWeights::from_shard(&tensors, ep, quant)?,
                            ),
                        }),
                    };
                    st.shards.insert(key, resident);
                }
            }
        }
        Ok(())
    }

    /// Run prefill for a [B, S] token batch; returns last-position
    /// logits [B, V]. Resets per-batch sequence state (positions, KV
    /// caches) while keeping resident weight shards warm.
    pub fn prefill(&mut self, tokens: &[i32], plan: &ShardPlan) -> Result<HostTensor> {
        let m = self.meta().clone();
        let (b, s) = (m.batch, m.prefill_len);
        if tokens.len() != b * s {
            anyhow::bail!("prefill expects {}x{} tokens, got {}", b, s, tokens.len());
        }
        if !self.plan_ready(plan) {
            self.validate(plan)?;
            self.ensure_resident(plan)?;
        }
        if self.kv_layout.is_paged() {
            anyhow::bail!(
                "gang prefill owns whole padded batches; the paged KV layout serves the \
                 streaming session paths (begin_session/prefill_slot/decode_slots)"
            );
        }
        let grid = DeviceGrid::lower(plan)?;
        self.attn = Some(plan.attn);
        self.pos = 0;
        // Gang prefill owns the whole batch: any streaming session's
        // per-slot KV is torn down with the caches below.
        self.session = false;
        for st in &mut self.devices {
            st.kv = (0..m.layers).map(|_| None).collect();
        }

        self.fault_tick();
        let mut x = self.embed(tokens, b, s, &m)?;
        for l in 0..m.layers {
            let a_out = self.attn_prefill_layer(&x, l, &grid, &m)?;
            x.add_assign(&a_out);
            let e_out = self.expert_layer(&x, l, &grid, &m, "prefill")?;
            x.add_assign(&e_out);
        }
        self.pos = s;
        self.head(&x, &m)
    }

    /// One decode step: `last_tokens` [B] (previous outputs), returns
    /// logits [B, V]. The plan's attention strategy must match
    /// prefill's (pinned by the KV cache layout); the expert strategy
    /// may differ — the paper's dynamic parallelism transition.
    pub fn decode_step(&mut self, last_tokens: &[i32], plan: &ShardPlan) -> Result<HostTensor> {
        let m = self.meta().clone();
        let b = m.batch;
        if last_tokens.len() != b {
            anyhow::bail!("decode expects {} tokens, got {}", b, last_tokens.len());
        }
        if self.pos + 1 > m.max_len {
            anyhow::bail!("KV cache exhausted at pos {}", self.pos);
        }
        let pinned = self.attn.ok_or_else(|| anyhow!("decode before prefill"))?;
        if plan.attn != pinned {
            anyhow::bail!("attention strategy is pinned by the KV cache ({pinned})");
        }
        if self.session {
            anyhow::bail!("executor holds a streaming session; use decode_slots");
        }
        // Per-token fast path: plans declared via `begin_batch` are
        // already validated and resident.
        if !self.plan_ready(plan) {
            self.validate(plan)?;
            self.ensure_resident(plan)?;
        }
        let grid = DeviceGrid::lower(plan)?;

        self.fault_tick();
        let mut x = self.embed(last_tokens, b, 1, &m)?;
        for l in 0..m.layers {
            let a_out = self.attn_decode_layer(&x, l, &grid, &m)?;
            x.add_assign(&a_out);
            let e_out = self.expert_layer(&x, l, &grid, &m, "decode")?;
            x.add_assign(&e_out);
        }
        self.pos += 1;
        self.head(&x, &m)
    }

    // ---- Streaming session (per-slot KV join/leave) ---------------------

    /// Start a streaming session: declare the (prefill, decode) plans,
    /// allocate zeroed per-device KV caches for the whole slot range,
    /// and reset per-slot state. Sequences then enter the live batch via
    /// [`Self::claim_slot`] + [`Self::prefill_slot`] and leave via
    /// [`Self::release_slot`] without resetting their peers.
    ///
    /// Host backend only: the fixed-shape PJRT artifacts take one
    /// scalar decode position per batch, which cannot express per-slot
    /// offsets (emitting per-slot-position artifacts is a ROADMAP
    /// follow-on).
    ///
    /// A mid-session switch that keeps the attention layout (expert
    /// resharding) needs no new session — call [`Self::begin_batch`]
    /// with the new plans; KV caches are untouched. A switch that
    /// changes the attention layout invalidates the KV sharding, so
    /// callers drain the running set and call `begin_session` again.
    pub fn begin_session(&mut self, prefill: &ShardPlan, decode: &ShardPlan) -> Result<()> {
        if matches!(self.backend, Backend::Pjrt(_)) {
            anyhow::bail!(
                "streaming sessions need per-slot decode positions; the fixed-shape PJRT \
                 artifacts pin one scalar position per batch — use the host backend"
            );
        }
        self.begin_batch(prefill, decode)?;
        let m = self.meta().clone();
        let t = prefill.attn.tp;
        let kv_l = (m.kv_heads / t).max(1);
        let bg = m.batch / prefill.attn.dp;
        match self.kv_layout {
            KvLayout::Padded => {
                for st in &mut self.devices {
                    st.kv = (0..m.layers)
                        .map(|_| {
                            Some(LayerCache {
                                k: HostTensor::zeros(vec![bg, m.max_len, kv_l, m.head_dim]),
                                v: HostTensor::zeros(vec![bg, m.max_len, kv_l, m.head_dim]),
                            })
                        })
                        .collect();
                }
                self.paged = None;
            }
            layout @ KvLayout::Paged { block_size, .. } => {
                // Block-pool layout: every device holds the full pool
                // (block ids are global) reinterpreted as
                // [num_blocks, block_size, KVH_l, D]; a block's data is
                // only ever written/read by one DP group's devices.
                let nb = layout.resolved_blocks(&m).unwrap();
                let tstride = m.max_len.div_ceil(block_size);
                if tstride > nb {
                    anyhow::bail!(
                        "paged KV pool of {nb} blocks cannot hold one {}-token sequence \
                         ({tstride} blocks of {block_size})",
                        m.max_len
                    );
                }
                for st in &mut self.devices {
                    st.kv = (0..m.layers)
                        .map(|_| {
                            Some(LayerCache {
                                k: HostTensor::zeros(vec![nb, block_size, kv_l, m.head_dim]),
                                v: HostTensor::zeros(vec![nb, block_size, kv_l, m.head_dim]),
                            })
                        })
                        .collect();
                }
                self.paged = Some(PagedSession {
                    block_size,
                    num_blocks: nb,
                    tstride,
                    pool: BlockPool::new(nb),
                    tables: vec![vec![NO_BLOCK; tstride]; m.batch],
                    prompts: vec![None; m.batch],
                    tries: (0..prefill.attn.dp).map(|_| PrefixTrie::new()).collect(),
                    prefix_hits: 0,
                    prefix_shared_tokens: 0,
                });
            }
        }
        self.attn = Some(prefill.attn);
        self.pos = 0;
        self.slot_pos = vec![0; m.batch];
        self.slot_live = vec![false; m.batch];
        self.session = true;
        Ok(())
    }

    /// True while a streaming session's per-slot KV is live.
    pub fn in_session(&self) -> bool {
        self.session
    }

    /// Per-slot sequence positions (tokens stored so far).
    pub fn slot_positions(&self) -> &[usize] {
        &self.slot_pos
    }

    /// Per-slot liveness flags.
    pub fn slot_liveness(&self) -> &[bool] {
        &self.slot_live
    }

    /// Number of unclaimed slots in the current session.
    pub fn free_slots(&self) -> usize {
        if !self.session {
            return 0;
        }
        self.slot_live.iter().filter(|&&l| !l).count()
    }

    /// Claim the first free batch slot for a joining sequence. Returns
    /// `None` when the session is full (or no session is active).
    pub fn claim_slot(&mut self) -> Option<usize> {
        if !self.session {
            return None;
        }
        let slot = self.slot_live.iter().position(|&l| !l)?;
        self.slot_live[slot] = true;
        self.slot_pos[slot] = 0;
        Some(slot)
    }

    /// Retire a slot: zero its KV rows (isolation — the next occupant
    /// starts from a clean cache) and mark it free. Peers are untouched.
    pub fn release_slot(&mut self, slot: usize) -> Result<()> {
        if !self.session || slot >= self.slot_live.len() {
            anyhow::bail!("release of slot {slot} outside an active session");
        }
        if !self.slot_live[slot] {
            anyhow::bail!("release of unclaimed slot {slot}");
        }
        if let Some(sess) = self.paged.as_mut() {
            // Paged release: hand every mapped block back to the pool
            // (trie-shared blocks just drop one refcount). No zeroing —
            // paged attention never reads past a slot's cursor, and a
            // block's next owner overwrites each position before any
            // kernel can read it.
            for entry in sess.tables[slot].iter_mut() {
                let b = std::mem::replace(entry, NO_BLOCK);
                if b != NO_BLOCK {
                    sess.pool.release(b);
                }
            }
            sess.prompts[slot] = None;
            self.slot_live[slot] = false;
            self.slot_pos[slot] = 0;
            return Ok(());
        }
        let attn = self.attn.ok_or_else(|| anyhow!("session has no pinned attention"))?;
        // Same group membership source as prefill_slot/decode_slots:
        // the lowered grid's roles, never a re-derived index formula.
        let (session_prefill, _) = self
            .batch_plans
            .ok_or_else(|| anyhow!("session has no resident plans"))?;
        let grid = DeviceGrid::lower(&session_prefill)?;
        let bg = self.slot_live.len() / attn.dp;
        let (g, r) = (slot / bg, slot % bg);
        for st in &mut self.devices {
            if grid.roles[st.device].dp_rank != g {
                continue;
            }
            for cache in st.kv.iter_mut().flatten() {
                let rowlen: usize = cache.k.shape[1..].iter().product();
                cache.k.data[r * rowlen..(r + 1) * rowlen].fill(0.0);
                cache.v.data[r * rowlen..(r + 1) * rowlen].fill(0.0);
            }
        }
        self.slot_live[slot] = false;
        self.slot_pos[slot] = 0;
        Ok(())
    }

    /// **Resumable** chunked prefill for a joiner: run the next chunk
    /// of the slot's padded prompt (`tokens`, `1..=S - slot_pos` of
    /// them) through the model in batch slot `slot`, writing its KV at
    /// positions `slot_pos..slot_pos + tokens.len()` while every other
    /// slot's state stays intact. The slot's cursor (`slot_pos`)
    /// advances by the chunk length; the slot becomes decodable once it
    /// reaches `prefill_len` ([`Self::decode_slots`] skips it until
    /// then). Returns the chunk's last-position logits `[1, V]` — only
    /// the *final* chunk's logits are the prompt's first-token logits
    /// (identical to a one-shot prefill of the whole row; intermediate
    /// chunks' logits are a mid-prompt byproduct callers discard).
    pub fn prefill_slot(
        &mut self,
        slot: usize,
        tokens: &[i32],
        plan: &ShardPlan,
    ) -> Result<HostTensor> {
        if matches!(self.backend, Backend::Pjrt(_)) {
            anyhow::bail!("prefill_slot runs on the host backend only (see begin_session)");
        }
        let m = self.meta().clone();
        let c = tokens.len();
        if !self.session {
            anyhow::bail!("prefill_slot outside a session (call begin_session)");
        }
        if !self.slot_live.get(slot).copied().unwrap_or(false) {
            anyhow::bail!("slot {slot} not claimed");
        }
        let start = self.slot_pos[slot];
        if c == 0 || start + c > m.prefill_len {
            anyhow::bail!(
                "slot {slot} chunk {start}..{} outside the {}-token prompt",
                start + c,
                m.prefill_len
            );
        }
        let pinned = self.attn.ok_or_else(|| anyhow!("session has no pinned attention"))?;
        if plan.attn != pinned {
            anyhow::bail!("attention strategy is pinned by the session KV layout ({pinned})");
        }
        if !self.plan_ready(plan) {
            self.validate(plan)?;
            self.ensure_resident(plan)?;
        }
        let grid = DeviceGrid::lower(plan)?;
        let t = plan.attn.tp;
        let q_l = m.q_heads / t;
        let kv_l = (m.kv_heads / t).max(1);
        let bg = m.batch / plan.attn.dp;
        let (g, r) = (slot / bg, slot % bg);

        // Paged: map (and COW-unshare) the blocks this chunk touches up
        // front, then hand the kernels a read-only table snapshot.
        let paged_table: Option<Vec<usize>> = if self.paged.is_some() {
            Some(self.paged_prepare_prefill(slot, g, start, c, &grid)?)
        } else {
            None
        };
        let pbs = self.paged.as_ref().map(|s| s.block_size).unwrap_or(1);

        self.fault_tick();
        let mut x = self.embed(tokens, 1, c, &m)?;
        for l in 0..m.layers {
            let a_out = {
                let roles = &grid.roles;
                let fam = attn_family(&plan.attn);
                let hd = m.head_dim;
                let xr = &x;
                // Only the slot's DP group computes (and stores KV);
                // the row's output is the group's TP partial-sum, folded
                // in the same member order as the gang combine. The
                // ranged kernel resumes against the slot's cache row:
                // earlier chunks' KV is read back, this chunk's written.
                let t_mod = Instant::now();
                let tbl_ref = paged_table.as_deref();
                let (outs, per_dev): (Vec<Option<HostTensor>>, Vec<f64>) =
                    map_devices_timed(self.mode, &mut self.devices, |st| {
                        let role = roles[st.device];
                        if role.dp_rank != g {
                            return Ok(None);
                        }
                        let w = st
                            .shards
                            .get(&(fam.clone(), l))
                            .ok_or_else(|| anyhow!("attn shard not resident"))?;
                        let cache = st.kv[l]
                            .as_mut()
                            .ok_or_else(|| anyhow!("session KV missing"))?;
                        let out = match tbl_ref {
                            Some(table) => w.attn_prefill_ranged_paged(
                                xr,
                                &mut cache.k,
                                &mut cache.v,
                                table,
                                pbs,
                                start,
                                q_l,
                                kv_l,
                                hd,
                            )?,
                            None => w.attn_prefill_ranged(
                                xr,
                                &mut cache.k,
                                &mut cache.v,
                                r,
                                start,
                                q_l,
                                kv_l,
                                hd,
                            )?,
                        };
                        Ok(Some(out))
                    })?;
                self.times.attn_s += t_mod.elapsed().as_secs_f64();
                for (d, dt) in per_dev.iter().enumerate() {
                    self.times.add_device(d, *dt);
                }
                // Same order-deterministic fold as the gang combine.
                let t_comb = Instant::now();
                let out = collectives::apply(&grid.attn_reduce[g], &outs)?;
                self.times.collective_s += t_comb.elapsed().as_secs_f64();
                out
            };
            x.add_assign(&a_out);
            let e_out = self.expert_layer(&x, l, &grid, &m, "prefill")?;
            x.add_assign(&e_out);
        }
        self.slot_pos[slot] = start + c;
        if self.paged.is_some() && start + c == m.prefill_len {
            self.paged_register_prompt(slot, g);
        }
        self.head(&x, &m)
    }

    /// Batched joiner prefill: run the **same-range** next chunk of
    /// several slots' prompts as one executor op — the "batch
    /// same-length joiner chunks into one ranged prefill call" half of
    /// the pipelined iteration loop. All slots must sit at the same
    /// prompt cursor and submit chunks of one common length `c`
    /// (`rows[i]` is slot `slots[i]`'s chunk); callers pass slots in
    /// ascending order so paged block mapping/COW stays deterministic.
    /// One fault-clock tick covers the whole batch — the engine forms
    /// groups from scheduler state alone, so the op sequence (and with
    /// it any fault schedule) is identical across engine modes.
    /// Per-slot ranged attention runs against each slot's own KV row
    /// inside the device closure in `slots` order; the expert/head math
    /// runs once over the stacked `[n·c, H]` rows (and micro-chunk
    /// pipelines when `pipeline_chunks > 1`). Every kernel in the path
    /// is row-independent, so each slot's tokens are bit-identical to
    /// `n` separate [`Self::prefill_slot`] calls. Returns each slot's
    /// chunk logits (`[1, V]`, input order); as with `prefill_slot`,
    /// only a *final* chunk's logits are the prompt's first-token
    /// logits.
    pub fn prefill_slots(
        &mut self,
        slots: &[usize],
        rows: &[&[i32]],
        plan: &ShardPlan,
    ) -> Result<Vec<HostTensor>> {
        if matches!(self.backend, Backend::Pjrt(_)) {
            anyhow::bail!("prefill_slots runs on the host backend only (see begin_session)");
        }
        let m = self.meta().clone();
        let n = slots.len();
        if n == 0 || rows.len() != n {
            anyhow::bail!(
                "prefill_slots needs one token row per slot ({n} slots, {} rows)",
                rows.len()
            );
        }
        if !self.session {
            anyhow::bail!("prefill_slots outside a session (call begin_session)");
        }
        let c = rows[0].len();
        for (i, &slot) in slots.iter().enumerate() {
            if !self.slot_live.get(slot).copied().unwrap_or(false) {
                anyhow::bail!("slot {slot} not claimed");
            }
            if slots[..i].contains(&slot) {
                anyhow::bail!("slot {slot} appears twice in one batched prefill");
            }
            if rows[i].len() != c {
                anyhow::bail!(
                    "batched prefill chunks must share one length ({c} vs {} for slot {slot})",
                    rows[i].len()
                );
            }
            if self.slot_pos[slot] != self.slot_pos[slots[0]] {
                anyhow::bail!(
                    "batched prefill slots must share one cursor ({} vs {} for slot {slot})",
                    self.slot_pos[slots[0]],
                    self.slot_pos[slot]
                );
            }
        }
        let start = self.slot_pos[slots[0]];
        if c == 0 || start + c > m.prefill_len {
            anyhow::bail!(
                "chunk {start}..{} outside the {}-token prompt",
                start + c,
                m.prefill_len
            );
        }
        let pinned = self.attn.ok_or_else(|| anyhow!("session has no pinned attention"))?;
        if plan.attn != pinned {
            anyhow::bail!("attention strategy is pinned by the session KV layout ({pinned})");
        }
        if !self.plan_ready(plan) {
            self.validate(plan)?;
            self.ensure_resident(plan)?;
        }
        let grid = DeviceGrid::lower(plan)?;
        let t = plan.attn.tp;
        let q_l = m.q_heads / t;
        let kv_l = (m.kv_heads / t).max(1);
        let bg = m.batch / plan.attn.dp;
        let groups: Vec<(usize, usize)> = slots.iter().map(|&s| (s / bg, s % bg)).collect();

        // Paged: map (and COW-unshare) each slot's blocks up front, in
        // input order — a scheduler-side decision made before the op,
        // identical across engine modes.
        let paged_tables: Option<Vec<Vec<usize>>> = if self.paged.is_some() {
            let mut tabs = Vec::with_capacity(n);
            for (i, &slot) in slots.iter().enumerate() {
                tabs.push(self.paged_prepare_prefill(slot, groups[i].0, start, c, &grid)?);
            }
            Some(tabs)
        } else {
            None
        };
        let pbs = self.paged.as_ref().map(|s| s.block_size).unwrap_or(1);

        self.fault_tick();
        let flat: Vec<i32> = rows.iter().flat_map(|r| r.iter().copied()).collect();
        let mut x = self.embed(&flat, n, c, &m)?;
        for l in 0..m.layers {
            let a_out = {
                let roles = &grid.roles;
                let fam = attn_family(&plan.attn);
                let hd = m.head_dim;
                let xr = &x;
                let groups_ref = &groups;
                let tabs_ref = paged_tables.as_ref();
                let t_mod = Instant::now();
                let (mut outs, per_dev): (Vec<Vec<Option<HostTensor>>>, Vec<f64>) =
                    map_devices_timed(self.mode, &mut self.devices, |st| {
                        let role = roles[st.device];
                        let mut mine: Vec<Option<HostTensor>> = vec![None; groups_ref.len()];
                        for (i, &(g, r)) in groups_ref.iter().enumerate() {
                            if role.dp_rank != g {
                                continue;
                            }
                            let w = st
                                .shards
                                .get(&(fam.clone(), l))
                                .ok_or_else(|| anyhow!("attn shard not resident"))?;
                            let cache = st.kv[l]
                                .as_mut()
                                .ok_or_else(|| anyhow!("session KV missing"))?;
                            let xi = xr.slice_outer(i, 1);
                            let out = match tabs_ref {
                                Some(tabs) => w.attn_prefill_ranged_paged(
                                    &xi,
                                    &mut cache.k,
                                    &mut cache.v,
                                    &tabs[i],
                                    pbs,
                                    start,
                                    q_l,
                                    kv_l,
                                    hd,
                                )?,
                                None => w.attn_prefill_ranged(
                                    &xi,
                                    &mut cache.k,
                                    &mut cache.v,
                                    r,
                                    start,
                                    q_l,
                                    kv_l,
                                    hd,
                                )?,
                            };
                            mine[i] = Some(out);
                        }
                        Ok(mine)
                    })?;
                self.times.attn_s += t_mod.elapsed().as_secs_f64();
                for (d, dt) in per_dev.iter().enumerate() {
                    self.times.add_device(d, *dt);
                }
                // Per-slot TP partial-sum — the same fold, in the same
                // member order, as the single-slot path — stitched back
                // in slot order.
                let t_comb = Instant::now();
                let mut slot_rows = Vec::with_capacity(n);
                for i in 0..n {
                    let table: Vec<Option<HostTensor>> =
                        outs.iter_mut().map(|per_slot| per_slot[i].take()).collect();
                    slot_rows.push(collectives::apply(&grid.attn_reduce[groups[i].0], &table)?);
                }
                let out = collectives::concat_chunks(&slot_rows)?;
                self.times.collective_s += t_comb.elapsed().as_secs_f64();
                out
            };
            x.add_assign(&a_out);
            let e_out = self.expert_layer(&x, l, &grid, &m, "prefill")?;
            x.add_assign(&e_out);
        }
        for (i, &slot) in slots.iter().enumerate() {
            self.slot_pos[slot] = start + c;
            if self.paged.is_some() && start + c == m.prefill_len {
                self.paged_register_prompt(slot, groups[i].0);
            }
        }
        let logits = self.head(&x, &m)?;
        let v = m.vocab;
        Ok((0..n)
            .map(|i| HostTensor::new(vec![1, v], logits.data[i * v..(i + 1) * v].to_vec()))
            .collect())
    }

    /// One decode iteration over the live slots: each **fully
    /// prefilled** claimed slot advances by one token at its own
    /// position. Free slots — and slots mid-way through a chunked
    /// prefill (`0 < slot_pos < prefill_len`) — are skipped by
    /// attention (no KV read/write, zero attention output, no position
    /// advance) but still ride through the shared embed/expert/head
    /// math, so their logits rows contain values — callers must
    /// consult [`Self::slot_liveness`]/[`Self::slot_positions`] and
    /// ignore those rows. `last_tokens` is the full `[B]` table
    /// (entries for skipped slots are ignored). Returns logits
    /// `[B, V]`.
    pub fn decode_slots(&mut self, last_tokens: &[i32], plan: &ShardPlan) -> Result<HostTensor> {
        if matches!(self.backend, Backend::Pjrt(_)) {
            anyhow::bail!("decode_slots runs on the host backend only (see begin_session)");
        }
        let m = self.meta().clone();
        let b = m.batch;
        if last_tokens.len() != b {
            anyhow::bail!("decode_slots expects {} tokens, got {}", b, last_tokens.len());
        }
        if !self.session {
            anyhow::bail!("decode_slots outside a session (call begin_session)");
        }
        let pinned = self.attn.ok_or_else(|| anyhow!("session has no pinned attention"))?;
        if plan.attn != pinned {
            anyhow::bail!("attention strategy is pinned by the session KV layout ({pinned})");
        }
        for slot in 0..b {
            if self.slot_live[slot] {
                if self.slot_pos[slot] == 0 {
                    anyhow::bail!("slot {slot} decoded before prefill");
                }
                if self.slot_pos[slot] >= m.max_len {
                    anyhow::bail!("KV cache exhausted for slot {slot}");
                }
            }
        }
        if !self.plan_ready(plan) {
            self.validate(plan)?;
            self.ensure_resident(plan)?;
        }
        let grid = DeviceGrid::lower(plan)?;
        let t = plan.attn.tp;
        let q_l = m.q_heads / t;
        let kv_l = (m.kv_heads / t).max(1);
        let bg = b / plan.attn.dp;
        let slot_pos = self.slot_pos.clone();
        // Decodable = claimed AND fully prefilled. A slot mid-way
        // through a chunked prefill (0 < pos < prefill_len) rides this
        // iteration inert — no KV read/write, zero attention output, no
        // position advance — exactly like a free slot, so peers decode
        // between its chunks.
        let slot_live: Vec<bool> = (0..b)
            .map(|s| self.slot_live[s] && self.slot_pos[s] >= m.prefill_len)
            .collect();

        // Paged: every decodable slot's next position must land in a
        // mapped, exclusively-owned block before any device writes —
        // mapping/COW is a scheduler-side decision, identical across
        // the group's devices, so it happens once up front.
        let paged_flat: Option<(Vec<usize>, usize, usize)> = if self.paged.is_some() {
            for slot in 0..b {
                if slot_live[slot] {
                    let bi = slot_pos[slot] / self.paged.as_ref().unwrap().block_size;
                    self.paged_map_block(slot, bi)?;
                    self.paged_make_writable(slot, slot / bg, bi, &grid)?;
                }
            }
            let sess = self.paged.as_ref().unwrap();
            let mut flat = Vec::with_capacity(b * sess.tstride);
            for table in &sess.tables {
                flat.extend_from_slice(table);
            }
            Some((flat, sess.tstride, sess.block_size))
        } else {
            None
        };

        self.fault_tick();
        let mut x = self.embed(last_tokens, b, 1, &m)?;
        for l in 0..m.layers {
            let a_out = {
                let roles = &grid.roles;
                let fam = attn_family(&plan.attn);
                let hd = m.head_dim;
                let xr = &x;
                let pos_ref = &slot_pos;
                let live_ref = &slot_live;
                let pf_ref = &paged_flat;
                let t_mod = Instant::now();
                let (outs, per_dev): (Vec<HostTensor>, Vec<f64>) =
                    map_devices_timed(self.mode, &mut self.devices, |st| {
                        let role = roles[st.device];
                        let xg = xr.slice_outer(role.dp_rank * bg, bg);
                        let cache = st.kv[l]
                            .as_mut()
                            .ok_or_else(|| anyhow!("session KV missing"))?;
                        let w = st
                            .shards
                            .get(&(fam.clone(), l))
                            .ok_or_else(|| anyhow!("attn shard not resident"))?;
                        match pf_ref {
                            Some((flat, tstride, pbs)) => w.attn_decode_slots_paged(
                                &xg,
                                &mut cache.k,
                                &mut cache.v,
                                &pos_ref[role.dp_rank * bg..(role.dp_rank + 1) * bg],
                                &live_ref[role.dp_rank * bg..(role.dp_rank + 1) * bg],
                                &flat[role.dp_rank * bg * tstride
                                    ..(role.dp_rank + 1) * bg * tstride],
                                *tstride,
                                *pbs,
                                q_l,
                                kv_l,
                                hd,
                            ),
                            None => w.attn_decode_slots(
                                &xg,
                                &mut cache.k,
                                &mut cache.v,
                                &pos_ref[role.dp_rank * bg..(role.dp_rank + 1) * bg],
                                &live_ref[role.dp_rank * bg..(role.dp_rank + 1) * bg],
                                q_l,
                                kv_l,
                                hd,
                            ),
                        }
                    })?;
                self.times.attn_s += t_mod.elapsed().as_secs_f64();
                for (d, dt) in per_dev.iter().enumerate() {
                    self.times.add_device(d, *dt);
                }
                let t_comb = Instant::now();
                let out = combine_attn(&grid, outs)?;
                self.times.collective_s += t_comb.elapsed().as_secs_f64();
                out
            };
            x.add_assign(&a_out);
            let e_out = self.expert_layer(&x, l, &grid, &m, "decode")?;
            x.add_assign(&e_out);
        }
        for slot in 0..b {
            if slot_live[slot] {
                self.slot_pos[slot] += 1;
            }
        }
        self.head(&x, &m)
    }

    // ---- Paged-KV session plumbing --------------------------------------

    /// Bind a joiner's full padded prompt row to its freshly claimed
    /// slot, before the first prefill chunk. Under the padded layout
    /// this is a no-op (`start == 0`: prefill everything). Under the
    /// paged layout the slot's prompt is matched against its DP group's
    /// prefix trie: every matched full block is attached to the slot's
    /// table as a shared (refcounted) block, and prefill may resume
    /// from `start = matched_tokens` — except the prompt's **final**
    /// position, which is always recomputed so its logits seed the
    /// first sampled token exactly as an unshared prefill would.
    pub fn attach_prompt(&mut self, slot: usize, row: &[i32]) -> Result<PrefixAttach> {
        if !self.session {
            anyhow::bail!("attach_prompt outside a session (call begin_session)");
        }
        if !self.slot_live.get(slot).copied().unwrap_or(false) {
            anyhow::bail!("slot {slot} not claimed");
        }
        let m = self.meta().clone();
        if row.len() != m.prefill_len {
            anyhow::bail!(
                "attach_prompt expects the padded {}-token prompt row, got {}",
                m.prefill_len,
                row.len()
            );
        }
        if self.slot_pos[slot] != 0 {
            anyhow::bail!("attach_prompt after prefill began for slot {slot}");
        }
        if self.paged.is_none() {
            return Ok(PrefixAttach::default());
        }
        let attn = self.attn.ok_or_else(|| anyhow!("session has no pinned attention"))?;
        let bg = self.slot_live.len() / attn.dp;
        let g = slot / bg;
        let sess = self.paged.as_mut().unwrap();
        sess.prompts[slot] = Some(row.to_vec());
        let matched = sess.tries[g].lookup(row, sess.block_size);
        for (bi, &b) in matched.iter().enumerate() {
            sess.pool.retain(b);
            sess.tables[slot][bi] = b;
        }
        let start = (matched.len() * sess.block_size).min(m.prefill_len - 1);
        if start > 0 {
            sess.prefix_hits += 1;
            sess.prefix_shared_tokens += start as u64;
        }
        self.slot_pos[slot] = start;
        Ok(PrefixAttach { start, shared_blocks: matched.len() })
    }

    /// Allocate one block, evicting trie-cached prefix leaves (in
    /// deterministic group-then-arena order) when the free list runs
    /// dry. Trie-held blocks are cache, not reservation: any block the
    /// trie alone owns is reclaimable.
    fn paged_alloc_block(&mut self) -> Result<usize> {
        let sess = self.paged.as_mut().expect("paged session");
        loop {
            if let Some(b) = sess.pool.alloc() {
                return Ok(b);
            }
            let mut evicted = false;
            for trie in sess.tries.iter_mut() {
                if let Some(b) = trie.evict_leaf() {
                    sess.pool.release(b);
                    evicted = true;
                    break;
                }
            }
            if !evicted {
                anyhow::bail!(
                    "paged KV pool exhausted ({} blocks all slot-owned)",
                    sess.num_blocks
                );
            }
        }
    }

    /// Ensure table entry `bi` of `slot` maps a physical block.
    fn paged_map_block(&mut self, slot: usize, bi: usize) -> Result<()> {
        let sess = self.paged.as_ref().expect("paged session");
        if bi >= sess.tstride {
            anyhow::bail!("slot {slot} block index {bi} past its table ({})", sess.tstride);
        }
        if sess.tables[slot][bi] != NO_BLOCK {
            return Ok(());
        }
        let b = self.paged_alloc_block()?;
        self.paged.as_mut().unwrap().tables[slot][bi] = b;
        Ok(())
    }

    /// Copy-on-write: if table entry `bi` of `slot` points at a shared
    /// block (refcount > 1), give the slot a private copy before any
    /// kernel writes into it. K/V at a position depends only on the
    /// tokens at and before it (causal), so byte-copying the block on
    /// the group's devices preserves bit-identity exactly.
    fn paged_make_writable(
        &mut self,
        slot: usize,
        g: usize,
        bi: usize,
        grid: &DeviceGrid,
    ) -> Result<()> {
        let src = self.paged.as_ref().expect("paged session").tables[slot][bi];
        if src == NO_BLOCK || self.paged.as_ref().unwrap().pool.refcount(src) <= 1 {
            return Ok(());
        }
        // The source holds >= 2 refs, so trie eviction inside the
        // alloc below can never free it out from under the copy.
        let fresh = self.paged_alloc_block()?;
        let sess = self.paged.as_mut().unwrap();
        let bs = sess.block_size;
        for st in &mut self.devices {
            if grid.roles[st.device].dp_rank != g {
                continue;
            }
            for cache in st.kv.iter_mut().flatten() {
                let blk_len = bs * cache.k.shape[2] * cache.k.shape[3];
                cache
                    .k
                    .data
                    .copy_within(src * blk_len..(src + 1) * blk_len, fresh * blk_len);
                cache
                    .v
                    .data
                    .copy_within(src * blk_len..(src + 1) * blk_len, fresh * blk_len);
            }
        }
        sess.tables[slot][bi] = fresh;
        sess.pool.release(src);
        sess.pool.note_cow();
        Ok(())
    }

    /// Map (and COW-unshare) every block a prefill chunk touches, and
    /// return the table prefix the paged kernels need.
    fn paged_prepare_prefill(
        &mut self,
        slot: usize,
        g: usize,
        start: usize,
        c: usize,
        grid: &DeviceGrid,
    ) -> Result<Vec<usize>> {
        let bs = self.paged.as_ref().expect("paged session").block_size;
        for bi in start / bs..=(start + c - 1) / bs {
            self.paged_map_block(slot, bi)?;
            self.paged_make_writable(slot, g, bi, grid)?;
        }
        let sess = self.paged.as_ref().unwrap();
        Ok(sess.tables[slot][..(start + c).div_ceil(bs)].to_vec())
    }

    /// After a slot finishes its prompt, publish its full blocks into
    /// the DP group's prefix trie so later identical prompts share
    /// them. Only block-aligned full prompt blocks register (a partial
    /// tail block stays private — it will take decode writes). The trie
    /// holds one refcount per node it actually created; on a duplicate
    /// chunk the first registration wins and this slot's private block
    /// simply frees at release.
    fn paged_register_prompt(&mut self, slot: usize, g: usize) {
        let sess = self.paged.as_mut().expect("paged session");
        let Some(row) = sess.prompts[slot].clone() else {
            return;
        };
        let bs = sess.block_size;
        let full = row.len() / bs;
        if full == 0 {
            return;
        }
        let blocks: Vec<usize> = sess.tables[slot][..full].to_vec();
        if blocks.iter().any(|&b| b == NO_BLOCK) {
            return;
        }
        let newly = sess.tries[g].register(&row[..full * bs], &blocks, bs);
        for b in newly {
            sess.pool.retain(b);
        }
    }

    // ---- Module drivers -------------------------------------------------

    fn embed(&mut self, tokens: &[i32], b: usize, s: usize, m: &TinyModelMeta) -> Result<HostTensor> {
        match self.backend {
            Backend::Host => kernels::embed_lookup(tokens, self.weights.get("embed")?, b, s),
            Backend::Pjrt(rt) => {
                let name = if s == 1 { "embed_decode" } else { "embed_prefill" };
                require_artifact(rt, name)?;
                if self.embed_buf.is_none() {
                    let lit = self.weights.get("embed")?.to_literal()?;
                    let buf = rt.to_device(&lit)?;
                    self.stats.materializations += 1;
                    self.stats.uploaded_floats += m.vocab * m.hidden;
                    self.embed_buf = Some((lit, buf));
                }
                let tok_lit = literal::tokens_literal(tokens, &[b, s])?;
                let tok_buf = rt.to_device(&tok_lit)?;
                let embed = &self.embed_buf.as_ref().unwrap().1;
                let outs = rt.execute_buffers(name, &[&tok_buf, embed])?;
                HostTensor::from_literal(&outs[0], vec![b, s, m.hidden])
            }
        }
    }

    /// Attention prefill across the grid: each device computes its
    /// `(dp, tp)` shard and stores its KV; TP groups partial-sum, DP
    /// groups batch-concat.
    fn attn_prefill_layer(
        &mut self,
        x: &HostTensor,
        l: usize,
        grid: &DeviceGrid,
        m: &TinyModelMeta,
    ) -> Result<HostTensor> {
        let plan = &grid.plan;
        let t = plan.attn.tp;
        let fam = attn_family(&plan.attn);
        let (b, s) = (m.batch, m.prefill_len);
        let bg = b / plan.attn.dp;
        let q_l = m.q_heads / t;
        let kv_l = (m.kv_heads / t).max(1);
        let max_len = m.max_len;

        let t_mod = Instant::now();
        let (outs, per_dev): (Vec<HostTensor>, Vec<f64>) = match self.backend {
            Backend::Host => {
                let roles = &grid.roles;
                map_devices_timed(self.mode, &mut self.devices, |st| {
                    let role = roles[st.device];
                    let xg = x.slice_outer(role.dp_rank * bg, bg);
                    let w = st
                        .shards
                        .get(&(fam.clone(), l))
                        .ok_or_else(|| anyhow!("attn shard not resident"))?;
                    let (out, k, v) = w.attn_prefill(&xg, q_l, kv_l, m.head_dim)?;
                    st.kv[l] = Some(LayerCache {
                        k: pad_cache(&k, max_len),
                        v: pad_cache(&v, max_len),
                    });
                    Ok(out)
                })?
            }
            Backend::Pjrt(rt) => {
                let name = format!("attn_prefill_tp{t}");
                require_artifact(rt, &name)?;
                let mut outs = Vec::with_capacity(self.devices.len());
                for st in &mut self.devices {
                    let role = grid.roles[st.device];
                    // Fixed-shape artifact: run the full-batch program
                    // on a zero-padded sub-batch, keep the group rows.
                    let xg = x.slice_outer(role.dp_rank * bg, bg);
                    let x_pad = pad_outer(&xg, b);
                    let x_lit = x_pad.to_literal()?;
                    let x_buf = rt.to_device(&x_lit)?;
                    let w = st
                        .bufs
                        .get(&(fam.clone(), l))
                        .ok_or_else(|| anyhow!("attn buffers not resident"))?;
                    let mut inputs: Vec<&xla::PjRtBuffer> = vec![&x_buf];
                    inputs.extend(w.iter().map(|(_, bf)| bf));
                    let res = rt.execute_buffers(&name, &inputs)?;
                    let out = HostTensor::from_literal(&res[0], vec![b, s, m.hidden])?
                        .slice_outer(0, bg);
                    let k = HostTensor::from_literal(&res[1], vec![b, s, kv_l, m.head_dim])?;
                    let v = HostTensor::from_literal(&res[2], vec![b, s, kv_l, m.head_dim])?;
                    st.kv[l] = Some(LayerCache {
                        k: pad_cache(&k, max_len),
                        v: pad_cache(&v, max_len),
                    });
                    outs.push(out);
                }
                (outs, Vec::new())
            }
        };
        self.times.attn_s += t_mod.elapsed().as_secs_f64();
        for (d, dt) in per_dev.iter().enumerate() {
            self.times.add_device(d, *dt);
        }
        let t_comb = Instant::now();
        let out = combine_attn(grid, outs);
        self.times.collective_s += t_comb.elapsed().as_secs_f64();
        out
    }

    fn attn_decode_layer(
        &mut self,
        x: &HostTensor,
        l: usize,
        grid: &DeviceGrid,
        m: &TinyModelMeta,
    ) -> Result<HostTensor> {
        let plan = &grid.plan;
        let t = plan.attn.tp;
        let fam = attn_family(&plan.attn);
        let b = m.batch;
        let bg = b / plan.attn.dp;
        let q_l = m.q_heads / t;
        let kv_l = (m.kv_heads / t).max(1);
        let pos = self.pos;

        let t_mod = Instant::now();
        let (outs, per_dev): (Vec<HostTensor>, Vec<f64>) = match self.backend {
            Backend::Host => {
                let roles = &grid.roles;
                map_devices_timed(self.mode, &mut self.devices, |st| {
                    let role = roles[st.device];
                    let xg = x.slice_outer(role.dp_rank * bg, bg);
                    let cache = st.kv[l]
                        .as_mut()
                        .ok_or_else(|| anyhow!("decode before prefill (no KV shard)"))?;
                    let w = st
                        .shards
                        .get(&(fam.clone(), l))
                        .ok_or_else(|| anyhow!("attn shard not resident"))?;
                    w.attn_decode(&xg, &mut cache.k, &mut cache.v, pos, q_l, kv_l, m.head_dim)
                })?
            }
            Backend::Pjrt(rt) => {
                let name = format!("attn_decode_tp{t}");
                require_artifact(rt, &name)?;
                let pos_lit = literal::scalar_i32(pos as i32);
                let pos_buf = rt.to_device(&pos_lit)?;
                let mut outs = Vec::with_capacity(self.devices.len());
                for st in &mut self.devices {
                    let role = grid.roles[st.device];
                    let xg = x.slice_outer(role.dp_rank * bg, bg);
                    let x_pad = pad_outer(&xg, b);
                    let x_lit = x_pad.to_literal()?;
                    let x_buf = rt.to_device(&x_lit)?;
                    let cache = st.kv[l]
                        .as_mut()
                        .ok_or_else(|| anyhow!("decode before prefill (no KV shard)"))?;
                    let k_lit = cache.k.to_literal()?;
                    let v_lit = cache.v.to_literal()?;
                    let k_buf = rt.to_device(&k_lit)?;
                    let v_buf = rt.to_device(&v_lit)?;
                    let w = st
                        .bufs
                        .get(&(fam.clone(), l))
                        .ok_or_else(|| anyhow!("attn buffers not resident"))?;
                    let mut inputs: Vec<&xla::PjRtBuffer> =
                        vec![&x_buf, &k_buf, &v_buf, &pos_buf];
                    inputs.extend(w.iter().map(|(_, bf)| bf));
                    let res = rt.execute_buffers(&name, &inputs)?;
                    let out = HostTensor::from_literal(&res[0], vec![b, 1, m.hidden])?
                        .slice_outer(0, bg);
                    cache.k =
                        HostTensor::from_literal(&res[1], vec![b, m.max_len, kv_l, m.head_dim])?;
                    cache.v =
                        HostTensor::from_literal(&res[2], vec![b, m.max_len, kv_l, m.head_dim])?;
                    outs.push(out);
                }
                (outs, Vec::new())
            }
        };
        self.times.attn_s += t_mod.elapsed().as_secs_f64();
        for (d, dt) in per_dev.iter().enumerate() {
            self.times.add_device(d, *dt);
        }
        let t_comb = Instant::now();
        let out = combine_attn(grid, outs);
        self.times.collective_s += t_comb.elapsed().as_secs_f64();
        out
    }

    /// Expert module across the grid: every device computes its
    /// `(ep, tp)` shard over all tokens; TP ranks partial-sum within
    /// each block, blocks contribution-sum.
    fn expert_layer(
        &mut self,
        x: &HostTensor,
        l: usize,
        grid: &DeviceGrid,
        m: &TinyModelMeta,
        stage: &str,
    ) -> Result<HostTensor> {
        let plan = &grid.plan;
        let fam = expert_family(plan);
        let ep = plan.expert.ep;
        let tokens: usize = x.shape[..2].iter().product();
        let x2 = HostTensor::new(vec![tokens, m.hidden], x.data.clone());

        if self.pipeline_chunks > 1 && matches!(self.backend, Backend::Host) {
            let out = self.expert_layer_chunked(&x2, l, grid, m)?;
            return Ok(HostTensor::new(x.shape.clone(), out.data));
        }

        let t_mod = Instant::now();
        let (outs, per_dev): (Vec<HostTensor>, Vec<f64>) = match self.backend {
            Backend::Host => {
                let top_k = m.top_k;
                map_devices_timed(self.mode, &mut self.devices, |st| {
                    let w = st
                        .shards
                        .get(&(fam.clone(), l))
                        .ok_or_else(|| anyhow!("expert shard not resident"))?;
                    w.expert_module(&x2, ep, top_k)
                })?
            }
            Backend::Pjrt(rt) => {
                // Hybrid EP×TP runs the EP-family artifact (weights
                // inter-padded at upload); pure layouts run exact.
                let name = if ep > 1 {
                    format!("expert_{stage}_ep{ep}")
                } else {
                    format!("expert_{stage}_tp{}", plan.expert.tp)
                };
                require_artifact(rt, &name)?;
                let x_lit = x2.to_literal()?;
                let x_buf = rt.to_device(&x_lit)?;
                let mut outs = Vec::with_capacity(self.devices.len());
                for st in &mut self.devices {
                    let w = st
                        .bufs
                        .get(&(fam.clone(), l))
                        .ok_or_else(|| anyhow!("expert buffers not resident"))?;
                    let mut inputs: Vec<&xla::PjRtBuffer> = vec![&x_buf];
                    inputs.extend(w.iter().map(|(_, bf)| bf));
                    let res = rt.execute_buffers(&name, &inputs)?;
                    outs.push(HostTensor::from_literal(&res[0], vec![tokens, m.hidden])?);
                }
                (outs, Vec::new())
            }
        };
        self.times.expert_s += t_mod.elapsed().as_secs_f64();
        for (d, dt) in per_dev.iter().enumerate() {
            self.times.add_device(d, *dt);
        }

        // Partial-sum within each expert block, then contribution-sum
        // across blocks.
        let t_comb = Instant::now();
        let out = fold_expert(grid, outs)?;
        self.times.collective_s += t_comb.elapsed().as_secs_f64();
        Ok(HostTensor::new(x.shape.clone(), out.data))
    }

    /// Micro-chunk pipelined expert module (host backend, K ≥ 2): the
    /// token rows of `x2 [T, H]` split into K contiguous chunks
    /// ([`collectives::chunk_ranges`]); each chunk's per-device expert
    /// FFN runs through the ranged kernel entry points while the
    /// coordinator folds the *previous* chunk's reduce/combine
    /// collectives. Under [`EngineMode::Parallel`] that fold genuinely
    /// overlaps the next chunk's compute — it runs between spawning and
    /// joining the chunk's device threads inside one `thread::scope`.
    /// (On this shared-memory demo node the dispatch side of the
    /// collective is the no-op broadcast of `x2`, so compute/combine is
    /// the overlap the pipeline realizes.)
    ///
    /// **Why every K is bit-identical to the unchunked path**: each
    /// expert-path kernel is row-independent, so a chunk's per-device
    /// output rows equal the same rows of the full-batch call; each
    /// chunk's combine folds the same operands in the same group member
    /// order on the coordinator; and the chunk outputs are explicit row
    /// ranges stitched by concatenation **in chunk order** — never
    /// zero-padded partials summed together (which would lose `-0.0`
    /// signs). [`EngineMode::Sequential`] runs the same chunk loop
    /// without the overlap and stays the equivalence oracle.
    ///
    /// Chunking is internal to one executor op: the fault clock ticked
    /// once for the op, and every chunk's device pass re-checks the
    /// same stamped verdicts, so fault schedules are unchanged at any
    /// K — a faulted device raises at the op's first chunk, before any
    /// cursor advances.
    ///
    /// Module-time attribution under overlap is **span-based**:
    /// `expert_s` takes each chunk's spawn→join span, `collective_s`
    /// the fold durations. The two can sum to more than wall-clock —
    /// that excess is exactly the overlap the planner's
    /// [`crate::sim::OverlapModel`] calibrates against.
    fn expert_layer_chunked(
        &mut self,
        x2: &HostTensor,
        l: usize,
        grid: &DeviceGrid,
        m: &TinyModelMeta,
    ) -> Result<HostTensor> {
        let plan = &grid.plan;
        let fam = expert_family(plan);
        let ep = plan.expert.ep;
        let top_k = m.top_k;
        let ranges = collectives::chunk_ranges(x2.shape[0], self.pipeline_chunks);
        let mut combined: Vec<HostTensor> = Vec::with_capacity(ranges.len());
        let mut pending: Option<Vec<HostTensor>> = None;
        let mut expert_secs = 0.0f64;
        let mut fold_secs = 0.0f64;
        let mut per_dev = vec![0.0f64; self.devices.len()];
        for &(start, len) in &ranges {
            match self.mode {
                EngineMode::Sequential => {
                    let t0 = Instant::now();
                    let (outs, dts) = map_devices_timed(self.mode, &mut self.devices, |st| {
                        let w = st
                            .shards
                            .get(&(fam.clone(), l))
                            .ok_or_else(|| anyhow!("expert shard not resident"))?;
                        w.expert_module_ranged(x2, ep, top_k, start, len)
                    })?;
                    expert_secs += t0.elapsed().as_secs_f64();
                    for (d, dt) in dts.iter().enumerate() {
                        per_dev[d] += *dt;
                    }
                    let t1 = Instant::now();
                    combined.push(fold_expert(grid, outs)?);
                    fold_secs += t1.elapsed().as_secs_f64();
                }
                EngineMode::Parallel => {
                    let famr = &fam;
                    let t0 = Instant::now();
                    let (outs, dts) = std::thread::scope(|scope| {
                        let handles: Vec<_> = self
                            .devices
                            .iter_mut()
                            .map(|st| {
                                scope.spawn(move || {
                                    fault_check(st)?;
                                    let t = Instant::now();
                                    let w = st
                                        .shards
                                        .get(&(famr.clone(), l))
                                        .ok_or_else(|| anyhow!("expert shard not resident"))?;
                                    let out = w.expert_module_ranged(x2, ep, top_k, start, len)?;
                                    Ok((out, t.elapsed().as_secs_f64()))
                                })
                            })
                            .collect();
                        // The overlap: fold chunk c-1's collectives on
                        // the coordinator while chunk c's device
                        // threads compute. Combine operands and fold
                        // order are untouched — only *when* the fold
                        // runs moves.
                        if let Some(prev) = pending.take() {
                            let tf = Instant::now();
                            combined.push(fold_expert(grid, prev)?);
                            fold_secs += tf.elapsed().as_secs_f64();
                        }
                        let mut outs = Vec::with_capacity(handles.len());
                        let mut dts = Vec::with_capacity(handles.len());
                        for h in handles {
                            let (o, dt) = h
                                .join()
                                .unwrap_or_else(|_| Err(anyhow!("device thread panicked")))?;
                            outs.push(o);
                            dts.push(dt);
                        }
                        Ok::<_, anyhow::Error>((outs, dts))
                    })?;
                    expert_secs += t0.elapsed().as_secs_f64();
                    for (d, dt) in dts.iter().enumerate() {
                        per_dev[d] += *dt;
                    }
                    pending = Some(outs);
                }
            }
        }
        if let Some(prev) = pending.take() {
            let tf = Instant::now();
            combined.push(fold_expert(grid, prev)?);
            fold_secs += tf.elapsed().as_secs_f64();
        }
        self.times.expert_s += expert_secs;
        self.times.collective_s += fold_secs;
        for (d, dt) in per_dev.iter().enumerate() {
            self.times.add_device(d, *dt);
        }
        collectives::concat_chunks(&combined)
    }

    /// Final norm + unembed on the last position. Batch size comes from
    /// `x` (a joiner's slot prefill runs a single row through here).
    fn head(&mut self, x: &HostTensor, m: &TinyModelMeta) -> Result<HostTensor> {
        let (b, h, v) = (x.shape[0], m.hidden, m.vocab);
        let s = x.shape[1];
        let mut last = Vec::with_capacity(b * h);
        for bi in 0..b {
            let base = (bi * s + (s - 1)) * h;
            last.extend_from_slice(&x.data[base..base + h]);
        }
        let last = HostTensor::new(vec![b, h], last);
        match self.backend {
            Backend::Host => match self.kernel_mode {
                KernelMode::Blocked => {
                    if self.packed_head.is_none() {
                        self.packed_head = Some(HeadWeights::new(
                            self.weights.get("ln_f")?,
                            self.weights.get("unembed")?,
                        ));
                    }
                    Ok(kernels::head(&last, self.packed_head.as_ref().unwrap()))
                }
                KernelMode::Reference => Ok(kernels::reference::head(
                    &last,
                    self.weights.get("ln_f")?,
                    self.weights.get("unembed")?,
                )),
            },
            Backend::Pjrt(rt) => {
                require_artifact(rt, "head")?;
                if self.head_bufs.is_none() {
                    let ln_lit = self.weights.get("ln_f")?.to_literal()?;
                    let ln = rt.to_device(&ln_lit)?;
                    let un_lit = self.weights.get("unembed")?.to_literal()?;
                    let un = rt.to_device(&un_lit)?;
                    self.stats.materializations += 1;
                    self.stats.uploaded_floats += h + h * v;
                    self.head_bufs = Some([(ln_lit, ln), (un_lit, un)]);
                }
                let last_lit = last.to_literal()?;
                let last_buf = rt.to_device(&last_lit)?;
                let [(_, ln), (_, un)] = self.head_bufs.as_ref().unwrap();
                let outs = rt.execute_buffers("head", &[&last_buf, ln, un])?;
                HostTensor::from_literal(&outs[0], vec![b, v])
            }
        }
    }
}

/// Shard-family key for an attention layout (shards depend on the TP
/// rank only; DP replicas hold copies of the same shard set).
fn attn_family(a: &AttnStrategy) -> String {
    format!("attn_tp{}", a.tp)
}

/// Shard-family key for an expert layout.
fn expert_family(p: &ShardPlan) -> String {
    format!("expert_ep{}tp{}", p.expert.ep, p.expert.tp)
}

fn require_artifact(rt: &PjrtRuntime, name: &str) -> Result<()> {
    if !rt.has(name) {
        anyhow::bail!(
            "artifact '{name}' not in the loaded set — rebuild artifacts/ (make artifacts) \
             or pick a plan the set covers"
        );
    }
    Ok(())
}

/// Raise a device's stamped fault verdict (if any) as a structured
/// error instead of running its closure — the injection point the
/// engine's recovery state machine classifies on.
fn fault_check(st: &DeviceState) -> Result<()> {
    match &st.fault {
        Some(msg) => Err(anyhow::Error::msg(msg.clone())),
        None => Ok(()),
    }
}

/// Run `f` over every device state — scoped threads in parallel mode,
/// a plain loop in sequential mode. Outputs are returned in device
/// order either way, so downstream combines are order-identical. A
/// device carrying an injected fault verdict errors instead of
/// computing (in both modes, before `f` runs).
fn map_devices<T, F>(mode: EngineMode, states: &mut [DeviceState], f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(&mut DeviceState) -> Result<T> + Sync,
{
    match mode {
        EngineMode::Sequential => states
            .iter_mut()
            .map(|st| fault_check(st).and_then(|_| f(st)))
            .collect(),
        EngineMode::Parallel => std::thread::scope(|scope| {
            let fr = &f;
            let handles: Vec<_> = states
                .iter_mut()
                .map(|st| scope.spawn(move || fault_check(st).and_then(|_| fr(st))))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("device thread panicked")))
                })
                .collect()
        }),
    }
}

/// [`map_devices`] plus per-device in-closure seconds (indexed by
/// device order), for the observability module-time attribution.
fn map_devices_timed<T, F>(
    mode: EngineMode,
    states: &mut [DeviceState],
    f: F,
) -> Result<(Vec<T>, Vec<f64>)>
where
    T: Send,
    F: Fn(&mut DeviceState) -> Result<T> + Sync,
{
    let timed = map_devices(mode, states, |st| {
        let t0 = Instant::now();
        let out = f(st)?;
        Ok((out, t0.elapsed().as_secs_f64()))
    })?;
    Ok(timed.into_iter().unzip())
}

/// Expert-side combine for one token range (a micro-chunk or the whole
/// batch): partial-sum within each expert block, then contribution-sum
/// across blocks — always on the coordinator, in group member order.
fn fold_expert(grid: &DeviceGrid, outs: Vec<HostTensor>) -> Result<HostTensor> {
    let table: Vec<Option<HostTensor>> = outs.into_iter().map(Some).collect();
    let mut leaders: Vec<Option<HostTensor>> = (0..grid.devices).map(|_| None).collect();
    for g in &grid.expert_reduce {
        leaders[g.members[0]] = Some(collectives::apply(g, &table)?);
    }
    collectives::apply(&grid.expert_combine, &leaders)
}

/// Reduce TP partials per DP group, then concat groups over the batch.
fn combine_attn(grid: &DeviceGrid, outs: Vec<HostTensor>) -> Result<HostTensor> {
    let table: Vec<Option<HostTensor>> = outs.into_iter().map(Some).collect();
    let mut leaders: Vec<Option<HostTensor>> = (0..grid.devices).map(|_| None).collect();
    for g in &grid.attn_reduce {
        leaders[g.members[0]] = Some(collectives::apply(g, &table)?);
    }
    collectives::apply(&grid.batch_split, &leaders)
}

/// Pad a [B, S, KVH, D] prefill cache to [B, M, KVH, D] with zeros.
fn pad_cache(c: &HostTensor, max_len: usize) -> HostTensor {
    let (b, s, kvh, d) = (c.shape[0], c.shape[1], c.shape[2], c.shape[3]);
    let mut out = HostTensor::zeros(vec![b, max_len, kvh, d]);
    let row = kvh * d;
    for bi in 0..b {
        let src = bi * s * row;
        let dst = bi * max_len * row;
        out.data[dst..dst + s * row].copy_from_slice(&c.data[src..src + s * row]);
    }
    out
}

/// Zero-pad the leading axis to `rows` (fixed-shape artifact bridging).
fn pad_outer(t: &HostTensor, rows: usize) -> HostTensor {
    let inner: usize = t.shape[1..].iter().product();
    let mut shape = t.shape.clone();
    shape[0] = rows;
    let mut out = HostTensor::zeros(shape);
    out.data[..t.data.len()].copy_from_slice(&t.data);
    out
}

/// Zero-pad a hybrid EP×TP expert shard's intermediate slices back to
/// the EP artifact's full-width shapes. Exact: the padded gate/up
/// columns are zero, so their activations contribute `act·0 = 0` and
/// the padded down rows are zero.
fn pad_expert_for_artifact(
    shard: &[HostTensor],
    inter: usize,
    tp: usize,
    tp_rank: usize,
) -> Vec<HostTensor> {
    if tp == 1 {
        return shard.to_vec();
    }
    // [ln, router, sel, wg, wu, wd] with wg/wu [e_l, H, I/tp], wd
    // [e_l, I/tp, H].
    let mut out = shard[..3].to_vec();
    let wg = &shard[3];
    let (e_l, h, i_l) = (wg.shape[0], wg.shape[1], wg.shape[2]);
    let off = tp_rank * i_l;
    for t in [&shard[3], &shard[4]] {
        let mut p = HostTensor::zeros(vec![e_l, h, inter]);
        for r in 0..e_l * h {
            p.data[r * inter + off..r * inter + off + i_l]
                .copy_from_slice(&t.data[r * i_l..(r + 1) * i_l]);
        }
        out.push(p);
    }
    let wd = &shard[5];
    let mut p = HostTensor::zeros(vec![e_l, inter, h]);
    for e in 0..e_l {
        let dst = (e * inter + off) * h;
        let src = e * i_l * h;
        p.data[dst..dst + i_l * h].copy_from_slice(&wd.data[src..src + i_l * h]);
    }
    out.push(p);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy::ExpertStrategy;

    #[test]
    fn pad_cache_places_rows() {
        let c = HostTensor::new(vec![1, 2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let p = pad_cache(&c, 4);
        assert_eq!(p.shape, vec![1, 4, 1, 2]);
        assert_eq!(p.data, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn plan_labels() {
        let s = ShardPlan::tp(4);
        assert_eq!(s.expert_label(), "TP4");
        let e = ShardPlan::new(AttnStrategy::new(2, 1), ExpertStrategy::new(1, 4));
        assert_eq!(e.expert_label(), "EP4");
    }

    #[test]
    fn families_distinguish_layouts() {
        assert_eq!(attn_family(&AttnStrategy::new(2, 2)), "attn_tp2");
        let hy = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));
        assert_eq!(expert_family(&hy), "expert_ep2tp2");
        assert_eq!(expert_family(&ShardPlan::tp(4)), "expert_ep1tp4");
    }

    #[test]
    fn quant_guards_and_eviction() {
        let m = crate::runtime::TinyModelMeta::host_demo();
        let w = crate::model::WeightStore::synthetic(&m, 1);
        let mut exec = ModelExecutor::host_with_mode(w, EngineMode::Sequential);
        let plan = ShardPlan::tp(4);
        exec.begin_batch(&plan, &plan).unwrap();
        let f32_bytes = exec.resident_weight_bytes();
        assert!(f32_bytes > 0);
        exec.set_quant(Some(QuantKind::Int8)).unwrap();
        assert_eq!(exec.resident_weight_bytes(), 0, "quant change evicts resident shards");
        exec.begin_batch(&plan, &plan).unwrap();
        let q_bytes = exec.resident_weight_bytes();
        assert!(q_bytes < f32_bytes, "int8 shards must shrink: {q_bytes} vs {f32_bytes}");
        assert!(
            exec.set_kernel_mode(KernelMode::Reference).is_err(),
            "reference kernels reject quantized shards"
        );
        exec.set_quant(None).unwrap();
        exec.set_kernel_mode(KernelMode::Reference).unwrap();
        assert!(exec.set_quant(Some(QuantKind::Int4)).is_err());
    }

    #[test]
    fn reference_mode_matches_blocked_tokens() {
        let m = crate::runtime::TinyModelMeta::host_demo();
        let plan = ShardPlan::tp(4);
        let toks: Vec<i32> = (0..(m.batch * m.prefill_len) as i32)
            .map(|i| i % m.vocab as i32)
            .collect();
        let run = |mode: KernelMode| -> Vec<f32> {
            let w = crate::model::WeightStore::synthetic(&m, 1);
            let mut exec = ModelExecutor::host_with_mode(w, EngineMode::Sequential);
            exec.set_kernel_mode(mode).unwrap();
            let mut out = exec.prefill(&toks, &plan).unwrap().data;
            out.extend(exec.decode_step(&vec![1; m.batch], &plan).unwrap().data);
            out
        };
        let blocked = run(KernelMode::Blocked);
        let reference = run(KernelMode::Reference);
        let eq = blocked
            .iter()
            .zip(&reference)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(eq, "blocked and reference executors must emit bit-identical logits");
    }

    #[test]
    fn session_slot_lifecycle_and_guards() {
        let m = crate::runtime::TinyModelMeta::host_demo();
        let w = crate::model::WeightStore::synthetic(&m, 1);
        let mut exec = ModelExecutor::host_with_mode(w, EngineMode::Sequential);
        let plan = ShardPlan::tp(4);
        assert!(exec.claim_slot().is_none(), "no session yet");
        exec.begin_session(&plan, &plan).unwrap();
        assert!(exec.in_session());
        assert_eq!(exec.free_slots(), m.batch);
        let s0 = exec.claim_slot().unwrap();
        assert_eq!(s0, 0);
        assert_eq!(exec.free_slots(), m.batch - 1);
        let toks: Vec<i32> = (0..m.prefill_len as i32).collect();
        // Decode before the slot's prefill is rejected.
        assert!(exec.decode_slots(&vec![0; m.batch], &plan).is_err());
        let logits = exec.prefill_slot(s0, &toks, &plan).unwrap();
        assert_eq!(logits.shape, vec![1, m.vocab]);
        assert!(exec.prefill_slot(s0, &toks, &plan).is_err(), "double prefill");
        assert_eq!(exec.slot_positions()[s0], m.prefill_len);
        exec.decode_slots(&vec![1; m.batch], &plan).unwrap();
        assert_eq!(exec.slot_positions()[s0], m.prefill_len + 1);
        exec.release_slot(s0).unwrap();
        assert!(exec.release_slot(s0).is_err(), "double release");
        assert_eq!(exec.free_slots(), m.batch);
        // Resumable chunked prefill: the cursor advances per chunk, a
        // mid-prefill slot is skipped by decode, and the final chunk
        // makes it decodable.
        let s1 = exec.claim_slot().unwrap();
        exec.prefill_slot(s1, &toks[..6], &plan).unwrap();
        assert_eq!(exec.slot_positions()[s1], 6);
        exec.decode_slots(&vec![1; m.batch], &plan).unwrap();
        assert_eq!(exec.slot_positions()[s1], 6, "mid-prefill slot must not decode");
        assert!(
            exec.prefill_slot(s1, &toks, &plan).is_err(),
            "chunk overrunning the prompt must be rejected"
        );
        let logits = exec.prefill_slot(s1, &toks[6..], &plan).unwrap();
        assert_eq!(logits.shape, vec![1, m.vocab]);
        assert_eq!(exec.slot_positions()[s1], m.prefill_len);
        exec.decode_slots(&vec![1; m.batch], &plan).unwrap();
        assert_eq!(exec.slot_positions()[s1], m.prefill_len + 1);
        exec.release_slot(s1).unwrap();
        // Gang prefill tears the session down.
        exec.prefill(&vec![1; m.batch * m.prefill_len], &plan).unwrap();
        assert!(!exec.in_session());
        assert!(exec.claim_slot().is_none());
    }

    #[test]
    fn pipelined_expert_layer_bit_identical() {
        let m = crate::runtime::TinyModelMeta::host_demo();
        let plan = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));
        let toks: Vec<i32> = (0..(m.batch * m.prefill_len) as i32)
            .map(|i| i % m.vocab as i32)
            .collect();
        let run = |mode: EngineMode, k: usize| -> Vec<f32> {
            let w = crate::model::WeightStore::synthetic(&m, 1);
            let mut exec = ModelExecutor::host_with_mode(w, mode);
            exec.set_pipeline_chunks(k).unwrap();
            let mut out = exec.prefill(&toks, &plan).unwrap().data;
            out.extend(exec.decode_step(&vec![1; m.batch], &plan).unwrap().data);
            out
        };
        let oracle = run(EngineMode::Sequential, 1);
        for k in [2, 3, 5, 8, 1000] {
            for mode in [EngineMode::Sequential, EngineMode::Parallel] {
                let got = run(mode, k);
                let eq = oracle.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(eq, "K={k} {mode:?} must match the unchunked sequential oracle");
            }
        }
        let w = crate::model::WeightStore::synthetic(&m, 1);
        let mut exec = ModelExecutor::host_with_mode(w, EngineMode::Sequential);
        assert!(exec.set_pipeline_chunks(0).is_err(), "K=0 is rejected");
    }

    #[test]
    fn batched_prefill_slots_match_single_slot_calls() {
        let m = crate::runtime::TinyModelMeta::host_demo();
        let plan = ShardPlan::tp(4);
        let rows: Vec<Vec<i32>> = (0..3)
            .map(|s| (0..m.prefill_len as i32).map(|i| (i * 7 + s) % m.vocab as i32).collect())
            .collect();
        let single = {
            let w = crate::model::WeightStore::synthetic(&m, 1);
            let mut exec = ModelExecutor::host_with_mode(w, EngineMode::Sequential);
            exec.begin_session(&plan, &plan).unwrap();
            let mut logits = Vec::new();
            for row in &rows {
                let slot = exec.claim_slot().unwrap();
                exec.prefill_slot(slot, &row[..6], &plan).unwrap();
                logits.push(exec.prefill_slot(slot, &row[6..], &plan).unwrap().data);
            }
            logits
        };
        let batched = {
            let w = crate::model::WeightStore::synthetic(&m, 1);
            let mut exec = ModelExecutor::host_with_mode(w, EngineMode::Sequential);
            exec.set_pipeline_chunks(3).unwrap();
            exec.begin_session(&plan, &plan).unwrap();
            let slots: Vec<usize> = rows.iter().map(|_| exec.claim_slot().unwrap()).collect();
            let first: Vec<&[i32]> = rows.iter().map(|r| &r[..6]).collect();
            exec.prefill_slots(&slots, &first, &plan).unwrap();
            let rest: Vec<&[i32]> = rows.iter().map(|r| &r[6..]).collect();
            let out = exec.prefill_slots(&slots, &rest, &plan).unwrap();
            out.into_iter().map(|t| t.data).collect::<Vec<_>>()
        };
        for (a, b) in single.iter().zip(&batched) {
            let eq = a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "batched same-range prefill must match per-slot calls bit-for-bit");
        }
        // Guards: mismatched cursors and duplicate slots are rejected.
        let w = crate::model::WeightStore::synthetic(&m, 1);
        let mut exec = ModelExecutor::host_with_mode(w, EngineMode::Sequential);
        exec.begin_session(&plan, &plan).unwrap();
        let s0 = exec.claim_slot().unwrap();
        let s1 = exec.claim_slot().unwrap();
        exec.prefill_slot(s0, &rows[0][..6], &plan).unwrap();
        let chunks: Vec<&[i32]> = vec![&rows[0][6..12], &rows[1][..6]];
        assert!(exec.prefill_slots(&[s0, s1], &chunks, &plan).is_err(), "cursor mismatch");
        let dup: Vec<&[i32]> = vec![&rows[0][6..12], &rows[0][6..12]];
        assert!(exec.prefill_slots(&[s0, s0], &dup, &plan).is_err(), "duplicate slot");
    }

    #[test]
    fn pad_expert_round_trips_slice() {
        // [e_l=1, h=2, i_l=2] slice of inter=4, tp_rank 1 → columns 2..4.
        let ln = HostTensor::new(vec![2], vec![1.0; 2]);
        let router = HostTensor::new(vec![2, 2], vec![0.0; 4]);
        let sel = HostTensor::new(vec![1, 2], vec![1.0, 0.0]);
        let wg = HostTensor::new(vec![1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let wd = HostTensor::new(vec![1, 2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let padded =
            pad_expert_for_artifact(&[ln, router, sel, wg.clone(), wg, wd], 4, 2, 1);
        assert_eq!(padded[3].shape, vec![1, 2, 4]);
        assert_eq!(padded[3].data, vec![0.0, 0.0, 1.0, 2.0, 0.0, 0.0, 3.0, 4.0]);
        assert_eq!(padded[5].shape, vec![1, 4, 2]);
        assert_eq!(
            padded[5].data,
            vec![0.0, 0.0, 0.0, 0.0, 5.0, 6.0, 7.0, 8.0]
        );
    }
}
