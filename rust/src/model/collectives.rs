//! Host-side collectives for the device grid: the combines the demo
//! node performs between per-device module calls.
//!
//! Every combine is **order-deterministic**: reductions fold member
//! outputs in the group's member order, concatenations stack them in
//! member order. Per-device compute may therefore run in parallel
//! threads while the combined result stays bit-identical to the
//! sequential reference path — the combine itself always runs on the
//! coordinating thread over the same operands in the same order.

use crate::model::grid::{CollectiveGroup, GroupKind};
use crate::runtime::literal::HostTensor;
use crate::Result;

/// Element-wise sum of tensors in the given order (TP partial-sum and
/// EP contribution-sum are both plain sums; their distinction is which
/// shards produced the operands).
pub fn sum_in_order(parts: &[&HostTensor]) -> Result<HostTensor> {
    let first = parts
        .first()
        .ok_or_else(|| anyhow::anyhow!("reduce over empty group"))?;
    let mut acc = (*first).clone();
    for p in &parts[1..] {
        if p.shape != acc.shape {
            anyhow::bail!("reduce shape mismatch: {:?} vs {:?}", p.shape, acc.shape);
        }
        acc.add_assign(p);
    }
    Ok(acc)
}

/// Concatenate along the leading (batch) axis in the given order; all
/// trailing dimensions must agree.
pub fn concat_rows(parts: &[&HostTensor]) -> Result<HostTensor> {
    let first = parts
        .first()
        .ok_or_else(|| anyhow::anyhow!("concat over empty group"))?;
    let tail = &first.shape[1..];
    let mut rows = 0usize;
    let mut data = Vec::new();
    for p in parts {
        if &p.shape[1..] != tail {
            anyhow::bail!("concat shape mismatch: {:?} vs {:?}", p.shape, first.shape);
        }
        rows += p.shape[0];
        data.extend_from_slice(&p.data);
    }
    let mut shape = vec![rows];
    shape.extend_from_slice(tail);
    Ok(HostTensor::new(shape, data))
}

/// Apply a collective group to the per-device output table (`outs[d]`
/// holds device `d`'s module output). Reductions sum members in order;
/// batch-split concatenates them in order.
pub fn apply(group: &CollectiveGroup, outs: &[Option<HostTensor>]) -> Result<HostTensor> {
    let mut parts = Vec::with_capacity(group.members.len());
    for &d in &group.members {
        let t = outs
            .get(d)
            .and_then(|o| o.as_ref())
            .ok_or_else(|| anyhow::anyhow!("collective member {d} produced no output"))?;
        parts.push(t);
    }
    match group.kind {
        GroupKind::PartialSum | GroupKind::ContributionSum => sum_in_order(&parts),
        GroupKind::BatchSplit => concat_rows(&parts),
    }
}

/// Split `tokens` rows into `chunks` contiguous `(start, len)` ranges
/// for the executor's micro-chunk pipeline. Remainder rows go to the
/// leading chunks one at a time, so any two calls with the same inputs
/// produce the same ranges and every token appears in exactly one
/// chunk. `chunks` is clamped to `[1, tokens]` (a zero-token batch
/// yields one empty range so callers need no special case).
pub fn chunk_ranges(tokens: usize, chunks: usize) -> Vec<(usize, usize)> {
    let k = chunks.clamp(1, tokens.max(1));
    let (base, rem) = (tokens / k, tokens % k);
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for c in 0..k {
        let len = base + usize::from(c < rem);
        out.push((start, len));
        start += len;
    }
    out
}

/// Stitch per-chunk combined outputs (each `[len_c, ..tail]`) back into
/// the full-batch tensor by concatenating **in chunk order**. Chunk
/// outputs are explicit row ranges — never zero-padded partials summed
/// together, which would lose `-0.0` signs — so the stitched tensor is
/// byte-identical to the unchunked combine.
pub fn concat_chunks(parts: &[HostTensor]) -> Result<HostTensor> {
    let refs: Vec<&HostTensor> = parts.iter().collect();
    concat_rows(&refs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        HostTensor::new(shape, data)
    }

    #[test]
    fn sum_folds_in_member_order() {
        let a = t(vec![2], vec![1.0, 2.0]);
        let b = t(vec![2], vec![10.0, 20.0]);
        let s = sum_in_order(&[&a, &b]).unwrap();
        assert_eq!(s.data, vec![11.0, 22.0]);
        assert!(sum_in_order(&[]).is_err());
    }

    #[test]
    fn concat_stacks_leading_axis() {
        let a = t(vec![1, 2], vec![1.0, 2.0]);
        let b = t(vec![2, 2], vec![3.0, 4.0, 5.0, 6.0]);
        let c = concat_rows(&[&a, &b]).unwrap();
        assert_eq!(c.shape, vec![3, 2]);
        assert_eq!(c.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let bad = t(vec![1, 3], vec![0.0; 3]);
        assert!(concat_rows(&[&a, &bad]).is_err());
    }

    #[test]
    fn chunk_ranges_cover_every_token_once() {
        for tokens in 0..40usize {
            for chunks in 1..10usize {
                let ranges = chunk_ranges(tokens, chunks);
                assert!(!ranges.is_empty());
                let mut next = 0usize;
                for &(start, len) in &ranges {
                    assert_eq!(start, next);
                    next += len;
                }
                assert_eq!(next, tokens);
                if tokens > 0 {
                    assert_eq!(ranges.len(), chunks.min(tokens));
                    let lens: Vec<usize> = ranges.iter().map(|r| r.1).collect();
                    let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                    assert!(max - min <= 1, "chunks must be balanced: {lens:?}");
                }
            }
        }
    }

    #[test]
    fn concat_chunks_matches_concat_rows() {
        let parts = vec![t(vec![1, 2], vec![1.0, -0.0]), t(vec![2, 2], vec![3.0, 4.0, 5.0, 6.0])];
        let c = concat_chunks(&parts).unwrap();
        assert_eq!(c.shape, vec![3, 2]);
        assert_eq!(c.data[1].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn apply_respects_group_kind_and_members() {
        let outs = vec![
            Some(t(vec![1, 2], vec![1.0, 1.0])),
            Some(t(vec![1, 2], vec![2.0, 2.0])),
            None,
        ];
        let red = CollectiveGroup { kind: GroupKind::PartialSum, members: vec![0, 1] };
        assert_eq!(apply(&red, &outs).unwrap().data, vec![3.0, 3.0]);
        let cat = CollectiveGroup { kind: GroupKind::BatchSplit, members: vec![1, 0] };
        // Member order controls stacking order.
        assert_eq!(apply(&cat, &outs).unwrap().data, vec![2.0, 2.0, 1.0, 1.0]);
        let missing = CollectiveGroup { kind: GroupKind::PartialSum, members: vec![2] };
        assert!(apply(&missing, &outs).is_err());
    }
}
