//! Weight storage and generic shard slicing — mirrors `model.py`'s
//! `shard_*` layout contract (validated end-to-end by
//! `rust/tests/runtime_e2e.rs` against the jax reference outputs), now
//! generalized to the full EP×TP expert grid.
//!
//! One entry point, [`WeightStore::shard`], serves every device role:
//! - `ShardSpec::Attn { tp, rank }` — TP head shard (DP replicas reuse
//!   the same shard for every `dp_rank`);
//! - `ShardSpec::Expert { ep, tp, ep_rank, tp_rank }` — EP block of
//!   whole experts, TP-sliced along the intermediate dim *within* the
//!   block. `ep == 1` degenerates to pure TP (no selection matrix),
//!   `tp == 1` to pure EP, and the general case is the hybrid grid.

use crate::model::kernels;
use crate::runtime::literal::HostTensor;
use crate::runtime::{Manifest, TinyModelMeta};
use crate::util::rng::Rng;
use crate::Result;
use anyhow::anyhow;
use std::collections::HashMap;

/// Which shard of which layer a device role needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardSpec {
    /// Attention TP shard `rank` of `tp` for one layer:
    /// `[ln, wq, wk, wv, wo]` in artifact input order. Q/O shard by
    /// query head; K/V by kv head (replicated when `tp > kv_heads`).
    Attn { layer: usize, tp: usize, rank: usize },
    /// Expert shard `(ep_rank, tp_rank)` of the `ep × tp` grid:
    /// `[ln, router, wg, wu, wd]` when `ep == 1`, else
    /// `[ln, router, sel, wg, wu, wd]` with `sel: [E/ep, E]`.
    Expert { layer: usize, ep: usize, tp: usize, ep_rank: usize, tp_rank: usize },
}

/// All model weights, resident on host, addressable by name.
pub struct WeightStore {
    pub meta: TinyModelMeta,
    tensors: HashMap<String, HostTensor>,
}

impl WeightStore {
    /// Build from the manifest's weight table + raw f32 blob.
    pub fn from_blob(manifest: &Manifest, blob: &[f32]) -> Result<WeightStore> {
        let mut tensors = HashMap::new();
        for w in &manifest.weights {
            let n = w.elements();
            let end = w.offset_floats + n;
            if end > blob.len() {
                anyhow::bail!("weight {} extends past blob ({} > {})", w.name, end, blob.len());
            }
            tensors.insert(
                w.name.clone(),
                HostTensor::new(w.shape.clone(), blob[w.offset_floats..end].to_vec()),
            );
        }
        Ok(WeightStore { meta: manifest.model.clone(), tensors })
    }

    /// Seeded synthetic weights for a given model shape — the same
    /// distribution `model.py::init_weights` uses (ones for norms,
    /// N(0, 0.02) for matmuls). Lets the host-backend engine, tests,
    /// and benches run without `artifacts/`.
    pub fn synthetic(meta: &TinyModelMeta, seed: u64) -> WeightStore {
        fn mat(
            rng: &mut Rng,
            tensors: &mut HashMap<String, HostTensor>,
            name: String,
            shape: Vec<usize>,
        ) {
            let n: usize = shape.iter().product();
            tensors.insert(name, HostTensor::new(shape, rng.normal_vec_f32(n, 0.02)));
        }
        let mut rng = Rng::new(seed);
        let mut tensors = HashMap::new();
        let (h, hd, v) = (meta.hidden, meta.head_dim, meta.vocab);
        let (e, i) = (meta.num_experts, meta.inter);
        mat(&mut rng, &mut tensors, "embed".into(), vec![v, h]);
        for l in 0..meta.layers {
            tensors.insert(format!("layer{l}.ln1"), HostTensor::new(vec![h], vec![1.0; h]));
            mat(&mut rng, &mut tensors, format!("layer{l}.wq"), vec![h, meta.q_heads * hd]);
            mat(&mut rng, &mut tensors, format!("layer{l}.wk"), vec![h, meta.kv_heads * hd]);
            mat(&mut rng, &mut tensors, format!("layer{l}.wv"), vec![h, meta.kv_heads * hd]);
            mat(&mut rng, &mut tensors, format!("layer{l}.wo"), vec![meta.q_heads * hd, h]);
            tensors.insert(format!("layer{l}.ln2"), HostTensor::new(vec![h], vec![1.0; h]));
            mat(&mut rng, &mut tensors, format!("layer{l}.router"), vec![h, e]);
            mat(&mut rng, &mut tensors, format!("layer{l}.wg"), vec![e, h, i]);
            mat(&mut rng, &mut tensors, format!("layer{l}.wu"), vec![e, h, i]);
            mat(&mut rng, &mut tensors, format!("layer{l}.wd"), vec![e, i, h]);
        }
        tensors.insert("ln_f".into(), HostTensor::new(vec![h], vec![1.0; h]));
        mat(&mut rng, &mut tensors, "unembed".into(), vec![h, v]);
        WeightStore { meta: meta.clone(), tensors }
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("missing weight '{name}'"))
    }

    /// Replace an existing weight tensor in place (same name, same
    /// shape). Used by tests/benches to pin weights to exact-round-trip
    /// quantization grids; the shape check keeps the store consistent
    /// with its manifest metadata.
    pub fn replace(&mut self, name: &str, tensor: HostTensor) -> Result<()> {
        let old = self.tensors.get(name).ok_or_else(|| anyhow!("missing weight '{name}'"))?;
        if old.shape != tensor.shape {
            anyhow::bail!(
                "replace '{name}': shape {:?} does not match existing {:?}",
                tensor.shape,
                old.shape
            );
        }
        self.tensors.insert(name.to_string(), tensor);
        Ok(())
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.tensors.values().map(|t| t.elements()).sum()
    }

    /// Slice the shard a device role needs (see [`ShardSpec`]).
    pub fn shard(&self, spec: &ShardSpec) -> Result<Vec<HostTensor>> {
        match *spec {
            ShardSpec::Attn { layer, tp, rank } => self.shard_attn(layer, tp, rank),
            ShardSpec::Expert { layer, ep, tp, ep_rank, tp_rank } => {
                self.shard_expert(layer, ep, tp, ep_rank, tp_rank)
            }
        }
    }

    /// Slice **and pack** a device role's shard into the blocked
    /// host-kernel layout ([`kernels::ShardWeights`]), optionally
    /// storing the matmul weights as int8/int4 per-group quantized
    /// codes dequantized on the fly inside the packed matmul. This is
    /// the storage the host executor caches per resident shard.
    pub fn shard_packed(
        &self,
        spec: &ShardSpec,
        quant: Option<crate::quant::QuantKind>,
    ) -> Result<kernels::ShardWeights> {
        let tensors = self.shard(spec)?;
        match *spec {
            ShardSpec::Attn { .. } => {
                Ok(kernels::ShardWeights::Attn(kernels::AttnWeights::from_shard(&tensors, quant)?))
            }
            ShardSpec::Expert { ep, .. } => Ok(kernels::ShardWeights::Expert(
                kernels::ExpertWeights::from_shard(&tensors, ep, quant)?,
            )),
        }
    }

    /// Attention TP shard: Q/O shard by query head; K/V by kv head
    /// (`tp ≤ kv_heads`), replicated per the GQA mapping beyond that.
    fn shard_attn(&self, l: usize, t: usize, d: usize) -> Result<Vec<HostTensor>> {
        let m = &self.meta;
        if t == 0 || m.q_heads % t != 0 || d >= t {
            anyhow::bail!("bad attention shard tp{t} rank {d} for {} heads", m.q_heads);
        }
        let hd = m.head_dim;
        let hq_l = m.q_heads / t;
        let kv_l = (m.kv_heads / t).max(1);
        let h = m.hidden;

        let ln = self.get(&format!("layer{l}.ln1"))?.clone();
        // wq stored [H, q_heads*hd]: take head columns [d*hq_l, (d+1)*hq_l).
        let wq = slice_head_cols(self.get(&format!("layer{l}.wq"))?, h, m.q_heads, hd, d * hq_l, hq_l);
        // KV heads shard when t ≤ kv_heads; beyond that each device
        // replicates the kv head its query heads map to (GQA).
        let kv_start = if t <= m.kv_heads { d * kv_l } else { d / (t / m.kv_heads) };
        let wk = slice_head_cols(self.get(&format!("layer{l}.wk"))?, h, m.kv_heads, hd, kv_start, kv_l);
        let wv = slice_head_cols(self.get(&format!("layer{l}.wv"))?, h, m.kv_heads, hd, kv_start, kv_l);
        // wo stored [q_heads*hd, H]: take head *rows*.
        let wo_full = self.get(&format!("layer{l}.wo"))?;
        let row_start = d * hq_l * hd;
        let rows = hq_l * hd;
        let wo = HostTensor::new(
            vec![rows, h],
            wo_full.data[row_start * h..(row_start + rows) * h].to_vec(),
        );
        Ok(vec![ln, wq, wk, wv, wo])
    }

    /// Expert grid shard: EP block `ep_rank` of whole experts, with the
    /// intermediate dim TP-sliced to `[tp_rank·I/tp, (tp_rank+1)·I/tp)`
    /// within the block.
    fn shard_expert(
        &self,
        l: usize,
        ep: usize,
        t: usize,
        ep_rank: usize,
        tp_rank: usize,
    ) -> Result<Vec<HostTensor>> {
        let m = &self.meta;
        let (h, e, i) = (m.hidden, m.num_experts, m.inter);
        if ep == 0 || t == 0 || e % ep != 0 || i % t != 0 || ep_rank >= ep || tp_rank >= t {
            anyhow::bail!("bad expert shard ep{ep}r{ep_rank} tp{t}r{tp_rank} for E={e} I={i}");
        }
        let e_l = e / ep;
        let i_l = i / t;
        let ln = self.get(&format!("layer{l}.ln2"))?.clone();
        let router = self.get(&format!("layer{l}.router"))?.clone();

        // Expert block [ep_rank·e_l, (ep_rank+1)·e_l), then the inter
        // slice within each owned expert.
        let wg_full = self.get(&format!("layer{l}.wg"))?;
        let wu_full = self.get(&format!("layer{l}.wu"))?;
        let wd_full = self.get(&format!("layer{l}.wd"))?;
        let e0 = ep_rank * e_l;
        // wg/wu [E, H, I] → block rows, slice last axis.
        let block_slice_last = |t_full: &HostTensor| -> HostTensor {
            let mut data = Vec::with_capacity(e_l * h * i_l);
            for ei in e0..e0 + e_l {
                for r in 0..h {
                    let base = (ei * h + r) * i + tp_rank * i_l;
                    data.extend_from_slice(&t_full.data[base..base + i_l]);
                }
            }
            HostTensor::new(vec![e_l, h, i_l], data)
        };
        let wg = block_slice_last(wg_full);
        let wu = block_slice_last(wu_full);
        // wd [E, I, H] → block rows, slice middle axis (rows of each
        // expert block).
        let mut wd_data = Vec::with_capacity(e_l * i_l * h);
        for ei in e0..e0 + e_l {
            let base = ei * i * h + tp_rank * i_l * h;
            wd_data.extend_from_slice(&wd_full.data[base..base + i_l * h]);
        }
        let wd = HostTensor::new(vec![e_l, i_l, h], wd_data);

        if ep == 1 {
            // Pure TP keeps the tp-artifact layout (no selection).
            Ok(vec![ln, router, wg, wu, wd])
        } else {
            // Selection matrix [e_l, E] picking the block's experts.
            let mut sel = vec![0.0f32; e_l * e];
            for j in 0..e_l {
                sel[j * e + e0 + j] = 1.0;
            }
            Ok(vec![ln, router, HostTensor::new(vec![e_l, e], sel), wg, wu, wd])
        }
    }

    /// Expert-module weights of one layer as flat f32 (for quantized
    /// backup in the transition demo).
    pub fn expert_layer_flat(&self, l: usize) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for name in ["wg", "wu", "wd"] {
            out.extend_from_slice(&self.get(&format!("layer{l}.{name}"))?.data);
        }
        Ok(out)
    }
}

/// Slice head-blocked columns: tensor [rows, heads*hd] → [rows, n*hd]
/// taking heads [start, start+n).
fn slice_head_cols(
    t: &HostTensor,
    rows: usize,
    heads: usize,
    hd: usize,
    start: usize,
    n: usize,
) -> HostTensor {
    let cols = heads * hd;
    assert_eq!(t.shape, vec![rows, cols]);
    let mut data = Vec::with_capacity(rows * n * hd);
    for r in 0..rows {
        let base = r * cols + start * hd;
        data.extend_from_slice(&t.data[base..base + n * hd]);
    }
    HostTensor::new(vec![rows, n * hd], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn tiny_manifest() -> Manifest {
        // Minimal manifest for a 1-layer miniature (h=4, heads=2, kv=1,
        // hd=2, E=2, I=4, V=8).
        Manifest::parse(
            r#"{
          "model": {"batch": 1, "prefill_len": 4, "max_len": 8, "hidden": 4,
                    "q_heads": 2, "kv_heads": 1, "head_dim": 2,
                    "num_experts": 2, "top_k": 1, "inter": 4, "vocab": 8,
                    "layers": 1},
          "weights_file": "weights.bin",
          "weights": [
            {"name": "embed", "shape": [8, 4], "offset_floats": 0},
            {"name": "layer0.ln1", "shape": [4], "offset_floats": 32},
            {"name": "layer0.wq", "shape": [4, 4], "offset_floats": 36},
            {"name": "layer0.wk", "shape": [4, 2], "offset_floats": 52},
            {"name": "layer0.wv", "shape": [4, 2], "offset_floats": 60},
            {"name": "layer0.wo", "shape": [4, 4], "offset_floats": 68},
            {"name": "layer0.ln2", "shape": [4], "offset_floats": 84},
            {"name": "layer0.router", "shape": [4, 2], "offset_floats": 88},
            {"name": "layer0.wg", "shape": [2, 4, 4], "offset_floats": 96},
            {"name": "layer0.wu", "shape": [2, 4, 4], "offset_floats": 128},
            {"name": "layer0.wd", "shape": [2, 4, 4], "offset_floats": 160},
            {"name": "ln_f", "shape": [4], "offset_floats": 192},
            {"name": "unembed", "shape": [4, 8], "offset_floats": 196}
          ],
          "entries": []
        }"#,
        )
        .unwrap()
    }

    fn store() -> WeightStore {
        let m = tiny_manifest();
        let blob: Vec<f32> = (0..228).map(|i| i as f32).collect();
        WeightStore::from_blob(&m, &blob).unwrap()
    }

    fn attn(s: &WeightStore, tp: usize, rank: usize) -> Vec<HostTensor> {
        s.shard(&ShardSpec::Attn { layer: 0, tp, rank }).unwrap()
    }

    fn expert(s: &WeightStore, ep: usize, tp: usize, er: usize, tr: usize) -> Vec<HostTensor> {
        s.shard(&ShardSpec::Expert { layer: 0, ep, tp, ep_rank: er, tp_rank: tr }).unwrap()
    }

    #[test]
    fn loads_all_weights() {
        let s = store();
        assert_eq!(s.num_params(), 228);
        assert_eq!(s.get("layer0.wq").unwrap().shape, vec![4, 4]);
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn attn_shards_partition_columns() {
        let s = store();
        let full = attn(&s, 1, 0);
        let d0 = attn(&s, 2, 0);
        let d1 = attn(&s, 2, 1);
        // wq (index 1): [4,4] split into [4,2]+[4,2] by head columns.
        assert_eq!(d0[1].shape, vec![4, 2]);
        for r in 0..4 {
            assert_eq!(d0[1].data[r * 2..r * 2 + 2], full[1].data[r * 4..r * 4 + 2]);
            assert_eq!(d1[1].data[r * 2..r * 2 + 2], full[1].data[r * 4 + 2..r * 4 + 4]);
        }
        // wo (index 4): rows split.
        assert_eq!(d0[4].shape, vec![2, 4]);
        assert_eq!(d0[4].data[..], full[4].data[..8]);
        assert_eq!(d1[4].data[..], full[4].data[8..]);
    }

    #[test]
    fn expert_tp_shards_slice_inter() {
        let s = store();
        let full = expert(&s, 1, 1, 0, 0);
        let d0 = expert(&s, 1, 2, 0, 0);
        let d1 = expert(&s, 1, 2, 0, 1);
        assert_eq!(d0[2].shape, vec![2, 4, 2]); // wg [E, H, I/2]
        // First row of expert 0: full wg row is [0..4) of that row.
        assert_eq!(d0[2].data[0..2], full[2].data[0..2]);
        assert_eq!(d1[2].data[0..2], full[2].data[2..4]);
        // wd rows: [E, I/2, H].
        assert_eq!(d0[4].shape, vec![2, 2, 4]);
        assert_eq!(d0[4].data[0..8], full[4].data[0..8]);
        assert_eq!(d1[4].data[0..8], full[4].data[8..16]);
    }

    #[test]
    fn expert_ep_shards_take_expert_blocks() {
        let s = store();
        let d0 = expert(&s, 2, 1, 0, 0);
        let d1 = expert(&s, 2, 1, 1, 0);
        let full_wg = s.get("layer0.wg").unwrap();
        // wg index 3 in [ln, router, sel, wg, wu, wd].
        assert_eq!(d0[3].shape, vec![1, 4, 4]);
        assert_eq!(d0[3].data[..], full_wg.data[..16]);
        assert_eq!(d1[3].data[..], full_wg.data[16..]);
        // sel matrices select disjoint experts.
        assert_eq!(d0[2].data, vec![1.0, 0.0]);
        assert_eq!(d1[2].data, vec![0.0, 1.0]);
    }

    #[test]
    fn hybrid_shards_block_then_slice() {
        // EP2×TP2 on the miniature: device (ep_rank 1, tp_rank 1) holds
        // expert 1's inter columns [2, 4).
        let s = store();
        let hy = expert(&s, 2, 2, 1, 1);
        assert_eq!(hy.len(), 6);
        assert_eq!(hy[3].shape, vec![1, 4, 2]); // wg [E/2, H, I/2]
        let full_wg = s.get("layer0.wg").unwrap();
        // Expert 1's wg rows live at data[16..32]; columns 2..4 of each
        // 4-wide row.
        for r in 0..4 {
            assert_eq!(hy[3].data[r * 2..r * 2 + 2], full_wg.data[16 + r * 4 + 2..16 + r * 4 + 4]);
        }
        // wd [E/2, I/2, H]: expert 1 rows 2..4.
        let full_wd = s.get("layer0.wd").unwrap();
        assert_eq!(hy[5].shape, vec![1, 2, 4]);
        assert_eq!(hy[5].data[..], full_wd.data[16 + 8..16 + 16]);
        // Selection matrix still picks expert 1.
        assert_eq!(hy[2].data, vec![0.0, 1.0]);
    }

    #[test]
    fn bad_specs_rejected() {
        let s = store();
        assert!(s.shard(&ShardSpec::Attn { layer: 0, tp: 3, rank: 0 }).is_err());
        assert!(s.shard(&ShardSpec::Attn { layer: 0, tp: 2, rank: 2 }).is_err());
        assert!(s
            .shard(&ShardSpec::Expert { layer: 0, ep: 3, tp: 1, ep_rank: 0, tp_rank: 0 })
            .is_err());
        assert!(s
            .shard(&ShardSpec::Expert { layer: 0, ep: 2, tp: 2, ep_rank: 0, tp_rank: 2 })
            .is_err());
    }

    #[test]
    fn shard_packed_matches_raw_shard() {
        let s = store();
        let spec = ShardSpec::Expert { layer: 0, ep: 2, tp: 2, ep_rank: 1, tp_rank: 0 };
        let raw = s.shard(&spec).unwrap();
        match s.shard_packed(&spec, None).unwrap() {
            kernels::ShardWeights::Expert(w) => {
                assert_eq!(w.wg.len(), 1);
                assert_eq!(w.wg[0].dequantized(), raw[3].data);
                assert_eq!(w.sel.as_ref().unwrap().data, raw[2].data);
            }
            kernels::ShardWeights::Attn(_) => panic!("expected expert shard"),
        }
        let aspec = ShardSpec::Attn { layer: 0, tp: 2, rank: 1 };
        let araw = s.shard(&aspec).unwrap();
        match s.shard_packed(&aspec, None).unwrap() {
            kernels::ShardWeights::Attn(w) => {
                assert_eq!(w.wq.dequantized(), araw[1].data);
                assert_eq!(w.wo.dequantized(), araw[4].data);
            }
            kernels::ShardWeights::Expert(_) => panic!("expected attention shard"),
        }
    }

    #[test]
    fn replace_checks_shape() {
        let mut s = store();
        assert!(s.replace("ln_f", HostTensor::new(vec![5], vec![0.0; 5])).is_err());
        assert!(s.replace("nope", HostTensor::new(vec![4], vec![0.0; 4])).is_err());
        s.replace("ln_f", HostTensor::new(vec![4], vec![2.0; 4])).unwrap();
        assert_eq!(s.get("ln_f").unwrap().data, vec![2.0; 4]);
    }

    #[test]
    fn synthetic_weights_have_model_shapes() {
        let meta = crate::runtime::TinyModelMeta::host_demo();
        let s = WeightStore::synthetic(&meta, 7);
        assert_eq!(s.get("embed").unwrap().shape, vec![meta.vocab, meta.hidden]);
        assert_eq!(
            s.get("layer0.wg").unwrap().shape,
            vec![meta.num_experts, meta.hidden, meta.inter]
        );
        assert_eq!(s.get("ln_f").unwrap().data, vec![1.0; meta.hidden]);
        // Deterministic per seed.
        let s2 = WeightStore::synthetic(&meta, 7);
        assert_eq!(s.get("layer1.wd").unwrap().data, s2.get("layer1.wd").unwrap().data);
    }
}
