//! Weight storage and shard slicing — mirrors `model.py`'s `shard_*`
//! layout contract exactly (validated end-to-end by
//! `rust/tests/runtime_e2e.rs` against the jax reference outputs).

use crate::runtime::literal::HostTensor;
use crate::runtime::{Manifest, TinyModelMeta};
use crate::Result;
use anyhow::anyhow;
use std::collections::HashMap;

/// All model weights, resident on host, addressable by name.
pub struct WeightStore {
    pub meta: TinyModelMeta,
    tensors: HashMap<String, HostTensor>,
}

impl WeightStore {
    /// Build from the manifest's weight table + raw f32 blob.
    pub fn from_blob(manifest: &Manifest, blob: &[f32]) -> Result<WeightStore> {
        let mut tensors = HashMap::new();
        for w in &manifest.weights {
            let n = w.elements();
            let end = w.offset_floats + n;
            if end > blob.len() {
                anyhow::bail!("weight {} extends past blob ({} > {})", w.name, end, blob.len());
            }
            tensors.insert(
                w.name.clone(),
                HostTensor::new(w.shape.clone(), blob[w.offset_floats..end].to_vec()),
            );
        }
        Ok(WeightStore { meta: manifest.model.clone(), tensors })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("missing weight '{name}'"))
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.tensors.values().map(|t| t.elements()).sum()
    }

    /// Attention TP shard `d` of `t` for layer `l`:
    /// `[ln, wq, wk, wv, wo]` in artifact input order.
    ///
    /// Q/O shard by query head; K/V by kv head (t ≤ kv_heads).
    pub fn shard_attn(&self, l: usize, t: usize, d: usize) -> Result<Vec<HostTensor>> {
        let m = &self.meta;
        let hd = m.head_dim;
        let hq_l = m.q_heads / t;
        let kv_l = (m.kv_heads / t).max(1);
        let h = m.hidden;

        let ln = self.get(&format!("layer{l}.ln1"))?.clone();
        // wq stored [H, q_heads*hd]: take head columns [d*hq_l, (d+1)*hq_l).
        let wq = slice_head_cols(self.get(&format!("layer{l}.wq"))?, h, m.q_heads, hd, d * hq_l, hq_l);
        // KV heads shard when t ≤ kv_heads; beyond that each device
        // replicates the kv head its query heads map to (GQA).
        let kv_start = if t <= m.kv_heads { d * kv_l } else { d / (t / m.kv_heads) };
        let wk = slice_head_cols(self.get(&format!("layer{l}.wk"))?, h, m.kv_heads, hd, kv_start, kv_l);
        let wv = slice_head_cols(self.get(&format!("layer{l}.wv"))?, h, m.kv_heads, hd, kv_start, kv_l);
        // wo stored [q_heads*hd, H]: take head *rows*.
        let wo_full = self.get(&format!("layer{l}.wo"))?;
        let row_start = d * hq_l * hd;
        let rows = hq_l * hd;
        let wo = HostTensor::new(
            vec![rows, h],
            wo_full.data[row_start * h..(row_start + rows) * h].to_vec(),
        );
        Ok(vec![ln, wq, wk, wv, wo])
    }

    /// Expert TP shard: `[ln, router, wg, wu, wd]` with inter sliced.
    pub fn shard_expert_tp(&self, l: usize, t: usize, d: usize) -> Result<Vec<HostTensor>> {
        let m = &self.meta;
        let (h, e, i) = (m.hidden, m.num_experts, m.inter);
        let i_l = i / t;
        let ln = self.get(&format!("layer{l}.ln2"))?.clone();
        let router = self.get(&format!("layer{l}.router"))?.clone();
        // wg/wu [E, H, I] → slice last axis.
        let wg = slice_last_axis(self.get(&format!("layer{l}.wg"))?, e * h, i, d * i_l, i_l);
        let wu = slice_last_axis(self.get(&format!("layer{l}.wu"))?, e * h, i, d * i_l, i_l);
        // wd [E, I, H] → slice middle axis = rows of each expert block.
        let wd_full = self.get(&format!("layer{l}.wd"))?;
        let mut wd_data = Vec::with_capacity(e * i_l * h);
        for ei in 0..e {
            let base = ei * i * h + d * i_l * h;
            wd_data.extend_from_slice(&wd_full.data[base..base + i_l * h]);
        }
        let wg = HostTensor::new(vec![e, h, i_l], wg.data);
        let wu = HostTensor::new(vec![e, h, i_l], wu.data);
        let wd = HostTensor::new(vec![e, i_l, h], wd_data);
        Ok(vec![ln, router, wg, wu, wd])
    }

    /// Expert EP shard: `[ln, router, sel, wg, wu, wd]` — device `d` of
    /// `ep` owns the contiguous expert block `[d·E/ep, (d+1)·E/ep)`.
    pub fn shard_expert_ep(&self, l: usize, ep: usize, d: usize) -> Result<Vec<HostTensor>> {
        let m = &self.meta;
        let (h, e, i) = (m.hidden, m.num_experts, m.inter);
        let e_l = e / ep;
        let ln = self.get(&format!("layer{l}.ln2"))?.clone();
        let router = self.get(&format!("layer{l}.router"))?.clone();
        // Selection matrix [e_l, E].
        let mut sel = vec![0.0f32; e_l * e];
        for j in 0..e_l {
            sel[j * e + d * e_l + j] = 1.0;
        }
        let sel = HostTensor::new(vec![e_l, e], sel);
        let take_block = |t: &HostTensor, per_expert: usize| -> HostTensor {
            let start = d * e_l * per_expert;
            HostTensor::new(
                {
                    let mut s = t.shape.clone();
                    s[0] = e_l;
                    s
                },
                t.data[start..start + e_l * per_expert].to_vec(),
            )
        };
        let wg = take_block(self.get(&format!("layer{l}.wg"))?, h * i);
        let wu = take_block(self.get(&format!("layer{l}.wu"))?, h * i);
        let wd = take_block(self.get(&format!("layer{l}.wd"))?, i * h);
        Ok(vec![ln, router, sel, wg, wu, wd])
    }

    /// Expert-module weights of one layer as flat f32 (for quantized
    /// backup in the transition demo).
    pub fn expert_layer_flat(&self, l: usize) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for name in ["wg", "wu", "wd"] {
            out.extend_from_slice(&self.get(&format!("layer{l}.{name}"))?.data);
        }
        Ok(out)
    }
}

/// Slice head-blocked columns: tensor [rows, heads*hd] → [rows, n*hd]
/// taking heads [start, start+n).
fn slice_head_cols(
    t: &HostTensor,
    rows: usize,
    heads: usize,
    hd: usize,
    start: usize,
    n: usize,
) -> HostTensor {
    let cols = heads * hd;
    assert_eq!(t.shape, vec![rows, cols]);
    let mut data = Vec::with_capacity(rows * n * hd);
    for r in 0..rows {
        let base = r * cols + start * hd;
        data.extend_from_slice(&t.data[base..base + n * hd]);
    }
    HostTensor::new(vec![rows, n * hd], data)
}

/// Slice the last axis of a tensor flattened as [outer, last]:
/// takes [start, start+n) of `last` for every outer row.
fn slice_last_axis(t: &HostTensor, outer: usize, last: usize, start: usize, n: usize) -> HostTensor {
    assert_eq!(t.elements(), outer * last);
    let mut data = Vec::with_capacity(outer * n);
    for r in 0..outer {
        let base = r * last + start;
        data.extend_from_slice(&t.data[base..base + n]);
    }
    HostTensor::new(vec![outer, n], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn tiny_manifest() -> Manifest {
        // Minimal manifest for a 1-layer miniature (h=4, heads=2, kv=1,
        // hd=2, E=2, I=4, V=8).
        Manifest::parse(
            r#"{
          "model": {"batch": 1, "prefill_len": 4, "max_len": 8, "hidden": 4,
                    "q_heads": 2, "kv_heads": 1, "head_dim": 2,
                    "num_experts": 2, "top_k": 1, "inter": 4, "vocab": 8,
                    "layers": 1},
          "weights_file": "weights.bin",
          "weights": [
            {"name": "embed", "shape": [8, 4], "offset_floats": 0},
            {"name": "layer0.ln1", "shape": [4], "offset_floats": 32},
            {"name": "layer0.wq", "shape": [4, 4], "offset_floats": 36},
            {"name": "layer0.wk", "shape": [4, 2], "offset_floats": 52},
            {"name": "layer0.wv", "shape": [4, 2], "offset_floats": 60},
            {"name": "layer0.wo", "shape": [4, 4], "offset_floats": 68},
            {"name": "layer0.ln2", "shape": [4], "offset_floats": 84},
            {"name": "layer0.router", "shape": [4, 2], "offset_floats": 88},
            {"name": "layer0.wg", "shape": [2, 4, 4], "offset_floats": 96},
            {"name": "layer0.wu", "shape": [2, 4, 4], "offset_floats": 128},
            {"name": "layer0.wd", "shape": [2, 4, 4], "offset_floats": 160},
            {"name": "ln_f", "shape": [4], "offset_floats": 192},
            {"name": "unembed", "shape": [4, 8], "offset_floats": 196}
          ],
          "entries": []
        }"#,
        )
        .unwrap()
    }

    fn store() -> WeightStore {
        let m = tiny_manifest();
        let blob: Vec<f32> = (0..228).map(|i| i as f32).collect();
        WeightStore::from_blob(&m, &blob).unwrap()
    }

    #[test]
    fn loads_all_weights() {
        let s = store();
        assert_eq!(s.num_params(), 228);
        assert_eq!(s.get("layer0.wq").unwrap().shape, vec![4, 4]);
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn attn_shards_partition_columns() {
        let s = store();
        let full = s.shard_attn(0, 1, 0).unwrap();
        let d0 = s.shard_attn(0, 2, 0).unwrap();
        let d1 = s.shard_attn(0, 2, 1).unwrap();
        // wq (index 1): [4,4] split into [4,2]+[4,2] by head columns.
        assert_eq!(d0[1].shape, vec![4, 2]);
        for r in 0..4 {
            assert_eq!(d0[1].data[r * 2..r * 2 + 2], full[1].data[r * 4..r * 4 + 2]);
            assert_eq!(d1[1].data[r * 2..r * 2 + 2], full[1].data[r * 4 + 2..r * 4 + 4]);
        }
        // wo (index 4): rows split.
        assert_eq!(d0[4].shape, vec![2, 4]);
        assert_eq!(d0[4].data[..], full[4].data[..8]);
        assert_eq!(d1[4].data[..], full[4].data[8..]);
    }

    #[test]
    fn expert_tp_shards_slice_inter() {
        let s = store();
        let full = s.shard_expert_tp(0, 1, 0).unwrap();
        let d0 = s.shard_expert_tp(0, 2, 0).unwrap();
        let d1 = s.shard_expert_tp(0, 2, 1).unwrap();
        assert_eq!(d0[2].shape, vec![2, 4, 2]); // wg [E, H, I/2]
        // First row of expert 0: full wg row is [0..4) of that row.
        assert_eq!(d0[2].data[0..2], full[2].data[0..2]);
        assert_eq!(d1[2].data[0..2], full[2].data[2..4]);
        // wd rows: [E, I/2, H].
        assert_eq!(d0[4].shape, vec![2, 2, 4]);
        assert_eq!(d0[4].data[0..8], full[4].data[0..8]);
        assert_eq!(d1[4].data[0..8], full[4].data[8..16]);
    }

    #[test]
    fn expert_ep_shards_take_expert_blocks() {
        let s = store();
        let d0 = s.shard_expert_ep(0, 2, 0).unwrap();
        let d1 = s.shard_expert_ep(0, 2, 1).unwrap();
        let full_wg = s.get("layer0.wg").unwrap();
        // wg index 3 in [ln, router, sel, wg, wu, wd].
        assert_eq!(d0[3].shape, vec![1, 4, 4]);
        assert_eq!(d0[3].data[..], full_wg.data[..16]);
        assert_eq!(d1[3].data[..], full_wg.data[16..]);
        // sel matrices select disjoint experts.
        assert_eq!(d0[2].data, vec![1.0, 0.0]);
        assert_eq!(d1[2].data, vec![0.0, 1.0]);
    }
}
