//! Deterministic device-fault injection for the grid engine.
//!
//! A [`FaultPlan`] is a *seeded schedule* of [`DeviceFault`]s keyed by
//! `(device, iteration)`, where an **iteration** is one executor
//! compute op — one `prefill`, `decode_step`, `prefill_slot`, or
//! `decode_slots` call. The host backend ticks the plan once per op
//! and stamps the resulting per-device verdicts into its device
//! states; `map_devices` then surfaces a stamped verdict as a
//! structured error *before* running the device closure. There are no
//! wall clocks and no run-time randomness anywhere on this path, so
//! every failure mode — and every recovery the serving engine performs
//! in response — is bit-reproducible in tests and benches.
//!
//! Three failure modes:
//!
//! - [`DeviceFault::Crash`] — the device is permanently lost from the
//!   scheduled iteration on. The engine responds with degraded
//!   re-planning (see `serving::engine`).
//! - [`DeviceFault::Stall { iters }`] — the device fails every op for
//!   `iters` iterations, then recovers. Each engine retry advances the
//!   fault clock by one op, so a bounded retry loop rides out the
//!   stall without requeueing work.
//! - [`DeviceFault::Transient { fail_n }`] — the next `fail_n` ops on
//!   the device fail, then succeed. Absorbed the same way.
//!
//! Fault errors carry a machine-readable prefix
//! (`fault[crash] device 2 at iter 5`) because the vendored error
//! shim has no downcasting; [`classify`] recovers the [`FaultKind`]
//! from any error chain that crossed a faulted device.

use crate::util::rng::Rng;
use crate::Result;
use std::collections::BTreeMap;

/// A scheduled failure mode for one device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceFault {
    /// Permanent loss from the scheduled iteration on.
    Crash,
    /// Every op fails for `iters` iterations, then the device recovers.
    Stall { iters: usize },
    /// The next `fail_n` ops fail, then succeed.
    Transient { fail_n: usize },
}

/// One schedule entry: `fault` fires on `device` when the plan's op
/// counter reaches `iter` (1-based: the first executor op is iter 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub device: usize,
    pub iter: u64,
    pub fault: DeviceFault,
}

/// The verdict a device carries for the current op — what the engine's
/// recovery state machine dispatches on. `Stall` and `Transient` are
/// retryable; `Crash` is terminal for the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Crash,
    Stall,
    Transient,
}

impl FaultKind {
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Stall => "stall",
            FaultKind::Transient => "transient",
        }
    }

    /// Whether bounded retry can absorb this fault without degrading.
    pub fn retryable(&self) -> bool {
        !matches!(self, FaultKind::Crash)
    }
}

/// The structured message `map_devices` raises for a faulted device.
/// The `fault[kind]` prefix is the classification contract — see
/// [`classify`].
pub fn fault_message(kind: FaultKind, device: usize, iter: u64) -> String {
    format!("fault[{}] device {} at iter {}", kind.label(), device, iter)
}

/// Recover the fault kind from an error chain, if any link in it is a
/// structured fault message. The vendored `anyhow` shim stores errors
/// as rendered strings, so prefix matching over the chain is the
/// downcast.
pub fn classify(err: &anyhow::Error) -> Option<FaultKind> {
    for msg in err.chain() {
        let Some(rest) = msg.strip_prefix("fault[") else {
            continue;
        };
        if rest.starts_with("crash]") {
            return Some(FaultKind::Crash);
        }
        if rest.starts_with("stall]") {
            return Some(FaultKind::Stall);
        }
        if rest.starts_with("transient]") {
            return Some(FaultKind::Transient);
        }
    }
    None
}

/// Recover the faulted device id from a structured fault message in
/// the error chain (`fault[kind] device D at iter K`) — used when an
/// exhausted retry budget promotes a stalling device to "lost".
pub fn faulted_device(err: &anyhow::Error) -> Option<usize> {
    for msg in err.chain() {
        if !msg.starts_with("fault[") {
            continue;
        }
        if let Some(rest) = msg.split("device ").nth(1) {
            let id: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if let Ok(d) = id.parse() {
                return Some(d);
            }
        }
    }
    None
}

/// A deterministic fault schedule plus its run-time activation state.
///
/// The executor drives it through [`FaultPlan::tick`] — once per
/// compute op — and reads back per-device verdicts for that op. All
/// state transitions are keyed on the op counter; replaying the same
/// workload under the same plan reproduces the same faults at the same
/// ops.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    schedule: Vec<FaultEvent>,
    /// Op counter (1-based after the first tick).
    iter: u64,
    /// Permanently lost devices (sorted, deduped).
    crashed: Vec<usize>,
    /// Stalled devices → last stalled iteration (inclusive).
    stalled: BTreeMap<usize, u64>,
    /// Transiently failing devices → remaining ops to fail.
    transient: BTreeMap<usize, usize>,
}

impl FaultPlan {
    pub fn new(mut schedule: Vec<FaultEvent>) -> FaultPlan {
        // Activation scans the schedule in order; sort so the plan's
        // behavior is independent of event-list authoring order.
        schedule.sort_by_key(|e| (e.iter, e.device));
        FaultPlan { schedule, ..FaultPlan::default() }
    }

    /// Parse a compact fault-trace string: comma-separated events, each
    /// `KIND@ITER[@dDEV]` with `KIND` one of `crash`, `stall<N>`
    /// (stall for N iterations), `transient<N>` (fail the next N ops).
    /// The device defaults to 0. Examples: `crash@3`,
    /// `stall2@5@d1`, `transient1@4,crash@9@d2`.
    pub fn parse_trace(trace: &str) -> Result<FaultPlan> {
        let mut schedule = Vec::new();
        for part in trace.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let mut fields = part.split('@');
            let kind = fields.next().unwrap_or("");
            let iter: u64 = fields
                .next()
                .ok_or_else(|| anyhow::anyhow!("fault event '{part}' missing '@iter'"))?
                .parse()
                .map_err(|_| anyhow::anyhow!("fault event '{part}': bad iteration"))?;
            let device = match fields.next() {
                None => 0usize,
                Some(d) => d
                    .strip_prefix('d')
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| {
                        anyhow::anyhow!("fault event '{part}': device must be 'd<N>'")
                    })?,
            };
            if iter == 0 {
                anyhow::bail!("fault event '{part}': iterations are 1-based");
            }
            let fault = if kind == "crash" {
                DeviceFault::Crash
            } else if let Some(n) = kind.strip_prefix("stall") {
                let iters: usize = n
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault event '{part}': stall<N> needs N"))?;
                DeviceFault::Stall { iters }
            } else if let Some(n) = kind.strip_prefix("transient") {
                let fail_n: usize = n
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault event '{part}': transient<N> needs N"))?;
                DeviceFault::Transient { fail_n }
            } else {
                anyhow::bail!(
                    "fault event '{part}': unknown kind '{kind}' (crash|stall<N>|transient<N>)"
                );
            };
            schedule.push(FaultEvent { device, iter, fault });
        }
        if schedule.is_empty() {
            anyhow::bail!("empty fault trace");
        }
        Ok(FaultPlan::new(schedule))
    }

    /// Deterministic pseudo-random schedule: `events` faults drawn over
    /// the first `horizon` iterations of an `n_devices` grid from a
    /// seeded generator. Same seed → same schedule, always.
    pub fn seeded(seed: u64, n_devices: usize, horizon: u64, events: usize) -> FaultPlan {
        let mut rng = Rng::new(seed);
        let schedule = (0..events)
            .map(|_| {
                let fault = match rng.below(3) {
                    0 => DeviceFault::Crash,
                    1 => DeviceFault::Stall { iters: rng.range(1, 3) },
                    _ => DeviceFault::Transient { fail_n: rng.range(1, 2) },
                };
                FaultEvent {
                    device: rng.below(n_devices.max(1)),
                    iter: 1 + rng.below(horizon.max(1) as usize) as u64,
                    fault,
                }
            })
            .collect();
        FaultPlan::new(schedule)
    }

    /// Advance to the next executor op and return the per-device fault
    /// verdicts for it (`verdicts[d]` = what device `d` suffers this
    /// op, `None` = healthy). One call = one compute op; `Transient`
    /// budgets are consumed here, once per op.
    pub fn tick(&mut self, n_devices: usize) -> Vec<Option<FaultKind>> {
        self.iter += 1;
        for i in 0..self.schedule.len() {
            let ev = self.schedule[i];
            if ev.iter != self.iter {
                continue;
            }
            match ev.fault {
                DeviceFault::Crash => {
                    if !self.crashed.contains(&ev.device) {
                        self.crashed.push(ev.device);
                        self.crashed.sort_unstable();
                    }
                }
                DeviceFault::Stall { iters } => {
                    self.stalled.insert(ev.device, self.iter + iters.max(1) as u64 - 1);
                }
                DeviceFault::Transient { fail_n } => {
                    self.transient.insert(ev.device, fail_n.max(1));
                }
            }
        }
        let mut verdicts = vec![None; n_devices];
        for (d, v) in verdicts.iter_mut().enumerate() {
            if self.crashed.contains(&d) {
                *v = Some(FaultKind::Crash);
                continue;
            }
            if let Some(&until) = self.stalled.get(&d) {
                if self.iter <= until {
                    *v = Some(FaultKind::Stall);
                    continue;
                }
            }
            if let Some(rem) = self.transient.get_mut(&d) {
                if *rem > 0 {
                    *rem -= 1;
                    *v = Some(FaultKind::Transient);
                }
            }
        }
        self.stalled.retain(|_, until| self.iter < *until);
        self.transient.retain(|_, rem| *rem > 0);
        verdicts
    }

    /// Current op counter (0 before the first tick).
    pub fn iteration(&self) -> u64 {
        self.iter
    }

    /// Permanently lost devices (logical ids of the grid the plan ran
    /// against), sorted.
    pub fn crashed(&self) -> &[usize] {
        &self.crashed
    }

    pub fn any_crashed(&self) -> bool {
        !self.crashed.is_empty()
    }

    /// Renumber for a degraded grid of `n_devices` survivors: the
    /// executor rebuilds logical devices `0..n_devices`, so the crashed
    /// set is forgotten and pending events that target out-of-range
    /// devices or already-passed iterations are dropped. The op counter
    /// keeps running (determinism: one clock per run).
    pub fn compact_for(&mut self, n_devices: usize) {
        self.crashed.clear();
        self.stalled.clear();
        self.transient.clear();
        let iter = self.iter;
        self.schedule.retain(|e| e.device < n_devices && e.iter > iter);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_trace_grammar() {
        let p = FaultPlan::parse_trace("crash@3").unwrap();
        assert_eq!(
            p.schedule,
            vec![FaultEvent { device: 0, iter: 3, fault: DeviceFault::Crash }]
        );
        let p = FaultPlan::parse_trace("stall2@5@d1, transient1@4").unwrap();
        assert_eq!(
            p.schedule,
            vec![
                FaultEvent { device: 0, iter: 4, fault: DeviceFault::Transient { fail_n: 1 } },
                FaultEvent { device: 1, iter: 5, fault: DeviceFault::Stall { iters: 2 } },
            ]
        );
        assert!(FaultPlan::parse_trace("").is_err());
        assert!(FaultPlan::parse_trace("crash").is_err());
        assert!(FaultPlan::parse_trace("crash@0").is_err());
        assert!(FaultPlan::parse_trace("melt@3").is_err());
        assert!(FaultPlan::parse_trace("crash@3@x1").is_err());
    }

    #[test]
    fn crash_is_permanent_and_stall_expires() {
        let mut p = FaultPlan::parse_trace("crash@2@d1,stall2@2@d0").unwrap();
        assert_eq!(p.tick(2), vec![None, None]); // iter 1
        assert_eq!(p.tick(2), vec![Some(FaultKind::Stall), Some(FaultKind::Crash)]); // 2
        assert_eq!(p.tick(2), vec![Some(FaultKind::Stall), Some(FaultKind::Crash)]); // 3
        assert_eq!(p.tick(2), vec![None, Some(FaultKind::Crash)]); // 4: stall over
        assert_eq!(p.crashed(), &[1]);
        assert!(p.any_crashed());
    }

    #[test]
    fn transient_budget_is_consumed_per_op() {
        let mut p = FaultPlan::parse_trace("transient2@1").unwrap();
        assert_eq!(p.tick(1), vec![Some(FaultKind::Transient)]);
        assert_eq!(p.tick(1), vec![Some(FaultKind::Transient)]);
        assert_eq!(p.tick(1), vec![None]);
        assert!(!p.any_crashed());
    }

    #[test]
    fn classify_round_trips_through_error_chains() {
        for kind in [FaultKind::Crash, FaultKind::Stall, FaultKind::Transient] {
            let e = anyhow::Error::msg(fault_message(kind, 2, 5)).context("decode step");
            assert_eq!(classify(&e), Some(kind), "{kind:?} lost in the chain");
            assert_eq!(faulted_device(&e), Some(2));
        }
        assert_eq!(classify(&anyhow::anyhow!("plain failure")), None);
        assert_eq!(faulted_device(&anyhow::anyhow!("plain failure")), None);
    }

    #[test]
    fn seeded_schedules_are_reproducible() {
        let a = FaultPlan::seeded(0xFA17, 4, 20, 6);
        let b = FaultPlan::seeded(0xFA17, 4, 20, 6);
        assert_eq!(a.schedule, b.schedule);
        assert_eq!(a.schedule.len(), 6);
        assert!(a.schedule.iter().all(|e| e.device < 4 && (1..=20).contains(&e.iter)));
        let c = FaultPlan::seeded(0xFA18, 4, 20, 6);
        assert_ne!(a.schedule, c.schedule, "seed must matter");
    }

    #[test]
    fn compact_for_drops_stale_and_out_of_range_events() {
        let mut p = FaultPlan::parse_trace("crash@1@d3,crash@5@d2,crash@9@d1").unwrap();
        p.tick(4); // iter 1: d3 crashes
        assert_eq!(p.crashed(), &[3]);
        p.compact_for(2); // degraded to devices {0, 1}
        assert!(!p.any_crashed());
        assert_eq!(
            p.schedule,
            vec![FaultEvent { device: 1, iter: 9, fault: DeviceFault::Crash }],
            "d2 event out of range and past events must be dropped"
        );
        // The clock keeps running across the degrade.
        assert_eq!(p.iteration(), 1);
        for _ in 0..7 {
            p.tick(2);
        }
        assert_eq!(p.tick(2), vec![None, Some(FaultKind::Crash)]); // iter 9
    }
}
