//! Dynamic parallelism-transition strategy (paper §III-D, eq. 6).
//!
//! Switching the Expert module's strategy between prefill and decode
//! requires redistributing ~90% of model weights. The paper offers two
//! mechanisms and picks per-transition via simulation:
//!
//! 1. **Reshard** — move shards over the interconnect with collectives
//!    (cost `T_reshard`);
//! 2. **INT4 backup** — an INT4-quantized copy of expert weights lives
//!    in CPU memory; each device uploads its *new* shard over PCIe and
//!    dequantizes on-device. Upload/dequant overlap with the last layers
//!    of prefill via multi-stream pipelines, so only the part exceeding
//!    the prefill compute time is charged:
//!
//! ```text
//! C_ij = min{ T_reshard,
//!             max{0, T_upload + T_dequant − (Sₖᵀ·T_a + E_i·T_e + T_Cₖᵢ)} }   (6)
//! ```
//!
//! A `V_dequant → T_dequant` dictionary (bucketed by upload volume, as
//! the paper builds per GPU count) provides the dequant term.

use crate::config::{hardware::GpuSpec, model::MoEModelConfig};
use crate::sim::comm::{self, CommEvent};
use crate::sim::latency::LatencyModel;
use crate::strategy::ExpertStrategy;

/// Which mechanism a transition uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransitionMethod {
    /// Same strategy in both stages — nothing to do.
    None,
    /// Collective-based weight redistribution.
    Reshard,
    /// INT4 CPU backup upload + on-device dequantization.
    Int4Backup,
}

impl TransitionMethod {
    pub fn name(self) -> &'static str {
        match self {
            TransitionMethod::None => "none",
            TransitionMethod::Reshard => "reshard",
            TransitionMethod::Int4Backup => "int4-backup",
        }
    }

    pub fn from_name(name: &str) -> Option<TransitionMethod> {
        match name {
            "none" => Some(TransitionMethod::None),
            "reshard" => Some(TransitionMethod::Reshard),
            "int4-backup" => Some(TransitionMethod::Int4Backup),
            _ => None,
        }
    }
}

/// Cost breakdown of one candidate transition.
#[derive(Debug, Clone, Copy)]
pub struct TransitionCost {
    pub method: TransitionMethod,
    /// Wall-clock overhead charged to the end-to-end latency (seconds).
    pub overhead: f64,
    /// Un-overlapped upload+dequant time (diagnostics).
    pub raw_pipeline: f64,
    /// Reshard alternative (diagnostics).
    pub reshard: f64,
}

impl TransitionCost {
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj(vec![
            ("method", self.method.name().into()),
            ("overhead", self.overhead.into()),
            ("raw_pipeline", self.raw_pipeline.into()),
            ("reshard", self.reshard.into()),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> Option<TransitionCost> {
        Some(TransitionCost {
            method: TransitionMethod::from_name(j.get("method")?.as_str()?)?,
            overhead: j.get("overhead")?.as_f64()?,
            raw_pipeline: j.get("raw_pipeline")?.as_f64()?,
            reshard: j.get("reshard")?.as_f64()?,
        })
    }
}

/// Throughput of the fused INT4 dequant kernel, elements/second —
/// matches the L1 Pallas `dequant` kernel's modeled rate: it is
/// bandwidth-bound (read 0.5 B + write 2 B per element ≈ 2.5 B/elem).
pub fn dequant_rate(gpu: &GpuSpec) -> f64 {
    gpu.hbm_bw * 0.6 / 2.5
}

/// The `V_dequant → T_dequant` dictionary (paper: keyed by volume per
/// GPU count, queried at runtime). Bucketed by power-of-two volume.
#[derive(Debug, Clone)]
pub struct DequantTable {
    /// (elements_upper_bound, seconds) pairs, ascending.
    entries: Vec<(f64, f64)>,
}

impl DequantTable {
    /// Build for a platform by sweeping volumes through the rate model.
    pub fn build(gpu: &GpuSpec) -> DequantTable {
        let rate = dequant_rate(gpu);
        let mut entries = Vec::new();
        let mut v = 1e6f64;
        while v <= 1e12 {
            entries.push((v, v / rate + 20e-6));
            v *= 2.0;
        }
        DequantTable { entries }
    }

    /// Query dequant time for `elements` (ceil to the next bucket, as a
    /// dictionary lookup would).
    pub fn lookup(&self, elements: f64) -> f64 {
        for &(bound, t) in &self.entries {
            if elements <= bound {
                return t;
            }
        }
        self.entries.last().map(|&(_, t)| t).unwrap_or(0.0) * (elements / 1e12)
    }
}

/// Transition-cost calculator for one (model, platform) pair.
pub struct TransitionModel<'a> {
    pub model: &'a MoEModelConfig,
    pub gpu: &'a GpuSpec,
    pub dequant_table: DequantTable,
}

impl<'a> TransitionModel<'a> {
    pub fn new(model: &'a MoEModelConfig, gpu: &'a GpuSpec) -> Self {
        TransitionModel { model, gpu, dequant_table: DequantTable::build(gpu) }
    }

    /// T_reshard: redistribute expert shards via collectives.
    pub fn reshard_time(
        &self,
        lm: &LatencyModel,
        from: &ExpertStrategy,
        to: &ExpertStrategy,
    ) -> f64 {
        let wire = comm::reshard_wire_bytes(self.model, from, to);
        if wire == 0.0 {
            return 0.0;
        }
        let n = from.devices();
        let event = CommEvent {
            collective: comm::Collective::AllGather,
            group: n,
            wire_bytes: wire,
            rounds: n - 1,
            label: "reshard",
        };
        lm.comm_time(&event)
    }

    /// T_upload: per-device INT4 shard upload over PCIe (0.5 B/elem +
    /// group parameters ≈ ×1.07).
    pub fn upload_time(&self, to: &ExpertStrategy) -> f64 {
        let elems = self.shard_elements(to);
        let bytes = elems * 0.5 * 1.07;
        bytes / self.gpu.h2d_bw
    }

    /// T_dequant via the dictionary.
    pub fn dequant_time(&self, to: &ExpertStrategy) -> f64 {
        self.dequant_table.lookup(self.shard_elements(to))
    }

    /// Expert-weight elements per device under a strategy.
    fn shard_elements(&self, s: &ExpertStrategy) -> f64 {
        (self.model.layers * self.model.expert_params_per_layer()) as f64 / s.devices() as f64
    }

    /// Eq. 6's minimum for one (from, to) pair, given precomputed
    /// T_reshard and T_upload+T_dequant.
    fn decide(reshard: f64, raw_pipeline: f64, prefill_stage_time: f64) -> TransitionCost {
        let overlapped = (raw_pipeline - prefill_stage_time).max(0.0);
        if reshard <= overlapped {
            TransitionCost { method: TransitionMethod::Reshard, overhead: reshard, raw_pipeline, reshard }
        } else {
            TransitionCost {
                method: TransitionMethod::Int4Backup,
                overhead: overlapped,
                raw_pipeline,
                reshard,
            }
        }
    }

    /// C_ij per eq. 6. `prefill_stage_time` is the prefill-stage term
    /// `Sₖᵀ·T_a + E_i·T_e + T_Cₖᵢ` the pipeline overlaps with.
    pub fn cost(
        &self,
        lm: &LatencyModel,
        from: &ExpertStrategy,
        to: &ExpertStrategy,
        prefill_stage_time: f64,
    ) -> TransitionCost {
        if from == to {
            return TransitionCost {
                method: TransitionMethod::None,
                overhead: 0.0,
                raw_pipeline: 0.0,
                reshard: 0.0,
            };
        }
        let reshard = self.reshard_time(lm, from, to);
        let raw_pipeline = self.upload_time(to) + self.dequant_time(to);
        Self::decide(reshard, raw_pipeline, prefill_stage_time)
    }

    /// The whole K_e × K_e switching-cost matrix in one shot: all
    /// reshard collectives go through a single batched ρ prediction and
    /// the per-destination upload/dequant terms are computed once per
    /// column. `prefill_budget[i]` is the overlap window when leaving
    /// strategy `i`. Entry-for-entry identical to calling
    /// [`Self::cost`] per pair.
    pub fn cost_matrix(
        &self,
        lm: &LatencyModel,
        experts: &[ExpertStrategy],
        prefill_budget: &[f64],
    ) -> Vec<Vec<TransitionCost>> {
        assert_eq!(experts.len(), prefill_budget.len());
        let k = experts.len();
        // Per-destination INT4 pipeline (pure arithmetic, reused per row).
        let raw: Vec<f64> =
            experts.iter().map(|to| self.upload_time(to) + self.dequant_time(to)).collect();
        // One reshard event per off-diagonal pair; zero-wire events are
        // mapped to zero time inside `comm_time_batch`, mirroring the
        // scalar early-out.
        let mut events = Vec::with_capacity(k * k);
        let mut slots = Vec::with_capacity(k * k);
        for (i, from) in experts.iter().enumerate() {
            for (j, to) in experts.iter().enumerate() {
                if from == to {
                    continue;
                }
                let n = from.devices();
                events.push(CommEvent {
                    collective: comm::Collective::AllGather,
                    group: n,
                    wire_bytes: comm::reshard_wire_bytes(self.model, from, to),
                    rounds: n - 1,
                    label: "reshard",
                });
                slots.push((i, j));
            }
        }
        let times = lm.comm_time_batch(&events);
        let mut reshard = vec![vec![0.0f64; k]; k];
        for (s, &(i, j)) in slots.iter().enumerate() {
            reshard[i][j] = times[s];
        }
        (0..k)
            .map(|i| {
                (0..k)
                    .map(|j| {
                        if experts[i] == experts[j] {
                            TransitionCost {
                                method: TransitionMethod::None,
                                overhead: 0.0,
                                raw_pipeline: 0.0,
                                reshard: 0.0,
                            }
                        } else {
                            Self::decide(reshard[i][j], raw[j], prefill_budget[i])
                        }
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, MoEModelConfig};
    use crate::sim::latency::LatencyModel;

    fn setup() -> (MoEModelConfig, GpuSpec) {
        (MoEModelConfig::mixtral_8x7b(), GpuSpec::a6000())
    }

    #[test]
    fn identity_transition_free() {
        let (m, g) = setup();
        let lm = LatencyModel::train(&g, 1);
        let tm = TransitionModel::new(&m, &g);
        let s = ExpertStrategy::new(4, 1);
        let c = tm.cost(&lm, &s, &s, 0.1);
        assert_eq!(c.method, TransitionMethod::None);
        assert_eq!(c.overhead, 0.0);
    }

    #[test]
    fn long_prefill_hides_upload() {
        // With a long prefill to overlap against, INT4 backup should be
        // near-free and selected over resharding on PCIe.
        let (m, g) = setup();
        let lm = LatencyModel::train(&g, 1);
        let tm = TransitionModel::new(&m, &g);
        let from = ExpertStrategy::new(1, 4);
        let to = ExpertStrategy::new(4, 1);
        let generous_prefill = 10.0; // 10 s of prefill compute
        let c = tm.cost(&lm, &from, &to, generous_prefill);
        assert_eq!(c.method, TransitionMethod::Int4Backup);
        assert_eq!(c.overhead, 0.0);
        assert!(c.reshard > 0.0);
    }

    #[test]
    fn zero_overlap_charges_full_pipeline_or_reshard() {
        let (m, g) = setup();
        let lm = LatencyModel::train(&g, 1);
        let tm = TransitionModel::new(&m, &g);
        let from = ExpertStrategy::new(1, 4);
        let to = ExpertStrategy::new(4, 1);
        let c = tm.cost(&lm, &from, &to, 0.0);
        assert!(c.overhead > 0.0);
        assert!(c.overhead <= c.reshard + 1e-9);
        assert!(c.overhead <= c.raw_pipeline + 1e-9);
    }

    #[test]
    fn dequant_table_monotone() {
        let (_, g) = setup();
        let t = DequantTable::build(&g);
        assert!(t.lookup(1e7) < t.lookup(1e9));
        assert!(t.lookup(1e9) < t.lookup(1e11));
    }

    #[test]
    fn upload_volume_scales_with_shard() {
        let (m, g) = setup();
        let tm = TransitionModel::new(&m, &g);
        // 4-device shard uploads half of what a 2-device shard does.
        let t4 = tm.upload_time(&ExpertStrategy::new(4, 1));
        let t2 = tm.upload_time(&ExpertStrategy::new(2, 1));
        // Note: devices() = tp×ep; (2,1) has 2 devices.
        assert!((t2 / t4 - 2.0).abs() < 0.01);
    }

    #[test]
    fn cost_matrix_matches_per_pair_cost() {
        let (m, g) = setup();
        let lm = LatencyModel::train(&g, 1);
        let tm = TransitionModel::new(&m, &g);
        let experts =
            [ExpertStrategy::new(4, 1), ExpertStrategy::new(2, 2), ExpertStrategy::new(1, 4)];
        let budgets = [0.0, 0.05, 0.4];
        let matrix = tm.cost_matrix(&lm, &experts, &budgets);
        for i in 0..experts.len() {
            for j in 0..experts.len() {
                let c = tm.cost(&lm, &experts[i], &experts[j], budgets[i]);
                assert_eq!(matrix[i][j].method, c.method, "({i},{j})");
                assert_eq!(matrix[i][j].overhead.to_bits(), c.overhead.to_bits(), "({i},{j})");
                assert_eq!(matrix[i][j].reshard.to_bits(), c.reshard.to_bits(), "({i},{j})");
                assert_eq!(
                    matrix[i][j].raw_pipeline.to_bits(),
                    c.raw_pipeline.to_bits(),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn nvlink_prefers_reshard_more_often() {
        // On A100/NVLink reshard is cheap; with little overlap budget it
        // should win against the PCIe-bound upload.
        let m = MoEModelConfig::mixtral_8x7b();
        let g = GpuSpec::a100();
        let lm = LatencyModel::train(&g, 1);
        let tm = TransitionModel::new(&m, &g);
        let c = tm.cost(&lm, &ExpertStrategy::new(1, 4), &ExpertStrategy::new(4, 1), 0.0);
        assert_eq!(c.method, TransitionMethod::Reshard, "overhead {:?}", c);
    }
}
