//! Declarative CLI flag parsing (std-only `clap` stand-in).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; generates usage text from the declarations.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One declared flag.
#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser.
///
/// ```no_run
/// # // no_run: rustdoc test binaries miss the xla_extension rpath in
/// # // this offline environment (libstdc++ lives there).
/// use hap::util::args::ArgSpec;
/// let mut spec = ArgSpec::new("hap plan", "Search a hybrid parallel plan");
/// spec.flag("model", "mixtral-8x7b", "model preset name");
/// spec.flag("gpus", "4", "number of devices");
/// spec.bool_flag("verbose", "print the full search space");
/// let parsed = spec.parse(&["--model".into(), "qwen2-57b".into()]).unwrap();
/// assert_eq!(parsed.get("model"), "qwen2-57b");
/// assert_eq!(parsed.get_usize("gpus").unwrap(), 4);
/// assert!(!parsed.get_bool("verbose"));
/// ```
#[derive(Debug, Clone)]
pub struct ArgSpec {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct ParsedArgs {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl ArgSpec {
    pub fn new(program: &str, about: &str) -> Self {
        ArgSpec { program: program.to_string(), about: about.to_string(), flags: Vec::new() }
    }

    /// Declare a valued flag with a default.
    pub fn flag(&mut self, name: &str, default: &str, help: &str) -> &mut Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a required valued flag (no default).
    pub fn required_flag(&mut self, name: &str, help: &str) -> &mut Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
        });
        self
    }

    /// Declare a boolean flag (defaults to false).
    pub fn bool_flag(&mut self, name: &str, help: &str) -> &mut Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: true,
        });
        self
    }

    /// Usage text.
    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nOptions:");
        for f in &self.flags {
            let meta = if f.is_bool { String::new() } else { " <value>".to_string() };
            let def = match (&f.default, f.is_bool) {
                (Some(d), _) => format!(" [default: {d}]"),
                (None, false) => " [required]".to_string(),
                _ => String::new(),
            };
            let _ = writeln!(s, "  --{}{:<14} {}{}", f.name, meta, f.help, def);
        }
        s
    }

    /// Parse a raw argument list (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<ParsedArgs, String> {
        let mut values = BTreeMap::new();
        let mut bools = BTreeMap::new();
        for f in &self.flags {
            if f.is_bool {
                bools.insert(f.name.clone(), false);
            } else if let Some(d) = &f.default {
                values.insert(f.name.clone(), d.clone());
            }
        }
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?;
                if spec.is_bool {
                    if let Some(v) = inline {
                        bools.insert(name, v == "true" || v == "1");
                    } else {
                        bools.insert(name, true);
                    }
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    values.insert(name, v);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if !f.is_bool && !values.contains_key(&f.name) {
                return Err(format!("missing required flag --{}\n\n{}", f.name, self.usage()));
            }
        }
        Ok(ParsedArgs { values, bools, positional })
    }
}

impl ParsedArgs {
    /// Get a valued flag (panics if not declared — programming error).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        *self.bools.get(name).unwrap_or(&false)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected integer, got '{}'", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected number, got '{}'", self.get(name)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        let mut s = ArgSpec::new("t", "test");
        s.flag("model", "mixtral-8x7b", "model");
        s.flag("gpus", "4", "gpus");
        s.bool_flag("verbose", "verbose");
        s
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse(&[]).unwrap();
        assert_eq!(p.get("model"), "mixtral-8x7b");
        assert_eq!(p.get_usize("gpus").unwrap(), 4);
        assert!(!p.get_bool("verbose"));
    }

    #[test]
    fn equals_and_space_forms() {
        let p = spec().parse(&sv(&["--gpus=8", "--model", "q", "--verbose"])).unwrap();
        assert_eq!(p.get_usize("gpus").unwrap(), 8);
        assert_eq!(p.get("model"), "q");
        assert!(p.get_bool("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(spec().parse(&sv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let p = spec().parse(&sv(&["plan", "--gpus", "2"])).unwrap();
        assert_eq!(p.positional, vec!["plan"]);
    }

    #[test]
    fn required_flag_enforced() {
        let mut s = ArgSpec::new("t", "test");
        s.required_flag("out", "output path");
        assert!(s.parse(&[]).is_err());
        assert!(s.parse(&sv(&["--out", "x"])).is_ok());
    }
}
