//! Descriptive statistics for benchmark and simulation results.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile; `q` in [0, 100]; 0 for empty input.
///
/// The empty-input zero is a deliberate, pinned contract (not a NaN or
/// a panic): metric exports build histogram snapshots from possibly
/// empty sample sets, and their quantile fields must stay
/// JSON-serializable. Display layers that want to distinguish "no
/// samples" from a true zero must check emptiness themselves (e.g.
/// `serving::Metrics::summary` renders `-`).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Mean absolute percentage error between predictions and truth.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let s: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| ((p - t) / t).abs())
        .sum();
    s / pred.len() as f64
}

/// Coefficient of determination R².
pub fn r2(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let m = mean(truth);
    let ss_res: f64 = pred.iter().zip(truth).map(|(p, t)| (t - p) * (t - p)).sum();
    let ss_tot: f64 = truth.iter().map(|t| (t - m) * (t - m)).sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Cosine similarity between two equal-length vectors.
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Root-mean-square error between two f32 slices.
pub fn rmse_f32(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum();
    (s / a.len() as f64).sqrt()
}

/// Maximum absolute difference between two f32 slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 - y as f64).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn empty_inputs_pin_to_zero() {
        // Pinned contract: empty in → finite 0.0 out, never NaN/panic.
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 100.0), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        // And a single sample is its own percentile everywhere.
        assert_eq!(percentile(&[7.0], 0.0), 7.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn error_metrics() {
        let truth = [10.0, 20.0];
        let pred = [11.0, 18.0];
        assert!((mape(&pred, &truth) - 0.1).abs() < 1e-12);
        assert!(r2(&truth, &truth) == 1.0);
    }

    #[test]
    fn cosine() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!(cosine_similarity(&a, &a) > 0.999_999);
        assert!(cosine_similarity(&a, &b).abs() < 1e-9);
    }
}
