//! Property-based testing harness (std-only `proptest` stand-in).
//!
//! Runs a property against many seeded random inputs and, on failure,
//! reports the seed and iteration so the case can be replayed
//! deterministically. Set `HAP_PROP_CASES` to change the case count.

use super::rng::Rng;

/// Number of cases per property (env-overridable).
pub fn default_cases() -> usize {
    std::env::var("HAP_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` for `cases` seeded inputs; panics with the failing seed.
///
/// The property receives a fresh `Rng` per case and should draw its own
/// inputs from it, returning `Err(description)` on violation.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base_seed: u64 = 0xC0FFEE_5EED_2025;
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Run with the default number of cases.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, default_cases(), prop)
}

/// Assert helper: returns Err with a formatted message when false.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 17, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 17);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check("fails", 8, |r| {
            let x = r.below(100);
            if x < 1000 {
                Err(format!("x = {x}"))
            } else {
                Ok(())
            }
        });
    }
}
