//! Minimal JSON parser and writer (RFC 8259 subset, std-only).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), bench
//! result dumps (`target/bench_results/*.json`), and plan serialization.
//! Numbers are stored as `f64`; object key order is preserved.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys (vector of pairs) for stable
    /// round-trips, plus O(log n) lookup via a side index.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Field lookup that errors with the key name (for manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError { msg: format!("missing key '{key}'"), pos: 0 })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Iterate object fields.
    pub fn fields(&self) -> &[(String, Json)] {
        match self {
            Json::Obj(f) => f,
            _ => &[],
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, Some(2), 0);
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Self {
        Json::Obj(m.into_iter().collect())
    }
}

/// Parse/serialization error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_json(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Json::Obj(fields) => {
            if fields.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
    }

    #[test]
    fn pretty_round_trip() {
        let v = Json::obj(vec![
            ("name", "tiny-moe".into()),
            ("layers", 4usize.into()),
            ("ids", vec![1usize, 2, 3].into()),
        ]);
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integers_serialize_without_point() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        let keys: Vec<&str> = v.fields().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }
}
