//! Standard-library-only utilities.
//!
//! The offline build environment ships no general-purpose crates (no
//! `rand`, `serde`, `clap`, `proptest`), so this module provides the
//! small, well-tested subset the rest of the crate needs: a seedable
//! PRNG ([`rng`]), a JSON parser/writer ([`json`]), a declarative CLI
//! flag parser ([`args`]), descriptive statistics ([`stats`]), and a
//! property-test harness ([`prop`]).

pub mod args;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Format a byte count with binary units (e.g. `1.50 GiB`).
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{:.2} {}", v, UNITS[u])
}

/// Format a duration in seconds with an adaptive unit (ns/µs/ms/s).
pub fn fmt_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(fmt_bytes(512.0), "512.00 B");
        assert_eq!(fmt_bytes(1536.0), "1.50 KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0 * 1024.0), "3.00 GiB");
    }

    #[test]
    fn secs_units() {
        assert_eq!(fmt_secs(2.5e-9), "2.5 ns");
        assert_eq!(fmt_secs(2.5e-5), "25.0 µs");
        assert_eq!(fmt_secs(2.5e-2), "25.00 ms");
        assert_eq!(fmt_secs(2.5), "2.500 s");
    }
}
