//! Seedable pseudo-random number generation (no external crates).
//!
//! [`Rng`] is a PCG32 generator seeded through SplitMix64, good enough
//! for workload generation, weight initialization, the microbenchmark
//! noise model, random-forest bagging, and property tests. It is *not*
//! cryptographic.

/// PCG32 (XSH-RR variant) pseudo-random number generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second normal deviate from the Box–Muller transform.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (stream derived from seed).
    pub fn new(seed: u64) -> Self {
        // Expand the seed with SplitMix64 so similar seeds diverge.
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm) | 1; // stream must be odd
        let mut rng = Rng { state: 0, inc: s1, gauss_spare: None };
        rng.state = s0.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Next uniform 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) using Lemire's method (unbiased enough
    /// for simulation purposes).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal deviate (Box–Muller with spare caching).
    pub fn gauss(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Normal deviate with given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Log-normal multiplicative noise factor with multiplicative sigma
    /// `sigma` (e.g. 0.05 → ±5%-ish noise), mean ≈ 1.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.gauss() * sigma).exp()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard-normal f32 values (weight init).
    pub fn normal_vec_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.gauss() as f32) * std).collect()
    }
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
