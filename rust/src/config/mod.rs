//! Configuration: MoE model presets (paper Table III), hardware platform
//! presets (A100/A6000/V100 nodes), and inference scenario presets
//! (paper Table II).

pub mod hardware;
pub mod model;
pub mod scenario;

pub use hardware::{GpuSpec, Interconnect, NodeConfig};
pub use model::MoEModelConfig;
pub use scenario::Scenario;
