//! Inference scenarios (paper Table II) and workload parameters.
//!
//! Four orthogonal scenarios along (context scale × generation length):
//! short/long context × constrained/extended output, plus the two 8-GPU
//! variants used in Fig 8.

use crate::util::json::Json;

/// One evaluation scenario: prompt length, generation length, batch size.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// Input (prompt) sequence length S_input.
    pub context: usize,
    /// Output generation length S_output.
    pub generate: usize,
    /// Global batch size B.
    pub batch: usize,
}

impl Scenario {
    pub fn new(name: &str, context: usize, generate: usize, batch: usize) -> Self {
        Scenario { name: name.into(), context, generate, batch }
    }

    /// Table II row 1: 256-token context, 64-token generation.
    pub fn short_constrained() -> Self {
        Self::new("short-constrained", 256, 64, 16)
    }

    /// Table II row 2: 256-token context, 2048-token generation.
    pub fn short_extended() -> Self {
        Self::new("short-extended", 256, 2048, 16)
    }

    /// Table II row 3: 4096-token context, 64-token generation.
    pub fn long_constrained() -> Self {
        Self::new("long-constrained", 4096, 64, 16)
    }

    /// Table II row 4: 4096-token context, 2048-token generation.
    pub fn long_extended() -> Self {
        Self::new("long-extended", 4096, 2048, 16)
    }

    /// Fig 8(a): 2048-token context, 128-token output (8×A100).
    pub fn fig8_a100() -> Self {
        Self::new("fig8-a100", 2048, 128, 16)
    }

    /// Fig 8(b): 2048-token context, 64-token output (8×V100).
    pub fn fig8_v100() -> Self {
        Self::new("fig8-v100", 2048, 64, 16)
    }

    /// All four Table II scenarios.
    pub fn table2() -> Vec<Self> {
        vec![
            Self::short_constrained(),
            Self::short_extended(),
            Self::long_constrained(),
            Self::long_extended(),
        ]
    }

    /// Same scenario with a different global batch size (the paper's
    /// per-figure bars sweep batch sizes).
    pub fn with_batch(&self, batch: usize) -> Self {
        Scenario { batch, ..self.clone() }
    }

    /// Total sequence length at end of generation.
    pub fn total_len(&self) -> usize {
        self.context + self.generate
    }

    /// Prefill-to-total token ratio — the scenario statistic that
    /// governs which phase dominates (paper IV-C).
    pub fn prefill_fraction(&self) -> f64 {
        self.context as f64 / self.total_len() as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("context", self.context.into()),
            ("generate", self.generate.into()),
            ("batch", self.batch.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Scenario> {
        Some(Scenario {
            name: j.get("name")?.as_str()?.to_string(),
            context: j.get("context")?.as_usize()?,
            generate: j.get("generate")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        let t = Scenario::table2();
        assert_eq!(t.len(), 4);
        assert_eq!((t[0].context, t[0].generate), (256, 64));
        assert_eq!((t[1].context, t[1].generate), (256, 2048));
        assert_eq!((t[2].context, t[2].generate), (4096, 64));
        assert_eq!((t[3].context, t[3].generate), (4096, 2048));
    }

    #[test]
    fn prefill_fraction_ordering() {
        // long-constrained is prefill-dominated; short-extended is
        // decode-dominated — the axis HAP adapts along.
        assert!(Scenario::long_constrained().prefill_fraction() > 0.98);
        assert!(Scenario::short_extended().prefill_fraction() < 0.12);
    }

    #[test]
    fn with_batch_overrides() {
        let s = Scenario::short_constrained().with_batch(32);
        assert_eq!(s.batch, 32);
        assert_eq!(s.context, 256);
    }
}
