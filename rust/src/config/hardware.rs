//! Hardware platform descriptions.
//!
//! The paper evaluates on 4–8 GPU single nodes: A100 (NVLink), A6000
//! (PCIe 4.0), V100 (PCIe 3.0). The interconnect asymmetry — high-BW
//! NVLink vs low-BW PCIe — is what flips the TP/EP decision (paper
//! Fig 2/7), so it is modeled explicitly.

use crate::util::json::Json;

/// Intra-node interconnect type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Interconnect {
    /// NVLink/NVSwitch: all-to-all, high bandwidth, low latency.
    NvLink,
    /// PCIe through a host bridge: shared, lower bandwidth.
    Pcie,
}

impl Interconnect {
    pub fn name(self) -> &'static str {
        match self {
            Interconnect::NvLink => "nvlink",
            Interconnect::Pcie => "pcie",
        }
    }
}

/// A single accelerator's capabilities.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Peak dense BF16/FP16 FLOP/s (tensor cores / MXU equivalent).
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Device memory capacity, bytes (M_gpu).
    pub mem_bytes: f64,
    /// Per-direction interconnect bandwidth, bytes/s.
    pub link_bw: f64,
    /// Interconnect kind.
    pub interconnect: Interconnect,
    /// Per-message collective launch latency, seconds.
    pub link_latency: f64,
    /// Host→device (PCIe) bandwidth, bytes/s — the INT4-backup upload path.
    pub h2d_bw: f64,
}

impl GpuSpec {
    /// NVIDIA A100-SXM 80GB: 312 TFLOP/s BF16, 2.0 TB/s HBM,
    /// NVLink3 300 GB/s per direction.
    pub fn a100() -> Self {
        GpuSpec {
            name: "A100".into(),
            peak_flops: 312e12,
            hbm_bw: 2.0e12,
            mem_bytes: 80e9,
            link_bw: 300e9,
            interconnect: Interconnect::NvLink,
            link_latency: 6e-6,
            h2d_bw: 25e9, // PCIe 4.0 x16 effective
        }
    }

    /// NVIDIA RTX A6000 48GB: 155 TFLOP/s FP16 tensor, 768 GB/s HBM.
    /// PCIe 4.0 x16 is ~25 GB/s line rate per direction, but 4-GPU
    /// collectives share the host bridge — measured ring-allreduce
    /// bus bandwidth lands near 12 GB/s, which is what the collectives
    /// actually see.
    pub fn a6000() -> Self {
        GpuSpec {
            name: "A6000".into(),
            peak_flops: 155e12,
            hbm_bw: 768e9,
            mem_bytes: 48e9,
            link_bw: 12e9,
            interconnect: Interconnect::Pcie,
            link_latency: 12e-6,
            h2d_bw: 25e9,
        }
    }

    /// NVIDIA V100 32GB: 125 TFLOP/s FP16, 900 GB/s HBM, PCIe 3.0 x16
    /// (paper's V100 node uses PCIe, not NVLink) — ~12 GB/s line rate,
    /// ~7 GB/s effective collective bandwidth through the host bridge.
    pub fn v100() -> Self {
        GpuSpec {
            name: "V100".into(),
            peak_flops: 125e12,
            hbm_bw: 900e9,
            mem_bytes: 32e9,
            link_bw: 7e9,
            interconnect: Interconnect::Pcie,
            link_latency: 12e-6,
            h2d_bw: 12e9,
        }
    }

    /// The CPU PJRT "device" used by the real tiny-MoE serving path.
    /// Rough numbers for a modern server core-set; used only for
    /// simulated-comm charging in the demo.
    pub fn cpu_sim() -> Self {
        GpuSpec {
            name: "CPU-sim".into(),
            peak_flops: 200e9,
            hbm_bw: 40e9,
            mem_bytes: 16e9,
            link_bw: 20e9,
            interconnect: Interconnect::Pcie,
            link_latency: 2e-6,
            h2d_bw: 20e9,
        }
    }

    pub fn preset(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "a100" => Some(Self::a100()),
            "a6000" => Some(Self::a6000()),
            "v100" => Some(Self::v100()),
            "cpu-sim" | "cpu" => Some(Self::cpu_sim()),
            _ => None,
        }
    }
}

/// A single-node multi-GPU configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeConfig {
    pub gpu: GpuSpec,
    /// Number of devices (N in the ILP).
    pub num_devices: usize,
}

impl NodeConfig {
    pub fn new(gpu: GpuSpec, num_devices: usize) -> Self {
        assert!(num_devices.is_power_of_two(), "device count must be a power of two");
        NodeConfig { gpu, num_devices }
    }

    /// 4× or 8× A100 node (NVLink).
    pub fn a100x(n: usize) -> Self {
        Self::new(GpuSpec::a100(), n)
    }

    /// 4× A6000 node (PCIe).
    pub fn a6000x(n: usize) -> Self {
        Self::new(GpuSpec::a6000(), n)
    }

    /// 8× V100 node (PCIe).
    pub fn v100x(n: usize) -> Self {
        Self::new(GpuSpec::v100(), n)
    }

    /// Demo node of simulated CPU devices.
    pub fn cpu_sim(n: usize) -> Self {
        Self::new(GpuSpec::cpu_sim(), n)
    }

    pub fn label(&self) -> String {
        format!("{}x{}", self.num_devices, self.gpu.name)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("gpu", self.gpu.name.as_str().into()),
            ("num_devices", self.num_devices.into()),
            ("interconnect", self.gpu.interconnect.name().into()),
            ("peak_flops", self.gpu.peak_flops.into()),
            ("link_bw", self.gpu.link_bw.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interconnect_asymmetry() {
        // The core hardware fact behind Fig 2/7: A100 NVLink BW is an
        // order of magnitude above A6000/V100 PCIe BW.
        let a100 = GpuSpec::a100();
        let a6000 = GpuSpec::a6000();
        let v100 = GpuSpec::v100();
        assert_eq!(a100.interconnect, Interconnect::NvLink);
        assert_eq!(a6000.interconnect, Interconnect::Pcie);
        assert!(a100.link_bw / a6000.link_bw > 10.0);
        assert!(a100.link_bw / v100.link_bw > 20.0);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        NodeConfig::a100x(3);
    }

    #[test]
    fn labels() {
        assert_eq!(NodeConfig::a6000x(4).label(), "4xA6000");
        assert_eq!(NodeConfig::v100x(8).label(), "8xV100");
    }
}
