//! MoE model configurations.
//!
//! Presets encode paper Table III exactly, plus the `tiny-moe` demo model
//! that the end-to-end PJRT serving path executes for real on CPU.

use crate::util::json::Json;

/// Architecture description of a decoder-only MoE transformer.
///
/// Shapes follow the paper's notation: `hidden` = Dim, `moe_inter_size` =
/// Dim_exp, `num_experts` = N_experts; GQA is modeled via `kv_heads`.
#[derive(Debug, Clone, PartialEq)]
pub struct MoEModelConfig {
    /// Preset name (e.g. "mixtral-8x7b").
    pub name: String,
    /// Total parameter count in billions (reported, for memory checks).
    pub params_b: f64,
    /// Number of transformer layers (N_layer).
    pub layers: usize,
    /// Query attention heads.
    pub q_heads: usize,
    /// Key/value heads (GQA; == q_heads for MHA).
    pub kv_heads: usize,
    /// Hidden size (Dim).
    pub hidden: usize,
    /// Head dimension (hidden / q_heads unless overridden).
    pub head_dim: usize,
    /// Routed experts per layer (N_experts).
    pub num_experts: usize,
    /// Experts activated per token (top-k).
    pub top_k: usize,
    /// Shared (always-active) experts per layer; 0 when absent.
    pub shared_experts: usize,
    /// Expert FFN intermediate size (Dim_exp).
    pub moe_inter_size: usize,
    /// Shared-expert FFN intermediate size (== moe_inter_size * n for
    /// Qwen-style fused shared experts).
    pub shared_inter_size: usize,
    /// Vocabulary size (for embedding/unembedding memory + logits).
    pub vocab: usize,
    /// Bytes per parameter at serving precision (2 for BF16/FP16).
    pub dtype_bytes: usize,
}

impl MoEModelConfig {
    /// Mixtral-8x7B (Table III row 1): 46.7B params, 32 layers, 32 heads,
    /// hidden 4096, 8 experts (top-2), expert inter 14336, GQA 8 KV heads.
    pub fn mixtral_8x7b() -> Self {
        MoEModelConfig {
            name: "mixtral-8x7b".into(),
            params_b: 46.7,
            layers: 32,
            q_heads: 32,
            kv_heads: 8,
            hidden: 4096,
            head_dim: 128,
            num_experts: 8,
            top_k: 2,
            shared_experts: 0,
            moe_inter_size: 14336,
            shared_inter_size: 0,
            vocab: 32000,
            dtype_bytes: 2,
        }
    }

    /// Qwen1.5-MoE-A2.7B (Table III row 2): 14.3B params, 24 layers, 16
    /// heads, hidden 2048, 60 experts (top-4) + 4 shared, inter 1408.
    pub fn qwen15_moe_a27b() -> Self {
        MoEModelConfig {
            name: "qwen1.5-moe-a2.7b".into(),
            params_b: 14.3,
            layers: 24,
            q_heads: 16,
            kv_heads: 16,
            hidden: 2048,
            head_dim: 128,
            num_experts: 60,
            top_k: 4,
            shared_experts: 4,
            moe_inter_size: 1408,
            shared_inter_size: 5632,
            vocab: 151936,
            dtype_bytes: 2,
        }
    }

    /// Qwen2-57B-A14B (Table III row 3): 57.4B params, 28 layers, 28
    /// heads (4 KV), hidden 3584, 64 experts (top-8) + shared, inter 2560.
    pub fn qwen2_57b_a14b() -> Self {
        MoEModelConfig {
            name: "qwen2-57b-a14b".into(),
            params_b: 57.4,
            layers: 28,
            q_heads: 28,
            kv_heads: 4,
            hidden: 3584,
            head_dim: 128,
            num_experts: 64,
            top_k: 8,
            shared_experts: 1,
            moe_inter_size: 2560,
            shared_inter_size: 20480,
            vocab: 151936,
            dtype_bytes: 2,
        }
    }

    /// The ~27M-parameter demo model that the end-to-end serving path
    /// runs for real through PJRT: 4 layers, hidden 256, 8 heads
    /// (4 KV), 8 experts (top-2), inter 512. Must match
    /// `python/compile/model.py::TINY`.
    pub fn tiny_moe() -> Self {
        MoEModelConfig {
            name: "tiny-moe".into(),
            params_b: 0.027,
            layers: 4,
            q_heads: 8,
            kv_heads: 4,
            hidden: 256,
            head_dim: 32,
            num_experts: 8,
            top_k: 2,
            shared_experts: 0,
            moe_inter_size: 512,
            shared_inter_size: 0,
            vocab: 512,
            dtype_bytes: 4, // f32 on the CPU PJRT path
        }
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "mixtral-8x7b" => Some(Self::mixtral_8x7b()),
            "qwen1.5-moe-a2.7b" | "qwen15-moe-a2.7b" => Some(Self::qwen15_moe_a27b()),
            "qwen2-57b-a14b" => Some(Self::qwen2_57b_a14b()),
            "tiny-moe" => Some(Self::tiny_moe()),
            _ => None,
        }
    }

    /// All paper evaluation models (Table III).
    pub fn paper_models() -> Vec<Self> {
        vec![Self::mixtral_8x7b(), Self::qwen15_moe_a27b(), Self::qwen2_57b_a14b()]
    }

    /// Attention-module weight parameters per layer:
    /// Q/K/V/O projections under GQA.
    pub fn attn_params_per_layer(&self) -> usize {
        let h = self.hidden;
        let q = h * self.q_heads * self.head_dim; // Wq
        let kv = 2 * h * self.kv_heads * self.head_dim; // Wk, Wv
        let o = self.q_heads * self.head_dim * h; // Wo
        q + kv + o
    }

    /// Routed-expert weight parameters per layer (SwiGLU: 3 matrices).
    pub fn expert_params_per_layer(&self) -> usize {
        self.num_experts * 3 * self.hidden * self.moe_inter_size
    }

    /// Shared-expert weight parameters per layer.
    pub fn shared_expert_params_per_layer(&self) -> usize {
        if self.shared_experts == 0 {
            0
        } else {
            3 * self.hidden * self.shared_inter_size
        }
    }

    /// KV-cache bytes per token (all layers).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.layers * self.kv_heads * self.head_dim * self.dtype_bytes
    }

    /// Total weight bytes (approximate: layers + embeddings).
    pub fn weight_bytes(&self) -> usize {
        let per_layer = self.attn_params_per_layer()
            + self.expert_params_per_layer()
            + self.shared_expert_params_per_layer()
            // router/gate + layer norms
            + self.hidden * self.num_experts
            + 2 * self.hidden;
        (self.layers * per_layer + 2 * self.vocab * self.hidden) * self.dtype_bytes
    }

    /// Fraction of weights held by the Expert module (the paper notes
    /// ~90% for typical MoE models — drives the transition-cost model).
    pub fn expert_weight_fraction(&self) -> f64 {
        let e = self.layers * self.expert_params_per_layer();
        let total = self.weight_bytes() / self.dtype_bytes;
        e as f64 / total as f64
    }

    /// Serialize for manifests/plan dumps.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("params_b", self.params_b.into()),
            ("layers", self.layers.into()),
            ("q_heads", self.q_heads.into()),
            ("kv_heads", self.kv_heads.into()),
            ("hidden", self.hidden.into()),
            ("head_dim", self.head_dim.into()),
            ("num_experts", self.num_experts.into()),
            ("top_k", self.top_k.into()),
            ("shared_experts", self.shared_experts.into()),
            ("moe_inter_size", self.moe_inter_size.into()),
            ("shared_inter_size", self.shared_inter_size.into()),
            ("vocab", self.vocab.into()),
            ("dtype_bytes", self.dtype_bytes.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_values_encoded() {
        let m = MoEModelConfig::mixtral_8x7b();
        assert_eq!((m.layers, m.q_heads, m.hidden), (32, 32, 4096));
        assert_eq!((m.num_experts, m.moe_inter_size), (8, 14336));
        let q = MoEModelConfig::qwen15_moe_a27b();
        assert_eq!((q.layers, q.q_heads, q.hidden), (24, 16, 2048));
        assert_eq!((q.num_experts, q.moe_inter_size), (60, 1408));
        let q2 = MoEModelConfig::qwen2_57b_a14b();
        assert_eq!((q2.layers, q2.q_heads, q2.hidden), (28, 28, 3584));
        assert_eq!((q2.num_experts, q2.moe_inter_size), (64, 2560));
    }

    #[test]
    fn weight_bytes_close_to_reported_params() {
        // Mixtral-8x7B is 46.7B params; our analytic count should be
        // within 5% (we approximate norms/router).
        let m = MoEModelConfig::mixtral_8x7b();
        let params = m.weight_bytes() as f64 / m.dtype_bytes as f64 / 1e9;
        assert!((params - m.params_b).abs() / m.params_b < 0.05, "params {params}");
    }

    #[test]
    fn expert_fraction_dominates() {
        // Paper III-D: expert weights ≈ 90% of total for Mixtral.
        let m = MoEModelConfig::mixtral_8x7b();
        let f = m.expert_weight_fraction();
        assert!(f > 0.85 && f < 0.99, "fraction {f}");
    }

    #[test]
    fn kv_bytes_per_token_mixtral() {
        // 2 * 32 layers * 8 kv heads * 128 dim * 2 bytes = 131072.
        assert_eq!(MoEModelConfig::mixtral_8x7b().kv_bytes_per_token(), 131072);
    }

    #[test]
    fn presets_resolve() {
        for n in ["mixtral-8x7b", "qwen1.5-moe-a2.7b", "qwen2-57b-a14b", "tiny-moe"] {
            assert!(MoEModelConfig::preset(n).is_some(), "{n}");
        }
        assert!(MoEModelConfig::preset("nope").is_none());
    }
}
