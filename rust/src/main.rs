//! `hap` — the CLI entrypoint for the HAP coordinator.
//!
//! Subcommands:
//!   plan        Search the optimal hybrid parallel strategy (ILP).
//!   breakdown   Per-layer latency breakdown, TP vs EP (paper Fig 2).
//!   sweep       Speedup table across scenarios/platforms (Fig 4–9).
//!   serve       Serve a synthetic workload on the tiny-MoE grid
//!               engine (PJRT artifacts, or --backend host for the
//!               artifact-free host kernels) under a chosen plan;
//!               --engine streaming runs the continuous-batching
//!               session engine, --engine gang the legacy
//!               run-to-completion scheduler.
//!   trace       Summarize a serve trace (JSONL from `serve
//!               --trace-out`) into a per-module time breakdown.
//!   quant-eval  Quantization scheme quality report (Table I).
//!   microbench  η/ρ simulation-model accuracy (Fig 5).

use hap::benchkit::Table;
use hap::config::{GpuSpec, MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Engine;
use hap::planner::HapPlanner;
use hap::quant::{self, Scheme};
use hap::serving::{serve_workload, Request, ServeConfig};
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::args::ArgSpec;
use hap::util::rng::Rng;
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &[] } else { &args[1..] };
    let result = match cmd {
        "plan" => cmd_plan(rest),
        "breakdown" => cmd_breakdown(rest),
        "sweep" => cmd_sweep(rest),
        "serve" => cmd_serve(rest),
        "trace" => cmd_trace(rest),
        "adapt-replay" => cmd_adapt_replay(rest),
        "quant-eval" => cmd_quant(rest),
        "microbench" => cmd_microbench(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            Err(anyhow::anyhow!("unknown command"))
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "hap — Hybrid Adaptive Parallelism for MoE inference (paper reproduction)\n\n\
         Usage: hap <command> [flags]\n\n\
         Commands:\n  \
         plan        search the optimal hybrid parallel strategy (ILP)\n  \
         breakdown   per-layer latency breakdown TP vs EP (Fig 2)\n  \
         sweep       HAP vs TP speedups across scenarios (Fig 4/6/7/9)\n  \
         serve       serve a workload on the tiny-MoE grid engine (pjrt or host backend;\n              \
                     --engine streaming|gang picks continuous batching vs run-to-completion;\n              \
                     --trace-out / --metrics-out export the run's telemetry)\n  \
         trace       summarize a serve trace (trace summarize --in <trace.jsonl>)\n  \
         adapt-replay  replay a traffic trace: adaptive vs static vs oracle\n  \
         quant-eval  INT4 scheme quality (Table I)\n  \
         microbench  η/ρ simulation-model accuracy (Fig 5)\n\n\
         Run `hap <command> --help` for flags."
    );
}

fn parse_node(gpu: &str, gpus: usize) -> anyhow::Result<NodeConfig> {
    let spec = GpuSpec::preset(gpu)
        .ok_or_else(|| anyhow::anyhow!("unknown GPU preset '{gpu}' (a100|a6000|v100|cpu-sim)"))?;
    Ok(NodeConfig::new(spec, gpus))
}

fn parse_model(name: &str) -> anyhow::Result<MoEModelConfig> {
    MoEModelConfig::preset(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown model preset '{name}' (mixtral-8x7b|qwen1.5-moe-a2.7b|qwen2-57b-a14b|tiny-moe)"
        )
    })
}

fn parse_scenario(name: &str, batch: usize) -> anyhow::Result<Scenario> {
    let s = match name {
        "short-constrained" => Scenario::short_constrained(),
        "short-extended" => Scenario::short_extended(),
        "long-constrained" => Scenario::long_constrained(),
        "long-extended" => Scenario::long_extended(),
        other => anyhow::bail!("unknown scenario '{other}'"),
    };
    Ok(s.with_batch(batch))
}

fn usize_flag(p: &hap::util::args::ParsedArgs, name: &str) -> anyhow::Result<usize> {
    p.get_usize(name).map_err(anyhow::Error::msg)
}

fn cmd_plan(args: &[String]) -> anyhow::Result<()> {
    let mut spec = ArgSpec::new("hap plan", "Search the optimal hybrid parallel strategy");
    spec.flag("model", "mixtral-8x7b", "model preset");
    spec.flag("gpu", "a6000", "GPU preset");
    spec.flag("gpus", "4", "number of devices");
    spec.flag("scenario", "long-constrained", "scenario preset");
    spec.flag("batch", "16", "global batch size");
    spec.bool_flag("verbose", "print the search space and pruning");
    let p = spec.parse(args).map_err(anyhow::Error::msg)?;

    let model = parse_model(p.get("model"))?;
    let node = parse_node(p.get("gpu"), usize_flag(&p, "gpus")?)?;
    let scenario = parse_scenario(p.get("scenario"), usize_flag(&p, "batch")?)?;

    let planner = HapPlanner::new(&model, &node);
    if p.get_bool("verbose") {
        let space = planner.search_space(&scenario);
        println!(
            "search space: K_a={} ({:?}) K_e={} ({:?}), {} decisions",
            space.k_a(),
            space.attn.iter().map(|a| a.label()).collect::<Vec<_>>(),
            space.k_e(),
            space.expert.iter().map(|e| e.label()).collect::<Vec<_>>(),
            space.decision_count()
        );
        for (label, why) in &space.pruned {
            println!("  pruned {label}: {why:?}");
        }
    }
    let plan = planner.plan(&scenario, scenario.generate)?;
    println!("{plan}");
    let tp = planner.tp_baseline(&scenario);
    println!(
        "\nTP baseline: {:.1} ms → predicted speedup {:.2}x",
        tp * 1e3,
        tp / plan.predicted_total
    );
    Ok(())
}

fn cmd_breakdown(args: &[String]) -> anyhow::Result<()> {
    let mut spec = ArgSpec::new("hap breakdown", "Per-layer latency breakdown (Fig 2)");
    spec.flag("model", "mixtral-8x7b", "model preset");
    spec.flag("gpu", "a6000", "GPU preset");
    spec.flag("gpus", "4", "number of devices");
    spec.flag("seq", "2048", "sequence length");
    spec.flag("batch", "16", "batch size");
    let p = spec.parse(args).map_err(anyhow::Error::msg)?;

    let model = parse_model(p.get("model"))?;
    let node = parse_node(p.get("gpu"), usize_flag(&p, "gpus")?)?;
    let n = node.num_devices;
    let sc = Scenario::new("breakdown", usize_flag(&p, "seq")?, 64, usize_flag(&p, "batch")?);
    let engine = Engine::new(&model, &node);

    let tp = engine.run_static(&AttnStrategy::new(n, 1), &ExpertStrategy::new(n, 1), &sc, 1);
    let ep = engine.run_static(&AttnStrategy::new(1, n), &ExpertStrategy::new(1, n), &sc, 1);

    let nl = model.layers as f64;
    let mut t =
        Table::new(&["stage", "strategy", "attn (ms)", "expert (ms)", "comm (ms)", "total (ms)"]);
    for (name, run) in [("TP", &tp), ("EP", &ep)] {
        t.row(&[
            "prefill".into(),
            name.into(),
            format!("{:.2}", run.prefill.attn / nl * 1e3),
            format!("{:.2}", run.prefill.expert / nl * 1e3),
            format!("{:.2}", run.prefill.comm / nl * 1e3),
            format!("{:.2}", run.prefill.total() / nl * 1e3),
        ]);
    }
    for (name, run) in [("TP", &tp), ("EP", &ep)] {
        let steps = sc.generate as f64;
        t.row(&[
            "decode".into(),
            name.into(),
            format!("{:.3}", run.decode.attn / nl / steps * 1e3),
            format!("{:.3}", run.decode.expert / nl / steps * 1e3),
            format!("{:.3}", run.decode.comm / nl / steps * 1e3),
            format!("{:.3}", run.decode.total() / nl / steps * 1e3),
        ]);
    }
    println!(
        "per-layer latency breakdown, {} on {} (seq {}):",
        model.name,
        node.label(),
        sc.context
    );
    t.print();
    Ok(())
}

fn cmd_sweep(args: &[String]) -> anyhow::Result<()> {
    let mut spec = ArgSpec::new("hap sweep", "HAP vs TP speedups across scenarios");
    spec.flag("gpu", "a6000", "GPU preset");
    spec.flag("gpus", "4", "number of devices");
    spec.flag("batch", "16", "global batch size");
    let p = spec.parse(args).map_err(anyhow::Error::msg)?;
    let node = parse_node(p.get("gpu"), usize_flag(&p, "gpus")?)?;
    let batch = usize_flag(&p, "batch")?;

    let mut t = Table::new(&["model", "scenario", "TP (s)", "HAP (s)", "speedup", "HAP plan"]);
    for model in MoEModelConfig::paper_models() {
        let planner = HapPlanner::new(&model, &node);
        let engine = Engine::new(&model, &node);
        for sc in Scenario::table2() {
            let sc = sc.with_batch(batch);
            let plan = planner.plan(&sc, sc.generate)?;
            let n = node.num_devices;
            let tp = engine
                .run_static(&AttnStrategy::new(n, 1), &ExpertStrategy::new(n, 1), &sc, 1)
                .total();
            let hap = engine.run_plan(&plan, &sc, 1).total();
            t.row(&[
                model.name.clone(),
                sc.name.clone(),
                format!("{:.3}", tp),
                format!("{:.3}", hap),
                format!("{:.2}x", tp / hap),
                plan.signature(),
            ]);
        }
    }
    println!("HAP vs static TP on {} (measured on the cluster simulator):", node.label());
    t.print();
    Ok(())
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let mut spec = ArgSpec::new("hap serve", "Serve a synthetic workload on the tiny-MoE");
    spec.flag("artifacts", "artifacts", "artifact directory (pjrt backend)");
    spec.flag(
        "backend",
        "pjrt",
        "execution backend: pjrt (AOT artifacts) | host (grid engine on synthetic weights)",
    );
    spec.flag(
        "engine",
        "gang",
        "scheduler: gang (batch run-to-completion) | streaming (continuous batching; host backend)",
    );
    spec.flag("requests", "16", "number of requests");
    spec.flag("gen", "16", "tokens to generate per request");
    spec.flag("plan", "hap", "plan: hap | tp | adaptive");
    spec.flag("tp", "4", "device count (attention TP degree)");
    spec.flag("plan-cache", "", "persist the adaptive plan cache at this path");
    spec.flag(
        "prefill-chunk",
        "0",
        "streaming engine: max prompt tokens prefilled per joiner per iteration (0 = unchunked)",
    );
    spec.flag(
        "pipeline-chunks",
        "1",
        "host backend: micro-chunk pipeline width K — expert layers split the token batch \
         into K ranged chunks whose FFN compute overlaps the previous chunk's combine, and \
         the streaming engine batches same-length joiner chunks (1 = module-sequential)",
    );
    spec.flag(
        "prefill-budget-ms",
        "0",
        "streaming engine with --pipeline-chunks > 1: size joiner prefill chunks from the \
         measured prefill rate so one chunk costs about this many ms (0 = static sizing)",
    );
    spec.flag(
        "quant",
        "",
        "weight quantization for the packed host kernels: int8 | int4 (host backend)",
    );
    spec.flag(
        "kv",
        "padded",
        "KV-cache layout: padded (per-slot max_len rows) | paged (block pool with \
         copy-on-write prefix sharing; host backend, forces --engine streaming)",
    );
    spec.flag(
        "kv-block",
        "8",
        "paged KV: tokens per block (with --kv paged)",
    );
    spec.flag(
        "fault-trace",
        "",
        "inject deterministic device faults: comma-separated KIND@ITER[@dDEV], \
         KIND = crash | stall<N> | transient<N> (host backend; forces --engine streaming)",
    );
    spec.flag(
        "trace-out",
        "",
        "record the deterministic event trace and write it (JSONL) to this path (host backend)",
    );
    spec.flag(
        "metrics-out",
        "",
        "write the final metrics registry to this path (.prom = Prometheus text, else JSON)",
    );
    let p = spec.parse(args).map_err(anyhow::Error::msg)?;

    let scheduling = hap::serving::Scheduling::parse(p.get("engine"))
        .ok_or_else(|| anyhow::anyhow!("unknown engine '{}' (gang | streaming)", p.get("engine")))?;
    let fault = match p.get("fault-trace") {
        "" => None,
        trace => Some(hap::model::FaultPlan::parse_trace(trace)?),
    };
    let scheduling = if fault.is_some() && scheduling == hap::serving::Scheduling::Gang {
        eprintln!(
            "--fault-trace: gang scheduling latches on the first fault; \
             upgrading to --engine streaming so recovery can run"
        );
        hap::serving::Scheduling::Streaming
    } else {
        scheduling
    };
    let kv = match p.get("kv") {
        "" | "padded" => hap::model::KvLayout::Padded,
        "paged" => {
            let block_size = usize_flag(&p, "kv-block")?;
            if block_size == 0 {
                anyhow::bail!("--kv-block must be at least 1");
            }
            // 0 blocks = auto: the padded-equal pool,
            // ceil(batch * max_len / block_size).
            hap::model::KvLayout::Paged { block_size, num_blocks: 0 }
        }
        other => anyhow::bail!("unknown kv layout '{other}' (padded | paged)"),
    };
    let scheduling = if kv.is_paged() && scheduling == hap::serving::Scheduling::Gang {
        eprintln!(
            "--kv paged: gang prefill owns whole padded batches; \
             upgrading to --engine streaming where the block pool serves"
        );
        hap::serving::Scheduling::Streaming
    } else {
        scheduling
    };

    let n = usize_flag(&p, "tp")?;
    let make_config = |meta: &hap::runtime::TinyModelMeta| -> anyhow::Result<ServeConfig> {
        let mut config = match p.get("plan") {
            "tp" => ServeConfig::tp(n),
            "hap" => ServeConfig::hap_transition(n),
            "adaptive" => {
                // Adapt for the model shape actually being served.
                let mut c = ServeConfig::adaptive(n);
                c.adaptive = c.adaptive.take().map(|a| a.with_manifest_model(meta));
                c
            }
            other => anyhow::bail!("unknown plan '{other}'"),
        };
        let cache_path = p.get("plan-cache");
        if !cache_path.is_empty() {
            if let Some(a) = &mut config.adaptive {
                a.plan_cache = Some(std::path::PathBuf::from(cache_path));
            } else {
                eprintln!("--plan-cache only applies to --plan adaptive (ignored)");
            }
        }
        config.prefill_chunk = usize_flag(&p, "prefill-chunk")?;
        if config.prefill_chunk > 0 && scheduling != hap::serving::Scheduling::Streaming {
            // Zeroed, not just warned about: the gang entry points now
            // reject streaming-only knobs with typed errors.
            eprintln!("--prefill-chunk only applies to --engine streaming (ignored)");
            config.prefill_chunk = 0;
        }
        config.pipeline_chunks = usize_flag(&p, "pipeline-chunks")?;
        if config.pipeline_chunks == 0 {
            anyhow::bail!("--pipeline-chunks must be at least 1");
        }
        config.prefill_budget_ms = p.get_f64("prefill-budget-ms").map_err(anyhow::Error::msg)?;
        if config.prefill_budget_ms < 0.0 {
            anyhow::bail!("--prefill-budget-ms must be >= 0");
        }
        if config.prefill_budget_ms > 0.0 && scheduling != hap::serving::Scheduling::Streaming {
            eprintln!("--prefill-budget-ms only applies to --engine streaming (ignored)");
            config.prefill_budget_ms = 0.0;
        }
        config.quant = match p.get("quant") {
            "" => None,
            q => Some(
                hap::quant::QuantKind::parse(q)
                    .ok_or_else(|| anyhow::anyhow!("unknown quant '{q}' (int8 | int4)"))?,
            ),
        };
        config.kv = kv;
        Ok(config)
    };
    let nreq = usize_flag(&p, "requests")?;
    let gen = usize_flag(&p, "gen")?;
    let make_workload = |meta: &hap::runtime::TinyModelMeta| -> Vec<Request> {
        let mut rng = Rng::new(7);
        (0..nreq as u64)
            .map(|id| {
                let len = rng.range(meta.prefill_len / 2, meta.prefill_len);
                let prompt: Vec<i32> =
                    (0..len).map(|_| rng.below(meta.vocab) as i32).collect();
                Request::new(id, prompt, gen)
            })
            .collect()
    };

    let trace_out = p.get("trace-out");
    let metrics_out = p.get("metrics-out");
    let report = match p.get("backend") {
        "pjrt" => {
            if fault.is_some() {
                anyhow::bail!(
                    "--fault-trace requires --backend host: fault injection instruments \
                     the host grid engine's device map"
                );
            }
            if !trace_out.is_empty() {
                anyhow::bail!(
                    "--trace-out requires --backend host (the recorder instruments the \
                     host grid engine)"
                );
            }
            if scheduling == hap::serving::Scheduling::Streaming {
                anyhow::bail!(
                    "--engine streaming requires --backend host: the fixed-shape PJRT \
                     artifacts pin one scalar decode position per batch"
                );
            }
            if !p.get("quant").is_empty() {
                anyhow::bail!(
                    "--quant requires --backend host: the PJRT artifacts consume f32 weights"
                );
            }
            if kv.is_paged() {
                anyhow::bail!(
                    "--kv paged requires --backend host: the fixed-shape PJRT artifacts \
                     address contiguous padded KV rows"
                );
            }
            if usize_flag(&p, "pipeline-chunks")? > 1 {
                anyhow::bail!(
                    "--pipeline-chunks requires --backend host: the PJRT artifacts are \
                     monolithic full-batch programs"
                );
            }
            let dir = Path::new(p.get("artifacts"));
            let rt = hap::runtime::PjrtRuntime::load(dir)?;
            let m = rt.manifest.model.clone();
            let config = make_config(&m)?;
            println!(
                "serving {} requests ({} plan: {}) on pjrt ...",
                nreq,
                p.get("plan"),
                config.label()
            );
            serve_workload(&rt, &config, make_workload(&m))?
        }
        "host" => {
            // Artifact-free: the grid engine's host kernels over
            // seeded synthetic weights.
            let meta = hap::runtime::TinyModelMeta::host_demo();
            let weights = hap::model::WeightStore::synthetic(&meta, 0);
            let mut exec = hap::model::ModelExecutor::host(weights);
            if let Some(fp) = fault {
                println!("fault injection: {}", p.get("fault-trace"));
                exec.set_fault_plan(fp);
            }
            let config = make_config(&meta)?;
            println!(
                "serving {} requests ({} plan: {}, {} engine) on the host grid engine ...",
                nreq,
                p.get("plan"),
                config.label(),
                p.get("engine"),
            );
            let recorder = if trace_out.is_empty() {
                hap::obs::Recorder::disabled()
            } else {
                hap::obs::Recorder::new()
            };
            hap::serving::serve_with_recorder(
                &mut exec,
                &config,
                scheduling,
                make_workload(&meta),
                recorder,
            )?
        }
        other => anyhow::bail!("unknown backend '{other}' (pjrt | host)"),
    };
    println!("{}", report.metrics.summary());
    println!(
        "compute split: prefill {:.2} s, decode {:.2} s",
        report.prefill_time, report.decode_time
    );
    if !trace_out.is_empty() {
        std::fs::write(trace_out, hap::obs::events_to_jsonl(&report.trace))?;
        println!("wrote {} trace events to {trace_out}", report.trace.len());
    }
    if !metrics_out.is_empty() {
        let text = if metrics_out.ends_with(".prom") {
            report.telemetry.to_prometheus()
        } else {
            report.telemetry.to_json().to_string_pretty()
        };
        std::fs::write(metrics_out, text)?;
        println!("wrote metrics to {metrics_out}");
    }
    Ok(())
}

fn cmd_trace(args: &[String]) -> anyhow::Result<()> {
    let sub = args.first().map(|s| s.as_str()).unwrap_or("");
    if sub != "summarize" {
        anyhow::bail!("usage: hap trace summarize --in <trace.jsonl> [--json <path>]");
    }
    let mut spec = ArgSpec::new(
        "hap trace summarize",
        "Fold a serve trace (JSONL) into a per-module time breakdown (Fig 2 style)",
    );
    spec.flag("in", "", "trace path (from `hap serve --trace-out`)");
    spec.flag("json", "", "also write the summary JSON to this path");
    let p = spec.parse(&args[1..]).map_err(anyhow::Error::msg)?;
    let path = p.get("in");
    if path.is_empty() {
        anyhow::bail!("--in <trace.jsonl> is required");
    }
    let text = std::fs::read_to_string(path)?;
    let mut lines = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        lines.push(
            hap::util::json::Json::parse(line)
                .map_err(|e| anyhow::anyhow!("{path}:{}: {e}", i + 1))?,
        );
    }
    let summary = hap::obs::summarize_lines(&lines);
    print!("{}", summary.render());
    let out = p.get("json");
    if !out.is_empty() {
        std::fs::write(out, summary.to_json().to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_adapt_replay(args: &[String]) -> anyhow::Result<()> {
    let mut spec = ArgSpec::new(
        "hap adapt-replay",
        "Replay a traffic trace: adaptive re-planning vs static plans vs oracle",
    );
    spec.flag("model", "mixtral-8x7b", "model preset");
    spec.flag("gpu", "a6000", "GPU preset");
    spec.flag("gpus", "4", "number of devices");
    spec.flag("trace", "phase-shift", "trace: phase-shift | diurnal | ramp | oscillating");
    spec.flag("batches", "80", "total trace length in batches");
    spec.flag("batch", "16", "nominal global batch size");
    spec.flag("seed", "17", "trace jitter seed");
    spec.flag("json", "", "write the comparison JSON to this path");
    spec.flag(
        "audit-out",
        "",
        "write the adaptive run's plan-decision audit log (JSONL, one consult per batch) here",
    );
    spec.flag("plan-cache", "", "load/save the adaptive plan cache at this path");
    spec.flag("fail-at", "", "also replay a device crash at this batch index (degraded re-plan)");
    spec.flag("survivors", "2", "surviving device count after --fail-at (power of two)");
    let p = spec.parse(args).map_err(anyhow::Error::msg)?;

    let model = parse_model(p.get("model"))?;
    let node = parse_node(p.get("gpu"), usize_flag(&p, "gpus")?)?;
    let batches = usize_flag(&p, "batches")?;
    let batch = usize_flag(&p, "batch")?;
    let seed = usize_flag(&p, "seed")? as u64;
    let trace = hap::adapt::WorkloadTrace::preset(p.get("trace"), batches, batch, seed)
        .ok_or_else(|| anyhow::anyhow!("unknown trace '{}'", p.get("trace")))?;

    let planner = HapPlanner::new(&model, &node);
    let cache_path = p.get("plan-cache");
    let seed_cache = if cache_path.is_empty() {
        None
    } else {
        let cache =
            hap::adapt::PlanCache::load(Path::new(cache_path), &model, &node)?;
        println!("plan cache: restored {} entries from {cache_path}", cache.restored);
        Some(cache)
    };
    let (cmp, warmed) = hap::adapt::replay::compare_seeded(
        &planner,
        &trace,
        &hap::adapt::ControllerConfig::default(),
        32,
        seed_cache,
    )?;
    if !cache_path.is_empty() {
        warmed.save(Path::new(cache_path))?;
        println!("plan cache: saved {} entries to {cache_path}", warmed.len());
    }

    println!(
        "replaying '{}' ({} batches) for {} on {}:",
        cmp.trace,
        cmp.batches,
        model.name,
        node.label()
    );
    let mut t = Table::new(&["policy", "total (s)", "switches", "switch time (s)", "vs adaptive"]);
    for r in cmp.policies() {
        t.row(&cmp.row_cells(r));
    }
    t.print();
    println!("{}", cmp.summary_line());
    let fail_at = p.get("fail-at");
    if !fail_at.is_empty() {
        let crash_at: usize = fail_at
            .parse()
            .map_err(|_| anyhow::anyhow!("--fail-at must be a batch index, got '{fail_at}'"))?;
        let survivors = usize_flag(&p, "survivors")?;
        let deg = hap::adapt::replay::replay_adaptive_degraded(
            &planner,
            &trace,
            &hap::adapt::ControllerConfig::default(),
            32,
            crash_at,
            survivors,
        )?;
        println!(
            "degraded replay (crash at batch {crash_at}, {survivors} survivors): \
             {:.3} s total, {} switches ({:.3} s) — {:+.1}% makespan vs no-fault adaptive",
            deg.total_s,
            deg.switches,
            deg.switch_time_s,
            (deg.total_s / cmp.adaptive.total_s - 1.0) * 100.0
        );
    }
    let audit_out = p.get("audit-out");
    if !audit_out.is_empty() {
        // Re-run the adaptive policy with the audit hook: every consult
        // records its breakeven arithmetic, so a divergence between the
        // table above and expectations can be explained line by line.
        let (_, audit) = hap::adapt::replay::replay_adaptive_audited(
            &planner,
            &trace,
            &hap::adapt::ControllerConfig::default(),
            32,
        )?;
        let mut text = String::new();
        for consult in &audit {
            text.push_str(&consult.to_json().to_string_compact());
            text.push('\n');
        }
        std::fs::write(audit_out, text)?;
        println!("wrote {} consult records to {audit_out}", audit.len());
    }
    let out = p.get("json");
    if !out.is_empty() {
        std::fs::write(out, cmp.to_json().to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_quant(args: &[String]) -> anyhow::Result<()> {
    let mut spec = ArgSpec::new("hap quant-eval", "INT4 scheme quality (Table I)");
    spec.flag("rows", "512", "matrix rows");
    spec.flag("cols", "1024", "matrix cols");
    spec.flag("seed", "3", "weight seed");
    let p = spec.parse(args).map_err(anyhow::Error::msg)?;
    let rows = usize_flag(&p, "rows")?;
    let cols = usize_flag(&p, "cols")?;
    let mut rng = Rng::new(usize_flag(&p, "seed")? as u64);
    let mut data = rng.normal_vec_f32(rows * cols, 0.02);
    for r in 0..rows {
        data[r * cols] = if r % 2 == 0 { 0.3 } else { -0.3 }; // outliers
    }
    let mut t = Table::new(&["scheme", "cosine sim", "rmse", "max err", "compression"]);
    for scheme in
        [Scheme::PerTensor, Scheme::PerChannel, Scheme::PerGroup { group_size: 128 }]
    {
        let rep = quant::evaluate(&data, rows, cols, scheme);
        t.row(&[
            rep.scheme.name(),
            format!("{:.5}", rep.cosine_similarity),
            format!("{:.2e}", rep.rmse),
            format!("{:.2e}", rep.max_abs_err),
            format!("{:.2}x", rep.compression_ratio()),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_microbench(args: &[String]) -> anyhow::Result<()> {
    let mut spec = ArgSpec::new("hap microbench", "η/ρ simulation-model accuracy (Fig 5)");
    spec.flag("gpu", "a6000", "GPU preset");
    spec.flag("samples", "300", "held-out samples");
    let p = spec.parse(args).map_err(anyhow::Error::msg)?;
    let gpu = GpuSpec::preset(p.get("gpu")).ok_or_else(|| anyhow::anyhow!("unknown gpu"))?;
    let lm = hap::sim::LatencyModel::train(&gpu, 0x4A9);
    let n = usize_flag(&p, "samples")?;

    let (comp_err, comm_err) = hap::sim::latency::heldout_errors(&lm, &gpu, n);
    println!(
        "computational model: mean err {:.1}% (paper target <10%)",
        hap::util::stats::mean(&comp_err) * 100.0
    );
    println!(
        "communication model: mean err {:.1}% (paper target <5%)",
        hap::util::stats::mean(&comm_err) * 100.0
    );
    Ok(())
}
