//! # HAP — Hybrid Adaptive Parallelism for Efficient MoE Inference
//!
//! Reproduction of *"HAP: Hybrid Adaptive Parallelism for Efficient
//! Mixture-of-Experts Inference"* (Lin et al., CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the coordinator: latency simulation models
//!   ([`sim`]), the parallel-strategy search space ([`strategy`]), an
//!   exact 0-1 ILP solver ([`ilp`]), the HAP planner ([`planner`]), the
//!   dynamic parallelism-transition mechanism ([`transition`], [`quant`]),
//!   a discrete-event multi-GPU cluster simulator ([`cluster`]) with an
//!   MoE execution engine ([`engine`]), an online adaptation loop
//!   ([`adapt`]: traffic window → plan cache → switch controller →
//!   trace replay), and a real serving runtime ([`serving`], [`model`])
//!   built on a device-grid execution engine (`ShardPlan` →
//!   `DeviceGrid` roles + collectives) that runs hybrid EP×TP / DP×TP
//!   plans either on AOT-compiled JAX/Pallas artifacts through PJRT
//!   ([`runtime`]) or artifact-free on host kernels. The public serving
//!   surface is the streaming [`serving::Engine`]: continuous batching
//!   with per-slot KV join/leave and in-flight plan switches at
//!   iteration granularity.
//! - **L2 (python/compile/model.py)** — the tiny-MoE JAX model, lowered
//!   once to HLO text (`artifacts/*.hlo.txt`).
//! - **L1 (python/compile/kernels/)** — Pallas kernels (expert FFN,
//!   attention, top-k gating, INT4 dequant), validated against pure-jnp
//!   oracles at build time.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! Rust binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use hap::config::{hardware::NodeConfig, model::MoEModelConfig, scenario::Scenario};
//! use hap::planner::HapPlanner;
//!
//! let model = MoEModelConfig::mixtral_8x7b();
//! let node = NodeConfig::a6000x(4);
//! let scenario = Scenario::long_constrained(); // 4096-token ctx, 64-token gen
//! let planner = HapPlanner::new(&model, &node);
//! let plan = planner.plan(&scenario, 8).expect("feasible plan");
//! println!("{plan}");
//! ```

pub mod adapt;
pub mod benchkit;
pub mod cluster;
pub mod config;
pub mod engine;
pub mod ilp;
pub mod model;
pub mod obs;
pub mod planner;
pub mod quant;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod strategy;
pub mod transition;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
