//! Sliding-window traffic estimation → quantized scenario keys.
//!
//! The router/batcher feeds one [`TrafficSample`] per admitted request;
//! the window keeps the last `capacity` samples, **quantizes each
//! sample individually** to power-of-two buckets
//! ([`QuantizedScenario`]), and emits the *modal* key — the bucket most
//! of the recent traffic falls in, with ties broken toward the most
//! recent samples. Voting over whole sample keys (rather than
//! summarizing each dimension independently) means the emitted key is
//! always one that real traffic produced: at a phase boundary the
//! window flips from the old phase's key to the new one without ever
//! synthesizing a "phantom" mixture (e.g. the old phase's generation
//! length paired with the new phase's context), so the controller
//! never pays a weight move toward traffic that does not exist.

use crate::config::scenario::Scenario;
use std::collections::{HashMap, VecDeque};

/// One observed request (or batch-aggregate) fed to the monitor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrafficSample {
    /// Prompt length in tokens.
    pub prompt: usize,
    /// Requested generation length in tokens.
    pub generate: usize,
    /// Batch size the request was (or will be) served under.
    pub batch: usize,
}

/// A scenario quantized to power-of-two buckets — the plan-cache key.
///
/// The stored values are the bucket *representatives* (powers of two),
/// so equal keys mean "same quantized traffic" and
/// [`QuantizedScenario::to_scenario`] reconstructs the representative
/// [`Scenario`] the planner solves for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QuantizedScenario {
    pub context: usize,
    pub generate: usize,
    pub batch: usize,
}

/// Round `x` to the nearest power of two in log space (ties go up);
/// `x = 0` maps to 1 so keys stay well-formed.
pub fn quantize_pow2(x: usize) -> usize {
    if x <= 1 {
        return 1;
    }
    let exp = (x as f64).log2().round() as u32;
    1usize << exp.min(usize::BITS - 2)
}

impl QuantizedScenario {
    /// Quantize raw per-dimension estimates into a key.
    pub fn from_estimates(context: usize, generate: usize, batch: usize) -> Self {
        QuantizedScenario {
            context: quantize_pow2(context),
            generate: quantize_pow2(generate),
            batch: quantize_pow2(batch),
        }
    }

    /// Quantize a full scenario (oracle/static baselines reuse the same
    /// bucketing the window applies).
    pub fn from_scenario(sc: &Scenario) -> Self {
        Self::from_estimates(sc.context, sc.generate, sc.batch)
    }

    /// The representative scenario this key stands for.
    pub fn to_scenario(&self) -> Scenario {
        Scenario::new(&self.label(), self.context, self.generate, self.batch)
    }

    pub fn label(&self) -> String {
        format!("q-ctx{}-gen{}-b{}", self.context, self.generate, self.batch)
    }
}

/// Sliding-window monitor over recent traffic.
#[derive(Debug, Clone)]
pub struct TrafficWindow {
    samples: VecDeque<TrafficSample>,
    capacity: usize,
}

impl TrafficWindow {
    pub fn new(capacity: usize) -> TrafficWindow {
        assert!(capacity > 0, "window capacity must be positive");
        TrafficWindow { samples: VecDeque::with_capacity(capacity), capacity }
    }

    /// Record one sample, evicting the oldest beyond capacity.
    pub fn observe(&mut self, sample: TrafficSample) {
        if self.samples.len() == self.capacity {
            self.samples.pop_front();
        }
        self.samples.push_back(sample);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn key_of(s: &TrafficSample) -> QuantizedScenario {
        QuantizedScenario::from_estimates(s.prompt, s.generate, s.batch)
    }

    /// Current quantized scenario estimate (None until any traffic):
    /// the modal per-sample key, ties broken toward recency. Always a
    /// key some real sample produced — never a cross-dimension mixture.
    pub fn scenario(&self) -> Option<QuantizedScenario> {
        if self.samples.is_empty() {
            return None;
        }
        let mut counts: HashMap<QuantizedScenario, usize> = HashMap::new();
        for s in &self.samples {
            *counts.entry(Self::key_of(s)).or_insert(0) += 1;
        }
        let max = counts.values().copied().max().unwrap_or(0);
        self.samples.iter().rev().map(Self::key_of).find(|k| counts[k] == max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_quantization_buckets_nearby_values() {
        assert_eq!(quantize_pow2(0), 1);
        assert_eq!(quantize_pow2(1), 1);
        assert_eq!(quantize_pow2(3), 4);
        assert_eq!(quantize_pow2(256), 256);
        // ±10% around a bucket center stays in the bucket.
        for x in [230, 256, 281] {
            assert_eq!(quantize_pow2(x), 256, "x={x}");
        }
        for x in [3700, 4096, 4500] {
            assert_eq!(quantize_pow2(x), 4096, "x={x}");
        }
    }

    #[test]
    fn window_emits_quantized_modal_key() {
        let mut w = TrafficWindow::new(16);
        assert!(w.scenario().is_none());
        for i in 0..8 {
            w.observe(TrafficSample { prompt: 250 + i, generate: 60 + i, batch: 16 });
        }
        let key = w.scenario().unwrap();
        assert_eq!(key, QuantizedScenario { context: 256, generate: 64, batch: 16 });
        assert_eq!(key.to_scenario().context, 256);
    }

    #[test]
    fn mixed_window_never_emits_phantom_keys() {
        // At a phase boundary the window holds both phases; the emitted
        // key must be one of the two real keys (most-recent on a tie),
        // never a cross-dimension mixture like (doc ctx, chat gen).
        let chat = TrafficSample { prompt: 256, generate: 2048, batch: 16 };
        let doc = TrafficSample { prompt: 4096, generate: 64, batch: 16 };
        let chat_key = QuantizedScenario::from_estimates(256, 2048, 16);
        let doc_key = QuantizedScenario::from_estimates(4096, 64, 16);
        let mut w = TrafficWindow::new(8);
        for _ in 0..8 {
            w.observe(chat);
        }
        for pushed in 1..=8usize {
            w.observe(doc);
            let key = w.scenario().unwrap();
            assert!(key == chat_key || key == doc_key, "phantom key {key:?}");
            // Majority (or most-recent on the 4/4 tie) rules.
            if pushed >= 4 {
                assert_eq!(key, doc_key, "after {pushed} doc samples");
            } else {
                assert_eq!(key, chat_key, "after {pushed} doc samples");
            }
        }
    }

    #[test]
    fn window_slides_to_new_phase() {
        let mut w = TrafficWindow::new(8);
        for _ in 0..8 {
            w.observe(TrafficSample { prompt: 256, generate: 2048, batch: 16 });
        }
        let chat = w.scenario().unwrap();
        // A full window of long-doc traffic flips the key.
        for _ in 0..8 {
            w.observe(TrafficSample { prompt: 4096, generate: 64, batch: 16 });
        }
        let doc = w.scenario().unwrap();
        assert_ne!(chat, doc);
        assert_eq!(doc.context, 4096);
        assert_eq!(doc.generate, 64);
    }

    #[test]
    fn jitter_within_a_phase_keeps_one_key() {
        let mut w = TrafficWindow::new(32);
        let mut keys = std::collections::HashSet::new();
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..200 {
            let jit = |x: usize| ((x as f64) * rng.range_f64(0.92, 1.08)) as usize;
            w.observe(TrafficSample { prompt: jit(4096), generate: jit(64), batch: jit(16) });
            keys.insert(w.scenario().unwrap());
        }
        assert_eq!(keys.len(), 1, "jittered phase split into {keys:?}");
    }
}
