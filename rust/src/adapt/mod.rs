//! Online adaptive re-planning (paper §III "adaptive" loop, closed).
//!
//! The planner ([`crate::planner`]) solves for the optimal hybrid
//! strategy of *one* scenario; this module is the control loop that
//! keeps consulting it while traffic shifts — the half of "Hybrid
//! **Adaptive** Parallelism" that a one-shot offline solve leaves on
//! the table (cf. HD-MoE's dynamic TP/EP scheduling, arXiv 2509.09420,
//! and EPS-MoE's phase-aware pipeline scheduling, arXiv 2410.12247).
//!
//! Four cooperating parts:
//!
//! - [`window`] — a sliding-window traffic monitor fed by the router/
//!   batcher that tracks batch-size, prompt-length, and generation-
//!   length distributions and emits a **quantized**
//!   [`window::QuantizedScenario`], bucketed so nearby traffic maps to
//!   the same key;
//! - [`cache`] — memoized `plan()` results keyed on (model, quantized
//!   scenario) with hit/miss counters, invalidated when the platform
//!   ([`crate::config::hardware::GpuSpec`] / device count) changes;
//! - [`controller`] — hysteresis logic that only re-shards weights when
//!   the projected per-batch gain of the candidate plan, amortized over
//!   an estimated phase dwell time, clears the strategy-switch cost by
//!   a configurable safety factor — with debounce + cooldown so
//!   oscillating traffic cannot thrash weights across layouts;
//! - [`replay`] — a trace-driven replay harness: synthetic workload
//!   traces (diurnal swell, chat→long-doc phase shift, context ramp,
//!   fast oscillation) replayed through [`crate::cluster::EventSim`]
//!   with [`crate::sim::LatencyModel`] durations, so adaptive vs
//!   static vs oracle comparisons run deterministically without PJRT
//!   artifacts.
//!
//! The serving [`crate::serving::Engine`] consumes the same parts
//! through [`crate::serving::ServeConfig::adaptive`] — consulted at
//! **iteration granularity**: every admission boundary of the streaming
//! scheduler (each batch, in the legacy gang mode). The controller's
//! dwell estimates are therefore denominated in consult boundaries,
//! whichever cadence the caller runs. The `hap adapt-replay` CLI
//! command drives [`replay::compare`] directly.

pub mod cache;
pub mod controller;
pub mod replay;
pub mod window;

pub use cache::PlanCache;
pub use controller::{ControllerConfig, SwitchController, SwitchDecision};
pub use replay::{ReplayComparison, ReplayReport, TracePoint, WorkloadTrace};
pub use window::{QuantizedScenario, TrafficSample, TrafficWindow};

use crate::config::hardware::NodeConfig;
use crate::config::scenario::Scenario;
use crate::obs::PlanConsult;
use crate::planner::{HapPlanner, HybridPlan};
use crate::Result;

/// A measured wall-clock observation for the adaptation loop: how many
/// seconds of model execution produced how many generated tokens under
/// the active plan since the previous consult.
///
/// Gang and streaming schedulers observe latency at different
/// granularities — one whole batch vs a dwell window of scheduler
/// iterations (decode steps + prefill chunks) between admission
/// boundaries. Normalizing both to **seconds per generated token**
/// ([`MeasuredLatency::per_token`]) makes them commensurable with each
/// other and with the planner's predictions (which [`AdaptLoop::step`]
/// divides by the traffic key's `generate × batch` tokens before
/// feeding the controller's mispredict EWMA).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeasuredLatency {
    /// Wall-clock seconds of execution observed.
    pub seconds: f64,
    /// Tokens generated in that time.
    pub tokens: usize,
}

impl MeasuredLatency {
    pub fn new(seconds: f64, tokens: usize) -> MeasuredLatency {
        MeasuredLatency { seconds, tokens }
    }

    /// Seconds per generated token (the normalized observation).
    pub fn per_token(&self) -> f64 {
        self.seconds / self.tokens.max(1) as f64
    }
}

/// The assembled adaptation loop — window → cache → controller — as
/// one per-batch step. Both the serving loop
/// ([`crate::serving::ServeConfig::adaptive`]) and the replay harness
/// ([`replay::replay_adaptive`]) drive this same implementation, so
/// the behavior the replay acceptance tests validate is exactly what
/// production serving executes.
pub struct AdaptLoop {
    pub window: TrafficWindow,
    pub cache: PlanCache,
    pub controller: SwitchController,
    /// Platform the controller's resident plan was selected for; a
    /// change resets the controller (the cache flushes itself).
    platform: Option<NodeConfig>,
    /// Traffic key of the previous step — the traffic a caller-supplied
    /// measured latency was observed under.
    last_key: Option<window::QuantizedScenario>,
    /// Audit record of the most recent [`Self::step`] consult —
    /// everything the controller saw plus its verdict, for the
    /// observability trace (`PlanConsult` events) and
    /// `hap adapt-replay --audit-out`.
    pub last_consult: Option<PlanConsult>,
}

impl AdaptLoop {
    pub fn new(config: ControllerConfig, window_capacity: usize) -> AdaptLoop {
        AdaptLoop {
            window: TrafficWindow::new(window_capacity),
            cache: PlanCache::new(),
            controller: SwitchController::new(config),
            platform: None,
            last_key: None,
            last_consult: None,
        }
    }

    /// One batch: feed `samples` to the window, consult the plan cache
    /// for the quantized key, and let the controller decide. Returns
    /// the plan to execute this batch under, plus the decision (so a
    /// caller can charge `SwitchDecision::Switch` costs to its
    /// timeline).
    ///
    /// `eval` is the scenario the controller's latency economics are
    /// evaluated on: the replay harness passes the actual trace point;
    /// pass `None` to use the quantized key's representative (the
    /// serving loop, which only has the window's view).
    ///
    /// `measured` closes the loop on mispredicted plans: the wall-clock
    /// execution observed since the *previous* consult (which ran under
    /// the current active plan on the previous key's traffic) — one
    /// whole batch in gang mode, the dwell window of scheduler
    /// iterations between admission boundaries in streaming mode. Both
    /// the observation and the planner's prediction for the previous
    /// key are normalized to **seconds per generated token** before
    /// being folded into the controller's mispredict EWMA, so the two
    /// cadences feed the same units and a plan that keeps overrunning
    /// its prediction gets demoted either way.
    pub fn step<I: IntoIterator<Item = TrafficSample>>(
        &mut self,
        planner: &HapPlanner,
        samples: I,
        eval: Option<&Scenario>,
        measured: Option<MeasuredLatency>,
    ) -> Result<(HybridPlan, SwitchDecision)> {
        // Measured-latency feedback for the window that just ran,
        // per-token normalized on both sides (the prediction covers a
        // whole batch of the previous key's traffic: `generate` tokens
        // for each of `batch` rows).
        if let (Some(m), Some(active), Some(lk)) =
            (measured, self.controller.active().cloned(), self.last_key)
        {
            let predicted = replay::predicted_plan_latency(planner, &active, &lk.to_scenario());
            let key_tokens = (lk.generate * lk.batch).max(1) as f64;
            self.controller.observe_measured(
                &active.signature(),
                m.per_token(),
                predicted / key_tokens,
            );
        }
        for s in samples {
            self.window.observe(s);
        }
        // A platform change orphans the resident plan — its strategies
        // target devices that no longer exist — so the controller is
        // re-seeded (counters carry over) and the next step re-adopts
        // from the freshly invalidated cache.
        if self.platform.as_ref() != Some(planner.node) {
            if self.platform.is_some() {
                let mut fresh = SwitchController::new(self.controller.config.clone());
                fresh.switches = self.controller.switches;
                fresh.suppressed = self.controller.suppressed;
                self.controller = fresh;
            }
            self.platform = Some(planner.node.clone());
        }
        let key = self.window.scenario().expect("step requires at least one observed sample");
        let hits_before = self.cache.hits;
        let candidate = self.cache.plan(planner, key)?;
        let cached = self.cache.hits > hits_before;
        // Latency economics only matter when the controller could reach
        // its break-even check this step; on the steady-state,
        // cold-start, debounce, and cooldown paths `step` ignores them,
        // so skip the forest evaluations entirely.
        let evaluated = self.controller.would_evaluate(key);
        let (active_latency, candidate_latency, cost) = if evaluated {
            let active = self.controller.active().expect("would_evaluate implies a resident plan");
            let representative = key.to_scenario();
            let sc = eval.unwrap_or(&representative);
            (
                replay::predicted_plan_latency(planner, active, sc),
                replay::predicted_plan_latency(planner, &candidate, sc),
                replay::switch_cost(planner, &active.expert_decode, &candidate.expert_prefill),
            )
        } else if self.controller.active().is_none() {
            (f64::INFINITY, 0.0, 0.0)
        } else {
            (0.0, 0.0, 0.0)
        };
        let candidate_sig = candidate.signature();
        let active_sig = self.controller.active().map(|p| p.signature());
        let key_tokens = (key.generate * key.batch).max(1) as f64;
        let decision =
            self.controller.step(key, &candidate, active_latency, candidate_latency, cost);
        self.last_consult = Some(PlanConsult {
            key: format!("ctx{}/gen{}/b{}", key.context, key.generate, key.batch),
            candidate: candidate_sig.clone(),
            cached,
            active: active_sig.clone(),
            evaluated,
            predicted_active_s: active_latency,
            predicted_candidate_s: candidate_latency,
            predicted_s_tok: candidate.predicted_total / key_tokens,
            measured_s_tok: measured.map(|m| m.per_token()),
            mispredict_active: active_sig.as_deref().and_then(|s| self.controller.mispredict_ewma(s)),
            mispredict_candidate: self.controller.mispredict_ewma(&candidate_sig),
            switch_cost_s: cost,
            expected_dwell: self.controller.expected_dwell(),
            decision: decision.label().to_string(),
            projected_savings_s: match decision {
                SwitchDecision::Switch { projected_savings, .. } => Some(projected_savings),
                _ => None,
            },
        });
        self.last_key = Some(key);
        let plan = self.controller.active().expect("plan adopted on first step").clone();
        Ok((plan, decision))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MoEModelConfig;

    #[test]
    fn adapt_loop_readopts_after_platform_change() {
        // A redeploy (different node) must not leak the old platform's
        // resident plan, even when the traffic key never changes.
        let m = MoEModelConfig::mixtral_8x7b();
        let pcie = NodeConfig::a6000x(4);
        let nvlink = NodeConfig::a100x(8);
        let mut al = AdaptLoop::new(ControllerConfig::default(), 16);
        let samples =
            || (0..4).map(|_| TrafficSample { prompt: 4096, generate: 64, batch: 4 });
        let p1 = HapPlanner::new(&m, &pcie);
        let (plan, d) = al.step(&p1, samples(), None, None).unwrap();
        assert_eq!(d, SwitchDecision::Adopt);
        assert_eq!(plan.node, pcie.label());
        let p2 = HapPlanner::new(&m, &nvlink);
        let (plan, d) = al.step(&p2, samples(), None, None).unwrap();
        assert_eq!(d, SwitchDecision::Adopt, "stale plan served after redeploy");
        assert_eq!(plan.node, nvlink.label());
        assert_eq!(al.cache.invalidations, 1);
        // Re-adoption is not a weight-moving switch.
        assert_eq!(al.controller.switches, 0);
    }

    #[test]
    fn measured_feedback_is_per_token_normalized() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let mut al = AdaptLoop::new(ControllerConfig::default(), 16);
        let samples =
            || (0..4).map(|_| TrafficSample { prompt: 512, generate: 64, batch: 8 });
        al.step(&planner, samples(), None, None).unwrap();
        let active = al.controller.active().unwrap().clone();
        let key = al.window.scenario().unwrap();
        let batch_pred = replay::predicted_plan_latency(&planner, &active, &key.to_scenario());
        let tokens = key.generate * key.batch;
        // Observe a window that ran exactly 2× slower than predicted,
        // expressed as aggregate seconds over `tokens` generated
        // tokens. The per-token normalization on BOTH sides must land
        // the EWMA at 0.5·1 + 0.5·2 = 1.5 — a unit mismatch (batch
        // seconds against per-token seconds) would clamp the ratio to
        // the 0.25 floor and land at 0.625 instead.
        let measured = MeasuredLatency::new(2.0 * batch_pred, tokens);
        al.step(&planner, samples(), None, Some(measured)).unwrap();
        let e = al
            .controller
            .mispredict_ewma(&active.signature())
            .expect("measured observation never reached the controller");
        assert!((e - 1.5).abs() < 1e-9, "per-token normalization broken: EWMA {e}");
        assert_eq!(al.controller.mispredict_observations(), 1);
    }

    #[test]
    fn consult_audit_records_cold_start_then_cache_hit() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let mut al = AdaptLoop::new(ControllerConfig::default(), 16);
        let samples =
            || (0..4).map(|_| TrafficSample { prompt: 512, generate: 64, batch: 8 });
        al.step(&planner, samples(), None, None).unwrap();
        let c = al.last_consult.clone().expect("consult recorded");
        assert_eq!(c.decision, "adopt");
        assert!(!c.cached, "first consult must be a cache miss");
        assert!(c.active.is_none(), "no active plan before cold start");
        assert!(c.key.starts_with("ctx") && c.key.contains("/gen"));
        assert!(c.predicted_s_tok > 0.0);
        // Second consult on the same key: steady-state stay, cache hit,
        // measured feedback lands in the record.
        al.step(&planner, samples(), None, Some(MeasuredLatency::new(1.0, 100))).unwrap();
        let c = al.last_consult.clone().unwrap();
        assert_eq!(c.decision, "stay");
        assert!(c.cached);
        assert_eq!(c.active, Some(al.controller.active().unwrap().signature()));
        assert!((c.measured_s_tok.unwrap() - 0.01).abs() < 1e-12);
        assert!(c.mispredict_active.is_some(), "feedback must reach the EWMA");
    }
}
