//! Trace-driven replay: adaptive vs static vs oracle, without PJRT.
//!
//! A [`WorkloadTrace`] is a sequence of per-batch traffic points
//! (prompt length, generation length, batch size). The replay harness
//! runs each policy over the trace on a [`EventSim`] device timeline
//! with durations from the platform's [`crate::sim::LatencyModel`]:
//!
//! - **adaptive** — the full loop: [`TrafficWindow`] → quantized key →
//!   [`PlanCache`] → [`SwitchController`]; weight-moving switches are
//!   charged as global transition spans;
//! - **static** — one fixed strategy triple for the whole trace (pure
//!   TP-N, or the best plan for the *first* phase chosen a priori);
//! - **oracle** — the per-phase optimal plan with *free* switches: the
//!   lower bound an online policy is judged against.
//!
//! Everything is deterministic: traces are seeded, the latency model is
//! deterministic per platform, and the simulator is exact, so replay
//! results are reproducible in tests and CI.

use crate::adapt::cache::PlanCache;
use crate::adapt::controller::{ControllerConfig, SwitchDecision};
use crate::adapt::window::{QuantizedScenario, TrafficSample};
use crate::adapt::AdaptLoop;
use crate::cluster::{EventSim, OpKind};
use crate::config::hardware::NodeConfig;
use crate::config::scenario::Scenario;
use crate::obs::PlanConsult;
use crate::planner::{HapPlanner, HybridPlan};
use crate::sim::latency::ModuleLatency;
use crate::strategy::{AttnStrategy, ExpertStrategy};
use crate::transition::TransitionModel;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::Result;

/// One batch worth of traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TracePoint {
    pub context: usize,
    pub generate: usize,
    pub batch: usize,
}

impl TracePoint {
    /// The exact (un-quantized) scenario this batch executes under.
    pub fn scenario(&self) -> Scenario {
        Scenario::new("trace-point", self.context, self.generate, self.batch)
    }

    fn jittered(rng: &mut Rng, context: usize, generate: usize, batch: usize) -> TracePoint {
        let j = |rng: &mut Rng, x: usize| {
            (((x as f64) * rng.range_f64(0.94, 1.06)).round() as usize).max(1)
        };
        TracePoint { context: j(rng, context), generate: j(rng, generate), batch: j(rng, batch) }
    }
}

/// (context, generate) of the "bursty chat" phase: short prompts,
/// extended generation — decode-dominated.
pub const CHAT_PHASE: (usize, usize) = (256, 2048);
/// (context, generate) of the "long document" phase: long prompts,
/// constrained generation — prefill-dominated.
pub const DOC_PHASE: (usize, usize) = (4096, 64);

/// A named, deterministic sequence of per-batch traffic points.
#[derive(Debug, Clone)]
pub struct WorkloadTrace {
    pub name: String,
    pub points: Vec<TracePoint>,
}

impl WorkloadTrace {
    /// Chat → long-doc phase change: `batches_per_phase` batches of
    /// [`CHAT_PHASE`] traffic, then the same of [`DOC_PHASE`], each
    /// point jittered ±6% (within one quantization bucket).
    pub fn phase_shift(batches_per_phase: usize, batch: usize, seed: u64) -> WorkloadTrace {
        let mut rng = Rng::new(seed);
        let mut points = Vec::with_capacity(2 * batches_per_phase);
        for (ctx, gen) in [CHAT_PHASE, DOC_PHASE] {
            for _ in 0..batches_per_phase {
                points.push(TracePoint::jittered(&mut rng, ctx, gen, batch));
            }
        }
        WorkloadTrace { name: "phase-shift".into(), points }
    }

    /// Diurnal load swell: fixed request shape, batch size sweeping
    /// 4 → `peak_batch` → 4 sinusoidally with period `period` batches.
    pub fn diurnal(batches: usize, period: usize, peak_batch: usize, seed: u64) -> WorkloadTrace {
        let mut rng = Rng::new(seed);
        let swing = peak_batch.max(5) as f64 - 4.0;
        let points = (0..batches)
            .map(|i| {
                let phase = (i as f64) / (period.max(1) as f64) * std::f64::consts::TAU;
                let batch = (4.0 + swing * 0.5 * (1.0 + phase.sin())).round() as usize;
                TracePoint::jittered(&mut rng, 512, 256, batch.max(1))
            })
            .collect();
        WorkloadTrace { name: "diurnal".into(), points }
    }

    /// Context ramp: prompt length grows geometrically 128 → 8192 over
    /// the trace (a fleet gradually shifting to long-document traffic).
    pub fn ramp(batches: usize, batch: usize, seed: u64) -> WorkloadTrace {
        let mut rng = Rng::new(seed);
        let points = (0..batches)
            .map(|i| {
                let t = i as f64 / (batches.max(2) - 1) as f64;
                let ctx = (128.0 * (2.0f64).powf(6.0 * t)).round() as usize;
                TracePoint::jittered(&mut rng, ctx, 128, batch)
            })
            .collect();
        WorkloadTrace { name: "ramp".into(), points }
    }

    /// Fast oscillation between [`CHAT_PHASE`] and [`DOC_PHASE`] every
    /// `period` batches — the flap-damping stress test.
    pub fn oscillating(batches: usize, period: usize, batch: usize, seed: u64) -> WorkloadTrace {
        let mut rng = Rng::new(seed);
        let points = (0..batches)
            .map(|i| {
                let (ctx, gen) =
                    if (i / period.max(1)) % 2 == 0 { CHAT_PHASE } else { DOC_PHASE };
                TracePoint::jittered(&mut rng, ctx, gen, batch)
            })
            .collect();
        WorkloadTrace { name: "oscillating".into(), points }
    }

    /// CLI-facing lookup; `batches` is the total trace length.
    pub fn preset(name: &str, batches: usize, batch: usize, seed: u64) -> Option<WorkloadTrace> {
        match name {
            "phase-shift" => {
                // Honor odd totals exactly: build ceil(b/2) per phase,
                // then trim the tail so points.len() == batches.
                let mut t = Self::phase_shift(batches.div_ceil(2).max(1), batch, seed);
                t.points.truncate(batches.max(1));
                Some(t)
            }
            "diurnal" => Some(Self::diurnal(batches, (batches / 4).max(2), batch.max(8), seed)),
            "ramp" => Some(Self::ramp(batches.max(2), batch, seed)),
            "oscillating" => Some(Self::oscillating(batches, 1, batch, seed)),
            _ => None,
        }
    }
}

/// Predicted per-batch cost of running a strategy triple on a scenario.
#[derive(Debug, Clone, Copy)]
pub struct BatchCost {
    pub prefill: ModuleLatency,
    pub decode: ModuleLatency,
    /// The plan's own prefill→decode expert transition (eq. 6), charged
    /// once per batch when the stages differ.
    pub stage_transition: f64,
}

impl BatchCost {
    pub fn total(&self) -> f64 {
        self.prefill.total() + self.decode.total() + self.stage_transition
    }
}

/// Evaluate a strategy triple on one scenario through the planner's
/// latency model (prefill + decode + eq.-6 stage transition).
pub fn batch_cost(
    planner: &HapPlanner,
    attn: &AttnStrategy,
    expert_prefill: &ExpertStrategy,
    expert_decode: &ExpertStrategy,
    sc: &Scenario,
) -> BatchCost {
    let lm = &*planner.latency;
    let prefill = lm.prefill_latency(planner.model, attn, expert_prefill, sc);
    let decode = lm.decode_latency(planner.model, attn, expert_decode, sc);
    let stage_transition = if expert_prefill == expert_decode {
        0.0
    } else {
        let tm = TransitionModel::new(planner.model, &planner.node.gpu);
        tm.cost(lm, expert_prefill, expert_decode, prefill.total()).overhead
    };
    BatchCost { prefill, decode, stage_transition }
}

/// Predicted per-batch latency of a whole plan on (possibly different)
/// traffic — what the controller's economics compare.
pub fn predicted_plan_latency(planner: &HapPlanner, plan: &HybridPlan, sc: &Scenario) -> f64 {
    batch_cost(planner, &plan.attn, &plan.expert_prefill, &plan.expert_decode, sc).total()
}

/// Cost of moving resident weights from one expert layout to another
/// between batches (no live prefill to overlap with → zero overlap
/// budget). Attention weights ride along in the same redistribution;
/// no KV cache moves because batches complete before a plan switch.
pub fn switch_cost(planner: &HapPlanner, from: &ExpertStrategy, to: &ExpertStrategy) -> f64 {
    if from == to {
        return 0.0;
    }
    let tm = TransitionModel::new(planner.model, &planner.node.gpu);
    tm.cost(&planner.latency, from, to, 0.0).overhead
}

fn execute_batch(sim: &mut EventSim, cost: &BatchCost) {
    let n = sim.num_devices();
    execute_batch_on(sim, cost, n);
}

/// [`execute_batch`] restricted to the first `n` devices — the
/// degraded-replay path schedules nothing on lost devices.
fn execute_batch_on(sim: &mut EventSim, cost: &BatchCost, n: usize) {
    let attn_t = cost.prefill.attn + cost.decode.attn;
    let expert_t = cost.prefill.expert + cost.decode.expert;
    let comm_t = cost.prefill.comm + cost.decode.comm;
    let attn_durs: Vec<(usize, f64)> = (0..n).map(|d| (d, attn_t)).collect();
    sim.parallel_compute(&attn_durs, OpKind::Attention, "adapt-attn");
    let expert_durs: Vec<(usize, f64)> = (0..n).map(|d| (d, expert_t)).collect();
    sim.parallel_compute(&expert_durs, OpKind::Expert, "adapt-experts");
    if comm_t > 0.0 {
        let all: Vec<usize> = (0..n).collect();
        sim.collective(&all, comm_t, "adapt-comm");
    }
    if cost.stage_transition > 0.0 {
        sim.transition(cost.stage_transition, "stage-transition");
    }
}

/// Aggregate result of replaying one policy over one trace.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub policy: String,
    pub batches: usize,
    /// End-to-end simulated makespan, seconds (switch costs included).
    pub total_s: f64,
    /// Weight-moving plan switches (inter-plan; oracle's are free).
    pub switches: usize,
    /// Seconds charged for inter-plan switches.
    pub switch_time_s: f64,
    pub cache_hits: usize,
    pub cache_misses: usize,
    pub cache_hit_rate: f64,
}

impl ReplayReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", self.policy.as_str().into()),
            ("batches", self.batches.into()),
            ("total_s", self.total_s.into()),
            ("switches", self.switches.into()),
            ("switch_time_s", self.switch_time_s.into()),
            ("cache_hits", self.cache_hits.into()),
            ("cache_misses", self.cache_misses.into()),
            ("cache_hit_rate", self.cache_hit_rate.into()),
        ])
    }
}

/// Replay the full adaptive loop (the shared [`AdaptLoop`]:
/// window → cache → controller), charging weight-moving switches to
/// the simulated timeline.
pub fn replay_adaptive(
    planner: &HapPlanner,
    trace: &WorkloadTrace,
    config: &ControllerConfig,
    window_capacity: usize,
) -> Result<ReplayReport> {
    replay_adaptive_seeded(planner, trace, config, window_capacity, None).map(|(r, _)| r)
}

/// [`replay_adaptive`] with an optional warm-start plan cache (e.g.
/// restored from disk via [`PlanCache::load`]); returns the cache as
/// warmed by the run so callers can persist it.
pub fn replay_adaptive_seeded(
    planner: &HapPlanner,
    trace: &WorkloadTrace,
    config: &ControllerConfig,
    window_capacity: usize,
    seed_cache: Option<PlanCache>,
) -> Result<(ReplayReport, PlanCache)> {
    replay_adaptive_inner(planner, trace, config, window_capacity, seed_cache, None)
}

/// [`replay_adaptive`] that also collects the per-batch [`PlanConsult`]
/// audit records — the `hap adapt-replay --audit-out` path, which lets
/// a diverging replay be explained consult by consult (cache hit?
/// economics evaluated? why stay?) instead of just scored.
pub fn replay_adaptive_audited(
    planner: &HapPlanner,
    trace: &WorkloadTrace,
    config: &ControllerConfig,
    window_capacity: usize,
) -> Result<(ReplayReport, Vec<PlanConsult>)> {
    let mut audit = Vec::with_capacity(trace.points.len());
    let (report, _) =
        replay_adaptive_inner(planner, trace, config, window_capacity, None, Some(&mut audit))?;
    Ok((report, audit))
}

fn replay_adaptive_inner(
    planner: &HapPlanner,
    trace: &WorkloadTrace,
    config: &ControllerConfig,
    window_capacity: usize,
    seed_cache: Option<PlanCache>,
    mut audit: Option<&mut Vec<PlanConsult>>,
) -> Result<(ReplayReport, PlanCache)> {
    let mut sim = EventSim::new(planner.node.num_devices);
    let mut control = AdaptLoop::new(config.clone(), window_capacity);
    if let Some(cache) = seed_cache {
        control.cache = cache;
    }
    let mut switch_time = 0.0;

    for point in &trace.points {
        // The batcher feeds one sample per request in the batch.
        let samples = (0..point.batch).map(|_| TrafficSample {
            prompt: point.context,
            generate: point.generate,
            batch: point.batch,
        });
        let sc = point.scenario();
        let (plan, decision) = control.step(planner, samples, Some(&sc), None)?;
        if let Some(aud) = &mut audit {
            aud.extend(control.last_consult.clone());
        }
        if let SwitchDecision::Switch { cost, .. } = decision {
            if cost > 0.0 {
                sim.transition(cost, "replan-switch");
                switch_time += cost;
            }
        }
        let bc = batch_cost(planner, &plan.attn, &plan.expert_prefill, &plan.expert_decode, &sc);
        execute_batch(&mut sim, &bc);
    }

    let report = ReplayReport {
        policy: "adaptive".into(),
        batches: trace.points.len(),
        total_s: sim.now(),
        switches: control.controller.switches,
        switch_time_s: switch_time,
        cache_hits: control.cache.hits,
        cache_misses: control.cache.misses,
        cache_hit_rate: control.cache.hit_rate(),
    };
    Ok((report, control.cache))
}

/// Replay the adaptive loop through a **mid-trace device loss**: the
/// first `crash_at` batches plan over the full node, every batch from
/// `crash_at` on plans over a degraded node of `survivors` devices
/// (same GPU type). This is the trace-driven twin of the serving
/// engine's degraded re-plan path: the shared [`AdaptLoop`] sees the
/// platform change exactly as the engine does — the [`PlanCache`]
/// flushes on the device-set fingerprint change and the controller
/// reseeds — so no stale full-grid plan is ever executed, and the
/// timeline is charged one `degraded-replan` transition modelling the
/// reshard of resident weights onto the survivors (from the TP
/// fallback layout the engine lowers onto first).
///
/// Deterministic like every other replay: compare against the no-fault
/// [`replay_adaptive`] run to read off the goodput cost of the crash.
pub fn replay_adaptive_degraded(
    planner: &HapPlanner,
    trace: &WorkloadTrace,
    config: &ControllerConfig,
    window_capacity: usize,
    crash_at: usize,
    survivors: usize,
) -> Result<ReplayReport> {
    let n = planner.node.num_devices;
    if !survivors.is_power_of_two() || survivors >= n {
        anyhow::bail!(
            "degraded replay needs a power-of-two survivor count below {n}, got {survivors}"
        );
    }
    if crash_at >= trace.points.len() {
        anyhow::bail!(
            "crash batch {crash_at} is past the end of the {}-batch trace",
            trace.points.len()
        );
    }
    let degraded_node = NodeConfig::new(planner.node.gpu.clone(), survivors);
    let degraded = HapPlanner::with_latency(planner.model, &degraded_node, planner.latency.clone());

    let mut sim = EventSim::new(n);
    let mut control = AdaptLoop::new(config.clone(), window_capacity);
    let mut switches = 0usize;
    let mut switch_time = 0.0;
    let mut replanned = false;

    for (i, point) in trace.points.iter().enumerate() {
        let (p, live) = if i < crash_at { (planner, n) } else { (&degraded, survivors) };
        let samples = (0..point.batch).map(|_| TrafficSample {
            prompt: point.context,
            generate: point.generate,
            batch: point.batch,
        });
        let sc = point.scenario();
        let (plan, decision) = control.step(p, samples, Some(&sc), None)?;
        if plan.attn.devices().max(plan.expert_prefill.devices()) > live {
            anyhow::bail!(
                "stale plan survived the degraded re-plan: {} devices on a {live}-device grid",
                plan.attn.devices().max(plan.expert_prefill.devices())
            );
        }
        if i >= crash_at && !replanned {
            replanned = true;
            // The reshard of resident weights onto the survivors: the
            // engine lowers onto a TP(survivors) fallback, then the
            // controller's first degraded plan moves weights from there.
            let cost =
                switch_cost(&degraded, &ExpertStrategy::new(survivors, 1), &plan.expert_prefill);
            if cost > 0.0 {
                sim.transition(cost, "degraded-replan");
                switch_time += cost;
            }
            switches += 1;
        } else if let SwitchDecision::Switch { cost, .. } = decision {
            if cost > 0.0 {
                sim.transition(cost, "replan-switch");
                switch_time += cost;
            }
            switches += 1;
        }
        let bc = batch_cost(p, &plan.attn, &plan.expert_prefill, &plan.expert_decode, &sc);
        execute_batch_on(&mut sim, &bc, live);
    }

    Ok(ReplayReport {
        policy: "adaptive-degraded".into(),
        batches: trace.points.len(),
        total_s: sim.now(),
        switches,
        switch_time_s: switch_time,
        cache_hits: control.cache.hits,
        cache_misses: control.cache.misses,
        cache_hit_rate: control.cache.hit_rate(),
    })
}

/// Replay one fixed strategy triple over the whole trace.
pub fn replay_fixed(
    planner: &HapPlanner,
    trace: &WorkloadTrace,
    policy: &str,
    attn: &AttnStrategy,
    expert_prefill: &ExpertStrategy,
    expert_decode: &ExpertStrategy,
) -> ReplayReport {
    let mut sim = EventSim::new(planner.node.num_devices);
    for point in &trace.points {
        let sc = point.scenario();
        let bc = batch_cost(planner, attn, expert_prefill, expert_decode, &sc);
        execute_batch(&mut sim, &bc);
    }
    ReplayReport {
        policy: policy.into(),
        batches: trace.points.len(),
        total_s: sim.now(),
        switches: 0,
        switch_time_s: 0.0,
        cache_hits: 0,
        cache_misses: 0,
        cache_hit_rate: 0.0,
    }
}

/// Replay the clairvoyant baseline: per-phase optimal plan, free
/// switches (no confirm delay, no weight-move cost).
pub fn replay_oracle(planner: &HapPlanner, trace: &WorkloadTrace) -> Result<ReplayReport> {
    let mut sim = EventSim::new(planner.node.num_devices);
    let mut cache = PlanCache::new();
    let mut switches = 0usize;
    let mut last_sig: Option<String> = None;
    for point in &trace.points {
        let key = QuantizedScenario::from_estimates(point.context, point.generate, point.batch);
        let plan = cache.plan(planner, key)?;
        let sig = plan.signature();
        if last_sig.as_deref().is_some_and(|s| s != sig.as_str()) {
            switches += 1;
        }
        last_sig = Some(sig);
        let sc = point.scenario();
        let bc = batch_cost(planner, &plan.attn, &plan.expert_prefill, &plan.expert_decode, &sc);
        execute_batch(&mut sim, &bc);
    }
    Ok(ReplayReport {
        policy: "oracle".into(),
        batches: trace.points.len(),
        total_s: sim.now(),
        switches,
        switch_time_s: 0.0,
        cache_hits: cache.hits,
        cache_misses: cache.misses,
        cache_hit_rate: cache.hit_rate(),
    })
}

/// All four policies over one trace.
#[derive(Debug, Clone)]
pub struct ReplayComparison {
    pub trace: String,
    pub batches: usize,
    pub adaptive: ReplayReport,
    pub static_tp: ReplayReport,
    /// Best single plan chosen a priori for the trace's *first* phase.
    pub static_first: ReplayReport,
    pub oracle: ReplayReport,
}

impl ReplayComparison {
    /// Policies in presentation order: baselines first, oracle last.
    pub fn policies(&self) -> [&ReplayReport; 4] {
        [&self.static_tp, &self.static_first, &self.adaptive, &self.oracle]
    }

    /// Table cells for one policy row: policy, total (s), switches,
    /// switch time (s), total relative to adaptive. Shared by the CLI
    /// and the bench so the two renderings cannot drift.
    pub fn row_cells(&self, r: &ReplayReport) -> Vec<String> {
        vec![
            r.policy.clone(),
            format!("{:.3}", r.total_s),
            format!("{}", r.switches),
            format!("{:.3}", r.switch_time_s),
            format!("{:.2}x", r.total_s / self.adaptive.total_s),
        ]
    }

    /// Headline ratios + plan-cache stats as one human-readable line.
    pub fn summary_line(&self) -> String {
        format!(
            "adaptive: {:.2}x vs static TP, {:.2}x vs static first-phase plan, \
             {:.1}% over oracle | plan cache: {} hits / {} misses ({:.0}% hit rate)",
            self.vs_static_tp(),
            self.vs_static_first(),
            (self.vs_oracle() - 1.0) * 100.0,
            self.adaptive.cache_hits,
            self.adaptive.cache_misses,
            self.adaptive.cache_hit_rate * 100.0
        )
    }

    /// Speedup of adaptive over pure static TP (>1 = adaptive wins).
    pub fn vs_static_tp(&self) -> f64 {
        self.static_tp.total_s / self.adaptive.total_s
    }

    pub fn vs_static_first(&self) -> f64 {
        self.static_first.total_s / self.adaptive.total_s
    }

    /// Adaptive excess over the free-switch oracle (1.0 = matches it).
    pub fn vs_oracle(&self) -> f64 {
        self.adaptive.total_s / self.oracle.total_s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace", self.trace.as_str().into()),
            ("batches", self.batches.into()),
            (
                "policies",
                Json::Arr(vec![
                    self.adaptive.to_json(),
                    self.static_tp.to_json(),
                    self.static_first.to_json(),
                    self.oracle.to_json(),
                ]),
            ),
            ("adaptive_vs_static_tp", self.vs_static_tp().into()),
            ("adaptive_vs_static_first", self.vs_static_first().into()),
            ("adaptive_vs_oracle", self.vs_oracle().into()),
            ("cache_hit_rate", self.adaptive.cache_hit_rate.into()),
        ])
    }
}

/// Run the standard four-way comparison on one trace.
pub fn compare(
    planner: &HapPlanner,
    trace: &WorkloadTrace,
    config: &ControllerConfig,
    window_capacity: usize,
) -> Result<ReplayComparison> {
    compare_seeded(planner, trace, config, window_capacity, None).map(|(c, _)| c)
}

/// [`compare`] with an optional warm-start plan cache for the adaptive
/// policy; returns the warmed cache for persistence.
pub fn compare_seeded(
    planner: &HapPlanner,
    trace: &WorkloadTrace,
    config: &ControllerConfig,
    window_capacity: usize,
    seed_cache: Option<PlanCache>,
) -> Result<(ReplayComparison, PlanCache)> {
    let n = planner.node.num_devices;
    let (adaptive, warmed) =
        replay_adaptive_seeded(planner, trace, config, window_capacity, seed_cache)?;
    let tp = ExpertStrategy::new(n, 1);
    let static_tp =
        replay_fixed(planner, trace, "static-tp", &AttnStrategy::new(n, 1), &tp, &tp);
    let first = trace.points.first().ok_or_else(|| anyhow::anyhow!("empty trace"))?;
    let first_key = QuantizedScenario::from_estimates(first.context, first.generate, first.batch);
    let first_sc = first_key.to_scenario();
    let first_plan = planner.plan(&first_sc, first_sc.generate)?;
    let static_first = replay_fixed(
        planner,
        trace,
        "static-first-phase",
        &first_plan.attn,
        &first_plan.expert_prefill,
        &first_plan.expert_decode,
    );
    let oracle = replay_oracle(planner, trace)?;
    let cmp = ReplayComparison {
        trace: trace.name.clone(),
        batches: trace.points.len(),
        adaptive,
        static_tp,
        static_first,
        oracle,
    };
    Ok((cmp, warmed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MoEModelConfig, NodeConfig};

    #[test]
    fn traces_are_deterministic_and_sized() {
        let a = WorkloadTrace::phase_shift(10, 16, 7);
        let b = WorkloadTrace::phase_shift(10, 16, 7);
        assert_eq!(a.points, b.points);
        assert_eq!(a.points.len(), 20);
        assert_eq!(WorkloadTrace::diurnal(30, 10, 32, 1).points.len(), 30);
        // The diurnal sweep honors the requested peak batch size.
        let peak = WorkloadTrace::diurnal(40, 10, 32, 1)
            .points
            .iter()
            .map(|p| p.batch)
            .max()
            .unwrap();
        assert!((28..=36).contains(&peak), "peak batch {peak}");
        assert_eq!(WorkloadTrace::ramp(12, 16, 1).points.len(), 12);
        assert_eq!(WorkloadTrace::oscillating(16, 1, 16, 1).points.len(), 16);
        assert!(WorkloadTrace::preset("phase-shift", 8, 16, 1).is_some());
        // Odd totals are honored exactly.
        assert_eq!(WorkloadTrace::preset("phase-shift", 25, 16, 1).unwrap().points.len(), 25);
        assert!(WorkloadTrace::preset("nope", 8, 16, 1).is_none());
    }

    #[test]
    fn ramp_context_grows_within_bounds() {
        let t = WorkloadTrace::ramp(20, 16, 3);
        assert!(t.points.first().unwrap().context < 200);
        assert!(t.points.last().unwrap().context > 6000);
    }

    #[test]
    fn oscillating_trace_never_thrashes_weights() {
        // Batch-period flapping between chat and long-doc traffic with
        // a one-tick window (16 samples = one 16-request batch): the
        // traffic key truly alternates every batch, so the debounce
        // guard must keep weights pinned — zero switches.
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let points: Vec<TracePoint> = (0..24)
            .map(|i| {
                let (ctx, gen) = if i % 2 == 0 { CHAT_PHASE } else { DOC_PHASE };
                TracePoint { context: ctx, generate: gen, batch: 16 }
            })
            .collect();
        let trace = WorkloadTrace { name: "osc-exact".into(), points };
        let report =
            replay_adaptive(&planner, &trace, &ControllerConfig::default(), 16).unwrap();
        assert_eq!(report.switches, 0, "flapping trace moved weights");
        assert_eq!(report.switch_time_s, 0.0);
        assert!(report.total_s.is_finite() && report.total_s > 0.0);
    }

    #[test]
    fn degraded_replay_flushes_cache_and_plans_on_survivors() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let trace = WorkloadTrace::phase_shift(3, 16, 5);
        let cfg = ControllerConfig::default();
        let full = replay_adaptive(&planner, &trace, &cfg, 16).unwrap();
        // Crash two of four devices after batch 2 (mid chat phase).
        let deg = replay_adaptive_degraded(&planner, &trace, &cfg, 16, 2, 2).unwrap();
        assert_eq!(deg.policy, "adaptive-degraded");
        assert_eq!(deg.batches, 6, "every batch accounted, before and after the crash");
        assert!(deg.total_s.is_finite() && deg.total_s > 0.0);
        // The device-set fingerprint change flushes the plan cache, so
        // the chat-phase key is re-solved on the 2-device grid: at
        // least one extra miss vs the no-fault run.
        assert!(
            deg.cache_misses > full.cache_misses,
            "degraded run re-solved nothing: {} vs {} misses",
            deg.cache_misses,
            full.cache_misses
        );
        // Determinism: same crash, same timeline.
        let again = replay_adaptive_degraded(&planner, &trace, &cfg, 16, 2, 2).unwrap();
        assert_eq!(deg.total_s, again.total_s);
        assert_eq!(deg.switches, again.switches);
        // Guard rails: non-power-of-two survivors and out-of-range
        // crash batches are rejected, as is a "degrade" to full size.
        assert!(replay_adaptive_degraded(&planner, &trace, &cfg, 16, 2, 3).is_err());
        assert!(replay_adaptive_degraded(&planner, &trace, &cfg, 16, 2, 4).is_err());
        assert!(replay_adaptive_degraded(&planner, &trace, &cfg, 16, 99, 2).is_err());
    }

    #[test]
    fn audited_replay_records_one_consult_per_batch() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let trace = WorkloadTrace::phase_shift(6, 16, 5);
        let cfg = ControllerConfig::default();
        let (report, audit) = replay_adaptive_audited(&planner, &trace, &cfg, 16).unwrap();
        assert_eq!(audit.len(), trace.points.len());
        assert_eq!(audit[0].decision, "adopt");
        let switches = audit.iter().filter(|c| c.decision == "switch").count();
        assert_eq!(switches, report.switches, "audit verdicts disagree with the report");
        // A switch verdict must carry its breakeven arithmetic.
        for c in audit.iter().filter(|c| c.decision == "switch") {
            assert!(c.evaluated);
            let savings = c.projected_savings_s.expect("switch without projected savings");
            assert!(savings >= cfg.breakeven_factor * c.switch_cost_s);
        }
        // The audit run scores identically to the unaudited one.
        let plain = replay_adaptive(&planner, &trace, &cfg, 16).unwrap();
        assert_eq!(plain.total_s, report.total_s);
    }

    #[test]
    fn fixed_replay_accounts_every_batch() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let trace = WorkloadTrace::phase_shift(3, 16, 5);
        let n = node.num_devices;
        let r = replay_fixed(
            &planner,
            &trace,
            "static-tp",
            &AttnStrategy::new(n, 1),
            &ExpertStrategy::new(n, 1),
            &ExpertStrategy::new(n, 1),
        );
        assert_eq!(r.batches, 6);
        // Sum of per-batch predictions equals the simulated makespan
        // (uniform per-device durations → no straggler skew).
        let expected: f64 = trace
            .points
            .iter()
            .map(|p| {
                batch_cost(
                    &planner,
                    &AttnStrategy::new(n, 1),
                    &ExpertStrategy::new(n, 1),
                    &ExpertStrategy::new(n, 1),
                    &p.scenario(),
                )
                .total()
            })
            .sum();
        assert!((r.total_s - expected).abs() < 1e-9 * expected.max(1.0));
    }
}
