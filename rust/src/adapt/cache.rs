//! Per-scenario plan cache (ROADMAP open item, now closed).
//!
//! Memoizes [`HapPlanner::plan`] results keyed on (model, quantized
//! scenario) so the serving router's re-planning under shifting traffic
//! is a hash lookup, not an ILP solve. The cache is pinned to one
//! platform: any change to the [`NodeConfig`] it last planned against
//! (a different [`crate::config::hardware::GpuSpec`], device count, or
//! interconnect) invalidates every entry, because cost tables — and
//! therefore optimal plans — are platform-specific.
//!
//! Cached plans are returned as clones of the original solve, so they
//! are bit-identical to a fresh `plan()` for the same key (the planner
//! is deterministic per platform; the property tests pin this down).

use crate::adapt::window::QuantizedScenario;
use crate::config::hardware::NodeConfig;
use crate::planner::{HapPlanner, HybridPlan};
use crate::Result;
use std::collections::HashMap;

/// Cache key: model preset + quantized traffic. The platform is held
/// out of the key on purpose — a platform change *invalidates* rather
/// than coexists, mirroring a serving node whose hardware is fixed
/// until a redeploy.
type PlanKey = (String, QuantizedScenario);

/// Memoized planner front-end with hit/miss accounting.
#[derive(Debug, Default)]
pub struct PlanCache {
    entries: HashMap<PlanKey, HybridPlan>,
    platform: Option<NodeConfig>,
    pub hits: usize,
    pub misses: usize,
    /// Number of whole-cache invalidations due to platform change.
    pub invalidations: usize,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Plan for a quantized scenario through the cache: a hit returns
    /// the memoized plan; a miss solves and memoizes. Detects platform
    /// changes against the planner's node and flushes stale entries.
    pub fn plan(&mut self, planner: &HapPlanner, key: QuantizedScenario) -> Result<HybridPlan> {
        if self.platform.as_ref() != Some(planner.node) {
            if self.platform.is_some() {
                self.invalidations += 1;
            }
            self.entries.clear();
            self.platform = Some(planner.node.clone());
        }
        let full_key = (planner.model.name.clone(), key);
        if let Some(plan) = self.entries.get(&full_key) {
            self.hits += 1;
            return Ok(plan.clone());
        }
        self.misses += 1;
        let scenario = key.to_scenario();
        let plan = planner.plan(&scenario, scenario.generate)?;
        self.entries.insert(full_key, plan.clone());
        Ok(plan)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Hit fraction over all lookups so far (0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MoEModelConfig, Scenario};

    fn key_for(sc: &Scenario) -> QuantizedScenario {
        QuantizedScenario::from_scenario(sc)
    }

    #[test]
    fn cache_hit_returns_bit_identical_plan() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let mut cache = PlanCache::new();
        let key = key_for(&Scenario::long_constrained());
        let first = cache.plan(&planner, key).unwrap();
        let second = cache.plan(&planner, key).unwrap();
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(first.signature(), second.signature());
        assert_eq!(first.predicted_total.to_bits(), second.predicted_total.to_bits());
        // And identical to a fresh uncached solve of the same key.
        let sc = key.to_scenario();
        let fresh = planner.plan(&sc, sc.generate).unwrap();
        assert_eq!(first.signature(), fresh.signature());
        assert_eq!(first.predicted_total.to_bits(), fresh.predicted_total.to_bits());
    }

    #[test]
    fn distinct_keys_solve_separately() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let mut cache = PlanCache::new();
        cache.plan(&planner, key_for(&Scenario::long_constrained())).unwrap();
        cache.plan(&planner, key_for(&Scenario::short_extended())).unwrap();
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn platform_change_invalidates() {
        let m = MoEModelConfig::mixtral_8x7b();
        let pcie = NodeConfig::a6000x(4);
        let nvlink = NodeConfig::a100x(4);
        let key = key_for(&Scenario::long_constrained());
        let mut cache = PlanCache::new();
        let on_pcie = cache.plan(&HapPlanner::new(&m, &pcie), key).unwrap();
        assert_eq!(cache.len(), 1);
        // New platform: the PCIe entry must not be served.
        let on_nvlink = cache.plan(&HapPlanner::new(&m, &nvlink), key).unwrap();
        assert_eq!(cache.invalidations, 1);
        assert_eq!(cache.misses, 2);
        assert_eq!(on_nvlink.node, nvlink.label());
        assert_eq!(on_pcie.node, pcie.label());
        // Returning to the original platform re-solves (no stale reuse).
        cache.plan(&HapPlanner::new(&m, &pcie), key).unwrap();
        assert_eq!(cache.invalidations, 2);
        assert_eq!(cache.misses, 3);
    }
}
