//! Per-scenario plan cache (ROADMAP open item, now closed).
//!
//! Memoizes [`HapPlanner::plan`] results keyed on (model, quantized
//! scenario) so the serving router's re-planning under shifting traffic
//! is a hash lookup, not an ILP solve. The cache is pinned to one
//! platform: any change to the [`NodeConfig`] it last planned against
//! (a different [`crate::config::hardware::GpuSpec`], device count, or
//! interconnect) invalidates every entry, because cost tables — and
//! therefore optimal plans — are platform-specific.
//!
//! Cached plans are returned as clones of the original solve, so they
//! are bit-identical to a fresh `plan()` for the same key (the planner
//! is deterministic per platform; the property tests pin this down).

use crate::adapt::window::QuantizedScenario;
use crate::config::hardware::NodeConfig;
use crate::config::model::MoEModelConfig;
use crate::planner::{HapPlanner, HybridPlan};
use crate::util::json::Json;
use crate::Result;
use std::collections::HashMap;
use std::path::Path;

/// Cache key: model preset + quantized traffic. The platform is held
/// out of the key on purpose — a platform change *invalidates* rather
/// than coexists, mirroring a serving node whose hardware is fixed
/// until a redeploy.
type PlanKey = (String, QuantizedScenario);

/// Memoized planner front-end with hit/miss accounting.
#[derive(Debug, Default)]
pub struct PlanCache {
    entries: HashMap<PlanKey, HybridPlan>,
    platform: Option<NodeConfig>,
    /// Execution-model fingerprint the cached plans were solved under:
    /// `"sequential"`, or `"pipelined/<overlap fingerprint>"` for a
    /// planner carrying a calibrated [`crate::sim::OverlapModel`]. A
    /// planner whose fingerprint differs flushes the cache exactly like
    /// a platform change — plans solved without (or with a different)
    /// overlap model may rank strategies differently.
    exec: Option<String>,
    pub hits: usize,
    pub misses: usize,
    /// Number of whole-cache invalidations due to platform change.
    pub invalidations: usize,
    /// Entries restored for the requested model by [`PlanCache::load`]
    /// (0 on fingerprint mismatch or a missing file).
    pub restored: usize,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Plan for a quantized scenario through the cache: a hit returns
    /// the memoized plan; a miss solves and memoizes. Detects platform
    /// and execution-model changes against the planner and flushes
    /// stale entries.
    pub fn plan(&mut self, planner: &HapPlanner, key: QuantizedScenario) -> Result<HybridPlan> {
        let exec_fp = Self::exec_fingerprint(planner);
        if self.platform.as_ref() != Some(planner.node)
            || self.exec.as_deref() != Some(exec_fp.as_str())
        {
            // Only discarding actual entries counts as an invalidation
            // (a fresh or already-flushed cache re-pins for free).
            if !self.entries.is_empty() {
                self.invalidations += 1;
            }
            self.entries.clear();
            self.platform = Some(planner.node.clone());
            self.exec = Some(exec_fp);
        }
        let full_key = (planner.model.name.clone(), key);
        if let Some(plan) = self.entries.get(&full_key) {
            self.hits += 1;
            return Ok(plan.clone());
        }
        self.misses += 1;
        let scenario = key.to_scenario();
        let plan = planner.plan(&scenario, scenario.generate)?;
        self.entries.insert(full_key, plan.clone());
        Ok(plan)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Hit fraction over all lookups so far (0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Platform / device-set identity the cache is pinned to.
    /// Everything latency-relevant goes in, and the device count leads:
    /// a degraded grid (same GPUs, fewer survivors after a device
    /// crash) is a *different platform*, so stale full-grid plans are
    /// never served for it — the fault-recovery path relies on this.
    pub fn platform_fingerprint(node: &NodeConfig) -> String {
        let g = &node.gpu;
        format!(
            "{}x{}|{}|{}|{}|{}|{}",
            node.num_devices,
            g.name,
            g.interconnect.name(),
            g.peak_flops,
            g.link_bw,
            g.mem_bytes,
            g.hbm_bw
        )
    }

    /// Execution-model identity of a planner: the iteration-loop cost
    /// model its plans were priced under. Distinct overlap parameters
    /// are distinct execution models (the fingerprint carries the raw
    /// f64 bits), so recalibration flushes like a platform change.
    pub fn exec_fingerprint(planner: &HapPlanner) -> String {
        match &planner.overlap {
            None => "sequential".to_string(),
            Some(om) => format!("pipelined/{}", om.fingerprint()),
        }
    }

    /// Serialize entries + platform fingerprint for persistence.
    pub fn to_json(&self) -> Json {
        let platform = self
            .platform
            .as_ref()
            .map(Self::platform_fingerprint)
            .map(Json::from)
            .unwrap_or(Json::Null);
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|((model, key), plan)| {
                Json::obj(vec![
                    ("model", model.as_str().into()),
                    (
                        "key",
                        Json::obj(vec![
                            ("context", key.context.into()),
                            ("generate", key.generate.into()),
                            ("batch", key.batch.into()),
                        ]),
                    ),
                    ("plan", plan.to_json()),
                ])
            })
            .collect();
        let exec = self.exec.as_deref().map(Json::from).unwrap_or(Json::Null);
        Json::obj(vec![
            ("kind", "hap-plan-cache".into()),
            ("platform", platform),
            ("exec", exec),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Persist the cache (JSON via `util::json`).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Restore a cache for a (model, platform) deployment. A missing
    /// file yields an empty warm-start; a platform-fingerprint mismatch
    /// discards everything (counted as an invalidation). The model
    /// fingerprint is the per-entry key: entries for *other* models are
    /// preserved verbatim (so a shared cache file survives runs for a
    /// different model and a later `save` does not destroy them) but
    /// can never be served for `model` — `restored` counts only the
    /// given model's entries. Restored plans are bit-identical to what
    /// was saved (shortest-round-trip f64 formatting).
    pub fn load(path: &Path, model: &MoEModelConfig, node: &NodeConfig) -> Result<PlanCache> {
        let mut cache = PlanCache::new();
        cache.platform = Some(node.clone());
        if !path.exists() {
            return Ok(cache);
        }
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("plan cache json: {e}"))?;
        // Files written before the pipelined-execution axis existed
        // carry no exec fingerprint: they were solved by sequential-only
        // planners, so their entries stay valid for one.
        cache.exec =
            Some(j.get("exec").and_then(|e| e.as_str()).unwrap_or("sequential").to_string());
        let fp = Self::platform_fingerprint(node);
        if j.get("platform").and_then(|p| p.as_str()) != Some(fp.as_str()) {
            cache.invalidations += 1;
            return Ok(cache);
        }
        for e in j.get("entries").and_then(|v| v.as_arr()).unwrap_or(&[]) {
            let Some(name) = e.get("model").and_then(|m| m.as_str()) else { continue };
            let Some(k) = e.get("key") else { continue };
            let key = (|| {
                Some(QuantizedScenario {
                    context: k.get("context")?.as_usize()?,
                    generate: k.get("generate")?.as_usize()?,
                    batch: k.get("batch")?.as_usize()?,
                })
            })();
            let Some(key) = key else { continue };
            let Some(plan) = e.get("plan").and_then(HybridPlan::from_json) else { continue };
            if name == model.name {
                cache.restored += 1;
            }
            cache.entries.insert((name.to_string(), key), plan);
        }
        Ok(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MoEModelConfig, Scenario};

    fn key_for(sc: &Scenario) -> QuantizedScenario {
        QuantizedScenario::from_scenario(sc)
    }

    #[test]
    fn cache_hit_returns_bit_identical_plan() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let mut cache = PlanCache::new();
        let key = key_for(&Scenario::long_constrained());
        let first = cache.plan(&planner, key).unwrap();
        let second = cache.plan(&planner, key).unwrap();
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(first.signature(), second.signature());
        assert_eq!(first.predicted_total.to_bits(), second.predicted_total.to_bits());
        // And identical to a fresh uncached solve of the same key.
        let sc = key.to_scenario();
        let fresh = planner.plan(&sc, sc.generate).unwrap();
        assert_eq!(first.signature(), fresh.signature());
        assert_eq!(first.predicted_total.to_bits(), fresh.predicted_total.to_bits());
    }

    #[test]
    fn distinct_keys_solve_separately() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let mut cache = PlanCache::new();
        cache.plan(&planner, key_for(&Scenario::long_constrained())).unwrap();
        cache.plan(&planner, key_for(&Scenario::short_extended())).unwrap();
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn save_load_round_trip_and_fingerprint_invalidation() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let mut cache = PlanCache::new();
        let k1 = key_for(&Scenario::long_constrained());
        let k2 = key_for(&Scenario::short_extended());
        let p1 = cache.plan(&planner, k1).unwrap();
        cache.plan(&planner, k2).unwrap();

        let dir = std::env::temp_dir().join("hap_plan_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        cache.save(&path).unwrap();

        // Same (model, platform): both entries restore, and a warm
        // lookup is a hit with a bit-identical plan — no re-solve.
        let mut warm = PlanCache::load(&path, &m, &node).unwrap();
        assert_eq!(warm.restored, 2);
        let from_disk = warm.plan(&planner, k1).unwrap();
        assert_eq!(warm.hits, 1);
        assert_eq!(warm.misses, 0);
        assert_eq!(from_disk.signature(), p1.signature());
        assert_eq!(from_disk.predicted_total.to_bits(), p1.predicted_total.to_bits());

        // Platform fingerprint mismatch: nothing restores.
        let other_node = NodeConfig::a100x(4);
        let cold = PlanCache::load(&path, &m, &other_node).unwrap();
        assert_eq!(cold.restored, 0);
        assert_eq!(cold.invalidations, 1);

        // Model mismatch: nothing restores *for* the other model (the
        // per-entry model name is the model fingerprint), but the
        // foreign entries are preserved so a later save keeps them.
        let other_model = MoEModelConfig::qwen15_moe_a27b();
        let cold2 = PlanCache::load(&path, &other_model, &node).unwrap();
        assert_eq!(cold2.restored, 0);
        assert_eq!(cold2.len(), 2, "other models' entries must survive the round trip");

        // A missing file is an empty warm start, not an error.
        let none = PlanCache::load(&dir.join("nope.json"), &m, &node).unwrap();
        assert_eq!(none.restored, 0);
        assert!(none.is_empty());
    }

    #[test]
    fn degraded_device_set_flushes_full_grid_plans() {
        // Fault recovery shrinks the node to the surviving device
        // count. Same GPUs, fewer devices ⇒ different fingerprint ⇒
        // every full-grid entry flushes, and the degraded solve's
        // plans fit the survivors.
        let m = MoEModelConfig::mixtral_8x7b();
        let full = NodeConfig::a6000x(4);
        let degraded = NodeConfig::new(full.gpu.clone(), 2);
        assert_ne!(
            PlanCache::platform_fingerprint(&full),
            PlanCache::platform_fingerprint(&degraded),
            "device count must lead the fingerprint"
        );
        let key = key_for(&Scenario::long_constrained());
        let mut cache = PlanCache::new();
        let wide = cache.plan(&HapPlanner::new(&m, &full), key).unwrap();
        assert_eq!(wide.attn.devices(), 4);
        let narrow = cache.plan(&HapPlanner::new(&m, &degraded), key).unwrap();
        assert_eq!(cache.invalidations, 1, "degraded grid must flush the cache");
        assert_eq!(cache.misses, 2, "no stale full-grid plan served");
        assert_eq!(narrow.attn.devices(), 2, "degraded plan fits the survivors");
        assert_eq!(narrow.expert_prefill.devices(), 2);
        assert_eq!(narrow.expert_decode.devices(), 2);
    }

    #[test]
    fn exec_model_change_flushes_cached_plans() {
        // Plans priced without the overlap model must never be served
        // to a planner that has one (and vice versa), and recalibrating
        // the overlap parameters is itself an execution-model change.
        use crate::sim::OverlapModel;
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let key = key_for(&Scenario::long_constrained());
        let mut cache = PlanCache::new();
        let seq = HapPlanner::new(&m, &node);
        cache.plan(&seq, key).unwrap();
        let pipe = HapPlanner::new(&m, &node).with_overlap(OverlapModel::new(0.1, 0.0));
        cache.plan(&pipe, key).unwrap();
        assert_eq!(cache.invalidations, 1, "overlap model must flush sequential plans");
        assert_eq!(cache.misses, 2);
        let recal = HapPlanner::new(&m, &node).with_overlap(OverlapModel::new(0.2, 0.0));
        cache.plan(&recal, key).unwrap();
        assert_eq!(cache.invalidations, 2, "recalibration must flush");
        // Stable planner → warm hit.
        cache.plan(&recal, key).unwrap();
        assert_eq!(cache.hits, 1);

        // The fingerprint survives persistence: a saved pipelined cache
        // re-serves for the same overlap model but flushes for a
        // sequential planner, and pre-exec-axis files (no "exec" key)
        // default to sequential.
        let dir = std::env::temp_dir().join("hap_plan_cache_exec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        cache.save(&path).unwrap();
        let mut warm = PlanCache::load(&path, &m, &node).unwrap();
        assert_eq!(warm.restored, 1);
        warm.plan(&recal, key).unwrap();
        assert_eq!((warm.hits, warm.misses), (1, 0), "same exec model must hit");
        let mut cold = PlanCache::load(&path, &m, &node).unwrap();
        cold.plan(&seq, key).unwrap();
        assert_eq!(cold.invalidations, 1, "sequential planner must flush pipelined plans");
        assert_eq!(cold.misses, 1);
    }

    #[test]
    fn platform_change_invalidates() {
        let m = MoEModelConfig::mixtral_8x7b();
        let pcie = NodeConfig::a6000x(4);
        let nvlink = NodeConfig::a100x(4);
        let key = key_for(&Scenario::long_constrained());
        let mut cache = PlanCache::new();
        let on_pcie = cache.plan(&HapPlanner::new(&m, &pcie), key).unwrap();
        assert_eq!(cache.len(), 1);
        // New platform: the PCIe entry must not be served.
        let on_nvlink = cache.plan(&HapPlanner::new(&m, &nvlink), key).unwrap();
        assert_eq!(cache.invalidations, 1);
        assert_eq!(cache.misses, 2);
        assert_eq!(on_nvlink.node, nvlink.label());
        assert_eq!(on_pcie.node, pcie.label());
        // Returning to the original platform re-solves (no stale reuse).
        cache.plan(&HapPlanner::new(&m, &pcie), key).unwrap();
        assert_eq!(cache.invalidations, 2);
        assert_eq!(cache.misses, 3);
    }
}
