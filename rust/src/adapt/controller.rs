//! Strategy-switch controller: hysteresis over break-even analysis.
//!
//! Re-planning is cheap once the [`crate::adapt::PlanCache`] is warm,
//! but *acting* on a new plan is not: adopting a different expert
//! layout redistributes ~90% of model weights (paper §III-D). The
//! controller therefore treats a plan switch as an investment decision:
//!
//! ```text
//! switch ⇔ (T_active − T_candidate) · E[dwell batches]
//!            ≥ breakeven_factor · C_switch
//! ```
//!
//! where `T_·` are predicted per-batch latencies on *current* traffic,
//! `E[dwell]` is an EWMA of observed phase lengths (how long a traffic
//! key persisted before changing), and `C_switch` is the weight-
//! redistribution cost from [`crate::transition`]. Two further guards
//! damp flapping:
//!
//! - **debounce** — a new traffic key must persist `confirm_batches`
//!   consecutive batches before it can trigger a switch, so a single
//!   outlier batch never moves weights;
//! - **cooldown** — at least `cooldown_batches` batches must pass
//!   between switches, bounding worst-case switch frequency even under
//!   adversarial traffic.
//!
//! The structural invariant (asserted by the no-thrash property tests):
//! the controller **never** switches when the projected dwell-time
//! savings fail to cover `breakeven_factor ×` the switch cost.
//!
//! "Batches" here means *consult boundaries*: the gang scheduler steps
//! the controller once per packed batch, the streaming engine once per
//! admission boundary. Dwell estimates, debounce, and cooldown all
//! count in whichever cadence the caller runs — the economics are
//! unitless ratios of predicted latencies to switch cost either way.

use crate::adapt::window::QuantizedScenario;
use crate::planner::HybridPlan;
use std::collections::HashMap;

/// Clamp on a single measured/predicted observation (guards against
/// one-off stalls dominating the EWMA).
const MISPREDICT_OBS_MAX: f64 = 8.0;
const MISPREDICT_OBS_MIN: f64 = 0.25;
/// Clamp on the correction factor applied in the economics. The floor
/// of 1.0 means measurements only *demote* (a plan that overruns its
/// prediction becomes easier to switch away from); they never make the
/// controller cling to a plan that happens to beat its prediction.
const MISPREDICT_FACTOR_MAX: f64 = 4.0;

/// Tunables for the hysteresis logic.
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Projected savings must exceed this multiple of the switch cost
    /// (≥ 1.0; higher = more conservative).
    pub breakeven_factor: f64,
    /// Consecutive batches a new key must persist before acting.
    pub confirm_batches: usize,
    /// Minimum batches between weight-moving switches.
    pub cooldown_batches: usize,
    /// Initial / maximum value of the dwell estimate (batches).
    pub initial_dwell_batches: f64,
    pub max_dwell_batches: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            breakeven_factor: 2.0,
            confirm_batches: 2,
            cooldown_batches: 8,
            initial_dwell_batches: 32.0,
            max_dwell_batches: 4096.0,
        }
    }
}

/// Outcome of one controller step.
#[derive(Debug, Clone, PartialEq)]
pub enum SwitchDecision {
    /// First plan adoption (no resident weights yet) — free.
    Adopt,
    /// Keep executing the active plan.
    Stay,
    /// Move weights to the candidate plan's layout.
    Switch {
        /// `(T_active − T_candidate) · E[dwell]`, seconds.
        projected_savings: f64,
        /// Charged switch cost, seconds.
        cost: f64,
    },
}

impl SwitchDecision {
    /// Stable lowercase label for audit records and trace events.
    pub fn label(&self) -> &'static str {
        match self {
            SwitchDecision::Adopt => "adopt",
            SwitchDecision::Stay => "stay",
            SwitchDecision::Switch { .. } => "switch",
        }
    }
}

/// Hysteresis controller; owns the active plan between steps.
#[derive(Debug)]
pub struct SwitchController {
    pub config: ControllerConfig,
    active: Option<HybridPlan>,
    active_key: Option<QuantizedScenario>,
    /// (key, consecutive observations) for the debounce guard.
    pending: Option<(QuantizedScenario, usize)>,
    batches_since_switch: usize,
    /// Batches the current key has been active (dwell-so-far).
    current_dwell: usize,
    /// EWMA of completed phase lengths, in batches.
    dwell_ewma: f64,
    /// EWMA of measured/predicted latency per plan signature (the
    /// [`crate::adapt::AdaptLoop`] normalizes both sides to seconds
    /// per generated token) — the closed loop on mispredicted plans. A
    /// plan that keeps running slower than its prediction gets its
    /// active latency scaled up in the break-even economics, so a
    /// candidate can displace it ("demotion") even when raw
    /// predictions would not.
    mispredict: HashMap<String, f64>,
    pub switches: usize,
    pub suppressed: usize,
}

impl SwitchController {
    pub fn new(config: ControllerConfig) -> SwitchController {
        assert!(config.breakeven_factor >= 1.0, "breakeven_factor must be >= 1");
        let dwell = config.initial_dwell_batches;
        SwitchController {
            config,
            active: None,
            active_key: None,
            pending: None,
            batches_since_switch: 0,
            current_dwell: 0,
            dwell_ewma: dwell,
            mispredict: HashMap::new(),
            switches: 0,
            suppressed: 0,
        }
    }

    /// Fold one measured-vs-predicted latency observation for the plan
    /// with `signature` into its mispredict EWMA. Only the
    /// `measured / predicted` *ratio* enters the economics, so callers
    /// may feed any granularity as long as both sides share units —
    /// [`crate::adapt::AdaptLoop`] normalizes both to **seconds per
    /// generated token**, which makes gang observations (one whole
    /// batch) and streaming observations (a dwell window of scheduler
    /// iterations between admission boundaries) commensurable.
    pub fn observe_measured(&mut self, signature: &str, measured: f64, predicted: f64) {
        if !(measured > 0.0) || !(predicted > 0.0) {
            return;
        }
        let ratio = (measured / predicted).clamp(MISPREDICT_OBS_MIN, MISPREDICT_OBS_MAX);
        let e = self.mispredict.entry(signature.to_string()).or_insert(1.0);
        *e = 0.5 * *e + 0.5 * ratio;
    }

    /// Raw (unclamped) mispredict EWMA for a plan signature — `None`
    /// until the first measured observation for that plan lands.
    pub fn mispredict_ewma(&self, signature: &str) -> Option<f64> {
        self.mispredict.get(signature).copied()
    }

    /// Number of plan signatures with at least one measured-latency
    /// observation (lets callers assert the feedback loop is closed).
    pub fn mispredict_observations(&self) -> usize {
        self.mispredict.len()
    }

    /// The correction applied to the active plan's predicted latency in
    /// the break-even economics (1.0 when unmeasured or accurate).
    pub fn mispredict_factor(&self, signature: &str) -> f64 {
        self.mispredict
            .get(signature)
            .copied()
            .unwrap_or(1.0)
            .clamp(1.0, MISPREDICT_FACTOR_MAX)
    }

    /// The plan currently executing (None before the first adoption).
    pub fn active(&self) -> Option<&HybridPlan> {
        self.active.as_ref()
    }

    /// The traffic key the active plan is pinned to. Lets callers skip
    /// computing latency economics on the steady-state path: when the
    /// incoming key equals this, [`Self::step`] returns `Stay` without
    /// reading its latency/cost arguments.
    pub fn active_key(&self) -> Option<QuantizedScenario> {
        self.active_key
    }

    /// Whether a [`Self::step`] with `key` *now* could reach the
    /// break-even economics: a resident plan pinned to a different key,
    /// the debounce about to be satisfied, and the cooldown expired.
    /// When this is false, `step` is guaranteed to ignore its
    /// latency/cost arguments, so callers can skip computing them —
    /// including on every batch of an alternating-key flap, where the
    /// debounce never confirms.
    pub fn would_evaluate(&self, key: QuantizedScenario) -> bool {
        let Some(active_key) = self.active_key else {
            return false;
        };
        if key == active_key {
            return false;
        }
        let seen = match self.pending {
            Some((k, n)) if k == key => n + 1,
            _ => 1,
        };
        // `step` increments batches_since_switch before its cooldown
        // check, hence the +1 here.
        seen >= self.config.confirm_batches
            && self.batches_since_switch + 1 >= self.config.cooldown_batches
    }

    /// Current expected-dwell estimate (batches).
    pub fn expected_dwell(&self) -> f64 {
        self.dwell_ewma.clamp(1.0, self.config.max_dwell_batches)
    }

    /// One control step, called once per batch *before* executing it.
    ///
    /// `candidate` is the plan-cache answer for `key`; `active_latency`
    /// / `candidate_latency` are predicted per-batch latencies on the
    /// current traffic; `switch_cost` is the weight-move cost from the
    /// active layout to the candidate's. Returns the decision and
    /// updates the active plan accordingly.
    pub fn step(
        &mut self,
        key: QuantizedScenario,
        candidate: &HybridPlan,
        active_latency: f64,
        candidate_latency: f64,
        switch_cost: f64,
    ) -> SwitchDecision {
        self.batches_since_switch += 1;

        let Some(active_key) = self.active_key else {
            // Cold start: nothing resident, adopting is free.
            self.active = Some(candidate.clone());
            self.active_key = Some(key);
            self.current_dwell = 1;
            return SwitchDecision::Adopt;
        };

        if key == active_key {
            self.pending = None;
            self.current_dwell += 1;
            return SwitchDecision::Stay;
        }

        // Key differs from the active phase: debounce it.
        let seen = match self.pending {
            Some((k, n)) if k == key => n + 1,
            _ => 1,
        };
        self.pending = Some((key, seen));
        if seen < self.config.confirm_batches {
            return SwitchDecision::Stay;
        }

        // Same layout under a new key: relabel for free (no weights move).
        let active_plan = self.active.as_ref().expect("active plan when key set");
        let active_sig = active_plan.signature();
        if active_plan.attn == candidate.attn
            && active_plan.expert_prefill == candidate.expert_prefill
            && active_plan.expert_decode == candidate.expert_decode
        {
            self.finish_phase(key);
            self.active = Some(candidate.clone());
            return SwitchDecision::Stay;
        }

        if self.batches_since_switch < self.config.cooldown_batches {
            self.suppressed += 1;
            return SwitchDecision::Stay;
        }

        // Break-even economics: only switch when the projected savings
        // over the expected dwell clear the cost with margin. Each
        // plan's prediction is scaled by its own measured mispredict
        // factor: a plan that keeps overrunning its prediction gets
        // demoted, while a model-wide scale bias (both plans measured
        // equally off) cancels instead of causing switch ping-pong.
        let gain_per_batch = active_latency * self.mispredict_factor(&active_sig)
            - candidate_latency * self.mispredict_factor(&candidate.signature());
        let projected_savings = gain_per_batch * self.expected_dwell();
        if gain_per_batch <= 0.0 || projected_savings < self.config.breakeven_factor * switch_cost
        {
            self.suppressed += 1;
            return SwitchDecision::Stay;
        }

        self.finish_phase(key);
        self.active = Some(candidate.clone());
        self.switches += 1;
        self.batches_since_switch = 0;
        SwitchDecision::Switch { projected_savings, cost: switch_cost }
    }

    /// Close out the current phase: fold its observed length into the
    /// dwell EWMA and reset per-phase state for `new_key`.
    fn finish_phase(&mut self, new_key: QuantizedScenario) {
        if self.current_dwell > 0 {
            self.dwell_ewma = 0.5 * self.dwell_ewma + 0.5 * self.current_dwell as f64;
        }
        self.active_key = Some(new_key);
        self.pending = None;
        self.current_dwell = 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::sim::latency::ModuleLatency;
    use crate::strategy::{AttnStrategy, ExpertStrategy};
    use crate::transition::{TransitionCost, TransitionMethod};

    fn plan(attn_tp: usize, pre_ep: usize, dec_ep: usize) -> HybridPlan {
        let n = 4;
        HybridPlan {
            model: "test".into(),
            node: "4xTest".into(),
            scenario: Scenario::short_constrained(),
            attn: AttnStrategy::new(attn_tp, n / attn_tp),
            expert_prefill: ExpertStrategy::new(n / pre_ep, pre_ep),
            expert_decode: ExpertStrategy::new(n / dec_ep, dec_ep),
            transition: TransitionCost {
                method: TransitionMethod::None,
                overhead: 0.0,
                raw_pipeline: 0.0,
                reshard: 0.0,
            },
            pipelined_prefill: false,
            pipelined_decode: false,
            predicted_prefill: ModuleLatency::default(),
            predicted_decode: ModuleLatency::default(),
            predicted_total: 1.0,
            solve_time: 0.0,
            k_a: 1,
            k_e: 1,
        }
    }

    fn key(ctx: usize) -> QuantizedScenario {
        QuantizedScenario { context: ctx, generate: 64, batch: 16 }
    }

    #[test]
    fn first_plan_adopted_free() {
        let mut c = SwitchController::new(ControllerConfig::default());
        let p = plan(4, 1, 1);
        assert_eq!(c.step(key(256), &p, 0.0, 1.0, 9.9), SwitchDecision::Adopt);
        assert!(c.active().is_some());
        assert_eq!(c.switches, 0);
    }

    #[test]
    fn switches_when_savings_clear_cost() {
        let cfg = ControllerConfig { cooldown_batches: 0, ..Default::default() };
        let mut c = SwitchController::new(cfg);
        let a = plan(4, 1, 1);
        let b = plan(4, 4, 1);
        c.step(key(256), &a, 0.0, 1.0, 0.0);
        for _ in 0..4 {
            c.step(key(256), &a, 1.0, 1.0, 0.0);
        }
        // New phase: candidate saves 0.5 s/batch, dwell estimate 32 →
        // 16 s projected vs 2×0.1 s cost → switch on the confirming
        // observation.
        assert_eq!(c.step(key(4096), &b, 1.5, 1.0, 0.1), SwitchDecision::Stay);
        match c.step(key(4096), &b, 1.5, 1.0, 0.1) {
            SwitchDecision::Switch { projected_savings, cost } => {
                assert!(projected_savings >= 2.0 * cost);
            }
            other => panic!("expected switch, got {other:?}"),
        }
        assert_eq!(c.switches, 1);
    }

    #[test]
    fn never_switches_below_breakeven() {
        let cfg = ControllerConfig { cooldown_batches: 0, ..Default::default() };
        let mut c = SwitchController::new(cfg);
        let a = plan(4, 1, 1);
        let b = plan(4, 4, 1);
        c.step(key(256), &a, 0.0, 1.0, 0.0);
        // Gain 1 ms/batch × dwell 32 = 32 ms << 2 × 10 s cost.
        for _ in 0..20 {
            let d = c.step(key(4096), &b, 1.001, 1.0, 10.0);
            assert_ne!(d, SwitchDecision::Switch { projected_savings: 0.0, cost: 0.0 });
            assert!(matches!(d, SwitchDecision::Stay));
        }
        assert_eq!(c.switches, 0);
        assert!(c.suppressed > 0);
    }

    #[test]
    fn alternating_keys_never_confirm() {
        // Period-1 oscillation: each key lasts one batch, below the
        // 2-batch debounce — weights must never move.
        let mut c = SwitchController::new(ControllerConfig::default());
        let a = plan(4, 1, 1);
        let b = plan(4, 4, 1);
        c.step(key(256), &a, 0.0, 1.0, 0.0);
        for i in 0..50 {
            let (k, p) = if i % 2 == 0 { (key(4096), &b) } else { (key(256), &a) };
            c.step(k, p, 10.0, 1.0, 0.001);
        }
        assert_eq!(c.switches, 0);
    }

    #[test]
    fn identical_layout_relabels_without_switch() {
        let mut c = SwitchController::new(ControllerConfig::default());
        let a = plan(4, 1, 1);
        c.step(key(256), &a, 0.0, 1.0, 0.0);
        for _ in 0..3 {
            c.step(key(512), &a, 1.0, 1.0, 5.0);
        }
        assert_eq!(c.switches, 0);
        // The key was re-pinned: staying on 512 is now Stay-with-reset.
        assert_eq!(c.step(key(512), &a, 1.0, 1.0, 5.0), SwitchDecision::Stay);
    }

    #[test]
    fn would_evaluate_mirrors_step_gating() {
        let cfg = ControllerConfig {
            confirm_batches: 2,
            cooldown_batches: 0,
            ..Default::default()
        };
        let mut c = SwitchController::new(cfg);
        let a = plan(4, 1, 1);
        assert!(!c.would_evaluate(key(256)), "no resident plan yet");
        c.step(key(256), &a, 0.0, 1.0, 0.0);
        assert!(!c.would_evaluate(key(256)), "steady state");
        assert!(!c.would_evaluate(key(4096)), "debounce: first sighting");
        c.step(key(4096), &a, 1.0, 1.0, 0.0);
        assert!(c.would_evaluate(key(4096)), "confirming step reaches economics");
        assert!(!c.would_evaluate(key(512)), "a different new key restarts debounce");
    }

    #[test]
    fn consistently_mispredicted_plan_gets_demoted() {
        // Candidate B predicts slightly WORSE than active A (1.2 vs
        // 1.0 s/batch): on predictions alone the controller never
        // switches. Once measurements show A consistently running ~4×
        // its prediction, the corrected economics demote A and adopt B.
        let cfg = ControllerConfig { cooldown_batches: 0, ..Default::default() };
        let mut c = SwitchController::new(cfg);
        let a = plan(4, 1, 1);
        let b = plan(4, 4, 1);
        c.step(key(256), &a, 0.0, 1.0, 0.0);
        for _ in 0..5 {
            let d = c.step(key(4096), &b, 1.0, 1.2, 0.01);
            assert!(matches!(d, SwitchDecision::Stay), "switched on raw predictions");
        }
        assert_eq!(c.switches, 0);
        assert_eq!(c.mispredict_factor(&a.signature()), 1.0);
        for _ in 0..4 {
            c.observe_measured(&a.signature(), 4.0, 1.0);
        }
        assert!(c.mispredict_factor(&a.signature()) > 3.0);
        match c.step(key(4096), &b, 1.0, 1.2, 0.01) {
            SwitchDecision::Switch { projected_savings, .. } => {
                assert!(projected_savings > 0.0);
            }
            other => panic!("mispredicted plan not demoted: {other:?}"),
        }
        assert_eq!(c.switches, 1);
        // The candidate (now active) carries no correction of its own.
        assert_eq!(c.mispredict_factor(&b.signature()), 1.0);

        // A model-wide bias — both plans equally mispredicted — cancels
        // in the two-sided economics: with equal predictions there is
        // no gain, so no ping-pong back.
        for _ in 0..4 {
            c.observe_measured(&b.signature(), 4.0, 1.0);
        }
        assert_eq!(
            c.mispredict_factor(&a.signature()),
            c.mispredict_factor(&b.signature())
        );
        for _ in 0..5 {
            let d = c.step(key(256), &a, 1.0, 1.0, 0.01);
            assert!(matches!(d, SwitchDecision::Stay), "uniform bias caused ping-pong");
        }
        assert_eq!(c.switches, 1);
    }

    #[test]
    fn cooldown_blocks_back_to_back_switches() {
        let cfg = ControllerConfig {
            cooldown_batches: 10,
            confirm_batches: 1,
            ..Default::default()
        };
        let mut c = SwitchController::new(cfg);
        let a = plan(4, 1, 1);
        let b = plan(4, 4, 1);
        c.step(key(256), &a, 0.0, 1.0, 0.0);
        for _ in 0..10 {
            c.step(key(256), &a, 1.0, 1.0, 0.0);
        }
        assert!(matches!(
            c.step(key(4096), &b, 9.0, 1.0, 0.001),
            SwitchDecision::Switch { .. }
        ));
        // Immediately profitable to go back — but cooldown holds it.
        let d = c.step(key(256), &a, 9.0, 1.0, 0.001);
        assert!(matches!(d, SwitchDecision::Stay));
        assert_eq!(c.switches, 1);
    }
}
