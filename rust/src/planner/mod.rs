//! The HAP planner (paper §III-C/D): optimal hybrid parallel strategy
//! search via ILP.
//!
//! Decision structure, matching eq. 4:
//! - `S_k`  — one-hot over attention strategies (shared by both stages,
//!   because the KV cache pins the attention layout);
//! - `E_i`  — one-hot over expert strategies for **prefill**;
//! - `E_j`  — one-hot over expert strategies for **decode**;
//! - minimize `N_layer · (Sᵀ·T_a^pre + E_i·T_e^pre + T_C(k,i))
//!   + S_out · N_layer · (Sᵀ·T_a^dec + E_j·T_e^dec + T_C(k,j))
//!   + E_iᵀ·C·E_j` where `C` is the transition-cost matrix (eq. 6).
//!
//! The bilinear terms (comm depends on the (k,i) pair; switching on the
//! (i,j) pair) are linearized with AND variables, so the formulation is
//! a faithful 0-1 ILP, solved exactly by [`crate::ilp`]. The brute-force
//! cross-check in the tests guarantees the linearization is tight.
//!
//! Planners carrying a calibrated [`OverlapModel`] (micro-chunk
//! pipelined execution, `ModelExecutor::set_pipeline_chunks`) solve one
//! more axis: per-stage binaries `P_pre`/`P_dec` choose the pipelined
//! iteration loop, and `ZP`/`WP` AND variables re-price the active comm
//! pair to the overlap model's effective (overlap-hidden) comm. Without
//! an overlap model the formulation is byte-identical to the
//! sequential-only planner.
//!
//! # Cost-table hot path
//!
//! `cost_tables` is the planner's inner loop: it evaluates the latency
//! model over every strategy/stage/pair point. It is built on the
//! **batched** simulation API — one `predict_batch` walk per regressor
//! per table block instead of per-entry forest walks — and the comm
//! tables no longer pay for the unused compute predictions the old
//! per-pair `layer_latency` calls made. The four independent table
//! blocks (attention, expert, comm-prefill, comm-decode) run under
//! `std::thread::scope` when the pair grid is large enough to amortize
//! spawning; the switching matrix (which needs the prefill tables for
//! its overlap budgets) follows as one batched `TransitionModel::
//! cost_matrix` call. `cost_tables_scalar` preserves the original
//! serial per-entry implementation as the reference for equivalence
//! tests and the perf-hotpath before/after measurement.
//!
//! Trained latency models are shared per platform through
//! [`LatencyModel::cached`], so sweeps and the serving router construct
//! planners without retraining forests.

pub mod plan;

pub use plan::HybridPlan;

use crate::cluster::imbalance;
use crate::config::{hardware::NodeConfig, model::MoEModelConfig, scenario::Scenario};
use crate::ilp::{self, LinExpr, Problem, Sense};
use crate::sim::comm;
use crate::sim::flops::{self, OpCost, Stage};
use crate::sim::latency::{LatencyModel, ModuleLatency, OverlapModel};
use crate::sim::memory::MemoryModel;
use crate::strategy::{AttnStrategy, ExecMode, ExpertStrategy, SearchSpace};
use crate::transition::{TransitionCost, TransitionModel};
use crate::Result;
use std::sync::Arc;
use std::time::Instant;

/// Seed used for planner-trained latency models (kept stable so the
/// per-platform model cache is shared across planners).
pub const PLANNER_SEED: u64 = 0x4A9;

/// Minimum (K_a × K_e) pair-grid size before `cost_tables` spawns
/// scoped threads for the independent table blocks.
const PARALLEL_PAIR_THRESHOLD: usize = 8;

/// Per-candidate cost tables the ILP consumes (also useful diagnostics).
#[derive(Debug, Clone)]
pub struct CostTables {
    /// T_a per attention strategy per stage (per layer, seconds).
    pub attn_prefill: Vec<f64>,
    pub attn_decode: Vec<f64>,
    /// T_e per expert strategy per stage (per layer).
    pub expert_prefill: Vec<f64>,
    pub expert_decode: Vec<f64>,
    /// T_C per (attention k, expert i) pair per stage (per layer).
    pub comm_prefill: Vec<Vec<f64>>,
    pub comm_decode: Vec<Vec<f64>>,
    /// Switching-cost matrix C_ij with its method (end-to-end seconds).
    pub switching: Vec<Vec<TransitionCost>>,
}

/// The HAP planner for one (model, node) deployment.
pub struct HapPlanner<'a> {
    pub model: &'a MoEModelConfig,
    pub node: &'a NodeConfig,
    pub latency: Arc<LatencyModel>,
    /// Calibrated micro-chunk overlap model. `None` (the default)
    /// leaves the search space and ILP formulation byte-identical to
    /// the sequential-only planner; `Some` widens the search space with
    /// a per-stage pipelined-execution axis priced by
    /// [`OverlapModel::effective_comm`].
    pub overlap: Option<OverlapModel>,
}

impl<'a> HapPlanner<'a> {
    /// Plan against the platform's (cached) simulation models — trains
    /// them on first use for a platform, reuses them afterwards.
    pub fn new(model: &'a MoEModelConfig, node: &'a NodeConfig) -> Self {
        HapPlanner {
            model,
            node,
            latency: LatencyModel::cached(&node.gpu, PLANNER_SEED),
            overlap: None,
        }
    }

    /// Reuse an existing latency model (sweeps, serving, tests).
    pub fn with_latency(
        model: &'a MoEModelConfig,
        node: &'a NodeConfig,
        latency: Arc<LatencyModel>,
    ) -> Self {
        HapPlanner { model, node, latency, overlap: None }
    }

    /// Enable the pipelined-execution axis with a calibrated overlap
    /// model (typically [`OverlapModel::fit`] over measured pipeline
    /// traces).
    pub fn with_overlap(mut self, overlap: OverlapModel) -> Self {
        self.overlap = Some(overlap);
        self
    }

    /// Build the search space for a scenario. Planners carrying an
    /// overlap model widen it with the pipelined-execution axis.
    pub fn search_space(&self, scenario: &Scenario) -> SearchSpace {
        let mut space = SearchSpace::enumerate(self.model, self.node, scenario);
        if self.overlap.is_some() {
            space.exec = vec![ExecMode::Sequential, ExecMode::Pipelined];
        }
        space
    }

    /// Evaluate all cost tables for the ILP on the batched simulation
    /// API, with independent blocks in parallel (see module docs).
    pub fn cost_tables(&self, space: &SearchSpace, scenario: &Scenario) -> CostTables {
        let lm = &*self.latency;
        let m = self.model;
        let b = scenario.batch;
        // Decode context representative point: mid-generation.
        let decode_ctx = scenario.context + scenario.generate / 2;

        // Compute terms are strategy-separable: batch each table as one
        // vector of op costs → one forest walk per regressor per stage.
        let attn_tables = || -> (Vec<f64>, Vec<f64>) {
            let pre: Vec<OpCost> = space
                .attn
                .iter()
                .map(|a| flops::attention_cost(m, a, Stage::Prefill, b, scenario.context))
                .collect();
            let dec: Vec<OpCost> = space
                .attn
                .iter()
                .map(|a| flops::attention_cost(m, a, Stage::Decode, b, decode_ctx))
                .collect();
            (lm.attn_time_batch(&pre), lm.attn_time_batch(&dec))
        };
        let expert_tables = || -> (Vec<f64>, Vec<f64>) {
            let cost_for = |e: &ExpertStrategy, stage: Stage, seq: usize| {
                let tokens = match stage {
                    Stage::Prefill => b * seq,
                    Stage::Decode => b,
                };
                let imb = imbalance::expected_imbalance(
                    m.num_experts,
                    e.ep,
                    tokens,
                    m.top_k,
                    imbalance::DEFAULT_SKEW,
                );
                flops::expert_cost(m, e, stage, b, seq, imb)
            };
            let pre: Vec<OpCost> = space
                .expert
                .iter()
                .map(|e| cost_for(e, Stage::Prefill, scenario.context))
                .collect();
            let dec: Vec<OpCost> =
                space.expert.iter().map(|e| cost_for(e, Stage::Decode, decode_ctx)).collect();
            (lm.expert_time_batch(&pre), lm.expert_time_batch(&dec))
        };
        // Comm is pairwise: flatten every pair's event schedule into one
        // ρ batch, then reduce back per pair. (The old path evaluated a
        // full layer_latency per pair, paying two compute predictions
        // per entry just to read `.comm`.)
        let comm_table = |stage: Stage, seq: usize| -> Vec<Vec<f64>> {
            let ke = space.k_e();
            let mut events = Vec::new();
            let mut offsets = Vec::with_capacity(space.k_a() * ke + 1);
            offsets.push(0usize);
            for a in &space.attn {
                for e in &space.expert {
                    events.extend(comm::layer_comm_events(m, a, e, stage, b, seq));
                    offsets.push(events.len());
                }
            }
            let times = lm.comm_time_batch(&events);
            (0..space.k_a())
                .map(|k| {
                    (0..ke)
                        .map(|i| {
                            let s = k * ke + i;
                            times[offsets[s]..offsets[s + 1]].iter().sum()
                        })
                        .collect()
                })
                .collect()
        };

        let ((attn_prefill, attn_decode), (expert_prefill, expert_decode), comm_prefill, comm_decode) =
            if space.k_a() * space.k_e() >= PARALLEL_PAIR_THRESHOLD {
                std::thread::scope(|s| {
                    let pre = s.spawn(|| comm_table(Stage::Prefill, scenario.context));
                    let dec = s.spawn(|| comm_table(Stage::Decode, decode_ctx));
                    let at = attn_tables();
                    let et = expert_tables();
                    (
                        at,
                        et,
                        pre.join().expect("comm-prefill table thread"),
                        dec.join().expect("comm-decode table thread"),
                    )
                })
            } else {
                (
                    attn_tables(),
                    expert_tables(),
                    comm_table(Stage::Prefill, scenario.context),
                    comm_table(Stage::Decode, decode_ctx),
                )
            };

        // Switching costs: overlap budget is the whole prefill stage
        // time under (probe attention, source expert strategy) — the
        // pipeline overlaps upload with prefill compute (paper Fig 3).
        let tm = TransitionModel::new(m, &self.node.gpu);
        let nl = m.layers as f64;
        let budgets: Vec<f64> = (0..space.k_e())
            .map(|i| nl * (attn_prefill[0] + expert_prefill[i] + comm_prefill[0][i]))
            .collect();
        let switching = tm.cost_matrix(lm, &space.expert, &budgets);

        CostTables {
            attn_prefill,
            attn_decode,
            expert_prefill,
            expert_decode,
            comm_prefill,
            comm_decode,
            switching,
        }
    }

    /// The original serial, per-entry cost-table build (uncached scalar
    /// forest walks, full `layer_latency` per pair). Retained as the
    /// reference implementation: equivalence tests pin `cost_tables`
    /// to it and `benches/perf_hotpath.rs` uses it as the before
    /// measurement. Combine with `LatencyModel::set_memo_enabled(false)`
    /// to reproduce pre-batching performance exactly.
    pub fn cost_tables_scalar(&self, space: &SearchSpace, scenario: &Scenario) -> CostTables {
        let lm = &*self.latency;
        let m = self.model;
        let b = scenario.batch;
        let decode_ctx = scenario.context + scenario.generate / 2;

        let eval = |attn: &AttnStrategy, expert: &ExpertStrategy, stage: Stage, seq: usize| {
            lm.layer_latency_uncached(m, attn, expert, stage, b, seq)
        };

        // For separable tables, pair each candidate with a fixed partner
        // (first feasible) — compute terms don't depend on the partner.
        let probe_e = space.expert[0];
        let probe_a = space.attn[0];
        let attn_prefill: Vec<f64> = space
            .attn
            .iter()
            .map(|a| eval(a, &probe_e, Stage::Prefill, scenario.context).attn)
            .collect();
        let attn_decode: Vec<f64> = space
            .attn
            .iter()
            .map(|a| eval(a, &probe_e, Stage::Decode, decode_ctx).attn)
            .collect();
        let expert_prefill: Vec<f64> = space
            .expert
            .iter()
            .map(|e| eval(&probe_a, e, Stage::Prefill, scenario.context).expert)
            .collect();
        let expert_decode: Vec<f64> = space
            .expert
            .iter()
            .map(|e| eval(&probe_a, e, Stage::Decode, decode_ctx).expert)
            .collect();

        let comm_prefill: Vec<Vec<f64>> = space
            .attn
            .iter()
            .map(|a| {
                space
                    .expert
                    .iter()
                    .map(|e| eval(a, e, Stage::Prefill, scenario.context).comm)
                    .collect()
            })
            .collect();
        let comm_decode: Vec<Vec<f64>> = space
            .attn
            .iter()
            .map(|a| {
                space
                    .expert
                    .iter()
                    .map(|e| eval(a, e, Stage::Decode, decode_ctx).comm)
                    .collect()
            })
            .collect();

        let tm = TransitionModel::new(m, &self.node.gpu);
        let nl = m.layers as f64;
        let switching: Vec<Vec<TransitionCost>> = space
            .expert
            .iter()
            .enumerate()
            .map(|(i, from)| {
                let prefill_budget =
                    nl * (attn_prefill[0] + expert_prefill[i] + comm_prefill[0][i]);
                space.expert.iter().map(|to| tm.cost(lm, from, to, prefill_budget)).collect()
            })
            .collect();

        CostTables {
            attn_prefill,
            attn_decode,
            expert_prefill,
            expert_decode,
            comm_prefill,
            comm_decode,
            switching,
        }
    }

    /// Formulate eq. 4–5 as a 0-1 ILP.
    pub fn formulate(
        &self,
        space: &SearchSpace,
        tables: &CostTables,
        scenario: &Scenario,
    ) -> (Problem, IlpVars) {
        let ka = space.k_a();
        let ke = space.k_e();
        let nl = self.model.layers as f64;
        let s_out = scenario.generate as f64;

        let mut p = Problem::new();
        let s = p.binaries("S", ka);
        let ei = p.binaries("Ei", ke);
        let ej = p.binaries("Ej", ke);
        p.exactly_one("attn-one-hot", &s);
        p.exactly_one("expert-prefill-one-hot", &ei);
        p.exactly_one("expert-decode-one-hot", &ej);

        // Separable compute terms.
        for (k, &v) in s.iter().enumerate() {
            p.set_objective_term(v, nl * tables.attn_prefill[k] + s_out * nl * tables.attn_decode[k]);
        }
        for (i, &v) in ei.iter().enumerate() {
            p.set_objective_term(v, nl * tables.expert_prefill[i]);
        }
        for (j, &v) in ej.iter().enumerate() {
            p.set_objective_term(v, s_out * nl * tables.expert_decode[j]);
        }

        // Pairwise comm terms: Z[k][i] = S_k ∧ E_i (prefill), W[k][j]
        // (decode).
        let mut z: Vec<Vec<ilp::Var>> = Vec::with_capacity(ka);
        let mut w: Vec<Vec<ilp::Var>> = Vec::with_capacity(ka);
        for k in 0..ka {
            let mut zr = Vec::with_capacity(ke);
            let mut wr = Vec::with_capacity(ke);
            for i in 0..ke {
                let zv = p.and_var(&format!("Z[{k}][{i}]"), s[k], ei[i]);
                p.set_objective_term(zv, nl * tables.comm_prefill[k][i]);
                zr.push(zv);
                let wv = p.and_var(&format!("W[{k}][{i}]"), s[k], ej[i]);
                p.set_objective_term(wv, s_out * nl * tables.comm_decode[k][i]);
                wr.push(wv);
            }
            z.push(zr);
            w.push(wr);
        }

        // Switching cost: Y[i][j] = E_i ∧ E_j.
        let mut y: Vec<Vec<ilp::Var>> = Vec::with_capacity(ke);
        for i in 0..ke {
            let mut yr = Vec::with_capacity(ke);
            for j in 0..ke {
                let yv = p.and_var(&format!("Y[{i}][{j}]"), ei[i], ej[j]);
                p.set_objective_term(yv, tables.switching[i][j].overhead);
                yr.push(yv);
            }
            y.push(yr);
        }

        // Pipelined-execution axis: one binary per stage selects the
        // micro-chunk pipelined loop, and ZP/WP AND-variables re-price
        // the active comm pair from the sequential table to the overlap
        // model's effective comm (the delta can take either sign — the
        // model's fixed overhead can exceed the hidden fraction on
        // comm-light pairs, and AND linearization is exact for both).
        // Without an overlap model the axis is absent and the
        // formulation stays byte-identical to the sequential planner.
        let mut p_pre = None;
        let mut p_dec = None;
        let mut zp: Vec<Vec<ilp::Var>> = Vec::new();
        let mut wp: Vec<Vec<ilp::Var>> = Vec::new();
        if let Some(om) = self.exec_axis(space) {
            let pre = pipelined_comm(&om, &tables.expert_prefill, &tables.comm_prefill);
            let dec = pipelined_comm(&om, &tables.expert_decode, &tables.comm_decode);
            let ppre = p.binary("P_pre");
            let pdec = p.binary("P_dec");
            for k in 0..ka {
                let mut zr = Vec::with_capacity(ke);
                let mut wr = Vec::with_capacity(ke);
                for i in 0..ke {
                    let zv = p.and_var(&format!("ZP[{k}][{i}]"), z[k][i], ppre);
                    p.set_objective_term(zv, nl * (pre[k][i] - tables.comm_prefill[k][i]));
                    zr.push(zv);
                    let wv = p.and_var(&format!("WP[{k}][{i}]"), w[k][i], pdec);
                    p.set_objective_term(wv, s_out * nl * (dec[k][i] - tables.comm_decode[k][i]));
                    wr.push(wv);
                }
                zp.push(zr);
                wp.push(wr);
            }
            p_pre = Some(ppre);
            p_dec = Some(pdec);
        }

        // Memory constraint (eq. 5): forbid (attention, expert) pairs
        // that exceed per-device capacity. The expert side must fit in
        // *both* stages' strategies.
        let mem = MemoryModel::new(self.model, scenario);
        for (k, a) in space.attn.iter().enumerate() {
            for (i, e) in space.expert.iter().enumerate() {
                let bytes = mem.per_device_bytes(a, e, self.node.num_devices);
                if bytes >= self.node.gpu.mem_bytes {
                    p.constrain(
                        &format!("mem[{k}][{i}]"),
                        LinExpr::new().term(s[k], 1.0).term(ei[i], 1.0),
                        Sense::Le,
                        1.0,
                    );
                    p.constrain(
                        &format!("mem-dec[{k}][{i}]"),
                        LinExpr::new().term(s[k], 1.0).term(ej[i], 1.0),
                        Sense::Le,
                        1.0,
                    );
                }
            }
        }

        (p, IlpVars { s, ei, ej, z, w, y, p_pre, p_dec, zp, wp })
    }

    /// The overlap model, when both the planner carries one and the
    /// space enumerates the pipelined mode (hand-built spaces may not).
    fn exec_axis(&self, space: &SearchSpace) -> Option<OverlapModel> {
        self.overlap.filter(|_| space.has_pipelined())
    }

    /// Shared tail of `plan` / `plan_reference`: formulate, solve, and
    /// assemble the winning plan from prebuilt tables.
    fn plan_from_tables(
        &self,
        space: &SearchSpace,
        tables: &CostTables,
        scenario: &Scenario,
        t0: Instant,
        reference_solver: bool,
    ) -> Result<HybridPlan> {
        let (problem, vars) = self.formulate(space, tables, scenario);
        // The brute-force-over-tables incumbent (cheap arithmetic over
        // the already-built cost tables) seeds branch & bound with a
        // tight upper bound; the reference path stays cold-start.
        let outcome = if reference_solver {
            ilp::solve_reference(&problem)
        } else {
            match self.brute_force_exec_from_tables(space, tables, scenario) {
                Some((k, i, j, pre, dec, _)) => ilp::solve_warm(
                    &problem,
                    &vars.assignment_exec(problem.num_vars, k, i, j, pre, dec),
                ),
                None => ilp::solve(&problem),
            }
        };
        let Some((x, objective)) = outcome.optimal() else {
            anyhow::bail!("ILP infeasible for {} on {}", self.model.name, self.node.label());
        };
        let pick = |vs: &[ilp::Var]| vs.iter().position(|v| x[v.0] > 0.5).expect("one-hot");
        let k = pick(&vars.s);
        let i = pick(&vars.ei);
        let j = pick(&vars.ej);
        let solve_time = t0.elapsed().as_secs_f64();

        let nl = self.model.layers as f64;
        let s_out = scenario.generate as f64;
        // Per-stage exec decision, re-derived from the tables rather
        // than read off the solver's P_pre/P_dec bits: when the
        // re-pricing delta is exactly zero either bit value is optimal,
        // and the strict-improvement rule keeps the reported flags (and
        // the predicted comm below) deterministic across solvers.
        let exec = self.exec_axis(space);
        let stage = |expert: f64, comm: f64| match exec {
            Some(om) => {
                let eff = om.overlapped(expert, comm) - expert;
                if eff < comm {
                    (eff, true)
                } else {
                    (comm, false)
                }
            }
            None => (comm, false),
        };
        let (pre_comm, pipelined_prefill) =
            stage(tables.expert_prefill[i], tables.comm_prefill[k][i]);
        let (dec_comm, pipelined_decode) =
            stage(tables.expert_decode[j], tables.comm_decode[k][j]);
        let prefill = ModuleLatency {
            attn: nl * tables.attn_prefill[k],
            expert: nl * tables.expert_prefill[i],
            comm: nl * pre_comm,
        };
        let decode = ModuleLatency {
            attn: s_out * nl * tables.attn_decode[k],
            expert: s_out * nl * tables.expert_decode[j],
            comm: s_out * nl * dec_comm,
        };
        Ok(HybridPlan {
            model: self.model.name.clone(),
            node: self.node.label(),
            scenario: scenario.clone(),
            attn: space.attn[k],
            expert_prefill: space.expert[i],
            expert_decode: space.expert[j],
            transition: tables.switching[i][j],
            pipelined_prefill,
            pipelined_decode,
            predicted_prefill: prefill,
            predicted_decode: decode,
            predicted_total: objective,
            solve_time,
            k_a: space.k_a(),
            k_e: space.k_e(),
        })
    }

    /// Run the full HAP search: enumerate → cost → formulate → solve.
    ///
    /// `s_output` overrides the scenario's generation length when the
    /// caller wants a custom horizon (the benches sweep it); pass
    /// `scenario.generate` normally.
    pub fn plan(&self, scenario: &Scenario, _s_output: usize) -> Result<HybridPlan> {
        let t0 = Instant::now();
        let space = self.search_space(scenario);
        if !space.is_feasible() {
            anyhow::bail!(
                "no feasible parallel strategy for {} on {}",
                self.model.name,
                self.node.label()
            );
        }
        let tables = self.cost_tables(&space, scenario);
        self.plan_from_tables(&space, &tables, scenario, t0, false)
    }

    /// Re-solve the HAP ILP with the search space restricted to a
    /// degraded device count — fault recovery's planning path: the
    /// surviving subset of a partially-failed grid becomes one more
    /// scenario dimension. The reduced node inherits this planner's
    /// GPU spec and (cached) latency model, so only the device
    /// dimension changes; plan caches key on the node fingerprint and
    /// therefore never serve a stale full-grid plan for the degraded
    /// platform.
    pub fn plan_degraded(&self, scenario: &Scenario, n_devices: usize) -> Result<HybridPlan> {
        if !n_devices.is_power_of_two() {
            anyhow::bail!(
                "degraded device count must be a power of two, got {n_devices} \
                 (round the survivor count down)"
            );
        }
        if n_devices == self.node.num_devices {
            return self.plan(scenario, scenario.generate);
        }
        let node = NodeConfig::new(self.node.gpu.clone(), n_devices);
        let degraded = HapPlanner::with_latency(self.model, &node, self.latency.clone());
        degraded.plan(scenario, scenario.generate)
    }

    /// `plan` over the pre-optimization code path end to end: scalar
    /// serial cost tables AND the reference ILP solver. Used as the
    /// before measurement in `benches/perf_hotpath.rs`. Selects the
    /// same plan (tables are numerically identical; both solvers are
    /// exact).
    pub fn plan_reference(&self, scenario: &Scenario) -> Result<HybridPlan> {
        let t0 = Instant::now();
        let space = self.search_space(scenario);
        if !space.is_feasible() {
            anyhow::bail!(
                "no feasible parallel strategy for {} on {}",
                self.model.name,
                self.node.label()
            );
        }
        let tables = self.cost_tables_scalar(&space, scenario);
        self.plan_from_tables(&space, &tables, scenario, t0, true)
    }

    /// Predicted end-to-end latency for a *fixed* strategy triple
    /// (baseline evaluation, e.g. static TP).
    pub fn predict_fixed(
        &self,
        scenario: &Scenario,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
    ) -> f64 {
        let st = self.latency.total_latency(self.model, attn, expert, scenario);
        st.total()
    }

    /// The static-TP baseline the paper compares against (attention TP,
    /// experts TP, both stages), evaluated through the same cost tables
    /// and objective the ILP uses so predicted speedups are
    /// apples-to-apples with `plan().predicted_total`.
    pub fn tp_baseline(&self, scenario: &Scenario) -> f64 {
        let n = self.node.num_devices;
        let space = self.search_space(scenario);
        let tables = self.cost_tables(&space, scenario);
        let nl = self.model.layers as f64;
        let s_out = scenario.generate as f64;
        let k = space.attn.iter().position(|a| *a == AttnStrategy::new(n, 1));
        let i = space.expert.iter().position(|e| *e == ExpertStrategy::new(n, 1));
        match (k, i) {
            (Some(k), Some(i)) => {
                nl * (tables.attn_prefill[k] + tables.expert_prefill[i] + tables.comm_prefill[k][i])
                    + s_out
                        * nl
                        * (tables.attn_decode[k]
                            + tables.expert_decode[i]
                            + tables.comm_decode[k][i])
            }
            // TP infeasible (pruned) — fall back to the direct estimate.
            _ => self.predict_fixed(
                scenario,
                &AttnStrategy::new(n, 1),
                &ExpertStrategy::new(n, 1),
            ),
        }
    }

    /// Brute-force optimum over the decision space (testing/validation).
    pub fn brute_force(&self, scenario: &Scenario) -> Option<(usize, usize, usize, f64)> {
        let space = self.search_space(scenario);
        if !space.is_feasible() {
            return None;
        }
        let tables = self.cost_tables(&space, scenario);
        self.brute_force_from_tables(&space, &tables, scenario)
    }

    /// [`Self::brute_force`] over prebuilt cost tables — O(K_a·K_e²)
    /// arithmetic, no simulation. `plan` uses the result as the ILP
    /// warm-start incumbent (ROADMAP: ILP warm starts). When the
    /// planner carries an overlap model the objective already folds in
    /// the optimal per-stage exec choice; use
    /// [`Self::brute_force_exec_from_tables`] to also read the flags.
    pub fn brute_force_from_tables(
        &self,
        space: &SearchSpace,
        tables: &CostTables,
        scenario: &Scenario,
    ) -> Option<(usize, usize, usize, f64)> {
        self.brute_force_exec_from_tables(space, tables, scenario)
            .map(|(k, i, j, _, _, obj)| (k, i, j, obj))
    }

    /// Brute-force optimum over the full decision space including the
    /// per-stage execution mode: `(k, i, j, pipelined_prefill,
    /// pipelined_decode, objective)`. Exec flags follow the same
    /// strict-improvement rule as `plan` (ties stay sequential), so the
    /// tuple lifts into a warm-start assignment via
    /// [`IlpVars::assignment_exec`].
    pub fn brute_force_exec_from_tables(
        &self,
        space: &SearchSpace,
        tables: &CostTables,
        scenario: &Scenario,
    ) -> Option<(usize, usize, usize, bool, bool, f64)> {
        let mem = MemoryModel::new(self.model, scenario);
        let nl = self.model.layers as f64;
        let s_out = scenario.generate as f64;
        let exec = self.exec_axis(space);
        let stage = |expert: f64, comm: f64| match exec {
            Some(om) => {
                let eff = om.overlapped(expert, comm) - expert;
                if eff < comm {
                    (eff, true)
                } else {
                    (comm, false)
                }
            }
            None => (comm, false),
        };
        let mut best: Option<(usize, usize, usize, bool, bool, f64)> = None;
        for k in 0..space.k_a() {
            for i in 0..space.k_e() {
                for j in 0..space.k_e() {
                    let a = &space.attn[k];
                    let fits = |e| {
                        mem.per_device_bytes(a, e, self.node.num_devices)
                            < self.node.gpu.mem_bytes
                    };
                    if !fits(&space.expert[i]) || !fits(&space.expert[j]) {
                        continue;
                    }
                    let (pre_comm, pre) =
                        stage(tables.expert_prefill[i], tables.comm_prefill[k][i]);
                    let (dec_comm, dec) =
                        stage(tables.expert_decode[j], tables.comm_decode[k][j]);
                    let obj = nl
                        * (tables.attn_prefill[k] + tables.expert_prefill[i] + pre_comm)
                        + s_out
                            * nl
                            * (tables.attn_decode[k] + tables.expert_decode[j] + dec_comm)
                        + tables.switching[i][j].overhead;
                    if best.map_or(true, |(.., b)| obj < b) {
                        best = Some((k, i, j, pre, dec, obj));
                    }
                }
            }
        }
        best
    }
}

/// Effective per-layer comm table under the micro-chunk pipelined
/// loop: for each (attention k, expert i) pair the overlap model folds
/// the collective behind the expert FFN, leaving
/// `max(e, c) + ε·min(e, c) + o − e` exposed (never negative — see
/// [`OverlapModel::effective_comm`]).
fn pipelined_comm(om: &OverlapModel, expert: &[f64], comm: &[Vec<f64>]) -> Vec<Vec<f64>> {
    comm.iter()
        .map(|row| row.iter().zip(expert).map(|(&c, &e)| om.overlapped(e, c) - e).collect())
        .collect()
}

/// Predicted per-module time shares of a plan, in the observability
/// subsystem's four-bucket layout (`attention`, `expert_ffn`,
/// `collective`, `reshard`) so a plan's prediction lines up
/// column-for-column with a measured `obs::TraceSummary::shares()` —
/// the simulator side of the paper's Fig. 2 breakdown. The whole-stage
/// prefill and decode latencies (decode already weighted by generated
/// tokens at plan time) fold together and the transition overhead
/// lands in the `reshard` bucket. Shares sum to 1.0 for any plan with
/// non-zero predicted time.
pub fn predicted_module_shares(plan: &HybridPlan) -> [(&'static str, f64); 4] {
    let p = plan.predicted_prefill.add(&plan.predicted_decode);
    let attn = p.attn;
    let expert = p.expert;
    let comm = p.comm;
    let reshard = plan.transition.overhead;
    let total = attn + expert + comm + reshard;
    let norm = |x: f64| if total > 0.0 { x / total } else { 0.0 };
    [
        ("attention", norm(attn)),
        ("expert_ffn", norm(expert)),
        ("collective", norm(comm)),
        ("reshard", norm(reshard)),
    ]
}

/// Handles to the decision variables (testing / introspection), plus
/// the linearization AND variables so a brute-force incumbent can be
/// lifted into a complete warm-start assignment.
pub struct IlpVars {
    pub s: Vec<ilp::Var>,
    pub ei: Vec<ilp::Var>,
    pub ej: Vec<ilp::Var>,
    /// Z[k][i] = S_k ∧ Ei_i (prefill comm pairs).
    pub z: Vec<Vec<ilp::Var>>,
    /// W[k][j] = S_k ∧ Ej_j (decode comm pairs).
    pub w: Vec<Vec<ilp::Var>>,
    /// Y[i][j] = Ei_i ∧ Ej_j (switching pairs).
    pub y: Vec<Vec<ilp::Var>>,
    /// Per-stage pipelined-execution binaries (absent without an
    /// overlap model).
    pub p_pre: Option<ilp::Var>,
    pub p_dec: Option<ilp::Var>,
    /// ZP[k][i] = Z[k][i] ∧ P_pre (pipelined prefill comm re-pricing).
    pub zp: Vec<Vec<ilp::Var>>,
    /// WP[k][j] = W[k][j] ∧ P_dec (pipelined decode comm re-pricing).
    pub wp: Vec<Vec<ilp::Var>>,
}

impl IlpVars {
    /// The full 0/1 assignment selecting decision (k, i, j), with every
    /// AND variable set consistently with its definition — feasible by
    /// construction whenever (k, i) and (k, j) pass the memory
    /// constraints, so it can seed the solver as a warm incumbent.
    /// Exec binaries (if present) stay sequential.
    pub fn assignment(&self, num_vars: usize, k: usize, i: usize, j: usize) -> Vec<f64> {
        let mut x = vec![0.0; num_vars];
        x[self.s[k].0] = 1.0;
        x[self.ei[i].0] = 1.0;
        x[self.ej[j].0] = 1.0;
        x[self.z[k][i].0] = 1.0;
        x[self.w[k][j].0] = 1.0;
        x[self.y[i][j].0] = 1.0;
        x
    }

    /// [`Self::assignment`] extended with the per-stage exec decision:
    /// a stage flagged pipelined turns on its P binary and the active
    /// pair's re-pricing AND variable, keeping every AND definition
    /// consistent so the assignment stays feasible by construction.
    pub fn assignment_exec(
        &self,
        num_vars: usize,
        k: usize,
        i: usize,
        j: usize,
        pre: bool,
        dec: bool,
    ) -> Vec<f64> {
        let mut x = self.assignment(num_vars, k, i, j);
        if let (Some(p), true) = (self.p_pre, pre) {
            x[p.0] = 1.0;
            x[self.zp[k][i].0] = 1.0;
        }
        if let (Some(p), true) = (self.p_dec, dec) {
            x[p.0] = 1.0;
            x[self.wp[k][j].0] = 1.0;
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NodeConfig, Scenario};

    #[test]
    fn ilp_matches_brute_force() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        for sc in Scenario::table2() {
            let plan = planner.plan(&sc, sc.generate).unwrap();
            let (_, _, _, bf_obj) = planner.brute_force(&sc).unwrap();
            let rel = (plan.predicted_total - bf_obj).abs() / bf_obj;
            assert!(rel < 1e-6, "{}: ilp {} vs brute {}", sc.name, plan.predicted_total, bf_obj);
        }
    }

    #[test]
    fn solve_time_well_under_paper_budget() {
        // Paper: "optimization completes consistently within one second".
        let m = MoEModelConfig::qwen2_57b_a14b();
        let node = NodeConfig::a100x(8);
        let planner = HapPlanner::new(&m, &node);
        let plan = planner.plan(&Scenario::long_extended(), 2048).unwrap();
        assert!(plan.solve_time < 1.0, "solve took {}", plan.solve_time);
    }

    #[test]
    fn hap_never_loses_to_tp() {
        // HAP's space contains pure TP, so its predicted latency must be
        // ≤ the TP baseline (paper: "comparable or superior").
        let m = MoEModelConfig::mixtral_8x7b();
        for node in [NodeConfig::a6000x(4), NodeConfig::a100x(4)] {
            let planner = HapPlanner::new(&m, &node);
            for sc in Scenario::table2() {
                let plan = planner.plan(&sc, sc.generate).unwrap();
                let tp = planner.tp_baseline(&sc);
                assert!(
                    plan.predicted_total <= tp * 1.001,
                    "{} on {}: HAP {} vs TP {}",
                    sc.name,
                    node.label(),
                    plan.predicted_total,
                    tp
                );
            }
        }
    }

    #[test]
    fn long_context_picks_low_comm_prefill_on_pcie() {
        // Paper IV-C3: on PCIe with a 4096-token context, HAP chooses
        // low-communication configurations (DP attention and/or EP
        // experts for prefill) and wins big.
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let plan = planner.plan(&Scenario::long_constrained(), 64).unwrap();
        let low_comm = plan.attn.dp > 1 || plan.expert_prefill.ep > 1;
        assert!(low_comm, "expected a low-comm prefill config, got {plan}");
        let tp = planner.tp_baseline(&Scenario::long_constrained());
        assert!(plan.predicted_total < tp * 0.9, "speedup too small");
    }

    #[test]
    fn decode_dominated_scenario_prefers_tp_decode() {
        // Paper IV-C2: with 2048-token generation the decode phase
        // dominates and favors TP for the Expert module.
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let plan = planner.plan(&Scenario::short_extended(), 2048).unwrap();
        assert_eq!(plan.expert_decode.ep, 1, "decode should be TP: {plan}");
    }

    #[test]
    fn predicted_module_shares_normalize() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let plan = planner.plan(&Scenario::long_constrained(), 64).unwrap();
        let shares = predicted_module_shares(&plan);
        let names: Vec<&str> = shares.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, ["attention", "expert_ffn", "collective", "reshard"]);
        let total: f64 = shares.iter().map(|(_, s)| s).sum();
        assert!((total - 1.0).abs() < 1e-9, "shares sum to {total}");
        assert!(shares.iter().all(|(_, s)| (0.0..=1.0).contains(s)));
    }

    #[test]
    fn plan_degraded_restricts_to_survivor_count() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let sc = Scenario::short_constrained();
        let degraded = planner.plan_degraded(&sc, 2).unwrap();
        assert_eq!(degraded.attn.devices(), 2, "degraded plan must fit survivors");
        assert_eq!(degraded.expert_prefill.devices(), 2);
        assert_eq!(degraded.expert_decode.devices(), 2);
        // The planner itself is untouched: a full-width plan still
        // solves over all four devices.
        let full = planner.plan(&sc, sc.generate).unwrap();
        assert_eq!(full.attn.devices(), 4);
        assert!(planner.plan_degraded(&sc, 3).is_err(), "non-pow2 survivor count rejected");
    }

    #[test]
    fn batched_tables_match_scalar_reference() {
        // The vectorized/parallel cost tables must be numerically
        // identical to the original per-entry build, entry for entry.
        let m = MoEModelConfig::mixtral_8x7b();
        for node in [NodeConfig::a6000x(4), NodeConfig::a100x(8)] {
            let planner = HapPlanner::new(&m, &node);
            for sc in [Scenario::long_constrained(), Scenario::short_extended()] {
                let space = planner.search_space(&sc);
                let fast = planner.cost_tables(&space, &sc);
                let slow = planner.cost_tables_scalar(&space, &sc);
                let eq = |a: &[f64], b: &[f64], what: &str| {
                    assert_eq!(a.len(), b.len(), "{what} len");
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} vs {y}");
                    }
                };
                eq(&fast.attn_prefill, &slow.attn_prefill, "attn_prefill");
                eq(&fast.attn_decode, &slow.attn_decode, "attn_decode");
                eq(&fast.expert_prefill, &slow.expert_prefill, "expert_prefill");
                eq(&fast.expert_decode, &slow.expert_decode, "expert_decode");
                for (fr, sr) in fast.comm_prefill.iter().zip(&slow.comm_prefill) {
                    eq(fr, sr, "comm_prefill");
                }
                for (fr, sr) in fast.comm_decode.iter().zip(&slow.comm_decode) {
                    eq(fr, sr, "comm_decode");
                }
                for (fr, sr) in fast.switching.iter().zip(&slow.switching) {
                    for (fc, sc_) in fr.iter().zip(sr) {
                        assert_eq!(fc.method, sc_.method);
                        assert_eq!(fc.overhead.to_bits(), sc_.overhead.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn warm_start_never_explores_more_nodes_on_standard_scenarios() {
        // ROADMAP satellite: seeding B&B with the brute-force-over-
        // tables incumbent must keep the optimum and never increase the
        // explored node count vs a cold start.
        let m = MoEModelConfig::mixtral_8x7b();
        for node in [NodeConfig::a6000x(4), NodeConfig::a100x(8)] {
            let planner = HapPlanner::new(&m, &node);
            for sc in Scenario::table2() {
                let space = planner.search_space(&sc);
                let tables = planner.cost_tables(&space, &sc);
                let (problem, vars) = planner.formulate(&space, &tables, &sc);
                let (k, i, j, bf_obj) =
                    planner.brute_force_from_tables(&space, &tables, &sc).unwrap();
                let warm = vars.assignment(problem.num_vars, k, i, j);
                assert!(problem.feasible(&warm, 1e-9), "warm assignment infeasible");
                assert!(
                    (problem.objective_value(&warm) - bf_obj).abs() <= 1e-9 * bf_obj.max(1.0),
                    "lifted assignment disagrees with brute-force objective"
                );
                let cold = ilp::solve(&problem);
                let hot = ilp::solve_warm(&problem, &warm);
                let (ilp::Outcome::Optimal { objective: co, nodes_explored: cn, .. },
                     ilp::Outcome::Optimal { objective: ho, nodes_explored: hn, .. }) =
                    (cold, hot)
                else {
                    panic!("{}: solver returned infeasible", sc.name);
                };
                assert!((co - ho).abs() <= 1e-9 * co.abs().max(1.0), "{}: {co} vs {ho}", sc.name);
                assert!(hn <= cn, "{} on {}: warm {hn} nodes > cold {cn}", sc.name, node.label());
            }
        }
    }

    #[test]
    fn overlap_planner_matches_exec_brute_force_and_never_loses() {
        // The pipelined-execution axis: ILP optimum == brute force over
        // (k, i, j, exec) for a planner carrying an overlap model, the
        // lifted warm start stays feasible and tight, and adding the
        // axis can never worsen the objective (sequential stays in the
        // space; the model here has zero fixed overhead).
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let seq = HapPlanner::new(&m, &node);
        let pipe = HapPlanner::new(&m, &node).with_overlap(OverlapModel::new(0.25, 0.0));
        for sc in Scenario::table2() {
            let space = pipe.search_space(&sc);
            assert!(space.has_pipelined(), "overlap planner must widen the space");
            let tables = pipe.cost_tables(&space, &sc);
            let (problem, vars) = pipe.formulate(&space, &tables, &sc);
            let (k, i, j, pre, dec, bf_obj) =
                pipe.brute_force_exec_from_tables(&space, &tables, &sc).unwrap();
            let warm = vars.assignment_exec(problem.num_vars, k, i, j, pre, dec);
            assert!(problem.feasible(&warm, 1e-9), "exec warm assignment infeasible");
            assert!(
                (problem.objective_value(&warm) - bf_obj).abs() <= 1e-9 * bf_obj.max(1.0),
                "lifted exec assignment disagrees with brute-force objective"
            );
            let plan = pipe.plan(&sc, sc.generate).unwrap();
            let rel = (plan.predicted_total - bf_obj).abs() / bf_obj;
            assert!(rel < 1e-6, "{}: ilp {} vs brute {}", sc.name, plan.predicted_total, bf_obj);
            let seq_plan = seq.plan(&sc, sc.generate).unwrap();
            assert!(
                plan.predicted_total <= seq_plan.predicted_total * (1.0 + 1e-9),
                "{}: pipelined axis worsened the plan",
                sc.name
            );
        }
    }

    #[test]
    fn overlap_model_flips_the_chosen_strategy() {
        // Synthetic cost tables where the sequential optimum is a
        // low-comm expert strategy but a full-overlap model hides the
        // comm-heavy candidate's collective behind its (cheaper) FFN —
        // the planner must flip strategies AND flag the stage
        // pipelined. This is the acceptance shape: a pipelined plan the
        // non-overlap model would never choose.
        use crate::transition::TransitionMethod;
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let seq = HapPlanner::new(&m, &node);
        let pipe = HapPlanner::new(&m, &node).with_overlap(OverlapModel::new(0.0, 0.0));
        let sc = Scenario::short_constrained();
        let space = pipe.search_space(&sc);
        let (ka, ke) = (space.k_a(), space.k_e());
        assert!(ke >= 2, "need at least two expert candidates");
        let k_tp = space
            .attn
            .iter()
            .position(|a| *a == AttnStrategy::new(node.num_devices, 1))
            .expect("TP attention is always feasible");
        // Attention pinned to TP (zero cost there, 1s elsewhere);
        // decode pinned to j=0 by a strictly increasing table.
        let mut attn_prefill = vec![1.0; ka];
        attn_prefill[k_tp] = 0.0;
        let mut expert_prefill = vec![10.0; ke];
        expert_prefill[0] = 2.2; // low-comm candidate: slow FFN
        expert_prefill[1] = 1.0; // comm-heavy candidate: fast FFN
        let mut comm_row = vec![10.0; ke];
        comm_row[0] = 0.1;
        comm_row[1] = 2.0;
        let no_switch = TransitionCost {
            method: TransitionMethod::None,
            overhead: 0.0,
            raw_pipeline: 0.0,
            reshard: 0.0,
        };
        let tables = CostTables {
            attn_prefill,
            attn_decode: vec![0.0; ka],
            expert_prefill,
            expert_decode: (0..ke).map(|j| 1e-3 * (j + 1) as f64).collect(),
            comm_prefill: vec![comm_row.clone(); ka],
            comm_decode: vec![vec![0.0; ke]; ka],
            switching: vec![vec![no_switch; ke]; ke],
        };
        let t0 = Instant::now();
        let seq_space = seq.search_space(&sc);
        let seq_plan = seq.plan_from_tables(&seq_space, &tables, &sc, t0, false).unwrap();
        let pipe_plan = pipe.plan_from_tables(&space, &tables, &sc, t0, false).unwrap();
        // Sequential: 2.2 + 0.1 < 1.0 + 2.0 → the slow-FFN/low-comm
        // candidate wins. Overlapped: max(2.2, 0.1) > max(1.0, 2.0) →
        // the fast-FFN/comm-heavy candidate wins, pipelined.
        assert_eq!(seq_plan.expert_prefill, space.expert[0], "{}", seq_plan.signature());
        assert!(!seq_plan.pipelined_prefill && !seq_plan.pipelined_decode);
        assert_eq!(pipe_plan.expert_prefill, space.expert[1], "{}", pipe_plan.signature());
        assert!(pipe_plan.pipelined_prefill, "stage must be flagged pipelined");
        assert!(!pipe_plan.pipelined_decode, "zero decode comm cannot profit from overlap");
        assert!(pipe_plan.signature().contains("exec=pipelined@prefill"));
        assert!(pipe_plan.predicted_total < seq_plan.predicted_total);
        // The predicted comm reflects the overlap-hidden collective.
        let nl = m.layers as f64;
        assert!((pipe_plan.predicted_prefill.comm - nl * 1.0).abs() < 1e-9);
        assert!((seq_plan.predicted_prefill.comm - nl * 0.1).abs() < 1e-9);
        // Objectives agree with the exec-aware brute force on both.
        let (.., bf) = pipe.brute_force_exec_from_tables(&space, &tables, &sc).unwrap();
        assert!((pipe_plan.predicted_total - bf).abs() <= 1e-9 * bf.max(1.0));
    }

    #[test]
    fn plan_reference_selects_the_same_plan() {
        let m = MoEModelConfig::mixtral_8x7b();
        let node = NodeConfig::a6000x(4);
        let planner = HapPlanner::new(&m, &node);
        let sc = Scenario::long_constrained();
        let fast = planner.plan(&sc, sc.generate).unwrap();
        let slow = planner.plan_reference(&sc).unwrap();
        assert_eq!(fast.signature(), slow.signature());
        let rel = (fast.predicted_total - slow.predicted_total).abs() / slow.predicted_total;
        assert!(rel < 1e-12, "fast {} vs slow {}", fast.predicted_total, slow.predicted_total);
    }
}
