//! The planner's output: a hybrid parallel execution plan.

use crate::config::scenario::Scenario;
use crate::sim::latency::ModuleLatency;
use crate::strategy::{AttnStrategy, ExpertStrategy};
use crate::transition::TransitionCost;
use crate::util::json::Json;
use std::fmt;

/// A complete HAP decision: one attention strategy (both stages), one
/// expert strategy per stage, and the transition mechanism between them.
#[derive(Debug, Clone)]
pub struct HybridPlan {
    pub model: String,
    pub node: String,
    pub scenario: Scenario,
    /// Attention-module strategy (pinned across stages by the KV cache).
    pub attn: AttnStrategy,
    /// Expert-module strategy during prefill.
    pub expert_prefill: ExpertStrategy,
    /// Expert-module strategy during decoding.
    pub expert_decode: ExpertStrategy,
    /// Transition mechanism and overhead between the two.
    pub transition: TransitionCost,
    /// Stage executes the micro-chunk pipelined iteration loop (expert
    /// FFN overlapping the combine collective) instead of the module-
    /// sequential loop. Only set by planners carrying a calibrated
    /// [`crate::sim::OverlapModel`]; token outputs are identical either
    /// way, so these flags are pure latency decisions.
    pub pipelined_prefill: bool,
    pub pipelined_decode: bool,
    /// Predicted stage latencies (whole stage, all layers).
    pub predicted_prefill: ModuleLatency,
    pub predicted_decode: ModuleLatency,
    /// ILP objective = predicted end-to-end latency (seconds).
    pub predicted_total: f64,
    /// Wall-clock of the full search incl. simulation + ILP (seconds).
    pub solve_time: f64,
    /// Search-space sizes (diagnostics).
    pub k_a: usize,
    pub k_e: usize,
}

impl HybridPlan {
    /// True if the expert strategy changes between stages.
    pub fn has_transition(&self) -> bool {
        self.expert_prefill != self.expert_decode
    }

    /// Short strategy signature, e.g. `attn=DP4 experts=EP4→TP4`. Plans
    /// choosing the pipelined iteration loop carry an `exec=` suffix so
    /// they are distinct plan identities from their sequential twins
    /// (the adaptive controller keys mispredict EWMAs on signatures).
    pub fn signature(&self) -> String {
        let mut sig = if self.has_transition() {
            format!(
                "attn={} experts={}→{} via {}",
                self.attn,
                self.expert_prefill,
                self.expert_decode,
                self.transition.method.name()
            )
        } else {
            format!("attn={} experts={}", self.attn, self.expert_prefill)
        };
        match (self.pipelined_prefill, self.pipelined_decode) {
            (false, false) => {}
            (true, true) => sig.push_str(" exec=pipelined"),
            (true, false) => sig.push_str(" exec=pipelined@prefill"),
            (false, true) => sig.push_str(" exec=pipelined@decode"),
        }
        sig
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.as_str().into()),
            ("node", self.node.as_str().into()),
            ("scenario", self.scenario.to_json()),
            ("attn", self.attn.to_json()),
            ("expert_prefill", self.expert_prefill.to_json()),
            ("expert_decode", self.expert_decode.to_json()),
            ("transition", self.transition.method.name().into()),
            ("transition_overhead_s", self.transition.overhead.into()),
            ("pipelined_prefill", self.pipelined_prefill.into()),
            ("pipelined_decode", self.pipelined_decode.into()),
            ("transition_cost", self.transition.to_json()),
            ("predicted_prefill", self.predicted_prefill.to_json()),
            ("predicted_decode", self.predicted_decode.to_json()),
            ("predicted_total_s", self.predicted_total.into()),
            ("solve_time_s", self.solve_time.into()),
            ("k_a", self.k_a.into()),
            ("k_e", self.k_e.into()),
        ])
    }

    /// Reconstruct a plan from [`Self::to_json`] output (the plan-cache
    /// persistence path). Round-trips bit-exactly: the JSON writer
    /// prints f64 with shortest-round-trip formatting.
    pub fn from_json(j: &Json) -> Option<HybridPlan> {
        Some(HybridPlan {
            model: j.get("model")?.as_str()?.to_string(),
            node: j.get("node")?.as_str()?.to_string(),
            scenario: Scenario::from_json(j.get("scenario")?)?,
            attn: AttnStrategy::from_json(j.get("attn")?)?,
            expert_prefill: ExpertStrategy::from_json(j.get("expert_prefill")?)?,
            expert_decode: ExpertStrategy::from_json(j.get("expert_decode")?)?,
            transition: TransitionCost::from_json(j.get("transition_cost")?)?,
            // Absent in plans persisted before the pipelined-execution
            // axis existed: those were solved sequential-only.
            pipelined_prefill: j
                .get("pipelined_prefill")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            pipelined_decode: j.get("pipelined_decode").and_then(|v| v.as_bool()).unwrap_or(false),
            predicted_prefill: ModuleLatency::from_json(j.get("predicted_prefill")?)?,
            predicted_decode: ModuleLatency::from_json(j.get("predicted_decode")?)?,
            predicted_total: j.get("predicted_total_s")?.as_f64()?,
            solve_time: j.get("solve_time_s")?.as_f64()?,
            k_a: j.get("k_a")?.as_usize()?,
            k_e: j.get("k_e")?.as_usize()?,
        })
    }
}

impl fmt::Display for HybridPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "HAP plan for {} on {} ({}: ctx={} gen={} batch={})",
            self.model,
            self.node,
            self.scenario.name,
            self.scenario.context,
            self.scenario.generate,
            self.scenario.batch
        )?;
        writeln!(f, "  attention       : {}", self.attn)?;
        writeln!(f, "  experts@prefill : {}", self.expert_prefill)?;
        writeln!(f, "  experts@decode  : {}", self.expert_decode)?;
        writeln!(
            f,
            "  transition      : {} (overhead {:.3} ms)",
            self.transition.method.name(),
            self.transition.overhead * 1e3
        )?;
        if self.pipelined_prefill || self.pipelined_decode {
            writeln!(
                f,
                "  execution       : prefill {} / decode {}",
                if self.pipelined_prefill { "pipelined" } else { "sequential" },
                if self.pipelined_decode { "pipelined" } else { "sequential" }
            )?;
        }
        writeln!(
            f,
            "  predicted       : prefill {:.1} ms + decode {:.1} ms = {:.1} ms total",
            self.predicted_prefill.total() * 1e3,
            self.predicted_decode.total() * 1e3,
            self.predicted_total * 1e3
        )?;
        write!(
            f,
            "  search          : K_a={} K_e={} solved in {:.1} ms",
            self.k_a,
            self.k_e,
            self.solve_time * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transition::{TransitionCost, TransitionMethod};

    fn dummy_plan(pre: ExpertStrategy, dec: ExpertStrategy) -> HybridPlan {
        HybridPlan {
            model: "mixtral-8x7b".into(),
            node: "4xA6000".into(),
            scenario: Scenario::long_constrained(),
            attn: AttnStrategy::new(1, 4),
            expert_prefill: pre,
            expert_decode: dec,
            transition: TransitionCost {
                method: TransitionMethod::Int4Backup,
                overhead: 0.001,
                raw_pipeline: 0.1,
                reshard: 0.2,
            },
            pipelined_prefill: false,
            pipelined_decode: false,
            predicted_prefill: Default::default(),
            predicted_decode: Default::default(),
            predicted_total: 1.5,
            solve_time: 0.02,
            k_a: 3,
            k_e: 3,
        }
    }

    #[test]
    fn transition_detection() {
        let p = dummy_plan(ExpertStrategy::new(1, 4), ExpertStrategy::new(4, 1));
        assert!(p.has_transition());
        assert!(p.signature().contains("EP4→TP4"));
        let q = dummy_plan(ExpertStrategy::new(4, 1), ExpertStrategy::new(4, 1));
        assert!(!q.has_transition());
    }

    #[test]
    fn json_has_key_fields() {
        let p = dummy_plan(ExpertStrategy::new(1, 4), ExpertStrategy::new(4, 1));
        let j = p.to_json();
        assert_eq!(j.get("model").unwrap().as_str(), Some("mixtral-8x7b"));
        assert!(j.get("predicted_total_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn pipelined_flags_round_trip_and_default_sequential() {
        let mut p = dummy_plan(ExpertStrategy::new(1, 4), ExpertStrategy::new(4, 1));
        p.pipelined_prefill = true;
        assert!(p.signature().ends_with("exec=pipelined@prefill"), "{}", p.signature());
        let q = HybridPlan::from_json(&p.to_json()).unwrap();
        assert!(q.pipelined_prefill && !q.pipelined_decode);
        assert_eq!(q.signature(), p.signature());
        // A plan persisted before the exec axis existed has no
        // pipelined keys — it was solved sequential-only and must
        // deserialize that way.
        let Json::Obj(fields) = p.to_json() else { panic!("plan json is an object") };
        let legacy =
            Json::Obj(fields.into_iter().filter(|(k, _)| !k.starts_with("pipelined")).collect());
        let old = HybridPlan::from_json(&legacy).unwrap();
        assert!(!old.pipelined_prefill && !old.pipelined_decode);
        assert!(!old.signature().contains("exec="));
    }

    #[test]
    fn json_round_trips_bit_exactly() {
        let p = dummy_plan(ExpertStrategy::new(1, 4), ExpertStrategy::new(4, 1));
        // Through text, as persistence does.
        let text = p.to_json().to_string_pretty();
        let j = crate::util::json::Json::parse(&text).unwrap();
        let q = HybridPlan::from_json(&j).expect("round trip");
        assert_eq!(q.signature(), p.signature());
        assert_eq!(q.scenario, p.scenario);
        assert_eq!(q.predicted_total.to_bits(), p.predicted_total.to_bits());
        assert_eq!(q.transition.overhead.to_bits(), p.transition.overhead.to_bits());
        assert_eq!(q.k_a, p.k_a);
    }
}
