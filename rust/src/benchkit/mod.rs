//! Benchmark harness (std-only `criterion` stand-in).
//!
//! Used by every `rust/benches/*.rs` target (built with
//! `harness = false`, run by `cargo bench`). Provides warmed-up timing
//! with outlier-robust statistics, aligned table printing for
//! paper-style rows, and JSON result dumps under
//! `target/bench_results/`.

use crate::util::json::Json;
use crate::util::stats;
use std::time::Instant;

/// Timing statistics of one measured function.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub std_dev: f64,
}

impl Timing {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("iters", self.iters.into()),
            ("mean_s", self.mean.into()),
            ("median_s", self.median.into()),
            ("p95_s", self.p95.into()),
            ("std_s", self.std_dev.into()),
        ])
    }
}

/// Measure `f`, auto-scaling iterations to ~`budget_s` seconds after
/// `warmup` calls. Returns robust statistics over per-iteration times.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget_s: f64, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    // Estimate cost to pick iteration count.
    let probe = {
        let t0 = Instant::now();
        f();
        t0.elapsed().as_secs_f64().max(1e-9)
    };
    let iters = ((budget_s / probe) as usize).clamp(5, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        name: name.to_string(),
        iters,
        mean: stats::mean(&samples),
        median: stats::median(&samples),
        p95: stats::percentile(&samples, 95.0),
        std_dev: stats::std_dev(&samples),
    }
}

/// Aligned table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Write a bench's result JSON under `target/bench_results/<id>.json`.
pub fn write_results(id: &str, value: &Json) {
    let dir = std::path::Path::new("target/bench_results");
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join(format!("{id}.json"));
        let _ = std::fs::write(path, value.to_string_pretty());
    }
}

/// Standard bench banner.
pub fn banner(id: &str, what: &str) {
    println!("\n=== {id}: {what} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let t = bench("noop-ish", 2, 0.02, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(t.iters >= 5);
        assert!(t.mean > 0.0);
        assert!(t.median <= t.p95 * 1.0001);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["model", "speedup"]);
        t.row(&["mixtral-8x7b".into(), "1.68x".into()]);
        t.row(&["qwen".into(), "1.1x".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("model"));
        assert!(lines[2].contains("1.68x"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".into()]);
    }
}
