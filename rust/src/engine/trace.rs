//! Execution-trace export: spans → Chrome trace-event JSON, plus
//! aligned-text timelines for quick terminal inspection.

use crate::cluster::event::{EventSim, OpKind, Span};
use crate::util::json::Json;

fn kind_name(kind: OpKind) -> &'static str {
    match kind {
        OpKind::Attention => "attention",
        OpKind::Expert => "expert",
        OpKind::Comm => "comm",
        OpKind::Transition => "transition",
        OpKind::Other => "other",
    }
}

/// Export spans in Chrome `chrome://tracing` format (one complete event
/// per span; device = tid).
pub fn to_chrome_trace(sim: &EventSim) -> Json {
    let events: Vec<Json> = sim
        .spans()
        .iter()
        .map(|s: &Span| {
            Json::obj(vec![
                ("name", s.label.into()),
                ("cat", kind_name(s.kind).into()),
                ("ph", "X".into()),
                ("ts", (s.start * 1e6).into()),
                ("dur", (s.dur * 1e6).into()),
                ("pid", 0usize.into()),
                ("tid", s.device.into()),
            ])
        })
        .collect();
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

/// A coarse ASCII timeline (one row per device, `width` columns).
pub fn ascii_timeline(sim: &EventSim, width: usize) -> String {
    let total = sim.now().max(1e-12);
    let n = sim.num_devices();
    let mut rows = vec![vec!['.'; width]; n];
    for s in sim.spans() {
        let c = match s.kind {
            OpKind::Attention => 'A',
            OpKind::Expert => 'E',
            OpKind::Comm => 'c',
            OpKind::Transition => 'T',
            OpKind::Other => '?',
        };
        let lo = ((s.start / total) * width as f64) as usize;
        let hi = (((s.start + s.dur) / total) * width as f64).ceil() as usize;
        for x in lo..hi.min(width) {
            rows[s.device][x] = c;
        }
    }
    rows.iter()
        .enumerate()
        .map(|(d, r)| format!("dev{d}: {}", r.iter().collect::<String>()))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{EventSim, OpKind};

    fn sample_sim() -> EventSim {
        let mut sim = EventSim::new(2);
        sim.parallel_compute(&[(0, 1.0), (1, 1.0)], OpKind::Attention, "attn");
        sim.collective(&[0, 1], 0.5, "ar");
        sim.parallel_compute(&[(0, 2.0), (1, 1.0)], OpKind::Expert, "exp");
        sim
    }

    #[test]
    fn chrome_trace_structure() {
        let sim = sample_sim();
        let j = to_chrome_trace(&sim);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), sim.spans().len());
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("X"));
    }

    #[test]
    fn ascii_has_all_devices() {
        let sim = sample_sim();
        let art = ascii_timeline(&sim, 40);
        assert!(art.contains("dev0:"));
        assert!(art.contains("dev1:"));
        assert!(art.contains('A'));
        assert!(art.contains('E'));
        assert!(art.contains('c'));
    }
}
