//! KV-cache accounting for the simulated engine.
//!
//! Tracks per-sequence cache growth and per-device memory pressure
//! under an attention strategy; the serving batcher uses it for
//! admission control, and it enforces the eq. 5 memory constraint at
//! run time (the planner enforces it statically).

use crate::config::model::MoEModelConfig;
use crate::strategy::AttnStrategy;

/// One sequence's cache state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeqCache {
    /// Tokens actually cached so far (prompt, then +1 per decode).
    pub tokens: usize,
    /// Tokens booked against the budget at admission: prompt + the
    /// generation budget. `extend` grows `tokens` inside this
    /// reservation without re-checking the budget.
    pub reserved: usize,
}

/// KV-cache manager for a fixed attention layout.
#[derive(Debug, Clone)]
pub struct KvCacheManager {
    /// Bytes per cached token per device.
    bytes_per_token_per_device: f64,
    /// Device memory budget for KV (bytes).
    budget: f64,
    seqs: Vec<SeqCache>,
}

impl KvCacheManager {
    /// `kv_budget` is the per-device byte budget reserved for KV.
    pub fn new(model: &MoEModelConfig, attn: &AttnStrategy, kv_budget: f64) -> Self {
        // TP shards KV heads across tp; DP partitions sequences (so the
        // per-device share of a *global* token is 1/dp on average).
        let per_tok = model.kv_bytes_per_token() as f64 / (attn.tp * attn.dp) as f64;
        KvCacheManager { bytes_per_token_per_device: per_tok, budget: kv_budget, seqs: Vec::new() }
    }

    /// Current per-device KV bytes *committed*: every admitted
    /// sequence's full reservation (prompt + generation budget), not
    /// just the tokens cached so far — admission that only counted
    /// cached tokens would over-admit and blow the budget mid-decode.
    pub fn used_bytes(&self) -> f64 {
        let tokens: usize = self.seqs.iter().map(|s| s.reserved).sum();
        tokens as f64 * self.bytes_per_token_per_device
    }

    /// Can a new sequence of `prompt + gen` tokens be admitted?
    pub fn can_admit(&self, total_tokens: usize) -> bool {
        self.used_bytes() + total_tokens as f64 * self.bytes_per_token_per_device <= self.budget
    }

    /// Admit a sequence, reserving its whole `prompt + generate`
    /// footprint (panics if over budget — callers must check).
    pub fn admit(&mut self, prompt_tokens: usize, generate_tokens: usize) -> usize {
        let total = prompt_tokens + generate_tokens;
        assert!(self.can_admit(total), "KV budget exceeded");
        self.seqs.push(SeqCache { tokens: prompt_tokens, reserved: total });
        self.seqs.len() - 1
    }

    /// Append one generated token to a sequence (within its
    /// reservation).
    pub fn extend(&mut self, seq: usize) {
        self.seqs[seq].tokens += 1;
        debug_assert!(
            self.seqs[seq].tokens <= self.seqs[seq].reserved,
            "sequence grew past its reservation"
        );
    }

    /// Release a finished sequence's cache (and its reservation).
    pub fn release(&mut self, seq: usize) {
        self.seqs[seq].tokens = 0;
        self.seqs[seq].reserved = 0;
    }

    pub fn active_tokens(&self) -> usize {
        self.seqs.iter().map(|s| s.tokens).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(budget: f64) -> KvCacheManager {
        let m = MoEModelConfig::mixtral_8x7b();
        KvCacheManager::new(&m, &AttnStrategy::new(4, 1), budget)
    }

    #[test]
    fn admission_respects_budget() {
        let m = MoEModelConfig::mixtral_8x7b();
        let per_tok = m.kv_bytes_per_token() as f64 / 4.0;
        let mut mgr = mgr(per_tok * 100.0);
        assert!(mgr.can_admit(100));
        assert!(!mgr.can_admit(101));
        mgr.admit(40, 20);
        assert!(mgr.can_admit(40));
        assert!(!mgr.can_admit(41));
    }

    #[test]
    fn admit_reserves_prompt_plus_generate() {
        // Regression: admit used to book only the prompt, so a second
        // sequence could be admitted into bytes the first's decode
        // budget had already committed.
        let m = MoEModelConfig::mixtral_8x7b();
        let per_tok = m.kv_bytes_per_token() as f64 / 4.0;
        let mut mgr = mgr(per_tok * 100.0);
        let s = mgr.admit(10, 80);
        // 90 tokens committed: only 10 remain admissible, and the
        // growth inside the reservation changes nothing.
        assert!(!mgr.can_admit(11));
        assert!((mgr.used_bytes() - per_tok * 90.0).abs() < 1e-6);
        mgr.extend(s);
        mgr.extend(s);
        assert_eq!(mgr.active_tokens(), 12);
        assert!((mgr.used_bytes() - per_tok * 90.0).abs() < 1e-6, "extend re-billed");
        assert!(!mgr.can_admit(11));
        assert!(mgr.can_admit(10));
        // Release frees the whole reservation.
        mgr.release(s);
        assert_eq!(mgr.used_bytes(), 0.0);
        assert!(mgr.can_admit(100));
    }

    #[test]
    fn extend_and_release() {
        let mut mgr = mgr(1e12);
        let s = mgr.admit(10, 16);
        mgr.extend(s);
        mgr.extend(s);
        assert_eq!(mgr.active_tokens(), 12);
        mgr.release(s);
        assert_eq!(mgr.active_tokens(), 0);
    }

    #[test]
    #[should_panic(expected = "KV budget exceeded")]
    fn over_admit_panics() {
        let mut mgr = mgr(1.0);
        mgr.admit(1000, 0);
    }

    #[test]
    #[should_panic(expected = "KV budget exceeded")]
    fn over_admit_on_generate_budget_panics() {
        // A prompt that fits but a generation budget that does not must
        // fail at admission, not mid-decode.
        let m = MoEModelConfig::mixtral_8x7b();
        let per_tok = m.kv_bytes_per_token() as f64 / 4.0;
        let mut mgr = mgr(per_tok * 100.0);
        mgr.admit(50, 51);
    }

    #[test]
    fn tp_shards_kv() {
        let m = MoEModelConfig::mixtral_8x7b();
        let tp4 = KvCacheManager::new(&m, &AttnStrategy::new(4, 1), 1e9);
        let tp1 = KvCacheManager::new(&m, &AttnStrategy::new(1, 1), 1e9);
        assert!((tp1.bytes_per_token_per_device / tp4.bytes_per_token_per_device - 4.0).abs() < 1e-9);
    }
}
