//! The MoE inference execution engine over the simulated cluster.
//!
//! Executes a scenario layer by layer on the discrete-event timeline
//! using the *noise-free ground-truth* operator model (the simulated
//! node's physics), with sampled expert routing for EP load imbalance.
//! This is the "measured" side of every experiment: the planner
//! predicts with regressors, the engine measures by (simulated)
//! execution — exactly the paper's predict-vs-measure split.

pub mod kvcache;
pub mod trace;

use crate::cluster::collective::{self};
use crate::cluster::imbalance;
use crate::cluster::{EventSim, OpKind, Topology};
use crate::config::{hardware::NodeConfig, model::MoEModelConfig, scenario::Scenario};
use crate::planner::HybridPlan;
use crate::sim::comm::{self, Collective};
use crate::sim::flops::{self, Stage};
use crate::sim::microbench;
use crate::strategy::{AttnStrategy, ExpertStrategy};
use crate::util::rng::Rng;

/// Stage-level measured breakdown (seconds of critical path).
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    pub attn: f64,
    pub expert: f64,
    pub comm: f64,
    pub transition: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.attn + self.expert + self.comm + self.transition
    }
}

/// End-to-end measured result.
#[derive(Debug, Clone, Default)]
pub struct RunResult {
    pub prefill: Breakdown,
    pub decode: Breakdown,
    /// Mean device utilization over the run.
    pub utilization: f64,
}

impl RunResult {
    pub fn total(&self) -> f64 {
        self.prefill.total() + self.decode.total()
    }
}

/// The execution engine for one (model, node) deployment.
pub struct Engine<'a> {
    pub model: &'a MoEModelConfig,
    pub node: &'a NodeConfig,
    pub topo: Topology,
    /// Decode steps are simulated at `decode_samples` context points and
    /// integrated, mirroring the latency model's quadrature.
    pub decode_samples: usize,
}

impl<'a> Engine<'a> {
    pub fn new(model: &'a MoEModelConfig, node: &'a NodeConfig) -> Self {
        Engine { model, node, topo: Topology::from_node(node), decode_samples: 8 }
    }

    /// Execute one full request batch under a fixed strategy pair (no
    /// transition) — the static-baseline path (TP, EP, ...).
    pub fn run_static(
        &self,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        scenario: &Scenario,
        seed: u64,
    ) -> RunResult {
        self.run_plan_inner(attn, expert, expert, 0.0, scenario, seed)
    }

    /// Execute a HAP plan, including the stage transition.
    pub fn run_plan(&self, plan: &HybridPlan, scenario: &Scenario, seed: u64) -> RunResult {
        self.run_plan_inner(
            &plan.attn,
            &plan.expert_prefill,
            &plan.expert_decode,
            plan.transition.overhead,
            scenario,
            seed,
        )
    }

    fn run_plan_inner(
        &self,
        attn: &AttnStrategy,
        expert_prefill: &ExpertStrategy,
        expert_decode: &ExpertStrategy,
        transition_overhead: f64,
        scenario: &Scenario,
        seed: u64,
    ) -> RunResult {
        let mut rng = Rng::new(seed);
        let mut sim = EventSim::new(self.topo.len());

        // ---- Prefill stage: all layers at full context.
        for _layer in 0..self.model.layers {
            self.execute_layer(
                &mut sim,
                attn,
                expert_prefill,
                Stage::Prefill,
                scenario.batch,
                scenario.context,
                &mut rng,
            );
        }
        let prefill = Breakdown {
            attn: sim.critical_time(OpKind::Attention),
            expert: sim.critical_time(OpKind::Expert),
            comm: sim.critical_time(OpKind::Comm),
            transition: 0.0,
        };

        // ---- Transition between stages.
        if transition_overhead > 0.0 && expert_prefill != expert_decode {
            sim.transition(transition_overhead, "strategy-switch");
        }
        let after_prefill = (
            sim.critical_time(OpKind::Attention),
            sim.critical_time(OpKind::Expert),
            sim.critical_time(OpKind::Comm),
        );

        // ---- Decode stage: sample context points, integrate.
        let q = self.decode_samples.min(scenario.generate.max(1));
        let step = scenario.generate as f64 / q as f64;
        for s in 0..q {
            let ctx = scenario.context as f64 + (s as f64 + 0.5) * step;
            // Simulate one step at this context; scale by charging the
            // layer `step` times (durations multiplied, not looped, to
            // keep the sim fast and exact under linearity).
            for _layer in 0..self.model.layers {
                self.execute_layer_scaled(
                    &mut sim,
                    attn,
                    expert_decode,
                    Stage::Decode,
                    scenario.batch,
                    ctx as usize,
                    step,
                    &mut rng,
                );
            }
        }

        let decode = Breakdown {
            attn: sim.critical_time(OpKind::Attention) - after_prefill.0,
            expert: sim.critical_time(OpKind::Expert) - after_prefill.1,
            comm: sim.critical_time(OpKind::Comm) - after_prefill.2,
            transition: sim.critical_time(OpKind::Transition),
        };

        let utilization = (0..self.topo.len())
            .map(|d| sim.utilization(d))
            .sum::<f64>()
            / self.topo.len() as f64;
        RunResult { prefill, decode, utilization }
    }

    fn execute_layer(
        &self,
        sim: &mut EventSim,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        stage: Stage,
        batch: usize,
        seq: usize,
        rng: &mut Rng,
    ) {
        self.execute_layer_scaled(sim, attn, expert, stage, batch, seq, 1.0, rng)
    }

    /// Execute one layer; all durations multiplied by `scale` (used to
    /// integrate multiple decode steps at one context point).
    fn execute_layer_scaled(
        &self,
        sim: &mut EventSim,
        attn: &AttnStrategy,
        expert: &ExpertStrategy,
        stage: Stage,
        batch: usize,
        seq: usize,
        scale: f64,
        rng: &mut Rng,
    ) {
        let gpu = &self.node.gpu;
        let n = self.topo.len();
        let m = self.model;

        // --- Attention compute: identical per device under TP/DP split.
        let a_cost = flops::attention_cost(m, attn, stage, batch, seq);
        let a_time = microbench::true_compute_time(gpu, &a_cost) * scale;
        let durs: Vec<(usize, f64)> = (0..n).map(|d| (d, a_time)).collect();
        sim.parallel_compute(&durs, OpKind::Attention, "attention");

        // --- Comm schedule + expert compute.
        let events = comm::layer_comm_events(m, attn, expert, stage, batch, seq);
        let tokens = match stage {
            Stage::Prefill => batch * seq,
            Stage::Decode => batch,
        };

        // Sampled per-EP-group loads (for imbalanced expert compute and
        // imbalanced All-to-All).
        let group_loads: Vec<f64> = if expert.ep > 1 {
            let probs = imbalance::group_probs(m.num_experts, expert.ep, imbalance::DEFAULT_SKEW);
            let routed = (tokens * m.top_k) as f64;
            // Gaussian multinomial approximation per group (fast, seeded).
            probs
                .iter()
                .map(|&p| {
                    let mean = routed * p;
                    let std = (routed * p * (1.0 - p)).sqrt();
                    (mean + std * rng.gauss()).max(0.0)
                })
                .collect()
        } else {
            vec![(tokens * m.top_k) as f64]
        };

        let all: Vec<usize> = (0..n).collect();
        for ev in &events {
            let t = match (ev.collective, ev.label) {
                (Collective::AllToAll, "ep-dispatch-a2a") | (Collective::AllToAll, "ep-combine-a2a") => {
                    // Imbalanced A2A: wire volume per group from loads.
                    let token_bytes = (m.hidden * m.dtype_bytes) as f64;
                    let wires = collective::ep_dispatch_wires(
                        &group_loads,
                        (tokens * m.top_k) as f64,
                        token_bytes,
                    );
                    collective::collective_time(&self.topo, ev, Some(&wires))
                }
                _ => collective::collective_time(&self.topo, ev, None),
            };
            sim.collective(&all, t * scale, ev.label);
            // Expert compute happens between dispatch and combine.
            if ev.label == "ep-dispatch-a2a" {
                self.expert_compute(sim, expert, stage, batch, seq, &group_loads, scale);
            }
        }
        // TP-only expert path has no dispatch marker — run experts after
        // the (optional) gather and before its AllReduce ordering is
        // already encoded in `events`; just ensure compute happens once.
        if expert.ep == 1 {
            self.expert_compute(sim, expert, stage, batch, seq, &group_loads, scale);
        }
    }

    fn expert_compute(
        &self,
        sim: &mut EventSim,
        expert: &ExpertStrategy,
        stage: Stage,
        batch: usize,
        seq: usize,
        group_loads: &[f64],
        scale: f64,
    ) {
        let m = self.model;
        let gpu = &self.node.gpu;
        let n = self.topo.len();
        let tokens = match stage {
            Stage::Prefill => batch * seq,
            Stage::Decode => batch,
        };
        let balanced = (tokens * m.top_k) as f64 / expert.ep as f64;
        let durs: Vec<(usize, f64)> = (0..n)
            .map(|d| {
                // Device d belongs to EP group (d / tp).
                let g = if expert.ep > 1 { d / expert.tp } else { 0 };
                let imb = if expert.ep > 1 && balanced > 0.0 {
                    (group_loads[g] / balanced).max(0.05)
                } else {
                    1.0
                };
                let cost = flops::expert_cost(m, expert, stage, batch, seq, imb);
                (d, microbench::true_compute_time(gpu, &cost) * scale)
            })
            .collect();
        sim.parallel_compute(&durs, OpKind::Expert, "experts");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NodeConfig, Scenario};

    fn mixtral_a6000() -> (MoEModelConfig, NodeConfig) {
        (MoEModelConfig::mixtral_8x7b(), NodeConfig::a6000x(4))
    }

    #[test]
    fn fig2_breakdown_shape() {
        // Reproduce Fig 2's qualitative claims on 4×A6000, seq 2K.
        let (m, node) = mixtral_a6000();
        let engine = Engine::new(&m, &node);
        let sc = Scenario::new("fig2", 2048, 64, 16);
        // EP deployment pairs DP attention with EP experts
        // (DeepSpeed-MoE convention the paper benchmarks against).
        let tp = engine.run_static(&AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), &sc, 1);
        let ep = engine.run_static(&AttnStrategy::new(1, 4), &ExpertStrategy::new(1, 4), &sc, 1);
        // Prefill: TP comm > EP comm.
        assert!(
            tp.prefill.comm > ep.prefill.comm,
            "tp comm {} vs ep comm {}",
            tp.prefill.comm,
            ep.prefill.comm
        );
        // Decode: EP expert compute > TP expert compute (imbalance).
        assert!(
            ep.decode.expert > tp.decode.expert,
            "ep expert {} vs tp expert {}",
            ep.decode.expert,
            tp.decode.expert
        );
    }

    #[test]
    fn deterministic_under_seed() {
        let (m, node) = mixtral_a6000();
        let engine = Engine::new(&m, &node);
        let sc = Scenario::short_constrained();
        let a = engine.run_static(&AttnStrategy::new(4, 1), &ExpertStrategy::new(1, 4), &sc, 7);
        let b = engine.run_static(&AttnStrategy::new(4, 1), &ExpertStrategy::new(1, 4), &sc, 7);
        assert_eq!(a.total(), b.total());
    }

    #[test]
    fn decode_time_grows_with_generation() {
        let (m, node) = mixtral_a6000();
        let engine = Engine::new(&m, &node);
        let short = engine.run_static(
            &AttnStrategy::new(4, 1),
            &ExpertStrategy::new(4, 1),
            &Scenario::short_constrained(),
            1,
        );
        let long = engine.run_static(
            &AttnStrategy::new(4, 1),
            &ExpertStrategy::new(4, 1),
            &Scenario::short_extended(),
            1,
        );
        assert!(long.decode.total() > 10.0 * short.decode.total());
    }

    #[test]
    fn utilization_bounded() {
        let (m, node) = mixtral_a6000();
        let engine = Engine::new(&m, &node);
        let r = engine.run_static(
            &AttnStrategy::new(2, 2),
            &ExpertStrategy::new(2, 2),
            &Scenario::short_constrained(),
            3,
        );
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
    }
}
