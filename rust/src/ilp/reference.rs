//! The pre-optimization ILP solver, kept verbatim as a reference:
//! per-row `Vec<Vec<f64>>` tableau, Bland's-rule-only pivoting, full
//! `x ≤ 1` bound rows, and branch & bound that re-solves each node's LP
//! on pop. `benches/perf_hotpath.rs` measures the production solver
//! against it, and the property tests cross-check that both return the
//! same optima on random HAP-shaped problems.

use super::{Outcome, Problem, Sense};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

const INT_TOL: f64 = 1e-6;
const EPS: f64 = 1e-9;

enum LpResult {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
}

/// Solve a 0-1 ILP exactly with the reference implementation.
pub fn solve(problem: &Problem) -> Outcome {
    branch_and_bound(problem)
}

struct Node {
    bound: f64,
    fixed: Vec<Option<f64>>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on bound via reversed comparison.
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

fn branch_and_bound(problem: &Problem) -> Outcome {
    let n = problem.num_vars;
    let root_fixed = vec![None; n];
    let mut heap = BinaryHeap::new();
    let mut incumbent: Option<(Vec<f64>, f64)> = None;
    let mut nodes_explored = 0usize;

    match solve_relaxation(problem, &root_fixed) {
        LpResult::Infeasible => return Outcome::Infeasible,
        LpResult::Optimal { x, objective } => {
            if most_fractional(&x, &root_fixed).is_some() {
                heap.push(Node { bound: objective, fixed: root_fixed.clone() });
            } else {
                return Outcome::Optimal { x, objective, nodes_explored: 1 };
            }
        }
    }

    while let Some(node) = heap.pop() {
        nodes_explored += 1;
        if nodes_explored > 200_000 {
            break; // safety valve; never hit at HAP sizes
        }
        if let Some((_, inc_obj)) = &incumbent {
            if node.bound >= *inc_obj - 1e-12 {
                continue;
            }
        }
        let LpResult::Optimal { x, objective } = solve_relaxation(problem, &node.fixed) else {
            continue;
        };
        if let Some((_, inc_obj)) = &incumbent {
            if objective >= *inc_obj - 1e-12 {
                continue;
            }
        }
        match most_fractional(&x, &node.fixed) {
            None => {
                let xi: Vec<f64> = x.iter().map(|&v| if v > 0.5 { 1.0 } else { 0.0 }).collect();
                if problem.feasible(&xi, 1e-6) {
                    let obj = problem.objective_value(&xi);
                    if incumbent.as_ref().map_or(true, |(_, o)| obj < *o) {
                        incumbent = Some((xi, obj));
                    }
                }
            }
            Some(branch_var) => {
                for v in [1.0, 0.0] {
                    let mut fixed = node.fixed.clone();
                    fixed[branch_var] = Some(v);
                    if let LpResult::Optimal { objective: child_bound, .. } =
                        solve_relaxation(problem, &fixed)
                    {
                        let prune = incumbent
                            .as_ref()
                            .map_or(false, |(_, o)| child_bound >= *o - 1e-12);
                        if !prune {
                            heap.push(Node { bound: child_bound, fixed });
                        }
                    }
                }
            }
        }
    }

    match incumbent {
        Some((x, objective)) => Outcome::Optimal { x, objective, nodes_explored },
        None => Outcome::Infeasible,
    }
}

fn most_fractional(x: &[f64], fixed: &[Option<f64>]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &v) in x.iter().enumerate() {
        if fixed[i].is_some() {
            continue;
        }
        let frac = (v - v.round()).abs();
        if frac > INT_TOL && best.map_or(true, |(_, f)| frac > f) {
            best = Some((i, frac));
        }
    }
    best.map(|(i, _)| i)
}

fn solve_relaxation(problem: &Problem, fixed: &[Option<f64>]) -> LpResult {
    let n = problem.num_vars;
    assert_eq!(fixed.len(), n);

    let free: Vec<usize> = (0..n).filter(|&i| fixed[i].is_none()).collect();
    let col_of: Vec<Option<usize>> = {
        let mut m = vec![None; n];
        for (c, &i) in free.iter().enumerate() {
            m[i] = Some(c);
        }
        m
    };
    let nf = free.len();

    struct Row {
        coeffs: Vec<f64>,
        sense: Sense,
        rhs: f64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for c in &problem.constraints {
        let mut coeffs = vec![0.0; nf];
        let mut rhs = c.rhs;
        for (&i, &a) in &c.expr.terms {
            match (col_of[i], fixed[i]) {
                (Some(col), _) => coeffs[col] += a,
                (None, Some(v)) => rhs -= a * v,
                (None, None) => unreachable!(),
            }
        }
        rows.push(Row { coeffs, sense: c.sense, rhs });
    }
    for c in 0..nf {
        let mut coeffs = vec![0.0; nf];
        coeffs[c] = 1.0;
        rows.push(Row { coeffs, sense: Sense::Le, rhs: 1.0 });
    }

    for r in rows.iter_mut() {
        if r.rhs < 0.0 {
            for a in &mut r.coeffs {
                *a = -*a;
            }
            r.rhs = -r.rhs;
            r.sense = match r.sense {
                Sense::Le => Sense::Ge,
                Sense::Ge => Sense::Le,
                Sense::Eq => Sense::Eq,
            };
        }
    }

    let m = rows.len();
    let mut n_slack = 0;
    for r in &rows {
        if r.sense != Sense::Eq {
            n_slack += 1;
        }
    }
    let mut n_art = 0;
    for r in &rows {
        if r.sense != Sense::Le {
            n_art += 1;
        }
    }
    let total = nf + n_slack + n_art;

    let mut t = vec![vec![0.0; total + 1]; m];
    let mut basis = vec![usize::MAX; m];
    let mut s_i = nf;
    let mut a_i = nf + n_slack;
    for (r_i, r) in rows.iter().enumerate() {
        for c in 0..nf {
            t[r_i][c] = r.coeffs[c];
        }
        t[r_i][total] = r.rhs;
        match r.sense {
            Sense::Le => {
                t[r_i][s_i] = 1.0;
                basis[r_i] = s_i;
                s_i += 1;
            }
            Sense::Ge => {
                t[r_i][s_i] = -1.0; // surplus
                s_i += 1;
                t[r_i][a_i] = 1.0;
                basis[r_i] = a_i;
                a_i += 1;
            }
            Sense::Eq => {
                t[r_i][a_i] = 1.0;
                basis[r_i] = a_i;
                a_i += 1;
            }
        }
    }

    if n_art > 0 {
        let mut z = vec![0.0; total + 1];
        for c in nf + n_slack..total {
            z[c] = 1.0;
        }
        for (r_i, &b) in basis.iter().enumerate() {
            if b >= nf + n_slack {
                for c in 0..=total {
                    z[c] -= t[r_i][c];
                }
            }
        }
        if !pivot_loop(&mut t, &mut z, &mut basis, total) {
            return LpResult::Infeasible;
        }
        if -z[total] > 1e-7 {
            return LpResult::Infeasible;
        }
        for r_i in 0..m {
            if basis[r_i] >= nf + n_slack {
                if let Some(c) = (0..nf + n_slack).find(|&c| t[r_i][c].abs() > EPS) {
                    do_pivot(&mut t, &mut basis, r_i, c, total);
                }
            }
        }
    }

    let mut z = vec![0.0; total + 1];
    for (&i, &cf) in &problem.objective.terms {
        if let Some(col) = col_of[i] {
            z[col] = cf;
        }
    }
    for c in nf + n_slack..total {
        z[c] = 1e18;
    }
    for (r_i, &b) in basis.iter().enumerate() {
        if z[b].abs() > EPS {
            let coef = z[b];
            for c in 0..=total {
                z[c] -= coef * t[r_i][c];
            }
        }
    }
    if !pivot_loop(&mut t, &mut z, &mut basis, total) {
        return LpResult::Infeasible;
    }

    let mut xf = vec![0.0; nf];
    for (r_i, &b) in basis.iter().enumerate() {
        if b < nf {
            xf[b] = t[r_i][total];
        }
    }
    let mut x = vec![0.0; n];
    for (c, &i) in free.iter().enumerate() {
        x[i] = xf[c].clamp(0.0, 1.0);
    }
    for i in 0..n {
        if let Some(v) = fixed[i] {
            x[i] = v;
        }
    }
    let objective = problem.objective.eval(&x);
    LpResult::Optimal { x, objective }
}

fn pivot_loop(t: &mut [Vec<f64>], z: &mut [f64], basis: &mut [usize], total: usize) -> bool {
    let m = t.len();
    let max_iters = 50 * (m + total);
    for _ in 0..max_iters {
        // Bland's rule: smallest-index entering column with negative
        // reduced cost.
        let Some(enter) = (0..total).find(|&c| z[c] < -1e-9) else {
            return true; // optimal
        };
        let mut leave: Option<usize> = None;
        let mut best = f64::INFINITY;
        for r in 0..m {
            if t[r][enter] > EPS {
                let ratio = t[r][total] / t[r][enter];
                if ratio < best - EPS
                    || (ratio < best + EPS && leave.map_or(true, |l| basis[r] < basis[l]))
                {
                    best = ratio;
                    leave = Some(r);
                }
            }
        }
        let Some(leave) = leave else {
            return false; // unbounded
        };
        do_pivot(t, basis, leave, enter, total);
        let f = z[enter];
        if f.abs() > EPS {
            for c in 0..=total {
                z[c] -= f * t[leave][c];
            }
        }
    }
    true
}

fn do_pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, total: usize) {
    let piv = t[row][col];
    for c in 0..=total {
        t[row][c] /= piv;
    }
    for r in 0..t.len() {
        if r != row && t[r][col].abs() > EPS {
            let f = t[r][col];
            for c in 0..=total {
                t[r][c] -= f * t[row][c];
            }
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use crate::ilp::{solve, LinExpr, Problem, Sense};
    use crate::util::rng::Rng;

    /// The production solver and the reference solver must agree on
    /// random problems (same optimum; both or neither infeasible).
    #[test]
    fn reference_and_production_solvers_agree() {
        let mut rng = Rng::new(0xBEEF);
        for trial in 0..40 {
            let n = rng.range(3, 10);
            let mut p = Problem::new();
            let vars = p.binaries("x", n);
            for &v in &vars {
                p.set_objective_term(v, rng.range_f64(-8.0, 8.0));
            }
            for ci in 0..rng.range(1, 4) {
                let mut e = LinExpr::new();
                for &v in &vars {
                    if rng.chance(0.6) {
                        e.add_term(v, rng.range_f64(-3.0, 5.0));
                    }
                }
                p.constrain(&format!("c{ci}"), e, Sense::Le, rng.range_f64(0.0, 6.0));
            }
            if rng.chance(0.6) {
                let k = rng.range(2, n);
                p.exactly_one("pick", &vars[0..k]);
            }
            if rng.chance(0.5) {
                let a = vars[rng.below(n)];
                let b = vars[rng.below(n)];
                if a != b {
                    let y = p.and_var("y", a, b);
                    p.set_objective_term(y, rng.range_f64(-1.0, 1.0));
                }
            }
            let fast = solve(&p);
            let slow = super::solve(&p);
            match (fast.optimal(), slow.optimal()) {
                (None, None) => {}
                (Some((_, f)), Some((_, s))) => {
                    assert!((f - s).abs() < 1e-6, "trial {trial}: fast {f} vs reference {s}");
                }
                (f, s) => panic!("trial {trial}: feasibility mismatch {f:?} vs {s:?}"),
            }
        }
    }
}
