//! Best-first branch & bound over LP relaxations.
//!
//! Standard 0-1 B&B: solve the relaxation, bound-prune against the
//! incumbent, branch on the most fractional variable (ties → lowest
//! index), explore best-bound-first via a priority queue. Exact for the
//! problem sizes HAP produces, typically a handful of nodes because the
//! one-hot structure makes relaxations nearly integral.
//!
//! Branching creates two siblings that fix the *same* variable set
//! (the parent's fixings plus the branch variable) and differ only in
//! the branch value, so the sparse→dense LP setup is built once per
//! parent via [`SiblingScaffold`] and replayed for both children —
//! bit-identical to two cold solves, same node count and objective.

use super::simplex::{implied_ub, solve_relaxation_with, LpResult, SiblingScaffold};
use super::{Outcome, Problem};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

const INT_TOL: f64 = 1e-6;

struct Node {
    bound: f64,
    fixed: Vec<Option<f64>>,
    /// The node's LP-relaxation solution, computed when the node was
    /// created — popping a node reuses it instead of re-solving the
    /// identical LP (halves the simplex work per explored node).
    x: Vec<f64>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on bound via reversed comparison.
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

/// Solve a 0-1 ILP exactly.
pub fn branch_and_bound(problem: &Problem) -> Outcome {
    branch_and_bound_warm(problem, None)
}

/// [`branch_and_bound`] seeded with a warm-start incumbent: a feasible
/// 0/1 assignment whose objective becomes the initial upper bound, so
/// bound-pruning is active from the first node instead of only after
/// the first integral solution is found. Infeasible or ill-sized warm
/// assignments are ignored (cold start); the result is always the
/// exact optimum, and the explored node count never exceeds the
/// cold-start count for the same problem.
pub fn branch_and_bound_warm(problem: &Problem, warm: Option<&[f64]>) -> Outcome {
    let n = problem.num_vars;
    let root_fixed = vec![None; n];
    let mut heap = BinaryHeap::new();
    let mut incumbent: Option<(Vec<f64>, f64)> = warm.and_then(|x| {
        if x.len() == n && problem.feasible(x, 1e-6) {
            Some((x.to_vec(), problem.objective_value(x)))
        } else {
            None
        }
    });
    let mut nodes_explored = 0usize;

    // Bound-implication analysis depends only on the problem; do it
    // once for every LP this solve will run.
    let implied = implied_ub(problem);
    match solve_relaxation_with(problem, &root_fixed, &implied) {
        LpResult::Infeasible => return Outcome::Infeasible,
        LpResult::Optimal { x, objective } => {
            if most_fractional(&x, &root_fixed).is_some() {
                // Root bound already meets the warm incumbent → the
                // incumbent is optimal; no nodes to explore.
                if let Some((ix, io)) = &incumbent {
                    if objective >= *io - 1e-12 {
                        return Outcome::Optimal {
                            x: ix.clone(),
                            objective: *io,
                            nodes_explored: 0,
                        };
                    }
                }
                heap.push(Node { bound: objective, fixed: root_fixed.clone(), x });
            } else {
                return Outcome::Optimal { x, objective, nodes_explored: 1 };
            }
        }
    }

    while let Some(node) = heap.pop() {
        nodes_explored += 1;
        if nodes_explored > 200_000 {
            break; // safety valve; never hit at HAP sizes
        }
        // Bound prune (the node's LP was solved at creation; its
        // solution rides along in `node.x`).
        if let Some((_, inc_obj)) = &incumbent {
            if node.bound >= *inc_obj - 1e-12 {
                continue;
            }
        }
        match most_fractional(&node.x, &node.fixed) {
            None => {
                // Integral: candidate incumbent (round off LP fuzz).
                let xi: Vec<f64> =
                    node.x.iter().map(|&v| if v > 0.5 { 1.0 } else { 0.0 }).collect();
                if problem.feasible(&xi, 1e-6) {
                    let obj = problem.objective_value(&xi);
                    if incumbent.as_ref().map_or(true, |(_, o)| obj < *o) {
                        incumbent = Some((xi, obj));
                    }
                }
            }
            Some(branch_var) => {
                let scaffold = SiblingScaffold::new(problem, &node.fixed, branch_var);
                for v in [1.0, 0.0] {
                    let mut fixed = node.fixed.clone();
                    fixed[branch_var] = Some(v);
                    if let LpResult::Optimal { x, objective: child_bound } =
                        scaffold.solve(problem, &fixed, &implied, v)
                    {
                        let prune = incumbent
                            .as_ref()
                            .map_or(false, |(_, o)| child_bound >= *o - 1e-12);
                        if !prune {
                            heap.push(Node { bound: child_bound, fixed, x });
                        }
                    }
                }
            }
        }
    }

    match incumbent {
        Some((x, objective)) => Outcome::Optimal { x, objective, nodes_explored },
        None => Outcome::Infeasible,
    }
}

/// Index of the most fractional unfixed variable, or None if integral.
fn most_fractional(x: &[f64], fixed: &[Option<f64>]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &v) in x.iter().enumerate() {
        if fixed[i].is_some() {
            continue;
        }
        let frac = (v - v.round()).abs();
        if frac > INT_TOL && best.map_or(true, |(_, f)| frac > f) {
            best = Some((i, frac));
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::{most_fractional, Node};
    use crate::ilp::simplex::{implied_ub, solve_relaxation_with, LpResult, SiblingScaffold};
    use crate::ilp::{solve, LinExpr, Outcome, Problem, Sense};
    use crate::util::rng::Rng;
    use std::collections::BinaryHeap;

    /// Brute-force 0-1 enumeration for cross-checking.
    fn brute_force(p: &Problem) -> Option<f64> {
        let n = p.num_vars;
        assert!(n <= 20);
        let mut best: Option<f64> = None;
        for mask in 0u32..(1 << n) {
            let x: Vec<f64> = (0..n).map(|i| ((mask >> i) & 1) as f64).collect();
            if p.feasible(&x, 1e-9) {
                let obj = p.objective_value(&x);
                if best.map_or(true, |b| obj < b) {
                    best = Some(obj);
                }
            }
        }
        best
    }

    /// Pre-scaffold branch & bound: identical search to the production
    /// path except every child LP is cold-solved. Oracle for the
    /// sibling-scaffold bit-equality test. Returns (objective, nodes).
    fn cold_branch_and_bound(problem: &Problem) -> Option<(f64, usize)> {
        let n = problem.num_vars;
        let root_fixed = vec![None; n];
        let mut heap = BinaryHeap::new();
        let mut incumbent: Option<(Vec<f64>, f64)> = None;
        let mut nodes_explored = 0usize;
        let implied = implied_ub(problem);
        match solve_relaxation_with(problem, &root_fixed, &implied) {
            LpResult::Infeasible => return None,
            LpResult::Optimal { x, objective } => {
                if most_fractional(&x, &root_fixed).is_some() {
                    heap.push(Node { bound: objective, fixed: root_fixed, x });
                } else {
                    return Some((objective, 1));
                }
            }
        }
        while let Some(node) = heap.pop() {
            nodes_explored += 1;
            if let Some((_, inc)) = &incumbent {
                if node.bound >= *inc - 1e-12 {
                    continue;
                }
            }
            match most_fractional(&node.x, &node.fixed) {
                None => {
                    let xi: Vec<f64> =
                        node.x.iter().map(|&v| if v > 0.5 { 1.0 } else { 0.0 }).collect();
                    if problem.feasible(&xi, 1e-6) {
                        let obj = problem.objective_value(&xi);
                        if incumbent.as_ref().map_or(true, |(_, o)| obj < *o) {
                            incumbent = Some((xi, obj));
                        }
                    }
                }
                Some(bv) => {
                    for v in [1.0, 0.0] {
                        let mut fixed = node.fixed.clone();
                        fixed[bv] = Some(v);
                        if let LpResult::Optimal { x, objective: cb } =
                            solve_relaxation_with(problem, &fixed, &implied)
                        {
                            let prune =
                                incumbent.as_ref().map_or(false, |(_, o)| cb >= *o - 1e-12);
                            if !prune {
                                heap.push(Node { bound: cb, fixed, x });
                            }
                        }
                    }
                }
            }
        }
        incumbent.map(|(_, o)| (o, nodes_explored))
    }

    #[test]
    fn sibling_scaffold_bit_equal_to_cold_start() {
        let mut rng = Rng::new(4242);
        for trial in 0..40 {
            let n = rng.range(3, 9);
            let mut p = Problem::new();
            let vars = p.binaries("x", n);
            for &v in &vars {
                p.set_objective_term(v, rng.range_f64(-10.0, 10.0));
            }
            for ci in 0..rng.range(1, 4) {
                let mut e = LinExpr::new();
                for &v in &vars {
                    if rng.chance(0.7) {
                        e.add_term(v, rng.range_f64(-3.0, 5.0));
                    }
                }
                p.constrain(&format!("c{ci}"), e, Sense::Le, rng.range_f64(0.0, 6.0));
            }
            if rng.chance(0.5) {
                let k = rng.range(2, n);
                p.exactly_one("pick", &vars[0..k]);
            }
            let implied = implied_ub(&p);

            // LP level: for random parent fixings and every possible
            // branch variable, the scaffold's sibling solves must be
            // bit-identical to cold translations — x and objective.
            let mut parent: Vec<Option<f64>> = vec![None; n];
            for slot in parent.iter_mut().take(n - 1) {
                if rng.chance(0.3) {
                    *slot = Some(if rng.chance(0.5) { 1.0 } else { 0.0 });
                }
            }
            for branch in 0..n {
                if parent[branch].is_some() {
                    continue;
                }
                let scaffold = SiblingScaffold::new(&p, &parent, branch);
                for v in [1.0, 0.0] {
                    let mut fixed = parent.clone();
                    fixed[branch] = Some(v);
                    let cold = solve_relaxation_with(&p, &fixed, &implied);
                    let shared = scaffold.solve(&p, &fixed, &implied, v);
                    match (cold, shared) {
                        (LpResult::Infeasible, LpResult::Infeasible) => {}
                        (
                            LpResult::Optimal { x: cx, objective: co },
                            LpResult::Optimal { x: sx, objective: so },
                        ) => {
                            assert_eq!(
                                co.to_bits(),
                                so.to_bits(),
                                "trial {trial} branch {branch} v {v}: objective drifted"
                            );
                            for (a, b) in cx.iter().zip(&sx) {
                                assert_eq!(
                                    a.to_bits(),
                                    b.to_bits(),
                                    "trial {trial} branch {branch} v {v}: x drifted"
                                );
                            }
                        }
                        _ => panic!("trial {trial} branch {branch}: feasibility disagreed"),
                    }
                }
            }

            // Search level: the production (scaffold-sharing) solver
            // explores the same nodes and lands on the same objective
            // bits as the cold-solving oracle.
            match (solve(&p), cold_branch_and_bound(&p)) {
                (Outcome::Infeasible, None) => {}
                (Outcome::Optimal { objective, nodes_explored, .. }, Some((co, cn))) => {
                    assert_eq!(
                        objective.to_bits(),
                        co.to_bits(),
                        "trial {trial}: objective bits differ from cold start"
                    );
                    assert_eq!(nodes_explored, cn, "trial {trial}: node count changed");
                }
                (o, c) => panic!("trial {trial}: feasibility mismatch {o:?} vs {c:?}"),
            }
        }
    }

    #[test]
    fn matches_brute_force_on_random_problems() {
        let mut rng = Rng::new(2025);
        for trial in 0..60 {
            let n = rng.range(3, 9);
            let mut p = Problem::new();
            let vars = p.binaries("x", n);
            for &v in &vars {
                p.set_objective_term(v, rng.range_f64(-10.0, 10.0));
            }
            // Random ≤ constraints.
            for ci in 0..rng.range(1, 4) {
                let mut e = LinExpr::new();
                for &v in &vars {
                    if rng.chance(0.7) {
                        e.add_term(v, rng.range_f64(-3.0, 5.0));
                    }
                }
                p.constrain(&format!("c{ci}"), e, Sense::Le, rng.range_f64(0.0, 6.0));
            }
            // Sometimes a one-hot over a subset.
            if rng.chance(0.5) {
                let k = rng.range(2, n);
                p.exactly_one("pick", &vars[0..k]);
            }
            let bf = brute_force(&p);
            let out = solve(&p);
            match (bf, out.optimal()) {
                (None, None) => {}
                (Some(b), Some((_, o))) => {
                    assert!(
                        (b - o).abs() < 1e-6,
                        "trial {trial}: brute {b} vs bb {o}"
                    );
                }
                (b, o) => panic!("trial {trial}: feasibility mismatch {b:?} vs {o:?}"),
            }
        }
    }

    #[test]
    fn warm_start_same_optimum_never_more_nodes() {
        use crate::ilp::{solve_warm, Outcome};
        let mut rng = Rng::new(77);
        for trial in 0..40 {
            let n = rng.range(4, 10);
            let mut p = Problem::new();
            let vars = p.binaries("x", n);
            for &v in &vars {
                p.set_objective_term(v, rng.range_f64(-8.0, 8.0));
            }
            for ci in 0..rng.range(1, 3) {
                let mut e = LinExpr::new();
                for &v in &vars {
                    if rng.chance(0.6) {
                        e.add_term(v, rng.range_f64(-2.0, 4.0));
                    }
                }
                p.constrain(&format!("c{ci}"), e, Sense::Le, rng.range_f64(1.0, 6.0));
            }
            let cold = solve(&p);
            let Outcome::Optimal { x, objective, nodes_explored: cold_nodes } = cold else {
                continue;
            };
            // Warm with the optimum itself (tightest possible bound).
            let warm = solve_warm(&p, &x);
            let Outcome::Optimal { objective: wo, nodes_explored: warm_nodes, .. } = warm
            else {
                panic!("trial {trial}: warm infeasible but cold optimal");
            };
            assert!((wo - objective).abs() < 1e-9, "trial {trial}: {wo} vs {objective}");
            assert!(
                warm_nodes <= cold_nodes,
                "trial {trial}: warm explored {warm_nodes} > cold {cold_nodes}"
            );
            // A bogus warm vector must be ignored, not corrupt the solve.
            let bogus = vec![1.0; p.num_vars + 3];
            let Outcome::Optimal { objective: bo, .. } =
                crate::ilp::bb::branch_and_bound_warm(&p, Some(&bogus))
            else {
                panic!("trial {trial}: bogus warm broke feasibility");
            };
            assert!((bo - objective).abs() < 1e-9);
        }
    }

    #[test]
    fn larger_one_hot_structure_fast() {
        // HAP-like: 3 one-hot groups of 8 + pairwise AND variables.
        let mut p = Problem::new();
        let s = p.binaries("s", 8);
        let ei = p.binaries("ei", 8);
        let ej = p.binaries("ej", 8);
        p.exactly_one("s1", &s);
        p.exactly_one("e1", &ei);
        p.exactly_one("e2", &ej);
        let mut rng = Rng::new(7);
        for (gi, g) in [&s, &ei, &ej].into_iter().enumerate() {
            for (k, &v) in g.iter().enumerate() {
                p.set_objective_term(v, rng.range_f64(1.0, 5.0) + (gi + k) as f64 * 0.01);
            }
        }
        for (i, &a) in ei.iter().enumerate() {
            for (j, &b) in ej.iter().enumerate() {
                let y = p.and_var(&format!("y[{i}][{j}]"), a, b);
                p.set_objective_term(y, rng.range_f64(0.0, 0.5));
            }
        }
        let out = solve(&p);
        let (x, _) = out.optimal().expect("feasible");
        // Exactly one of each group selected.
        for g in [&s, &ei, &ej] {
            let sum: f64 = g.iter().map(|v| x[v.0]).sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
    }
}
