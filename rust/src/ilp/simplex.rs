//! Two-phase dense simplex for LP relaxations of 0-1 problems.
//!
//! Solves `min cᵀx  s.t.  A·x {≤,=,≥} b,  0 ≤ x ≤ 1` by converting to
//! standard form with slack/surplus variables, using explicit upper
//! bounds as additional `x_i ≤ 1` rows (simple and robust at the sizes
//! HAP needs: tens of variables, hundreds of rows). Phase 1 minimizes
//! artificial-variable sum; Phase 2 optimizes the true objective.
//! Bland's rule guards against cycling.

use super::{Problem, Sense};

/// LP relaxation result.
#[derive(Debug, Clone)]
pub enum LpResult {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
}

const EPS: f64 = 1e-9;

/// Solve the LP relaxation of `problem` with extra variable fixings:
/// `fixed[i] = Some(v)` pins x_i = v (used by branch & bound).
pub fn solve_relaxation(problem: &Problem, fixed: &[Option<f64>]) -> LpResult {
    let n = problem.num_vars;
    assert_eq!(fixed.len(), n);

    // Collect rows: constraints + upper bounds x_i ≤ 1 for unfixed vars.
    // Fixed vars are substituted out (their contribution moves to rhs).
    let free: Vec<usize> = (0..n).filter(|&i| fixed[i].is_none()).collect();
    let col_of: Vec<Option<usize>> = {
        let mut m = vec![None; n];
        for (c, &i) in free.iter().enumerate() {
            m[i] = Some(c);
        }
        m
    };
    let nf = free.len();

    struct Row {
        coeffs: Vec<f64>,
        sense: Sense,
        rhs: f64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for c in &problem.constraints {
        let mut coeffs = vec![0.0; nf];
        let mut rhs = c.rhs;
        for (&i, &a) in &c.expr.terms {
            match (col_of[i], fixed[i]) {
                (Some(col), _) => coeffs[col] += a,
                (None, Some(v)) => rhs -= a * v,
                (None, None) => unreachable!(),
            }
        }
        rows.push(Row { coeffs, sense: c.sense, rhs });
    }
    for c in 0..nf {
        let mut coeffs = vec![0.0; nf];
        coeffs[c] = 1.0;
        rows.push(Row { coeffs, sense: Sense::Le, rhs: 1.0 });
    }

    // Normalize to rhs ≥ 0.
    for r in rows.iter_mut() {
        if r.rhs < 0.0 {
            for a in &mut r.coeffs {
                *a = -*a;
            }
            r.rhs = -r.rhs;
            r.sense = match r.sense {
                Sense::Le => Sense::Ge,
                Sense::Ge => Sense::Le,
                Sense::Eq => Sense::Eq,
            };
        }
    }

    // Standard form: columns = free vars + slacks + artificials.
    let m = rows.len();
    let mut n_slack = 0;
    for r in &rows {
        if r.sense != Sense::Eq {
            n_slack += 1;
        }
    }
    // Artificials for ≥ and = rows.
    let mut n_art = 0;
    for r in &rows {
        if r.sense != Sense::Le {
            n_art += 1;
        }
    }
    let total = nf + n_slack + n_art;

    // Tableau: m rows × (total + 1) columns (last = rhs).
    let mut t = vec![vec![0.0; total + 1]; m];
    let mut basis = vec![usize::MAX; m];
    let mut s_i = nf;
    let mut a_i = nf + n_slack;
    for (r_i, r) in rows.iter().enumerate() {
        for c in 0..nf {
            t[r_i][c] = r.coeffs[c];
        }
        t[r_i][total] = r.rhs;
        match r.sense {
            Sense::Le => {
                t[r_i][s_i] = 1.0;
                basis[r_i] = s_i;
                s_i += 1;
            }
            Sense::Ge => {
                t[r_i][s_i] = -1.0; // surplus
                s_i += 1;
                t[r_i][a_i] = 1.0;
                basis[r_i] = a_i;
                a_i += 1;
            }
            Sense::Eq => {
                t[r_i][a_i] = 1.0;
                basis[r_i] = a_i;
                a_i += 1;
            }
        }
    }

    // Phase 1: minimize sum of artificials.
    if n_art > 0 {
        let mut z = vec![0.0; total + 1];
        for c in nf + n_slack..total {
            z[c] = 1.0;
        }
        // Make reduced costs consistent with the basis (price out).
        for (r_i, &b) in basis.iter().enumerate() {
            if b >= nf + n_slack {
                for c in 0..=total {
                    z[c] -= t[r_i][c];
                }
            }
        }
        if !pivot_loop(&mut t, &mut z, &mut basis, total) {
            return LpResult::Infeasible; // unbounded phase 1 can't happen
        }
        if -z[total] > 1e-7 {
            return LpResult::Infeasible;
        }
        // Drive remaining artificials out of the basis when possible.
        for r_i in 0..m {
            if basis[r_i] >= nf + n_slack {
                if let Some(c) = (0..nf + n_slack).find(|&c| t[r_i][c].abs() > EPS) {
                    do_pivot(&mut t, &mut basis, r_i, c, total);
                }
            }
        }
    }

    // Phase 2: true objective over free vars only (fixed contribute a
    // constant added back at the end).
    let mut z = vec![0.0; total + 1];
    for (&i, &cf) in &problem.objective.terms {
        if let Some(col) = col_of[i] {
            z[col] = cf;
        }
    }
    // Zero out artificial columns so they never re-enter.
    // (Columns stay in the tableau; give them +inf-ish cost.)
    for c in nf + n_slack..total {
        z[c] = 1e18;
    }
    for (r_i, &b) in basis.iter().enumerate() {
        if z[b].abs() > EPS {
            let coef = z[b];
            for c in 0..=total {
                z[c] -= coef * t[r_i][c];
            }
        }
    }
    if !pivot_loop(&mut t, &mut z, &mut basis, total) {
        // Unbounded below can't occur with 0 ≤ x ≤ 1 box, but guard.
        return LpResult::Infeasible;
    }

    // Extract solution.
    let mut xf = vec![0.0; nf];
    for (r_i, &b) in basis.iter().enumerate() {
        if b < nf {
            xf[b] = t[r_i][total];
        }
    }
    let mut x = vec![0.0; n];
    for (c, &i) in free.iter().enumerate() {
        x[i] = xf[c].clamp(0.0, 1.0);
    }
    for i in 0..n {
        if let Some(v) = fixed[i] {
            x[i] = v;
        }
    }
    let objective = problem.objective.eval(&x);
    LpResult::Optimal { x, objective }
}

/// Run simplex pivots until optimal. Returns false on unboundedness.
fn pivot_loop(
    t: &mut [Vec<f64>],
    z: &mut [f64],
    basis: &mut [usize],
    total: usize,
) -> bool {
    let m = t.len();
    let max_iters = 50 * (m + total);
    for _ in 0..max_iters {
        // Bland's rule: smallest-index entering column with negative
        // reduced cost.
        let Some(enter) = (0..total).find(|&c| z[c] < -1e-9) else {
            return true; // optimal
        };
        // Ratio test.
        let mut leave: Option<usize> = None;
        let mut best = f64::INFINITY;
        for r in 0..m {
            if t[r][enter] > EPS {
                let ratio = t[r][total] / t[r][enter];
                if ratio < best - EPS || (ratio < best + EPS && leave.map_or(true, |l| basis[r] < basis[l]))
                {
                    best = ratio;
                    leave = Some(r);
                }
            }
        }
        let Some(leave) = leave else {
            return false; // unbounded
        };
        do_pivot_with_z(t, z, basis, leave, enter, total);
    }
    true // iteration cap: treat as converged (tolerances loose enough)
}

fn do_pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, total: usize) {
    let piv = t[row][col];
    for c in 0..=total {
        t[row][c] /= piv;
    }
    for r in 0..t.len() {
        if r != row && t[r][col].abs() > EPS {
            let f = t[r][col];
            for c in 0..=total {
                t[r][c] -= f * t[row][c];
            }
        }
    }
    basis[row] = col;
}

fn do_pivot_with_z(
    t: &mut [Vec<f64>],
    z: &mut [f64],
    basis: &mut [usize],
    row: usize,
    col: usize,
    total: usize,
) {
    do_pivot(t, basis, row, col, total);
    let f = z[col];
    if f.abs() > EPS {
        for c in 0..=total {
            z[c] -= f * t[row][c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::{LinExpr, Problem, Sense};

    #[test]
    fn simple_lp() {
        // min -x0 - x1 s.t. x0 + x1 ≤ 1.5, 0 ≤ x ≤ 1 → obj -1.5.
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        p.set_objective_term(a, -1.0);
        p.set_objective_term(b, -1.0);
        p.constrain("cap", LinExpr::sum(&[a, b]), Sense::Le, 1.5);
        match solve_relaxation(&p, &[None, None]) {
            LpResult::Optimal { objective, .. } => assert!((objective + 1.5).abs() < 1e-6),
            _ => panic!("expected optimal"),
        }
    }

    #[test]
    fn equality_constraint() {
        // min x0 + 2x1 s.t. x0 + x1 = 1 → x0 = 1.
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        p.set_objective_term(a, 1.0);
        p.set_objective_term(b, 2.0);
        p.exactly_one("one", &[a, b]);
        match solve_relaxation(&p, &[None, None]) {
            LpResult::Optimal { x, objective } => {
                assert!((objective - 1.0).abs() < 1e-6);
                assert!((x[0] - 1.0).abs() < 1e-6);
            }
            _ => panic!("expected optimal"),
        }
    }

    #[test]
    fn infeasible_lp() {
        let mut p = Problem::new();
        let a = p.binary("a");
        p.constrain("hi", LinExpr::new().term(a, 1.0), Sense::Ge, 2.0); // x ≤ 1 conflicts
        assert!(matches!(solve_relaxation(&p, &[None]), LpResult::Infeasible));
    }

    #[test]
    fn fixing_respected() {
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        p.set_objective_term(a, -3.0);
        p.set_objective_term(b, -1.0);
        match solve_relaxation(&p, &[Some(0.0), None]) {
            LpResult::Optimal { x, objective } => {
                assert_eq!(x[0], 0.0);
                assert!((x[1] - 1.0).abs() < 1e-6);
                assert!((objective + 1.0).abs() < 1e-6);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn ge_constraints() {
        // min x0 + x1 s.t. x0 + x1 ≥ 1.2 → obj 1.2.
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        p.set_objective_term(a, 1.0);
        p.set_objective_term(b, 1.0);
        p.constrain("lo", LinExpr::sum(&[a, b]), Sense::Ge, 1.2);
        match solve_relaxation(&p, &[None, None]) {
            LpResult::Optimal { objective, .. } => assert!((objective - 1.2).abs() < 1e-6),
            _ => panic!(),
        }
    }
}
