//! Two-phase dense simplex for LP relaxations of 0-1 problems.
//!
//! Solves `min cᵀx  s.t.  A·x {≤,=,≥} b,  0 ≤ x ≤ 1` by converting to
//! standard form with slack/surplus variables. Phase 1 minimizes the
//! artificial-variable sum; Phase 2 optimizes the true objective.
//!
//! This is the planner's second hot loop (every branch-and-bound node
//! solves one of these), so the implementation is laid out for speed:
//!
//! - the tableau is a **single flattened row-major `Vec<f64>`** (not a
//!   `Vec<Vec<f64>>`), so pivots stream contiguous memory and each LP
//!   does two allocations instead of one per row;
//! - explicit `x_i ≤ 1` rows are **elided when provably redundant** —
//!   a variable in an all-ones `Σx = 1` one-hot row, or one bounded by
//!   a `y − a ≤ 0` AND-linearization row whose bounder is itself
//!   bounded, can never exceed 1. In HAP formulations this removes
//!   every upper-bound row;
//! - the entering column uses **Dantzig's most-negative rule**, which
//!   takes far fewer pivots than Bland's rule on these LPs; after an
//!   iteration budget it falls back to Bland's rule, which guarantees
//!   termination (no cycling), so exactness is unaffected.

use super::{Problem, Sense};

/// LP relaxation result.
#[derive(Debug, Clone)]
pub enum LpResult {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
}

const EPS: f64 = 1e-9;

/// Variables whose `x ≤ 1` bound is implied by the constraints:
/// members of all-ones `Σ x = 1` rows, plus (transitively) variables
/// `y` with a `y − a ≤ 0` row where `a` is already known bounded.
/// Depends only on the problem, not on branch fixings — branch & bound
/// computes it once and passes it to [`solve_relaxation_with`].
pub fn implied_ub(problem: &Problem) -> Vec<bool> {
    let n = problem.num_vars;
    let mut bounded = vec![false; n];
    // Seed: one-hot equality rows (all coefficients exactly 1, rhs 1).
    for c in &problem.constraints {
        if c.sense == Sense::Eq
            && c.rhs == 1.0
            && !c.expr.terms.is_empty()
            && c.expr.terms.values().all(|&a| a == 1.0)
        {
            for &i in c.expr.terms.keys() {
                bounded[i] = true;
            }
        }
    }
    // Propagate through `y - a ≤ 0` rows (AND-var linearizations).
    // Every non-implied variable still gets an explicit bound row, so
    // `a` being non-implied is also fine — but propagating lets whole
    // chains drop their rows. A couple of passes reach the fixpoint.
    loop {
        let mut changed = false;
        for c in &problem.constraints {
            if c.sense != Sense::Le || c.rhs != 0.0 || c.expr.terms.len() != 2 {
                continue;
            }
            let mut pos: Option<usize> = None;
            let mut neg: Option<usize> = None;
            for (&i, &a) in &c.expr.terms {
                if a == 1.0 {
                    pos = Some(i);
                } else if a == -1.0 {
                    neg = Some(i);
                }
            }
            if let (Some(y), Some(a)) = (pos, neg) {
                if bounded[a] && !bounded[y] {
                    bounded[y] = true;
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    bounded
}

/// A dense constraint row over the free-variable columns.
struct Row {
    coeffs: Vec<f64>,
    sense: Sense,
    rhs: f64,
}

/// Shared LP-construction scaffold for the two sibling children of one
/// branch-and-bound node. Both siblings fix the same variable set (the
/// parent's fixings plus the branch variable — only the branch *value*
/// differs), so the sparse→dense translation of every constraint is
/// done once here instead of once per child. Coefficient rows depend
/// only on which variables are free and are cloned verbatim; the rhs
/// is re-derived per sibling by replaying the exact per-term
/// subtraction sequence the cold path executes (same term order, same
/// operations), so the resulting tableau — and therefore every simplex
/// pivot, the solution, and the objective — is bit-identical to
/// [`solve_relaxation_with`] on the same fixings.
pub struct SiblingScaffold {
    free: Vec<usize>,
    col_of: Vec<Option<usize>>,
    /// Per constraint: dense coefficients, sense, original rhs, and the
    /// rhs replay program — `(coeff, Some(fixed value))` for inherited
    /// fixings, `(coeff, None)` for the branch variable, in the
    /// constraint's term-iteration order.
    rows: Vec<(Vec<f64>, Sense, f64, Vec<(f64, Option<f64>)>)>,
}

impl SiblingScaffold {
    /// Build for the children of a branch node: `fixed` is the parent's
    /// fixing vector and `branch` the variable both siblings fix.
    pub fn new(problem: &Problem, fixed: &[Option<f64>], branch: usize) -> SiblingScaffold {
        let n = problem.num_vars;
        assert_eq!(fixed.len(), n);
        assert!(fixed[branch].is_none(), "branch variable already fixed");
        let free: Vec<usize> =
            (0..n).filter(|&i| fixed[i].is_none() && i != branch).collect();
        let col_of: Vec<Option<usize>> = {
            let mut m = vec![None; n];
            for (c, &i) in free.iter().enumerate() {
                m[i] = Some(c);
            }
            m
        };
        let nf = free.len();
        let rows = problem
            .constraints
            .iter()
            .map(|c| {
                let mut coeffs = vec![0.0; nf];
                let mut replay = Vec::new();
                for (&i, &a) in &c.expr.terms {
                    if let Some(col) = col_of[i] {
                        coeffs[col] += a;
                    } else if i == branch {
                        replay.push((a, None));
                    } else {
                        replay.push((a, Some(fixed[i].expect("non-free, non-branch is fixed"))));
                    }
                }
                (coeffs, c.sense, c.rhs, replay)
            })
            .collect();
        SiblingScaffold { free, col_of, rows }
    }

    /// Solve one sibling's relaxation. `fixed` must be the parent's
    /// fixings with the branch variable set to `value` — exactly the
    /// vector [`solve_relaxation_with`] would receive; the result is
    /// bit-identical to that call.
    pub fn solve(
        &self,
        problem: &Problem,
        fixed: &[Option<f64>],
        implied: &[bool],
        value: f64,
    ) -> LpResult {
        let rows: Vec<Row> = self
            .rows
            .iter()
            .map(|(coeffs, sense, rhs0, replay)| {
                let mut rhs = *rhs0;
                for &(a, v) in replay {
                    rhs -= a * v.unwrap_or(value);
                }
                Row { coeffs: coeffs.clone(), sense: *sense, rhs }
            })
            .collect();
        solve_prepared(problem, fixed, &self.free, &self.col_of, implied, rows)
    }
}

/// Solve the LP relaxation of `problem` with extra variable fixings:
/// `fixed[i] = Some(v)` pins x_i = v (used by branch & bound).
pub fn solve_relaxation(problem: &Problem, fixed: &[Option<f64>]) -> LpResult {
    solve_relaxation_with(problem, fixed, &implied_ub(problem))
}

/// [`solve_relaxation`] with a precomputed [`implied_ub`] mask (branch
/// & bound amortizes the analysis over all of a problem's LP solves).
pub fn solve_relaxation_with(
    problem: &Problem,
    fixed: &[Option<f64>],
    implied: &[bool],
) -> LpResult {
    let n = problem.num_vars;
    assert_eq!(fixed.len(), n);
    assert_eq!(implied.len(), n);

    let free: Vec<usize> = (0..n).filter(|&i| fixed[i].is_none()).collect();
    let col_of: Vec<Option<usize>> = {
        let mut m = vec![None; n];
        for (c, &i) in free.iter().enumerate() {
            m[i] = Some(c);
        }
        m
    };
    let nf = free.len();

    let mut rows: Vec<Row> = Vec::with_capacity(problem.constraints.len() + nf);
    for c in &problem.constraints {
        let mut coeffs = vec![0.0; nf];
        let mut rhs = c.rhs;
        for (&i, &a) in &c.expr.terms {
            match (col_of[i], fixed[i]) {
                (Some(col), _) => coeffs[col] += a,
                (None, Some(v)) => rhs -= a * v,
                (None, None) => unreachable!(),
            }
        }
        rows.push(Row { coeffs, sense: c.sense, rhs });
    }
    solve_prepared(problem, fixed, &free, &col_of, implied, rows)
}

/// Shared tail of [`solve_relaxation_with`] and
/// [`SiblingScaffold::solve`]: append upper-bound rows, normalize, run
/// the two simplex phases, and extract the solution.
fn solve_prepared(
    problem: &Problem,
    fixed: &[Option<f64>],
    free: &[usize],
    col_of: &[Option<usize>],
    implied: &[bool],
    mut rows: Vec<Row>,
) -> LpResult {
    let n = problem.num_vars;
    let nf = free.len();
    // Upper bounds x_i ≤ 1 only where the constraints don't already
    // imply them.
    for (c, &i) in free.iter().enumerate() {
        if implied[i] {
            continue;
        }
        let mut coeffs = vec![0.0; nf];
        coeffs[c] = 1.0;
        rows.push(Row { coeffs, sense: Sense::Le, rhs: 1.0 });
    }

    // Normalize to rhs ≥ 0.
    for r in rows.iter_mut() {
        if r.rhs < 0.0 {
            for a in &mut r.coeffs {
                *a = -*a;
            }
            r.rhs = -r.rhs;
            r.sense = match r.sense {
                Sense::Le => Sense::Ge,
                Sense::Ge => Sense::Le,
                Sense::Eq => Sense::Eq,
            };
        }
    }

    // Standard form: columns = free vars + slacks + artificials.
    let m = rows.len();
    let mut n_slack = 0;
    for r in &rows {
        if r.sense != Sense::Eq {
            n_slack += 1;
        }
    }
    // Artificials for ≥ and = rows.
    let mut n_art = 0;
    for r in &rows {
        if r.sense != Sense::Le {
            n_art += 1;
        }
    }
    let total = nf + n_slack + n_art;
    let stride = total + 1; // last column = rhs

    // Flattened row-major tableau: row r occupies t[r*stride..(r+1)*stride].
    let mut t = vec![0.0f64; m * stride];
    let mut basis = vec![usize::MAX; m];
    let mut s_i = nf;
    let mut a_i = nf + n_slack;
    for (r_i, r) in rows.iter().enumerate() {
        let row = &mut t[r_i * stride..(r_i + 1) * stride];
        row[..nf].copy_from_slice(&r.coeffs);
        row[total] = r.rhs;
        match r.sense {
            Sense::Le => {
                row[s_i] = 1.0;
                basis[r_i] = s_i;
                s_i += 1;
            }
            Sense::Ge => {
                row[s_i] = -1.0; // surplus
                s_i += 1;
                row[a_i] = 1.0;
                basis[r_i] = a_i;
                a_i += 1;
            }
            Sense::Eq => {
                row[a_i] = 1.0;
                basis[r_i] = a_i;
                a_i += 1;
            }
        }
    }
    let mut scratch = vec![0.0f64; stride];

    // Phase 1: minimize sum of artificials.
    if n_art > 0 {
        let mut z = vec![0.0; stride];
        for c in nf + n_slack..total {
            z[c] = 1.0;
        }
        // Make reduced costs consistent with the basis (price out).
        for (r_i, &b) in basis.iter().enumerate() {
            if b >= nf + n_slack {
                let row = &t[r_i * stride..(r_i + 1) * stride];
                for (zc, rc) in z.iter_mut().zip(row) {
                    *zc -= rc;
                }
            }
        }
        if !pivot_loop(&mut t, &mut z, &mut basis, total, &mut scratch) {
            return LpResult::Infeasible; // unbounded phase 1 can't happen
        }
        if -z[total] > 1e-7 {
            return LpResult::Infeasible;
        }
        // Drive remaining artificials out of the basis when possible.
        for r_i in 0..m {
            if basis[r_i] >= nf + n_slack {
                let row = &t[r_i * stride..(r_i + 1) * stride];
                if let Some(c) = (0..nf + n_slack).find(|&c| row[c].abs() > EPS) {
                    do_pivot(&mut t, &mut basis, r_i, c, stride, &mut scratch);
                }
            }
        }
    }

    // Phase 2: true objective over free vars only (fixed contribute a
    // constant added back at the end).
    let mut z = vec![0.0; stride];
    for (&i, &cf) in &problem.objective.terms {
        if let Some(col) = col_of[i] {
            z[col] = cf;
        }
    }
    // Artificial columns must never re-enter: effectively +inf cost.
    for c in nf + n_slack..total {
        z[c] = 1e18;
    }
    for (r_i, &b) in basis.iter().enumerate() {
        if z[b].abs() > EPS {
            let coef = z[b];
            let row = &t[r_i * stride..(r_i + 1) * stride];
            for (zc, rc) in z.iter_mut().zip(row) {
                *zc -= coef * rc;
            }
        }
    }
    if !pivot_loop(&mut t, &mut z, &mut basis, total, &mut scratch) {
        // Unbounded below can't occur with 0 ≤ x ≤ 1 box, but guard.
        return LpResult::Infeasible;
    }

    // Extract solution.
    let mut xf = vec![0.0; nf];
    for (r_i, &b) in basis.iter().enumerate() {
        if b < nf {
            xf[b] = t[r_i * stride + total];
        }
    }
    let mut x = vec![0.0; n];
    for (c, &i) in free.iter().enumerate() {
        x[i] = xf[c].clamp(0.0, 1.0);
    }
    for i in 0..n {
        if let Some(v) = fixed[i] {
            x[i] = v;
        }
    }
    let objective = problem.objective.eval(&x);
    LpResult::Optimal { x, objective }
}

/// Run simplex pivots until optimal. Returns false on unboundedness.
///
/// Entering rule: Dantzig (most negative reduced cost) for speed, with
/// a switch to Bland's rule (smallest index) after `bland_after`
/// iterations to guarantee finite termination on degenerate LPs.
fn pivot_loop(
    t: &mut [f64],
    z: &mut [f64],
    basis: &mut [usize],
    total: usize,
    scratch: &mut [f64],
) -> bool {
    let stride = total + 1;
    let m = t.len() / stride;
    let max_iters = 50 * (m + total);
    let bland_after = 2 * (m + total);
    for iter in 0..max_iters {
        let enter = if iter < bland_after {
            // Dantzig: most negative reduced cost.
            let mut best: Option<(usize, f64)> = None;
            for (c, &zc) in z[..total].iter().enumerate() {
                if zc < -1e-9 && best.map_or(true, |(_, bz)| zc < bz) {
                    best = Some((c, zc));
                }
            }
            best.map(|(c, _)| c)
        } else {
            // Bland: smallest-index negative column (anti-cycling).
            (0..total).find(|&c| z[c] < -1e-9)
        };
        let Some(enter) = enter else {
            return true; // optimal
        };
        // Ratio test.
        let mut leave: Option<usize> = None;
        let mut best = f64::INFINITY;
        for r in 0..m {
            let row = &t[r * stride..(r + 1) * stride];
            if row[enter] > EPS {
                let ratio = row[total] / row[enter];
                if ratio < best - EPS
                    || (ratio < best + EPS && leave.map_or(true, |l| basis[r] < basis[l]))
                {
                    best = ratio;
                    leave = Some(r);
                }
            }
        }
        let Some(leave) = leave else {
            return false; // unbounded
        };
        do_pivot(t, basis, leave, enter, stride, scratch);
        let f = z[enter];
        if f.abs() > EPS {
            for (zc, rc) in z.iter_mut().zip(&scratch[..stride]) {
                *zc -= f * rc;
            }
        }
    }
    true // iteration cap: treat as converged (tolerances loose enough)
}

/// Pivot on (row, col): normalize the pivot row, eliminate the column
/// from every other row. The normalized pivot row is left in `scratch`
/// so callers can update their reduced-cost vector without re-reading
/// the tableau.
fn do_pivot(
    t: &mut [f64],
    basis: &mut [usize],
    row: usize,
    col: usize,
    stride: usize,
    scratch: &mut [f64],
) {
    let m = t.len() / stride;
    {
        let prow = &mut t[row * stride..(row + 1) * stride];
        let piv = prow[col];
        for v in prow.iter_mut() {
            *v /= piv;
        }
        scratch[..stride].copy_from_slice(prow);
    }
    for r in 0..m {
        if r == row {
            continue;
        }
        let rrow = &mut t[r * stride..(r + 1) * stride];
        let f = rrow[col];
        if f.abs() > EPS {
            for (v, p) in rrow.iter_mut().zip(&scratch[..stride]) {
                *v -= f * p;
            }
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::{LinExpr, Problem, Sense};

    #[test]
    fn simple_lp() {
        // min -x0 - x1 s.t. x0 + x1 ≤ 1.5, 0 ≤ x ≤ 1 → obj -1.5.
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        p.set_objective_term(a, -1.0);
        p.set_objective_term(b, -1.0);
        p.constrain("cap", LinExpr::sum(&[a, b]), Sense::Le, 1.5);
        match solve_relaxation(&p, &[None, None]) {
            LpResult::Optimal { objective, .. } => assert!((objective + 1.5).abs() < 1e-6),
            _ => panic!("expected optimal"),
        }
    }

    #[test]
    fn equality_constraint() {
        // min x0 + 2x1 s.t. x0 + x1 = 1 → x0 = 1.
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        p.set_objective_term(a, 1.0);
        p.set_objective_term(b, 2.0);
        p.exactly_one("one", &[a, b]);
        match solve_relaxation(&p, &[None, None]) {
            LpResult::Optimal { x, objective } => {
                assert!((objective - 1.0).abs() < 1e-6);
                assert!((x[0] - 1.0).abs() < 1e-6);
            }
            _ => panic!("expected optimal"),
        }
    }

    #[test]
    fn infeasible_lp() {
        let mut p = Problem::new();
        let a = p.binary("a");
        p.constrain("hi", LinExpr::new().term(a, 1.0), Sense::Ge, 2.0); // x ≤ 1 conflicts
        assert!(matches!(solve_relaxation(&p, &[None]), LpResult::Infeasible));
    }

    #[test]
    fn fixing_respected() {
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        p.set_objective_term(a, -3.0);
        p.set_objective_term(b, -1.0);
        match solve_relaxation(&p, &[Some(0.0), None]) {
            LpResult::Optimal { x, objective } => {
                assert_eq!(x[0], 0.0);
                assert!((x[1] - 1.0).abs() < 1e-6);
                assert!((objective + 1.0).abs() < 1e-6);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn ge_constraints() {
        // min x0 + x1 s.t. x0 + x1 ≥ 1.2 → obj 1.2.
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        p.set_objective_term(a, 1.0);
        p.set_objective_term(b, 1.0);
        p.constrain("lo", LinExpr::sum(&[a, b]), Sense::Ge, 1.2);
        match solve_relaxation(&p, &[None, None]) {
            LpResult::Optimal { objective, .. } => assert!((objective - 1.2).abs() < 1e-6),
            _ => panic!(),
        }
    }

    #[test]
    fn one_hot_members_have_implied_bounds() {
        let mut p = Problem::new();
        let vars = p.binaries("x", 3);
        p.exactly_one("pick", &vars);
        let y = p.and_var("y", vars[0], vars[1]);
        let implied = implied_ub(&p);
        for v in &vars {
            assert!(implied[v.0], "one-hot member should be implied");
        }
        assert!(implied[y.0], "AND var bounded through its .le rows");
    }

    #[test]
    fn implied_bound_elision_keeps_objective_below_one() {
        // max x0 (min -x0) with only a one-hot: the elided x ≤ 1 row
        // must still be enforced through the one-hot equality.
        let mut p = Problem::new();
        let vars = p.binaries("x", 2);
        p.exactly_one("pick", &vars);
        p.set_objective_term(vars[0], -1.0);
        match solve_relaxation(&p, &[None, None]) {
            LpResult::Optimal { x, objective } => {
                assert!((objective + 1.0).abs() < 1e-6);
                assert!(x[0] <= 1.0 + 1e-9);
            }
            _ => panic!(),
        }
    }
}
